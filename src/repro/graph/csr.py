"""CSR web-graph containers and JAX-friendly sparse matvec.

The adjacency matrix A (A[i, j] = 1 iff page i links to page j) is stored in
CSR over *rows* (out-links). PageRank iterates with P^T (in-links weighted by
1/outdeg), so we also materialize the transpose in CSR form once; the
per-iteration matvec is then a pure gather + segment-sum, which maps onto the
TPU (and onto the block-CSR Pallas kernel in repro.kernels.bsr_spmv).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
import scipy.sparse as sp


@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Unweighted directed graph in CSR (row = source page, col = target)."""

    n: int
    indptr: np.ndarray   # int64 (n + 1,)
    indices: np.ndarray  # int32 (nnz,)

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    @property
    def out_degree(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int64)

    @property
    def dangling_mask(self) -> np.ndarray:
        """d_i = 1 iff deg(i) == 0 (the paper's dangling index vector)."""
        return (self.out_degree == 0)

    def to_scipy(self) -> sp.csr_matrix:
        data = np.ones(self.nnz, dtype=np.float64)
        return sp.csr_matrix((data, self.indices, self.indptr), shape=(self.n, self.n))

    @staticmethod
    def from_scipy(m: sp.spmatrix) -> "CSRGraph":
        m = m.tocsr().astype(bool).astype(np.int8)
        m.sum_duplicates()
        return CSRGraph(
            n=m.shape[0],
            indptr=np.asarray(m.indptr, dtype=np.int64),
            indices=np.asarray(m.indices, dtype=np.int32),
        )

    @staticmethod
    def from_edges(n: int, src: np.ndarray, dst: np.ndarray) -> "CSRGraph":
        """Build from an edge list.

        Duplicate (src, dst) pairs are collapsed to a single edge here.
        Self-loops are KEPT: a page may link to itself and the transition
        weight 1/outdeg then counts that link (callers that want a loop-free
        graph must filter src == dst before calling)."""
        key = src.astype(np.int64) * n + dst.astype(np.int64)
        key = np.unique(key)
        src_u = (key // n).astype(np.int64)
        dst_u = (key % n).astype(np.int32)
        counts = np.bincount(src_u, minlength=n)
        indptr = np.zeros(n + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        return CSRGraph(n=n, indptr=indptr, indices=dst_u)


@dataclasses.dataclass(frozen=True)
class TransitionT:
    """P^T in CSR over rows (row j = in-links of page j, weighted 1/outdeg).

    This is the per-iteration operator of the paper: (P^T x)_j aggregates the
    rank mass flowing into page j. Stored padded-flat so every array has a
    static shape under jit.
    """

    n: int
    indptr: np.ndarray    # int64 (n + 1,)
    src: np.ndarray       # int32 (nnz,) source page per in-edge
    weight: np.ndarray    # float (nnz,) = 1 / outdeg(src)
    row_ids: np.ndarray   # int32 (nnz,) destination page per in-edge
    dangling: np.ndarray  # bool (n,)

    @property
    def nnz(self) -> int:
        return int(self.src.shape[0])

    @staticmethod
    def from_graph(g: CSRGraph, dtype=np.float64) -> "TransitionT":
        deg = g.out_degree
        # row ids of A (source of each edge), expanded from indptr
        src_of_edge = np.repeat(np.arange(g.n, dtype=np.int64), deg)
        dst_of_edge = g.indices.astype(np.int64)
        w = 1.0 / deg[src_of_edge]
        # sort edges by destination -> CSR of P^T
        order = np.argsort(dst_of_edge, kind="stable")
        dst_sorted = dst_of_edge[order]
        counts = np.bincount(dst_sorted, minlength=g.n)
        indptr = np.zeros(g.n + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        return TransitionT(
            n=g.n,
            indptr=indptr,
            src=src_of_edge[order].astype(np.int32),
            weight=w[order].astype(dtype),
            row_ids=dst_sorted.astype(np.int32),
            dangling=g.dangling_mask,
        )

    def to_scipy(self) -> sp.csr_matrix:
        return sp.csr_matrix(
            (np.asarray(self.weight, dtype=np.float64), self.src, self.indptr),
            shape=(self.n, self.n),
        )

    # ---- device-side (JAX) matvec --------------------------------------
    def device_arrays(self, dtype=None):
        """Arrays needed on device for the segment-sum matvec.

        Results are memoized per dtype so repeated solves against the same
        operator reuse the device buffers instead of re-uploading the edge
        arrays every call (TransitionT is immutable, so this is safe).
        """
        # the x64 flag changes what asarray/astype produce, so it is part
        # of the cache key (an f32 array must not satisfy an f64 request)
        key = ("native" if dtype is None else np.dtype(dtype).name,
               bool(jax.config.jax_enable_x64))
        cache = self.__dict__.get("_dev_cache")
        if cache is None:
            cache = {}
            object.__setattr__(self, "_dev_cache", cache)
        hit = cache.get(key)
        if hit is not None:
            return dict(hit)
        w = jnp.asarray(self.weight)
        if dtype is not None:
            w = w.astype(dtype)
        dev = dict(
            src=jnp.asarray(self.src),
            weight=w,
            row_ids=jnp.asarray(self.row_ids),
        )
        cache[key] = dev
        return dict(dev)


def pt_matvec(dev: dict, x: jax.Array, n: int) -> jax.Array:
    """y = P^T x as gather + segment-sum (TPU-friendly; no scatter).

    x may be a single vector (n,) or an (n, nv) stack of iterates (nv
    personalized PageRank problems sharing every edge gather).
    dev comes from TransitionT.device_arrays().
    """
    w = dev["weight"] if x.ndim == 1 else dev["weight"][:, None]
    contrib = w * x[dev["src"]]
    return jax.ops.segment_sum(contrib, dev["row_ids"], num_segments=n)


def pt_matvec_block(dev_block: dict, x: jax.Array, block_size: int,
                    row_offset: int) -> jax.Array:
    """(P^T x) restricted to rows [row_offset, row_offset + block_size).

    dev_block holds the edge slice for those rows with row_ids already
    rebased to the block (see core.partition.slice_transition).
    """
    contrib = dev_block["weight"] * x[dev_block["src"]]
    return jax.ops.segment_sum(contrib, dev_block["row_ids"], num_segments=block_size)
