"""Page-ID permutations that densify the BSR blocks (paper §6 future work:
"use of suitable permutations (cf. [11])" — Choi & Szyld, threshold
partitioning for Markov chains).

The TPU SpMV kernel multiplies dense 128x128 blocks on the MXU; its
efficiency is the block fill ratio. Raw crawl orderings scatter each page's
in-links across block columns. Two classical reorderings:

  * reverse Cuthill-McKee on the symmetrized adjacency — clusters connected
    pages, concentrating mass near the diagonal;
  * in-degree sort — packs hub columns together so their dense columns
    share blocks.
"""
from __future__ import annotations

import numpy as np
import scipy.sparse as sp
from scipy.sparse.csgraph import reverse_cuthill_mckee

from .csr import CSRGraph


def apply_permutation(g: CSRGraph, perm: np.ndarray) -> CSRGraph:
    """Relabel pages: new_id = perm[old_id]."""
    deg = g.out_degree
    src_old = np.repeat(np.arange(g.n, dtype=np.int64), deg)
    dst_old = g.indices.astype(np.int64)
    return CSRGraph.from_edges(g.n, perm[src_old], perm[dst_old])


def invert(perm: np.ndarray) -> np.ndarray:
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm))
    return inv


def rcm_permutation(g: CSRGraph) -> np.ndarray:
    """Reverse Cuthill-McKee over A + A^T (bandwidth-minimizing)."""
    a = g.to_scipy()
    sym = ((a + a.T) > 0).astype(np.int8).tocsr()
    order = np.asarray(reverse_cuthill_mckee(sym, symmetric_mode=True))
    # order[k] = old id placed at position k  ->  perm[old] = k
    return invert(order.astype(np.int64))


def degree_sort_permutation(g: CSRGraph) -> np.ndarray:
    """Pages sorted by in-degree (descending): hub columns share blocks."""
    indeg = np.bincount(g.indices, minlength=g.n)
    order = np.argsort(-indeg, kind="stable").astype(np.int64)
    return invert(order)


def reorder_operator(op, method: str = "indeg"):
    """Permute a GoogleOperator's page ids to densify BSR blocks.

    method: "rcm" | "indeg", or a precomputed permutation array with
    perm[old_id] = new_id. Returns (op_perm, perm); the teleportation vector
    rides along (v_perm[perm] = v, lane-wise for (n, nv) stacks). A solution
    x_perm in the permuted space maps back as x = x_perm[perm].
    """
    import dataclasses as _dc
    from .csr import TransitionT
    from .google import GoogleOperator  # local import avoids a cycle

    g = CSRGraph.from_edges(op.n, op.pt.src.astype(np.int64),
                            op.pt.row_ids.astype(np.int64))
    if isinstance(method, np.ndarray):
        perm = method.astype(np.int64)
    elif method == "rcm":
        perm = rcm_permutation(g)
    elif method == "indeg":
        perm = degree_sort_permutation(g)
    else:
        raise ValueError(f"unknown reorder method {method!r}")
    g2 = apply_permutation(g, perm)
    v2 = None
    if op.v is not None:
        v = np.asarray(op.v, dtype=np.float64)
        v2 = np.empty_like(v)
        v2[perm] = v
    op2 = GoogleOperator(pt=TransitionT.from_graph(g2), alpha=op.alpha, v=v2)
    return op2, perm
