"""Google-matrix pipeline: A -> P -> S -> G (paper §2), matrix-free.

G = alpha * S + (1 - alpha) * v e^T,   S = P^T + w d^T,  w = e/n.

We never form S or G: the iteration applies
    G x = alpha * P^T x + alpha * w (d^T x) + (1 - alpha) * v (e^T x)
and the linear-system (Jacobi/Richardson) form
    R x + b = alpha * (P^T x + w (d^T x)) + b,   b = (1 - alpha) * v.
Both preserve ||x||_1 = 1 for the power form when x0 is a distribution.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
import scipy.sparse as sp

from .csr import CSRGraph, TransitionT, pt_matvec

DEFAULT_ALPHA = 0.85


@dataclasses.dataclass(frozen=True)
class GoogleOperator:
    """Matrix-free Google matrix over a web graph."""

    pt: TransitionT
    alpha: float = DEFAULT_ALPHA
    v: Optional[np.ndarray] = None  # teleportation (personalization) vector

    @property
    def n(self) -> int:
        return self.pt.n

    def teleport(self) -> np.ndarray:
        if self.v is not None:
            return np.asarray(self.v, dtype=np.float64)
        return np.full(self.n, 1.0 / self.n, dtype=np.float64)

    def _cache(self) -> dict:
        cache = self.__dict__.get("_op_cache")
        if cache is None:
            cache = {}
            object.__setattr__(self, "_op_cache", cache)
        return cache

    def hybrid_bsr(self, bm: int = 128, bn: int = 128,
                   hub_quantile: float = 0.99):
        """Solve-grade hub-split BSR of P^T, built once per layout and
        memoized on the operator (the host-side packing is the expensive
        part of a BSR solve; repeated solves must not repeat it)."""
        from ..kernels.bsr_spmv import hybrid_from_transition
        key = ("hybrid", bm, bn, hub_quantile)
        cache = self._cache()
        if key not in cache:
            cache[key] = hybrid_from_transition(
                self.pt, bm=bm, bn=bn, hub_quantile=hub_quantile)
        return cache[key]

    # ---------------- numpy/scipy reference path ------------------------
    def to_scipy_pt(self) -> sp.csr_matrix:
        return self.pt.to_scipy()

    def apply_numpy(self, x: np.ndarray, pt_sp: Optional[sp.csr_matrix] = None
                    ) -> np.ndarray:
        """y = G x (dense vector or (n, nv) lane stack, matrix-free)."""
        pt_sp = self.to_scipy_pt() if pt_sp is None else pt_sp
        v = self.teleport()
        if x.ndim == 2 and v.ndim == 1:
            v = v[:, None]
        dangling_mass = x[self.pt.dangling].sum(axis=0)
        y = self.alpha * (pt_sp @ x)
        y += self.alpha * dangling_mass / self.n  # w = e/n
        y += (1.0 - self.alpha) * x.sum(axis=0) * v
        return y

    def apply_linear_numpy(self, x: np.ndarray,
                           pt_sp: Optional[sp.csr_matrix] = None) -> np.ndarray:
        """y = R x + b with R = alpha S, b = (1 - alpha) v.

        `x` may be an (n, nv) stack; with a lane-stacked teleport `v` this
        is the host-side exact residual route for batched personalized
        solves (one spmm certifies every lane)."""
        pt_sp = self.to_scipy_pt() if pt_sp is None else pt_sp
        v = self.teleport()
        if x.ndim == 2 and v.ndim == 1:
            v = v[:, None]
        dangling_mass = x[self.pt.dangling].sum(axis=0)
        y = self.alpha * (pt_sp @ x)
        y += self.alpha * dangling_mass / self.n
        y += (1.0 - self.alpha) * v
        return y

    # ---------------- JAX path ------------------------------------------
    def device_arrays(self, dtype=jnp.float32) -> dict:
        """Device arrays for the segment-sum apply, memoized per dtype (and
        per x64 mode) so repeated solves reuse the uploaded buffers."""
        key = ("dev", np.dtype(dtype).name,
               bool(jax.config.jax_enable_x64))
        cache = self._cache()
        hit = cache.get(key)
        if hit is not None:
            return dict(hit)
        dev = self.pt.device_arrays(dtype=dtype)
        dev["dangling"] = jnp.asarray(self.pt.dangling)
        dev["v"] = jnp.asarray(self.teleport(), dtype=dtype)
        cache[key] = dev
        return dict(dev)

    def apply_jax(self, dev: dict, x: jax.Array) -> jax.Array:
        n = self.n
        y = self.alpha * pt_matvec(dev, x, n)
        dangling_mass = jnp.sum(jnp.where(dev["dangling"], x, 0.0))
        y = y + self.alpha * dangling_mass / n
        y = y + (1.0 - self.alpha) * jnp.sum(x) * dev["v"]
        return y

    def apply_linear_jax(self, dev: dict, x: jax.Array) -> jax.Array:
        n = self.n
        y = self.alpha * pt_matvec(dev, x, n)
        dangling_mass = jnp.sum(jnp.where(dev["dangling"], x, 0.0))
        y = y + self.alpha * dangling_mass / n
        y = y + (1.0 - self.alpha) * dev["v"]
        return y


def exact_pagerank(op: GoogleOperator, tol: float = 1e-12,
                   maxiter: int = 10_000) -> np.ndarray:
    """High-precision reference PageRank (double precision power method)."""
    pt_sp = op.to_scipy_pt()
    n = op.n
    x = np.full(n, 1.0 / n, dtype=np.float64)
    for _ in range(maxiter):
        y = op.apply_numpy(x, pt_sp)
        if np.abs(y - x).sum() < tol:
            return y
        x = y
    return x
