"""Synthetic web-graph generation.

The paper's experiments use the Stanford-Web matrix (281,903 pages,
2,312,497 non-zeros, 172 dangling nodes) from an actual web crawl. That file
is not reachable from this offline container, so we synthesize graphs whose
statistics match the published numbers, following the measured structure of
the web (power-law in/out-degrees, Broder et al., WWW 2000) — the same
statistical-generation route the paper itself cites as an alternative to
crawling ("synthetically generated using statistical results, e.g. [10]").
"""
from __future__ import annotations

import numpy as np

from .csr import CSRGraph

# Published Stanford-Web statistics (paper §5.2).
STANFORD_N = 281_903
STANFORD_NNZ = 2_312_497
STANFORD_DANGLING = 172


def powerlaw_webgraph(
    n: int,
    target_nnz: int,
    n_dangling: int = 0,
    alpha_out: float = 2.2,
    alpha_in: float = 2.1,
    locality: float = 0.8,
    site_size: int = 512,
    seed: int = 0,
) -> CSRGraph:
    """Directed power-law graph with ~target_nnz edges and exactly
    n_dangling out-degree-0 nodes.

    Out-degrees ~ truncated zeta(alpha_out); targets chosen by a Zipf
    popularity ranking (preferential-attachment-like in-degree tail,
    Broder et al. report alpha_in ≈ 2.1). A fraction `locality` of links
    stay within the source's "site" (consecutive-id block of `site_size`
    pages) — real crawls are dominated by intra-site links, which both
    slows mixing (second eigenvalue close to alpha, hence the paper's ~44
    power iterations) and produces the block structure that consecutive-row
    partitioning exploits (Kamvar et al. [18])."""
    rng = np.random.default_rng(seed)

    # --- out-degrees -----------------------------------------------------
    n_linked = n - n_dangling
    # zipf gives k >= 1; cap to keep max outdegree realistic (~1k)
    deg = rng.zipf(alpha_out, size=n_linked).astype(np.int64)
    deg = np.minimum(deg, 1000)
    # rescale to hit target_nnz
    scale = target_nnz / max(deg.sum(), 1)
    if scale > 1.0:
        # add uniform extra links where needed
        extra = rng.multinomial(target_nnz - deg.sum(), np.ones(n_linked) / n_linked)
        deg = deg + extra
    else:
        deg = np.maximum((deg * scale).astype(np.int64), 1)
    # exact correction toward target
    diff = int(target_nnz - deg.sum())
    if diff != 0:
        idx = rng.choice(n_linked, size=abs(diff), replace=True)
        np.add.at(deg, idx, 1 if diff > 0 else -1)
        deg = np.maximum(deg, 1)

    nnz = int(deg.sum())

    # --- targets: Zipf-ranked popularity --------------------------------
    # popularity rank permutation so popular pages are spread over id space
    perm = rng.permutation(n)
    src_linked = np.repeat(np.arange(n_linked, dtype=np.int64), deg)
    # place dangling nodes at random ids: build a permutation mapping
    node_perm = rng.permutation(n)
    src = node_perm[src_linked]
    # dangling ids are node_perm[n_linked:]; nothing points out of them.

    def draw_dst(k, src_ids):
        ranks = (rng.zipf(alpha_in, size=k).astype(np.int64) - 1) % n
        global_dst = perm[ranks].astype(np.int64)
        if locality <= 0.0:
            return global_dst
        local = rng.random(k) < locality
        site_start = (src_ids // site_size) * site_size
        local_dst = site_start + rng.integers(0, site_size, size=k)
        local_dst = np.minimum(local_dst, n - 1)
        return np.where(local, local_dst, global_dst)

    # Zipf targets collide heavily; redraw duplicate (src, dst) pairs so the
    # deduplicated edge count stays close to target_nnz.
    dst = draw_dst(nnz, src)
    key = src * n + dst
    for _ in range(40):
        order = np.argsort(key, kind="stable")
        key_sorted = key[order]
        dup_sorted = np.zeros(nnz, dtype=bool)
        dup_sorted[1:] = key_sorted[1:] == key_sorted[:-1]
        dup = np.zeros(nnz, dtype=bool)
        dup[order] = dup_sorted
        ndup = int(dup.sum())
        if ndup == 0:
            break
        # redraw: mostly Zipf, some uniform to break persistent collisions
        new_dst = draw_dst(ndup, src[dup])
        uni = rng.random(ndup) < 0.5
        new_dst[uni] = rng.integers(0, n, size=int(uni.sum()))
        dst[dup] = new_dst
        key[dup] = src[dup] * n + dst[dup]

    g = CSRGraph.from_edges(n, src, dst)
    return g


def stanford_web_replica(seed: int = 0) -> CSRGraph:
    """A graph matching the published Stanford-Web statistics.

    locality/site_size are calibrated so the synchronous power method needs
    a similar iteration count to the paper's 44 (we get ~33 at l2 tol 1e-6;
    the residual gap is real-crawl structure a generator cannot copy)."""
    return powerlaw_webgraph(
        n=STANFORD_N,
        target_nnz=STANFORD_NNZ,
        n_dangling=STANFORD_DANGLING,
        locality=0.93,
        site_size=256,
        seed=seed,
    )


def small_test_graph(n: int = 64, avg_deg: int = 6, n_dangling: int = 3,
                     seed: int = 0) -> CSRGraph:
    """Small deterministic graph for unit tests."""
    return powerlaw_webgraph(n=n, target_nnz=n * avg_deg,
                             n_dangling=n_dangling, seed=seed)


def cycle_graph(n: int) -> CSRGraph:
    """n-cycle: closed-form PageRank = uniform. Useful oracle."""
    src = np.arange(n, dtype=np.int64)
    dst = (src + 1) % n
    return CSRGraph.from_edges(n, src, dst)
