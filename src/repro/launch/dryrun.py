import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax-touching import: jax locks the device count at
# first backend init. Only the dry-run uses placeholder devices.

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs import get_config, ARCH_NAMES
from ..models.config import ModelConfig
from ..models.sharding import activation_sharding
from ..models.decode import decode_step
from ..models.transformer import forward
from ..training.optimizer import OptConfig
from ..training.train_step import make_train_step
from ..analysis import roofline as rl
from .mesh import make_production_mesh
from .specs import (SHAPES, batch_specs, state_specs, params_specs_only,
                    cache_abstract, cache_pspecs, attach)

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def opt_config_for(cfg: ModelConfig) -> OptConfig:
    # 671B: bf16 moments (ZeRO-1 state fits 16 GB/chip), bf16 grad
    # accumulation over 8 microbatches (activation peak /8)
    if "671b" in cfg.name:
        return OptConfig(opt_dtype="bfloat16", accum_steps=8,
                         accum_dtype="bfloat16")
    return OptConfig()


def input_specs(arch: str, shape_name: str, mesh, kind=None):
    """Public helper: attached ShapeDtypeStructs for one cell."""
    cfg = get_config(arch)
    sh = SHAPES[shape_name]
    kind = kind or sh["kind"]
    if kind == "train":
        s_avals, s_specs = state_specs(cfg, opt_config_for(cfg), mesh)
        b_avals, b_specs = batch_specs(cfg, shape_name, mesh)
        return (attach(s_avals, s_specs, mesh), attach(b_avals, b_specs, mesh))
    if kind == "prefill":
        p_avals, p_specs = params_specs_only(cfg, mesh)
        b_avals, b_specs = batch_specs(cfg, shape_name, mesh)
        return (attach(p_avals, p_specs, mesh), attach(b_avals, b_specs, mesh))
    # decode
    p_avals, p_specs = params_specs_only(cfg, mesh)
    b_avals, b_specs = batch_specs(cfg, shape_name, mesh)
    c_avals = cache_abstract(cfg, shape_name)
    c_specs = cache_pspecs(cfg, shape_name, mesh, c_avals)
    return (attach(p_avals, p_specs, mesh),
            attach(b_avals, b_specs, mesh),
            attach(c_avals, c_specs, mesh))


def step_fn(cfg: ModelConfig, kind: str):
    if kind == "train":
        ts = make_train_step(cfg, opt_config_for(cfg))
        return lambda state, batch: ts(state, batch)
    if kind == "prefill":
        def prefill(params, batch):
            kw = {}
            if cfg.is_encdec:
                kw["enc_inputs"] = batch["enc_inputs"]
            if cfg.prefix_len:
                kw["prefix_embeds"] = batch["prefix_embeds"]
            logits, _ = forward(params, cfg, batch["tokens"], **kw)
            return logits
        return prefill

    def serve(params, batch, cache):
        return decode_step(params, cfg, batch["token"], cache)
    return serve


def _body_cost(fn, avals, mesh, multi_pod):
    """Compile a standalone layer-group body and return its per-device
    (flops, bytes, collective-operand-bytes, collective-per-chip-bytes)."""
    with mesh, activation_sharding(multi_pod):
        comp = jax.jit(fn).lower(*avals).compile()
        cost = comp.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        coll = rl.parse_collectives(comp.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            float(coll.total_operand_bytes),
            float(coll.total_per_chip_bytes))


def scan_corrections(cfg: ModelConfig, shape_name: str, mesh, multi_pod: bool
                     ) -> dict:
    """XLA's cost_analysis counts while/scan bodies ONCE, ignoring trip
    count. We therefore compile each scanned layer-group body standalone and
    add (repeats - 1) x body_cost to the module numbers. (Methodology noted
    in EXPERIMENTS.md §Roofline.)"""
    import dataclasses as dc
    from ..models.transformer import (stack_plan, _sig, _apply_layer,
                                      layer_defs, model_defs)
    from ..models.param import abstract_params, pspec_tree
    from ..models.decode import _layer_step, init_cache
    from .specs import cache_abstract, cache_pspecs, _dp

    sh = SHAPES[shape_name]
    B, S = sh["batch"], sh["seq"]
    kind = sh["kind"]
    # gradient accumulation: the module's loop body runs one microbatch;
    # total work = repeats * accum bodies at the microbatch size
    accum = opt_config_for(cfg).accum_steps if kind == "train" else 1
    B = B // accum
    dt = cfg.dtype()
    bax_spec = P(_dp(mesh, B), None, None)
    out = dict(flops=0.0, bytes=0.0, coll=0.0, coll_chip=0.0)

    def add_stack(local_cfg, n_layers, first_dense, causal, seq_len,
                  cross=False):
        plan = stack_plan(local_cfg, n_layers, first_dense)
        if plan.repeats * accum <= 1:
            return
        base = len(plan.head)
        sigs = [_sig(local_cfg, base + j) for j in plan.pattern]
        group_defs = {f"pos{j}": layer_defs(local_cfg, *sigs[j], cross)
                      for j in plan.pattern}
        p_avals = attach(abstract_params(group_defs),
                         pspec_tree(group_defs, multi_pod), mesh)
        x_aval = jax.ShapeDtypeStruct(
            (B, seq_len, local_cfg.d_model), dt,
            sharding=jax.NamedSharding(mesh, bax_spec))
        positions = jnp.arange(seq_len)

        if kind == "train":
            def apply_one(pl_j, x, j):
                f = lambda p_, x_: _apply_layer(
                    p_, x_, local_cfg, sigs[j][0], sigs[j][1],
                    positions=positions, causal=causal)
                if local_cfg.remat:   # match the module's remat recompute
                    return jax.checkpoint(f)(pl_j, x)
                return f(pl_j, x)

            def body(pl, x):
                def fwd(pl, x):
                    for j in plan.pattern:
                        x, _ = apply_one(pl[f"pos{j}"], x, j)
                    return jnp.sum(x.astype(jnp.float32))
                g = jax.grad(fwd, argnums=(0, 1))(pl, x)
                return g
        else:
            def body(pl, x):
                for j in plan.pattern:
                    x, _ = _apply_layer(
                        pl[f"pos{j}"], x, local_cfg, sigs[j][0],
                        sigs[j][1], positions=positions, causal=causal)
                return x
        f, b, c, cc = _body_cost(body, (p_avals, x_aval), mesh, multi_pod)
        mult = plan.repeats * accum - 1
        out["flops"] += f * mult
        out["bytes"] += b * mult
        out["coll"] += c * mult
        out["coll_chip"] += cc * mult

    def add_decode_stack():
        plan = stack_plan(cfg, cfg.n_layers, cfg.first_dense_layers)
        if plan.repeats <= 1:
            return
        base = len(plan.head)
        sigs = [_sig(cfg, base + j) for j in plan.pattern]
        c_avals_full = cache_abstract(cfg, shape_name)
        c_specs_full = cache_pspecs(cfg, shape_name, mesh, c_avals_full)
        # one slice of the stacked cache (drop the leading layer dim)
        def unstack(a):
            return jax.ShapeDtypeStruct(a.shape[1:], a.dtype)
        def unstack_spec(s):
            return P(*s[1:])
        group_cache = {}
        for j in plan.pattern:
            nm = f"pos{j}"
            av = jax.tree_util.tree_map(
                unstack, c_avals_full["stack"][nm])
            sp = jax.tree_util.tree_map(
                unstack_spec, c_specs_full["stack"][nm],
                is_leaf=lambda x: isinstance(x, P))
            group_cache[nm] = attach(av, sp, mesh)
        group_defs = {f"pos{j}": layer_defs(cfg, *sigs[j], cfg.is_encdec)
                      for j in plan.pattern}
        p_avals = attach(abstract_params(group_defs),
                         pspec_tree(group_defs, multi_pod), mesh)
        x_aval = jax.ShapeDtypeStruct(
            (B, 1, cfg.d_model), dt,
            sharding=jax.NamedSharding(mesh, bax_spec))
        length = jax.ShapeDtypeStruct((), jnp.int32)

        def body(pl, cl, x, length):
            for j in plan.pattern:
                nm = f"pos{j}"
                x, _ = _layer_step(pl[nm], cl[nm], x, cfg, sigs[j][0],
                                   sigs[j][1], length)
            return x
        f, b, c, cc = _body_cost(
            body, (p_avals, group_cache, x_aval, length), mesh, multi_pod)
        mult = plan.repeats - 1
        out["flops"] += f * mult
        out["bytes"] += b * mult
        out["coll"] += c * mult
        out["coll_chip"] += cc * mult

    if kind in ("train", "prefill"):
        s_tok = S  # prefix archs: total seq incl. prefix
        add_stack(cfg, cfg.n_layers, cfg.first_dense_layers, True, s_tok,
                  cross=cfg.is_encdec)
        if cfg.is_encdec:
            enc_cfg = dc.replace(cfg, block_pattern=("attn",), n_experts=0,
                                 first_dense_layers=0)
            add_stack(enc_cfg, cfg.n_enc_layers, 0, False,
                      int(S * cfg.enc_seq_ratio))
    else:
        add_decode_stack()
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: Path = RESULTS_DIR, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    ok, why = cfg.supports_shape(shape_name)
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    rec = dict(arch=arch, shape=shape_name, mesh=mesh_tag)
    if not ok:
        rec.update(status="skipped", reason=why)
        return _save(rec, out_dir)

    sh = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    fn = step_fn(cfg, sh["kind"])
    args = input_specs(arch, shape_name, mesh)

    # buffer donation + explicit out shardings: the new train state aliases
    # the old (in-place update), the new decode cache aliases the old —
    # without this XLA double-books state memory (and may replicate the
    # output cache).
    def shard_of(tree):
        return jax.tree_util.tree_map(lambda a: a.sharding, tree)

    repl = jax.NamedSharding(mesh, P())
    if sh["kind"] == "train":
        jit_kw = dict(donate_argnums=(0,),
                      out_shardings=(shard_of(args[0]), repl))
    elif sh["kind"] == "decode":
        jit_kw = dict(donate_argnums=(2,),
                      out_shardings=(None, shard_of(args[2])))
    else:
        jit_kw = {}

    t0 = time.time()
    try:
        with mesh, activation_sharding(multi_pod):
            lowered = jax.jit(fn, **jit_kw).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = {}
            try:
                ma = compiled.memory_analysis()
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "alias_size_in_bytes",
                          "generated_code_size_in_bytes"):
                    if hasattr(ma, k):
                        mem[k] = int(getattr(ma, k))
                if verbose:
                    print(f"  memory_analysis: {mem}")
            except Exception as e:  # CPU backend may not implement it
                mem = {"error": str(e)}

            cost = compiled.cost_analysis()
            if isinstance(cost, list):
                cost = cost[0]
            text = compiled.as_text()
            coll = rl.parse_collectives(text)

            flops_dev = float(cost.get("flops", 0.0))
            bytes_dev = float(cost.get("bytes accessed", 0.0))

            # scan trip-count correction (XLA counts loop bodies once)
            corr = scan_corrections(cfg, shape_name, mesh, multi_pod)
            flops_c = flops_dev + corr["flops"]
            bytes_c = bytes_dev + corr["bytes"]
            coll_c = float(coll.total_operand_bytes) + corr["coll"]
            coll_chip_c = float(coll.total_per_chip_bytes) + corr["coll_chip"]

            roof = rl.Roofline(
                flops=flops_c * chips, hbm_bytes=bytes_c * chips,
                collective_bytes=coll_c * chips,
                collective_per_chip=coll_chip_c,
                chips=chips)
            rec.update(
                status="ok", chips=chips, kind=sh["kind"],
                seconds_lower=round(t_lower, 1),
                seconds_compile=round(t_compile, 1),
                memory=mem,
                flops_per_device_raw=flops_dev,
                bytes_per_device_raw=bytes_dev,
                flops_per_device=flops_c,
                bytes_per_device=bytes_c,
                scan_correction=corr,
                collective_operand_bytes_per_device=coll.total_operand_bytes,
                collective_per_chip_bytes=coll.total_per_chip_bytes,
                collective_counts=coll.counts,
                collective_breakdown=coll.operand_bytes,
                roofline=roof.as_dict(),
            )
            if verbose:
                print(f"  cost: flops/dev={flops_dev:.3e} "
                      f"bytes/dev={bytes_dev:.3e} "
                      f"coll/dev={coll.total_operand_bytes:.3e}")
                print(f"  roofline: compute={roof.compute_s:.4f}s "
                      f"memory={roof.memory_s:.4f}s "
                      f"collective={roof.collective_s:.4f}s "
                      f"-> {roof.dominant}-bound")
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        if verbose:
            print(f"  ERROR {type(e).__name__}: {e}")
    return _save(rec, out_dir)


def _save(rec: dict, out_dir: Path) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    name = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}.json"
    (out_dir / name).write_text(json.dumps(rec, indent=1, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all",
                    help=f"one of {ARCH_NAMES} or 'all'")
    ap.add_argument("--shape", default="all",
                    help=f"one of {list(SHAPES)} or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = ARCH_NAMES if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = "2x16x16" if mp else "16x16"
                out = RESULTS_DIR / f"{arch}_{shape}_{tag}.json"
                if args.skip_existing and out.exists():
                    prev = json.loads(out.read_text())
                    if prev.get("status") in ("ok", "skipped"):
                        print(f"[skip] {arch} {shape} {tag}")
                        continue
                print(f"[cell] {arch} {shape} {tag}")
                t0 = time.time()
                rec = run_cell(arch, shape, mp)
                print(f"  -> {rec['status']} in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
