"""Production mesh factory.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before any jax init)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh for in-container multi-device tests (host devices)."""
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def dp_size(mesh) -> int:
    sizes = mesh_axis_sizes(mesh)
    return sizes.get("data", 1) * sizes.get("pod", 1)


def tp_size(mesh) -> int:
    return mesh_axis_sizes(mesh).get("model", 1)
