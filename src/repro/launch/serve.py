"""Serving driver: prefill + batched autoregressive decode.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
        --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from ..configs import get_config, get_smoke_config
from ..models.param import init_params
from ..models.transformer import model_defs
from ..models.decode import init_cache, decode_step
from ..serving.engine import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    defs = model_defs(cfg)
    params = init_params(defs, jax.random.PRNGKey(args.seed))

    eng = ServeEngine(cfg, params,
                      max_len=args.prompt_len + args.gen + 1)
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           size=(args.batch, args.prompt_len))

    t0 = time.time()
    out = eng.generate(jnp.asarray(prompts, jnp.int32), args.gen,
                       temperature=args.temperature,
                       seed=args.seed)
    dt = time.time() - t0
    toks = args.batch * args.gen
    print(f"generated {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s incl. compile)")
    print("sample:", np.asarray(out[0])[:24])
    return out


if __name__ == "__main__":
    main()
