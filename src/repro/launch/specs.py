"""ShapeDtypeStruct stand-ins + PartitionSpecs for every (arch x shape) cell.

Nothing here allocates device memory: params/opt-state/caches are abstract
(jax.eval_shape / ShapeDtypeStruct), which is what lets a 671B model "fit"
in a CPU container for lowering.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..models.config import ModelConfig
from ..models.param import abstract_params, pspec_tree, resolve_axis
from ..models.transformer import model_defs
from ..models.decode import init_cache
from ..training.optimizer import OptConfig, abstract_opt_state, \
    opt_state_pspecs
from .mesh import dp_size, tp_size

SHAPES: Dict[str, dict] = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32_768, batch=32),
    "decode_32k": dict(kind="decode", seq=32_768, batch=128),
    "long_500k": dict(kind="decode", seq=524_288, batch=1),
}


def _dp(mesh, dim: int):
    """'dp' resolved, or None when the dim does not divide."""
    multi = "pod" in mesh.axis_names
    ax = resolve_axis("dp", multi)
    return ax if dim % dp_size(mesh) == 0 else None


def _tp(mesh, dim: int):
    return "model" if dim % tp_size(mesh) == 0 else None


# ----------------------------------------------------------- batch specs ---
def batch_specs(cfg: ModelConfig, shape_name: str, mesh
                ) -> Tuple[dict, dict]:
    sh = SHAPES[shape_name]
    B, S = sh["batch"], sh["seq"]
    dt = cfg.dtype()
    avals: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    bax = _dp(mesh, B)

    if sh["kind"] in ("train", "prefill"):
        s_tok = S - cfg.prefix_len if cfg.prefix_len else S
        avals["tokens"] = jax.ShapeDtypeStruct((B, s_tok), jnp.int32)
        specs["tokens"] = P(bax, None)
        if cfg.prefix_len:
            avals["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.prefix_len, cfg.d_model), dt)
            specs["prefix_embeds"] = P(bax, None, None)
        if cfg.is_encdec:
            s_enc = int(S * cfg.enc_seq_ratio)
            avals["enc_inputs"] = jax.ShapeDtypeStruct(
                (B, s_enc, cfg.d_model), dt)
            specs["enc_inputs"] = P(bax, None, None)
    else:  # decode
        avals["token"] = jax.ShapeDtypeStruct((B,), jnp.int32)
        specs["token"] = P(bax)
    return avals, specs


# ----------------------------------------------------------- state specs ---
def state_specs(cfg: ModelConfig, opt_cfg: OptConfig, mesh
                ) -> Tuple[dict, dict]:
    defs = model_defs(cfg)
    multi = "pod" in mesh.axis_names
    params_avals = abstract_params(defs)
    params_specs = pspec_tree(defs, multi_pod=multi,
                              fsdp_dp=dp_size(mesh) if cfg.fsdp else 0)
    avals = {"params": params_avals,
             "opt": abstract_opt_state(params_avals, opt_cfg)}
    specs = {"params": params_specs,
             "opt": opt_state_pspecs(defs, opt_cfg, dp_size(mesh),
                                     multi_pod=multi)}
    return avals, specs


def params_specs_only(cfg: ModelConfig, mesh) -> Tuple[dict, dict]:
    defs = model_defs(cfg)
    multi = "pod" in mesh.axis_names
    return abstract_params(defs), pspec_tree(
        defs, multi_pod=multi, fsdp_dp=dp_size(mesh) if cfg.fsdp else 0)


# ----------------------------------------------------------- cache specs ---
def cache_abstract(cfg: ModelConfig, shape_name: str) -> dict:
    sh = SHAPES[shape_name]
    B, S = sh["batch"], sh["seq"]
    defs = model_defs(cfg)
    aparams = abstract_params(defs)

    if cfg.is_encdec:
        s_enc = int(S * cfg.enc_seq_ratio)
        enc_out = jax.ShapeDtypeStruct((B, s_enc, cfg.d_model), cfg.dtype())
        return jax.eval_shape(
            lambda p, e: init_cache(cfg, B, S, enc_out=e, params=p),
            aparams, enc_out)
    return jax.eval_shape(lambda: init_cache(cfg, B, S))


def cache_pspecs(cfg: ModelConfig, shape_name: str, mesh, cache_avals
                 ) -> Any:
    sh = SHAPES[shape_name]
    B = sh["batch"]
    bax = _dp(mesh, B)

    def rule(path, aval):
        if not hasattr(aval, "shape") or aval.ndim == 0:
            return P()
        keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        name = keys[-1] if keys else ""
        stacked = "stack" in keys
        lead = (None,) if stacked else ()

        def spec(*rest):
            return P(*lead, *rest)

        if name in ("k", "v"):          # (B, Hkv, T, dh) — self or cross
            T = aval.shape[-2]
            return spec(bax, None, _tp(mesh, T), None)
        if name == "c":                  # MLA latent (B, T, r)
            return spec(bax, _tp(mesh, aval.shape[-2]), None)
        if name == "kr":
            return spec(bax, _tp(mesh, aval.shape[-2]), None)
        if name == "slot_pos":
            return spec(None)
        if name == "h" and aval.ndim - len(lead) == 4:   # ssd (B,H,P,N)
            return spec(bax, _tp(mesh, aval.shape[len(lead) + 1]),
                        None, None)
        if name == "h":                  # rglru (B, W)
            return spec(bax, _tp(mesh, aval.shape[-1]))
        if name.startswith("conv"):
            return spec(bax, None, _tp(mesh, aval.shape[-1]))
        if name == "length":
            return P()
        return spec(*([None] * (aval.ndim - len(lead))))

    return jax.tree_util.tree_map_with_path(rule, cache_avals)


def attach(avals, specs, mesh):
    """ShapeDtypeStructs with NamedShardings (for .lower with shardings)."""
    return jax.tree_util.tree_map(
        lambda a, s: jax.ShapeDtypeStruct(
            a.shape, a.dtype, sharding=jax.NamedSharding(mesh, s)),
        avals, specs)
