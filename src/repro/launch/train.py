"""End-to-end training driver with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --smoke --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt

Features exercised here (DESIGN §6):
  * auto-resume from the newest complete checkpoint (crash-restart safe)
  * async checkpoint writer, last-k retention
  * monitor-style convergence/health detection reusing the paper's
    persistence-counter protocol on the loss signal
  * optional bounded-staleness async-DP (--sync-every > 1)
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp

from ..configs import get_config, get_smoke_config
from ..data.pipeline import DataConfig, SyntheticTokens, make_batch
from ..models.param import init_params
from ..models.transformer import model_defs
from ..training.optimizer import OptConfig, init_opt_state
from ..training.train_step import make_train_step
from ..training.checkpoint import CheckpointManager
from ..core.termination import ComputingUEState


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--loss-tol", type=float, default=0.0,
                    help="early-stop when |dloss| < tol persistently "
                         "(paper's termination protocol on the loss)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    opt_cfg = OptConfig(peak_lr=args.lr, warmup_steps=20,
                        total_steps=args.steps)

    defs = model_defs(cfg)
    params = init_params(defs, jax.random.PRNGKey(args.seed))
    state = {"params": params, "opt": init_opt_state(params, opt_cfg)}

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch, seed=args.seed)
    pipe = SyntheticTokens(dcfg)

    start_step = 0
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=3)
        if mgr.latest_step() is not None:
            state, start_step = mgr.restore(state)
            print(f"[resume] restored step {start_step} from {args.ckpt_dir}")

    step_fn = jax.jit(make_train_step(cfg, opt_cfg))

    # paper's Fig.1 persistence machinery as a training health monitor
    monitor = ComputingUEState(pc_max=5)
    prev_loss = None

    t0 = time.time()
    losses = []
    for step in range(start_step, args.steps):
        batch = make_batch(pipe, cfg, step)
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.2f} "
                  f"({(time.time()-t0):.1f}s)")
        if mgr and step and step % args.ckpt_every == 0:
            mgr.save(step, state)
        if args.loss_tol > 0 and prev_loss is not None:
            monitor, msg = monitor.step(abs(prev_loss - loss) < args.loss_tol)
            if msg is not None and msg.name == "CONVERGE":
                print(f"[monitor] persistent convergence at step {step}")
                break
        prev_loss = loss

    if mgr:
        mgr.save(args.steps, state, blocking=True)
        mgr.wait()
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f}); "
          f"{args.steps - start_step} steps in {time.time()-t0:.1f}s")
    return losses


if __name__ == "__main__":
    main()
