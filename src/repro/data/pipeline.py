"""Deterministic synthetic token pipeline (offline container: no corpora).

Produces a reproducible mixture resembling language statistics: Zipf
unigrams + short-range Markov structure + copy spans, so models have
something learnable (loss drops measurably within a few hundred steps).
Sharding: each DP shard reads only its slice (host-sharded iterator);
state (step) is checkpointable for exact resume.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np
import jax
import jax.numpy as jnp


@dataclasses.dataclass
class DataConfig:
    vocab_size: int = 32_000
    seq_len: int = 512
    global_batch: int = 8
    zipf_a: float = 1.3
    markov_strength: float = 0.7   # prob of a structured transition
    copy_prob: float = 0.1         # chance of a copy-back span
    seed: int = 1234


class SyntheticTokens:
    """Stateless-per-step generator: batch t is a pure function of (seed, t),
    so restart-at-step-k reproduces the exact stream (checkpoint/resume)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        base = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # fixed Zipf unigram table + a sparse deterministic successor map
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = ranks ** -cfg.zipf_a
        self.unigram = p / p.sum()
        self.successor = base.permutation(v)  # tok -> likely next tok

    def batch(self, step: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S = cfg.global_batch, cfg.seq_len
        toks = rng.choice(cfg.vocab_size, size=(B, S), p=self.unigram)
        # Markov structure: with prob markov_strength, next = successor[cur]
        use = rng.random((B, S)) < cfg.markov_strength
        for t in range(1, S):
            toks[:, t] = np.where(use[:, t], self.successor[toks[:, t - 1]],
                                  toks[:, t])
        # copy spans
        n_copy = int(B * cfg.copy_prob)
        if n_copy and S >= 32:
            rows = rng.choice(B, size=n_copy, replace=False)
            for r in rows:
                src = rng.integers(0, S // 2 - 8)
                dst = rng.integers(S // 2, S - 8)
                toks[r, dst:dst + 8] = toks[r, src:src + 8]
        return toks.astype(np.int32)

    def shard_iter(self, shard: int, n_shards: int,
                   start_step: int = 0) -> Iterator[np.ndarray]:
        """Host-sharded stream: each host materializes only its rows."""
        assert self.cfg.global_batch % n_shards == 0
        rows = self.cfg.global_batch // n_shards
        step = start_step
        while True:
            b = self.batch(step)
            yield b[shard * rows:(shard + 1) * rows]
            step += 1


def make_batch(pipe: SyntheticTokens, cfg_model, step: int,
               mesh=None) -> Dict[str, jax.Array]:
    """Full global batch on one host (this container) with optional
    device placement onto the mesh's DP sharding."""
    tokens = pipe.batch(step)
    batch = {"tokens": jnp.asarray(tokens)}
    B = tokens.shape[0]
    if cfg_model.is_encdec:
        rng = np.random.default_rng((pipe.cfg.seed, step, 7))
        s_enc = int(pipe.cfg.seq_len * cfg_model.enc_seq_ratio)
        batch["enc_inputs"] = jnp.asarray(
            rng.standard_normal((B, s_enc, cfg_model.d_model)) * 0.02,
            cfg_model.dtype())
    if cfg_model.prefix_len:
        rng = np.random.default_rng((pipe.cfg.seed, step, 11))
        batch["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg_model.prefix_len,
                                 cfg_model.d_model)) * 0.02,
            cfg_model.dtype())
    return batch
