"""TPU-native bounded-staleness PageRank under shard_map (beyond-paper form).

True message-level asynchrony cannot exist inside one XLA program (its
collectives are bulk-synchronous). The paper's own conclusion points the way
to the TPU adaptation: the win is not unblocking threads but *reducing and
re-scheduling communication* — "we would like to avoid the use of all-to-all
communication schemes ... the flexibility of asynchronous iterations gives
us a choice on the targets of produced messages" (§6).

We therefore express asynchrony as bounded staleness over sparsified
collective schedules:

  schedule="allgather"    : all-gather every superstep (synchronous baseline,
                            eq. 4 distributed).
  schedule="allgather_k"  : all-gather every k supersteps; local iterations
                            in between use stale fragments (staleness <= k-1).
  schedule="ring"         : one collective_permute stage per superstep — each
                            shard refreshes exactly one peer fragment per
                            step (1/p of the all-gather bytes; staleness of
                            fragment j at shard i is (i - j) mod p steps).
  delivery_prob < 1       : models canceled/dropped messages (paper cancels
                            overdue send threads); a rejected delivery keeps
                            the stale copy, exactly like eq. (5) with larger
                            tau.

Convergence for all schedules follows from bounded delays (Frommer-Szyld
[15]; Lubachevsky-Mitra [21] for the unit-spectral-radius power form).
Termination detection runs in-loop: per-shard persistence counters plus a
monitor counter over the all-reduced convergence bits — the bulk-synchronous
rendering of Fig. 1.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .partition import Partition, block_rows
from ..graph.google import GoogleOperator


@dataclasses.dataclass
class SPMDConfig:
    p: int                       # number of UEs = mesh size along 'ue'
    schedule: str = "allgather"  # allgather | allgather_k | ring
    sync_every: int = 4          # k for allgather_k
    delivery_prob: float = 1.0   # per-fragment acceptance probability
    tol: float = 1e-6            # local convergence threshold (inf-norm)
    pc_max_compute: int = 1
    pc_max_monitor: int = 1
    max_supersteps: int = 2000
    kind: str = "power"          # power (eq. 6) | linear (eq. 7)
    dtype: str = "float32"
    seed: int = 0


@dataclasses.dataclass
class SPMDResult:
    x: np.ndarray
    supersteps: int
    local_resid: np.ndarray      # (p,) final per-shard residuals
    comm_bytes_per_step: int     # payload bytes moved per superstep (model)


def _pack_blocks(op: GoogleOperator, part: Partition, dtype):
    """Pad per-block edge slices of P^T to a common edge budget so the
    sharded arrays have static shapes."""
    from .partition import slice_transition

    p = part.p
    blocks = [slice_transition(op.pt, part, i) for i in range(p)]
    emax = max(b["src"].shape[0] for b in blocks)
    bsize = int(part.sizes().max())
    n = part.n

    src = np.zeros((p, emax), dtype=np.int32)
    wgt = np.zeros((p, emax), dtype=dtype)
    rid = np.zeros((p, emax), dtype=np.int32)
    vblk = np.zeros((p, bsize), dtype=dtype)
    v = op.teleport()
    for i, b in enumerate(blocks):
        e = b["src"].shape[0]
        src[i, :e] = b["src"]
        wgt[i, :e] = b["weight"]
        rid[i, :e] = b["row_ids"]
        s, t = part.block(i)
        vblk[i, : t - s] = v[s:t]
    dang = np.zeros((n,), dtype=bool)
    dang[: op.pt.dangling.shape[0]] = op.pt.dangling
    return dict(src=src, wgt=wgt, rid=rid, vblk=vblk, dang=dang,
                emax=emax, bsize=bsize)


def solve_spmd(op: GoogleOperator, cfg: SPMDConfig,
               mesh: Optional[Mesh] = None) -> SPMDResult:
    p = cfg.p
    n = op.n
    dtype = jnp.dtype(cfg.dtype)
    if mesh is None:
        devs = jax.devices()
        assert len(devs) >= p, f"need {p} devices, have {len(devs)}"
        mesh = jax.make_mesh((p,), ("ue",), devices=devs[:p])

    # uniform blocks (paper's ceil(n/p) scheme) padded to p * bsize
    part = block_rows(n, p)
    packed = _pack_blocks(op, part, np.dtype(cfg.dtype))
    bsize = packed["bsize"]
    n_pad = p * bsize

    dang_pad = np.zeros(n_pad, dtype=bool)
    dang_pad[:n] = packed["dang"]

    alpha = float(op.alpha)
    linear = cfg.kind == "linear"
    tol = cfg.tol
    q = cfg.delivery_prob
    seed = cfg.seed

    # device inputs, sharded over 'ue'
    sh = lambda *spec: jax.NamedSharding(mesh, P(*spec))
    src = jax.device_put(packed["src"], sh("ue", None))
    wgt = jax.device_put(packed["wgt"], sh("ue", None))
    rid = jax.device_put(packed["rid"], sh("ue", None))
    vblk = jax.device_put(packed["vblk"], sh("ue", None))
    dang = jax.device_put(np.broadcast_to(dang_pad, (p, n_pad)).copy(),
                          sh("ue", None))
    x0_blocks = np.full((p, bsize), 1.0 / n, dtype=cfg.dtype)
    # zero the padded tail of the last block
    pad = n_pad - n
    if pad:
        x0_blocks[-1, bsize - pad:] = 0.0
    x0 = jax.device_put(x0_blocks, sh("ue", None))

    def body_fn(src, wgt, rid, vblk, dang, x0):
        """Runs on one shard: src/wgt/rid (1, emax), vblk/x0 (1, bsize),
        dang (1, n_pad)."""
        src_, wgt_, rid_, vb_, dg_, myx = (
            src[0], wgt[0], rid[0], vblk[0], dang[0], x0[0])
        i = jax.lax.axis_index("ue")

        def local_update(view, frag):
            """f_i: new own fragment from the (stale) full view."""
            contrib = wgt_ * view[src_]
            y = alpha * jax.ops.segment_sum(contrib, rid_, num_segments=bsize)
            dmass = jnp.sum(jnp.where(dg_, view, 0.0))
            y = y + alpha * dmass / n
            if linear:
                y = y + (1.0 - alpha) * vb_
            else:
                y = y + (1.0 - alpha) * jnp.sum(view) * vb_
            return y

        perm = [(j, (j + 1) % p) for j in range(p)]

        def superstep(carry):
            view, frag, ring, step, pc, mon_pc, done = carry
            newfrag = local_update(view, frag)
            resid = jnp.max(jnp.abs(newfrag - frag))

            # ---- communication -------------------------------------------
            key = jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(seed), step), i)
            accept = jax.random.uniform(key) < q

            if cfg.schedule == "ring" and p > 1:
                ring_in = jax.lax.ppermute(ring, "ue", perm)
                # at superstep s (0-based), incoming fragment belongs to
                # UE (i - s - 1) mod p
                owner = jnp.mod(i - step - 1, p)
                # my own slot must always hold the fresh fragment
                view = jax.lax.dynamic_update_slice(
                    view, newfrag, (i * bsize,))
                updated = jax.lax.dynamic_update_slice(
                    view, ring_in, (owner * bsize,))
                view = jnp.where(
                    jnp.logical_and(accept, owner != i), updated, view)
                # forward own fragment afresh every p steps, else relay
                restart = jnp.mod(step + 1, p) == 0
                ring = jnp.where(restart, newfrag, ring_in)
            elif cfg.schedule == "allgather_k":
                do_sync = jnp.mod(step, cfg.sync_every) == cfg.sync_every - 1
                def gather(_):
                    allv = jax.lax.all_gather(newfrag, "ue")  # (p, bsize)
                    return allv.reshape(n_pad)
                def keep(_):
                    return jax.lax.dynamic_update_slice(
                        view, newfrag, (i * bsize,))
                sync_ok = jnp.logical_and(do_sync, accept)
                view = jax.lax.cond(sync_ok, gather, keep, operand=None)
            else:  # allgather (synchronous baseline)
                allv = jax.lax.all_gather(newfrag, "ue")
                view = allv.reshape(n_pad)

            # ---- in-loop Fig. 1 protocol ----------------------------------
            locally_conv = resid < tol
            pc = jnp.where(locally_conv, pc + 1, 0)
            flag = pc >= cfg.pc_max_compute
            nconv = jax.lax.psum(flag.astype(jnp.int32), "ue")
            all_conv = nconv == p
            mon_pc = jnp.where(all_conv, mon_pc + 1, 0)
            done = mon_pc >= cfg.pc_max_monitor
            return view, newfrag, ring, step + 1, pc, mon_pc, done

        def cond(carry):
            *_, step, pc, mon_pc, done = carry
            return jnp.logical_and(~done, step < cfg.max_supersteps)

        view0 = jnp.zeros((n_pad,), dtype) + jnp.asarray(1.0 / n, dtype)
        if pad:
            view0 = view0.at[n:].set(0.0)
        carry = (view0, myx, myx, jnp.asarray(0), jnp.asarray(0),
                 jnp.asarray(0), jnp.asarray(False))
        view, frag, ring, step, pc, mon_pc, done = jax.lax.while_loop(
            cond, lambda c: superstep(c), carry)
        resid = jnp.max(jnp.abs(local_update(view, frag) - frag))
        return frag[None], step[None], resid[None]

    mapped = shard_map(
        body_fn, mesh=mesh,
        in_specs=(P("ue", None),) * 6,
        out_specs=(P("ue", None), P("ue"), P("ue")),
        check_rep=False,
    )
    frags, steps, resids = jax.jit(mapped)(src, wgt, rid, vblk, dang, x0)
    x = np.asarray(frags, dtype=np.float64).reshape(n_pad)[:n]
    s = x.sum()
    if s > 0:
        x = x / s

    frag_bytes = bsize * np.dtype(cfg.dtype).itemsize
    if cfg.schedule == "ring":
        comm = p * frag_bytes                      # one permute stage
    elif cfg.schedule == "allgather_k":
        comm = p * (p - 1) * frag_bytes // cfg.sync_every
    else:
        comm = p * (p - 1) * frag_bytes            # full all-gather
    return SPMDResult(x=x, supersteps=int(steps.max()),
                      local_resid=np.asarray(resids),
                      comm_bytes_per_step=int(comm))
