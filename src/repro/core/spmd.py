"""TPU-native bounded-staleness PageRank under shard_map (beyond-paper form).

True message-level asynchrony cannot exist inside one XLA program (its
collectives are bulk-synchronous). The paper's own conclusion points the way
to the TPU adaptation: the win is not unblocking threads but *reducing and
re-scheduling communication* — "we would like to avoid the use of all-to-all
communication schemes ... the flexibility of asynchronous iterations gives
us a choice on the targets of produced messages" (§6).

We therefore express asynchrony as bounded staleness over sparsified
collective schedules.  The schedules are the bulk-synchronous rendering of
`runtime.ExchangePlan` (see runtime/exchange.py — the host rendering drives
the DES engine and the sharded streaming updater):

  schedule="allgather"    : all-gather every superstep (synchronous baseline,
                            eq. 4 distributed).
  schedule="allgather_k"  : all-gather every k supersteps; local iterations
                            in between use stale fragments (staleness <= k-1).
  schedule="ring"         : one collective_permute stage per superstep — each
                            shard refreshes exactly one peer fragment per
                            step (1/p of the all-gather bytes; staleness of
                            fragment j at shard i is (i - j) mod p steps).
  schedule="sparsified"   : the §6 message-targeting plan — each shard ships
                            only the top-k rows whose |delta| since the last
                            send exceeds a threshold, as (idx, value) pairs;
                            payloads shrink as shards converge, and a forced
                            full all-gather every `sparsify_refresh_every`
                            supersteps keeps delays bounded.
  delivery_prob < 1       : models canceled/dropped messages (paper cancels
                            overdue send threads); a rejected delivery keeps
                            the stale copy, exactly like eq. (5) with larger
                            tau.

Every schedule's local update runs through the selected matvec backend
(cfg.backend): "segment_sum" (gather + segment-sum over the shard's edge
slice) or "bsr_pallas" (each UE packs its own block-row slice of P^T into
the hub-split BSR layout once, then every superstep is dense block
multiplies + a small segment-sum side path — the MXU form on TPU).

The teleport may be an (n, nv) stack: nv personalized PageRank lanes share
every operator load, with per-lane Fig. 1 termination counters.  With
``freeze_lanes=True`` a lane whose all-reduced monitor counter has fired is
frozen (its fragment stops updating — the multi-lane rendering of the
per-lane freezing in core.pagerank), so finished lanes stop perturbing the
exchange while slow lanes run to their own tolerance.

Convergence for all schedules follows from bounded delays (Frommer-Szyld
[15]; Lubachevsky-Mitra [21] for the unit-spectral-radius power form; the
sparsified plan's forced refresh is exactly the bounded-delay condition).
Termination detection runs in-loop through
`runtime.TerminationDriver.bits_step` — per-shard persistence counters plus
a monitor counter over the all-reduced convergence bits, the
bulk-synchronous rendering of Fig. 1.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple, Union

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .partition import Partition, block_rows
# submodule reference (see des.py): runtime.driver imports core.termination,
# so its class attributes may not exist yet during an `import repro.runtime`
from ..runtime import driver as _runtime_driver
from ..runtime import step as _runtime_step
from ..runtime import transport as _runtime_transport
from ..runtime.exchange import spmd_exchange
from ..graph.google import GoogleOperator


@dataclasses.dataclass
class SPMDConfig:
    p: int                       # number of UEs = mesh size along 'ue'
    schedule: str = "allgather"  # allgather | allgather_k | ring | sparsified
    sync_every: int = 4          # k for allgather_k
    delivery_prob: float = 1.0   # per-fragment acceptance probability
    tol: float = 1e-6            # local convergence threshold (inf-norm)
    pc_max_compute: int = 1
    pc_max_monitor: int = 1
    max_supersteps: int = 2000
    kind: str = "power"          # power (eq. 6) | linear (eq. 7)
    dtype: str = "float32"
    seed: int = 0
    backend: str = "segment_sum"  # segment_sum | bsr_pallas
    bsr_bm: int = 0               # block edge; 0 = auto (128 TPU / 8 CPU)
    bsr_impl: str = "auto"        # auto | pallas | interpret | ref
    hub_quantile: float = 0.99    # rows above this row-nnz quantile -> COO
    freeze_lanes: bool = False    # freeze lanes whose monitor counter fired
    compact_lanes: bool = False   # pow2 lane *compaction* between shard_map
    #                             # chunks: exit the while_loop once enough
    #                             # lanes are frozen (see compact_exit),
    #                             # shrink the (n, nv) stack to the
    #                             # unfinished lanes (padded to the next
    #                             # pow2) and re-enter — frozen lanes stop
    #                             # costing flops instead of being masked
    #                             # (requires freeze_lanes)
    compact_exit: Union[str, float] = "auto"
    #                             # when a compact chunk hands back to the
    #                             # host: a float f exits once done lanes
    #                             # >= ceil(f * lanes) (0.5 pins the
    #                             # historic half rule on pow2 widths);
    #                             # "auto" exits at the earliest count that
    #                             # can actually shrink the pow2 stack and,
    #                             # when the previous chunk's lane
    #                             # completions clustered, runs to all-done
    #                             # instead (a boundary would not pay)
    # --- sparsified schedule (runtime.ExchangePlan, §6 targeting) ---
    sparsify_k: int = 0           # max rows per payload; 0 = auto (bsize/8)
    sparsify_thresh: float = 0.0  # per-row |delta| floor (0 = any change)
    sparsify_refresh_every: int = 16  # forced full all-gather cadence
    sparsify_adaptive: bool = False   # pick k from the observed row-delta
    #                                 # distribution (sparsify_k becomes a
    #                                 # static budget; EWMA-smoothed)
    sparsify_cover_frac: float = 0.9  # |delta| mass the payload must cover
    sparsify_ewma: float = 0.5        # new-observation EWMA weight


@dataclasses.dataclass
class SPMDResult:
    x: np.ndarray                # (n,) — or (n, nv) for teleport stacks
    supersteps: int
    local_resid: np.ndarray      # (p,) final per-shard residuals
                                 # ((p, nv) for teleport stacks)
    comm_bytes_per_step: int     # payload bytes moved per superstep (model)
    comm_bytes_total: int = 0    # payload bytes over the whole run (model)
    rows_sent: int = 0           # sparsified: sparse payload rows shipped
    lane_supersteps: Optional[np.ndarray] = None  # (nv,) first-done step
    lane_chunks: int = 1         # shard_map chunks run (compact_lanes)
    # observe=True: one dict per shard_map chunk (lanes/steps/rows/fulls/
    # bytes).  The in-loop counters restart at zero on every chunk's
    # schedule re-keying, so the cumulative contract is
    # comm_bytes_total == sum(c["bytes"]) and rows_sent == sum(c["rows"])
    # across the log (pinned by tests/test_observe.py)
    chunk_log: Optional[List[dict]] = None


# the accept-draw hash moved to the shared step module; kept under the
# historic name for the kernel/SPMD tests that pin its distribution
_hash_uniform = _runtime_step.hash_uniform


def _resolve_bsr(cfg: SPMDConfig) -> Tuple[int, str]:
    """Resolve auto block size / impl with the same policy as the solver
    backends (single source of truth in BackendSpec.resolved())."""
    from .backend import BackendSpec
    spec = BackendSpec(name="bsr_pallas", impl=cfg.bsr_impl,
                       bm=cfg.bsr_bm).resolved()
    return spec.bm, spec.impl


def _pack_blocks(op: GoogleOperator, part: Partition, dtype,
                 cfg: SPMDConfig, v_stack: np.ndarray):
    """Pad per-block state of P^T to common budgets so the sharded arrays
    have static shapes.

    segment_sum: per-shard edge slices padded to a common edge count.
    bsr_pallas : per-shard hub-split BSR — a global hub mask (row-nnz
                 quantile over all pages) splits each shard's edges; the
                 block-CSR parts share one K budget, the COO hub parts one
                 edge budget.
    Always packed: per-shard teleport fragments ((bsize, nv) lanes) and a
    valid-row mask (the scalar dangling/teleport corrections must not leak
    into padding rows).
    """
    from .partition import slice_transition

    p = part.p
    nv = v_stack.shape[1]
    bsize = int(part.sizes().max())
    if cfg.backend == "bsr_pallas":
        bm, _ = _resolve_bsr(cfg)
        bsize = -(-bsize // bm) * bm       # block-align every fragment
    n = part.n
    n_pad = p * bsize

    blocks = [slice_transition(op.pt, part, i) for i in range(p)]
    vblk = np.zeros((p, bsize, nv), dtype=dtype)
    valid = np.zeros((p, bsize), dtype=dtype)
    for i in range(p):
        s, t = part.block(i)
        vblk[i, : t - s] = v_stack[s:t]
        valid[i, : t - s] = 1.0
    # the dangling mask lives in *packed-view* coordinates: with
    # block-aligned fragments the view rows shift relative to page ids
    dang = np.zeros((n_pad,), dtype=bool)
    for i in range(p):
        s, t = part.block(i)
        dang[i * bsize: i * bsize + (t - s)] = op.pt.dangling[s:t]

    packed = dict(vblk=vblk, valid=valid, dang=dang, bsize=bsize,
                  n_pad=n_pad)

    if cfg.backend == "bsr_pallas":
        from ..kernels.bsr_spmv import build_bsr
        row_nnz = np.diff(op.pt.indptr)
        if cfg.hub_quantile < 1.0:
            cut = np.quantile(row_nnz, cfg.hub_quantile)
            hub_row = row_nnz > cut
        else:
            hub_row = np.zeros(n, dtype=bool)

        # per-shard split; columns live in packed-view coordinates
        col_map = np.zeros(n, dtype=np.int64)
        for j in range(p):
            s, t = part.block(j)
            col_map[s:t] = np.arange(j * bsize, j * bsize + (t - s))

        shard = []
        for i, b in enumerate(blocks):
            s, t = part.block(i)
            rows_g = b["row_ids"].astype(np.int64) + s
            is_hub = hub_row[rows_g]
            shard.append(dict(
                rows=b["row_ids"].astype(np.int64)[~is_hub],
                cols=col_map[b["src"].astype(np.int64)[~is_hub]],
                vals=np.asarray(b["weight"], dtype=np.float32)[~is_hub],
                h_rows=b["row_ids"].astype(np.int64)[is_hub],
                h_cols=col_map[b["src"].astype(np.int64)[is_hub]],
                h_vals=np.asarray(b["weight"], dtype=np.float32)[is_hub],
            ))

        # shared K budget across shards (static shapes under shard_map)
        nbc_g = n_pad // bm
        K = 1
        for sh in shard:
            key = np.unique((sh["rows"] // bm) * nbc_g + sh["cols"] // bm)
            if len(key):
                per = np.bincount((key // nbc_g).astype(np.int64),
                                  minlength=bsize // bm)
                K = max(K, int(per.max()))
        hmax = max(1, max(len(sh["h_rows"]) for sh in shard))

        nbr_l = bsize // bm
        blk = np.zeros((p, nbr_l, K, bm, bm), dtype=np.float32)
        bcols = np.zeros((p, nbr_l, K), dtype=np.int32)
        hrow = np.zeros((p, hmax), dtype=np.int32)
        hcol = np.zeros((p, hmax), dtype=np.int32)
        hval = np.zeros((p, hmax), dtype=np.float32)
        fills = []
        for i, sh in enumerate(shard):
            b = build_bsr(sh["rows"], sh["cols"], sh["vals"],
                          n_rows=bsize, n_cols=n_pad, bm=bm, bn=bm,
                          k_budget=K, unique_pairs=True)
            blk[i] = b.blocks
            bcols[i] = b.blk_cols
            e = len(sh["h_rows"])
            hrow[i, :e] = sh["h_rows"]
            hcol[i, :e] = sh["h_cols"]
            hval[i, :e] = sh["h_vals"]
            fills.append(b.fill_ratio)
        packed.update(blk=blk, bcols=bcols, hrow=hrow, hcol=hcol, hval=hval,
                      K=K, bm=bm, fill_ratio=float(np.mean(fills)))
    else:
        emax = max(b["src"].shape[0] for b in blocks)
        src = np.zeros((p, emax), dtype=np.int32)
        wgt = np.zeros((p, emax), dtype=dtype)
        rid = np.zeros((p, emax), dtype=np.int32)
        for i, b in enumerate(blocks):
            e = b["src"].shape[0]
            # sources also live in packed-view coordinates
            src[i, :e] = col_map_seg(part, bsize, b["src"])
            wgt[i, :e] = b["weight"]
            rid[i, :e] = b["row_ids"]
        packed.update(src=src, wgt=wgt, rid=rid, emax=emax)
    return packed


def col_map_seg(part: Partition, bsize: int, cols: np.ndarray) -> np.ndarray:
    """Map global column ids into packed-view coordinates (identity when
    fragments are unpadded, shifted when block-aligned)."""
    out = np.empty(len(cols), dtype=np.int32)
    owners = np.searchsorted(np.asarray(part.ends), cols, side="right")
    starts = np.asarray(part.starts)
    out[:] = owners * bsize + (cols - starts[owners])
    return out


def solve_spmd(op: GoogleOperator, cfg: SPMDConfig,
               mesh: Optional[Mesh] = None,
               v: Optional[np.ndarray] = None,
               observe: bool = False) -> SPMDResult:
    if cfg.compact_lanes and not cfg.freeze_lanes:
        raise ValueError("compact_lanes=True requires freeze_lanes=True "
                         "(compaction shrinks the stack to unfrozen lanes)")
    ce = cfg.compact_exit
    if not (ce == "auto" or (isinstance(ce, (int, float))
                             and not isinstance(ce, bool)
                             and 0.0 < float(ce) <= 1.0)):
        raise ValueError(f"compact_exit must be 'auto' or a fraction in "
                         f"(0, 1], got {ce!r}")
    p = cfg.p
    n = op.n
    dtype = jnp.dtype(cfg.dtype)
    if mesh is None:
        devs = jax.devices()
        assert len(devs) >= p, f"need {p} devices, have {len(devs)}"
        mesh = jax.make_mesh((p,), ("ue",), devices=devs[:p])

    v_stack = np.asarray(op.teleport() if v is None else v,
                         dtype=np.float64)
    if v_stack.ndim == 1:
        v_stack = v_stack[:, None]
    if v_stack.shape[0] != n:
        raise ValueError(f"teleport v has {v_stack.shape[0]} rows, "
                         f"operator has {n}")
    nv = v_stack.shape[1]

    # uniform blocks (paper's ceil(n/p) scheme) padded to p * bsize
    part = block_rows(n, p)
    packed = _pack_blocks(op, part, np.dtype(cfg.dtype), cfg, v_stack)
    bsize = packed["bsize"]
    n_pad = packed["n_pad"]

    alpha = float(op.alpha)
    linear = cfg.kind == "linear"
    tol = cfg.tol
    q = cfg.delivery_prob
    seed = cfg.seed
    use_bsr = cfg.backend == "bsr_pallas"
    if use_bsr:
        bm, bsr_impl = _resolve_bsr(cfg)

    init_comm, comm = spmd_exchange(
        cfg.schedule, p=p, bsize=bsize, n_pad=n_pad,
        sync_every=cfg.sync_every, sparsify_k=cfg.sparsify_k,
        sparsify_row_thresh=cfg.sparsify_thresh,
        sparsify_refresh_every=cfg.sparsify_refresh_every,
        sparsify_adaptive=cfg.sparsify_adaptive,
        sparsify_cover_frac=cfg.sparsify_cover_frac,
        sparsify_ewma=cfg.sparsify_ewma,
        # endgame guard: a delta mass at the tolerance scale ships full
        # payloads so the persistence counters can settle
        sparsify_endgame_mass=cfg.tol * bsize * nv)

    # device inputs, sharded over 'ue' (lane-independent ones placed once)
    sh = lambda *spec: jax.NamedSharding(mesh, P(*spec))
    valid = jax.device_put(packed["valid"], sh("ue", None))
    dang = jax.device_put(
        np.broadcast_to(packed["dang"], (p, n_pad)).copy(), sh("ue", None))
    x0_blocks = (np.full((p, bsize, nv), 1.0 / n, dtype=cfg.dtype)
                 * packed["valid"].astype(cfg.dtype)[:, :, None])

    if use_bsr:
        op_args = tuple(jax.device_put(packed[k], sh("ue", *([None] * nd)))
                        for k, nd in (("blk", 4), ("bcols", 2), ("hrow", 1),
                                      ("hcol", 1), ("hval", 1)))
    else:
        op_args = tuple(jax.device_put(packed[k], sh("ue", None))
                        for k in ("src", "wgt", "rid"))

    def run_chunk(vblk_np, x0_np, max_steps, compact_exit, exit_k=0):
        """One shard_map while_loop over the lanes of `vblk_np`
        ((p, bsize, nv_c) teleport blocks) from iterate `x0_np`.  With
        `compact_exit` the loop also exits once `exit_k` lanes are done
        (the pow2-compaction hook, threshold picked by the host per
        chunk); otherwise behavior is the pre-compaction loop
        verbatim."""
        nv_c = vblk_np.shape[2]
        vblk = jax.device_put(np.ascontiguousarray(vblk_np),
                              sh("ue", None, None))
        x0 = jax.device_put(np.ascontiguousarray(x0_np),
                            sh("ue", None, None))

        def body_fn(vblk, valid, dang, x0, *op_args):
            """Runs on one shard. vblk/x0: (1, bsize, nv), valid:
            (1, bsize), dang: (1, n_pad); op_args are the shard's
            operator slice (edge or block form).

            The body is assembled from the shared ShardStep builders
            (runtime/step.py) — the same traced step the device
            transport runs, so the bulk-synchronous solver and the async
            streaming drain share one local update / exchange /
            termination body."""
            vb_, val_, dg_, myx = vblk[0], valid[0], dang[0], x0[0]
            i = jax.lax.axis_index("ue")

            op_slice = tuple(a[0] for a in op_args)
            if use_bsr:
                pt_apply = _runtime_step.shard_pt_apply(
                    op_slice, use_bsr=True, bsize=bsize, nv=nv_c,
                    n_pad=n_pad, bm=bm, impl=bsr_impl)
            else:
                pt_apply = _runtime_step.shard_pt_apply(
                    op_slice, use_bsr=False, bsize=bsize, nv=nv_c)
            local_update = _runtime_step.shard_local_update(
                pt_apply, alpha=alpha, linear=linear, n=n,
                vb=vb_, val=val_, dang=dg_)
            superstep, cond = _runtime_step.shard_superstep_fns(
                local_update, comm, i=i, p=p, tol=tol,
                pc_max_compute=cfg.pc_max_compute,
                pc_max_monitor=cfg.pc_max_monitor,
                seed=seed, q=q, freeze_lanes=cfg.freeze_lanes,
                max_steps=max_steps, compact_exit=compact_exit,
                exit_k=exit_k, conv="linf", axis="ue")

            carry = _runtime_step.init_carry(myx, init_comm, nv=nv_c,
                                             n_pad=n_pad, axis="ue")
            (view, frag, _, step, pc, mon_pc, lane_done, lane_step,
             rows_sent, fulls) = jax.lax.while_loop(
                cond, lambda c: superstep(c), carry)
            resid = jnp.max(jnp.abs(local_update(view) - frag), axis=0)
            return (frag[None], step[None], resid[None], lane_step[None],
                    rows_sent[None], fulls[None])

        mapped = shard_map(
            body_fn, mesh=mesh,
            in_specs=(P("ue", None, None), P("ue", None), P("ue", None),
                      P("ue", None, None))
            + tuple(P("ue", *([None] * (a.ndim - 1))) for a in op_args),
            out_specs=(P("ue", None, None), P("ue"), P("ue", None),
                       P("ue", None), P("ue"), P("ue")),
            check_rep=False,
        )
        frags, steps, resids, lane_steps, rows_sent, fulls = \
            jax.jit(mapped)(vblk, valid, dang, x0, *op_args)
        return (np.asarray(frags, dtype=np.float64), int(steps.max()),
                np.asarray(resids), np.asarray(lane_steps,
                                               dtype=np.int64).max(axis=0),
                int(np.asarray(rows_sent).sum()),
                int(np.asarray(fulls).sum()))

    def chunk_bytes(nv_c, steps_c, rows_c, fulls_c):
        """The per-chunk rendering of the byte model (the static schedules
        scale with the chunk's lane count; sparsified uses the honest
        in-loop counters).  Delegates to the one shared model in
        runtime/step.py — the device transport and its bench gate report
        through the identical accounting."""
        return _runtime_step.comm_bytes_model(
            cfg.schedule, p=p, bsize=bsize,
            itemsize=np.dtype(cfg.dtype).itemsize, nv=nv_c,
            steps=steps_c, rows=rows_c, fulls=fulls_c,
            sync_every=cfg.sync_every)

    compact = bool(cfg.compact_lanes and cfg.freeze_lanes and nv > 1)
    vblk_full = packed["vblk"]
    chunk_log: Optional[List[dict]] = [] if observe else None
    if not compact:
        frag_mat, supersteps, resid_mat, lane_out, rows_total, fulls_total \
            = run_chunk(vblk_full, x0_blocks, cfg.max_supersteps, False)
        comm_total = chunk_bytes(nv, supersteps, rows_total, fulls_total)
        chunks = 1
        if chunk_log is not None:
            chunk_log.append(dict(chunk=0, lanes=nv, steps=supersteps,
                                  rows=rows_total, fulls=fulls_total,
                                  bytes=comm_total))
    else:
        # ---- pow2 lane compaction between shard_map chunks -------------
        # Run until enough active lanes are frozen (compact_exit), then
        # shrink the (bsize, nv) stack to the survivors padded to the
        # next pow2 (padding duplicates a survivor so the Fig. 1 bits of
        # every carried lane are real) and re-enter with the current
        # fragments as x0.  Frozen lanes stop costing flops and exchange
        # bytes; their results are recorded at the chunk boundary.
        frag_mat = np.zeros((p, bsize, nv))
        resid_mat = np.zeros((p, nv), dtype=cfg.dtype)
        lane_out = np.full(nv, -1, dtype=np.int64)
        active = list(range(nv))            # real lane id per position
        real = [True] * nv                  # padding positions are False
        cur_v, cur_x0 = vblk_full, x0_blocks
        steps_done = 0
        comm_total = 0
        rows_total = fulls_total = 0
        chunks = 0
        prev_done_rel = None    # last chunk's lane-completion steps
        prev_st = 0
        while True:
            chunks += 1
            budget = cfg.max_supersteps - steps_done
            nv_c = cur_v.shape[2]
            if ce != "auto":
                exit_k = max(1, int(np.ceil(float(ce) * nv_c)))
            else:
                # the earliest done-count at which the pow2 stack width
                # can actually shrink (on pow2 widths this is the
                # historic half rule; ragged first chunks exit sooner)
                half = (1 << max(nv_c - 1, 0).bit_length()) // 2
                exit_k = max(1, nv_c - half)
                # spread adaptation: when the previous chunk's lane
                # completions clustered inside a quarter of the chunk,
                # the survivors are expected to land together too — run
                # this chunk to all-done instead of paying a compaction
                # boundary the stragglers would immediately catch up to
                if (prev_done_rel is not None and prev_done_rel.size >= 2
                        and prev_st > 0
                        and float(prev_done_rel.max() - prev_done_rel.min())
                        <= 0.25 * prev_st):
                    exit_k = nv_c + 1
            fr, st, rs, ls, rows_c, fulls_c = run_chunk(
                cur_v, cur_x0, budget, True, exit_k)
            prev_done_rel = ls[np.asarray(real) & (ls >= 0)]
            prev_st = st
            steps_done += st
            cb = chunk_bytes(len(active), st, rows_c, fulls_c)
            # the in-loop counters restarted at zero with this chunk's
            # re-keyed schedule state, so the totals accumulate here —
            # comm_bytes_total / rows_sent are cumulative across every
            # chunk boundary (the chunk_log makes that checkable)
            comm_total += cb
            rows_total += rows_c
            fulls_total += fulls_c
            if chunk_log is not None:
                chunk_log.append(dict(chunk=chunks - 1, lanes=len(active),
                                      steps=st, rows=rows_c,
                                      fulls=fulls_c, bytes=cb))
            done_pos = ls >= 0
            for pos, lane in enumerate(active):
                if not real[pos]:
                    continue
                finished = bool(done_pos[pos])
                if finished or steps_done >= cfg.max_supersteps \
                        or np.all(done_pos):
                    frag_mat[:, :, lane] = fr[:, :, pos]
                    resid_mat[:, lane] = rs[:, pos]
                    if finished:
                        lane_out[lane] = steps_done - st + int(ls[pos])
            survivors = [active[pos] for pos in range(len(active))
                         if real[pos] and not done_pos[pos]]
            if not survivors or steps_done >= cfg.max_supersteps:
                break
            nv_next = 1 << (len(survivors) - 1).bit_length()
            pad = nv_next - len(survivors)
            pos_of = {lane: pos for pos, lane in enumerate(active)}
            keep_pos = [pos_of[ln] for ln in survivors] \
                + [pos_of[survivors[0]]] * pad
            cur_v = np.ascontiguousarray(cur_v[:, :, keep_pos])
            cur_x0 = np.ascontiguousarray(
                fr[:, :, keep_pos].astype(cfg.dtype))
            active = survivors + [survivors[0]] * pad
            real = [True] * len(survivors) + [False] * pad
        supersteps = steps_done

    # un-pack: drop each fragment's block-alignment padding
    x = np.empty((n, nv), dtype=np.float64)
    for i in range(p):
        s, t = part.block(i)
        x[s:t] = frag_mat[i, : t - s]
    s_ = x.sum(axis=0)
    x = np.where(s_ > 0, x / np.where(s_ > 0, s_, 1.0), x)

    comm_step = comm_total // max(supersteps, 1)
    resid_out = np.asarray(resid_mat)                   # (p, nv)
    if nv == 1:
        x = x[:, 0]
        resid_out = resid_out[:, 0]
    return SPMDResult(x=x, supersteps=supersteps,
                      local_resid=resid_out,
                      comm_bytes_per_step=int(comm_step),
                      comm_bytes_total=int(comm_total),
                      rows_sent=int(rows_total),
                      lane_supersteps=lane_out if nv > 1 else None,
                      lane_chunks=chunks, chunk_log=chunk_log)
