"""TPU-native bounded-staleness PageRank under shard_map (beyond-paper form).

True message-level asynchrony cannot exist inside one XLA program (its
collectives are bulk-synchronous). The paper's own conclusion points the way
to the TPU adaptation: the win is not unblocking threads but *reducing and
re-scheduling communication* — "we would like to avoid the use of all-to-all
communication schemes ... the flexibility of asynchronous iterations gives
us a choice on the targets of produced messages" (§6).

We therefore express asynchrony as bounded staleness over sparsified
collective schedules:

  schedule="allgather"    : all-gather every superstep (synchronous baseline,
                            eq. 4 distributed).
  schedule="allgather_k"  : all-gather every k supersteps; local iterations
                            in between use stale fragments (staleness <= k-1).
  schedule="ring"         : one collective_permute stage per superstep — each
                            shard refreshes exactly one peer fragment per
                            step (1/p of the all-gather bytes; staleness of
                            fragment j at shard i is (i - j) mod p steps).
  delivery_prob < 1       : models canceled/dropped messages (paper cancels
                            overdue send threads); a rejected delivery keeps
                            the stale copy, exactly like eq. (5) with larger
                            tau.

Every schedule's local update runs through the selected matvec backend
(cfg.backend): "segment_sum" (gather + segment-sum over the shard's edge
slice) or "bsr_pallas" (each UE packs its own block-row slice of P^T into
the hub-split BSR layout once, then every superstep is dense block
multiplies + a small segment-sum side path — the MXU form on TPU).

Convergence for all schedules follows from bounded delays (Frommer-Szyld
[15]; Lubachevsky-Mitra [21] for the unit-spectral-radius power form).
Termination detection runs in-loop: per-shard persistence counters plus a
monitor counter over the all-reduced convergence bits — the bulk-synchronous
rendering of Fig. 1.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .partition import Partition, block_rows
from ..graph.google import GoogleOperator


@dataclasses.dataclass
class SPMDConfig:
    p: int                       # number of UEs = mesh size along 'ue'
    schedule: str = "allgather"  # allgather | allgather_k | ring
    sync_every: int = 4          # k for allgather_k
    delivery_prob: float = 1.0   # per-fragment acceptance probability
    tol: float = 1e-6            # local convergence threshold (inf-norm)
    pc_max_compute: int = 1
    pc_max_monitor: int = 1
    max_supersteps: int = 2000
    kind: str = "power"          # power (eq. 6) | linear (eq. 7)
    dtype: str = "float32"
    seed: int = 0
    backend: str = "segment_sum"  # segment_sum | bsr_pallas
    bsr_bm: int = 0               # block edge; 0 = auto (128 TPU / 8 CPU)
    bsr_impl: str = "auto"        # auto | pallas | interpret | ref
    hub_quantile: float = 0.99    # rows above this row-nnz quantile -> COO


@dataclasses.dataclass
class SPMDResult:
    x: np.ndarray
    supersteps: int
    local_resid: np.ndarray      # (p,) final per-shard residuals
    comm_bytes_per_step: int     # payload bytes moved per superstep (model)


def _hash_uniform(seed: int, step: jax.Array, lane: jax.Array) -> jax.Array:
    """Counter-based uniform in [0, 1): a SplitMix-style integer mix of
    (seed, superstep, shard). jax.random inside shard_map lowers to a
    PartitionId instruction XLA's SPMD partitioner rejects; this hash is
    deterministic, partitionable, and plenty for a drop model."""
    z = (step.astype(jnp.uint32) * jnp.uint32(0x9E3779B9)
         + lane.astype(jnp.uint32) * jnp.uint32(0x85EBCA6B)
         + jnp.uint32(seed & 0xFFFFFFFF))
    z = (z ^ (z >> 16)) * jnp.uint32(0x7FEB352D)
    z = (z ^ (z >> 15)) * jnp.uint32(0x846CA68B)
    z = z ^ (z >> 16)
    return z.astype(jnp.float32) * jnp.float32(2.0 ** -32)


def _resolve_bsr(cfg: SPMDConfig) -> Tuple[int, str]:
    """Resolve auto block size / impl with the same policy as the solver
    backends (single source of truth in BackendSpec.resolved())."""
    from .backend import BackendSpec
    spec = BackendSpec(name="bsr_pallas", impl=cfg.bsr_impl,
                       bm=cfg.bsr_bm).resolved()
    return spec.bm, spec.impl


def _pack_blocks(op: GoogleOperator, part: Partition, dtype,
                 cfg: SPMDConfig):
    """Pad per-block state of P^T to common budgets so the sharded arrays
    have static shapes.

    segment_sum: per-shard edge slices padded to a common edge count.
    bsr_pallas : per-shard hub-split BSR — a global hub mask (row-nnz
                 quantile over all pages) splits each shard's edges; the
                 block-CSR parts share one K budget, the COO hub parts one
                 edge budget.
    Always packed: per-shard teleport fragments and a valid-row mask (the
    scalar dangling/teleport corrections must not leak into padding rows).
    """
    from .partition import slice_transition

    p = part.p
    bsize = int(part.sizes().max())
    if cfg.backend == "bsr_pallas":
        bm, _ = _resolve_bsr(cfg)
        bsize = -(-bsize // bm) * bm       # block-align every fragment
    n = part.n
    n_pad = p * bsize

    blocks = [slice_transition(op.pt, part, i) for i in range(p)]
    v = op.teleport()
    vblk = np.zeros((p, bsize), dtype=dtype)
    valid = np.zeros((p, bsize), dtype=dtype)
    for i in range(p):
        s, t = part.block(i)
        vblk[i, : t - s] = v[s:t]
        valid[i, : t - s] = 1.0
    # the dangling mask lives in *packed-view* coordinates: with
    # block-aligned fragments the view rows shift relative to page ids
    dang = np.zeros((n_pad,), dtype=bool)
    for i in range(p):
        s, t = part.block(i)
        dang[i * bsize: i * bsize + (t - s)] = op.pt.dangling[s:t]

    packed = dict(vblk=vblk, valid=valid, dang=dang, bsize=bsize,
                  n_pad=n_pad)

    if cfg.backend == "bsr_pallas":
        from ..kernels.bsr_spmv import build_bsr
        row_nnz = np.diff(op.pt.indptr)
        if cfg.hub_quantile < 1.0:
            cut = np.quantile(row_nnz, cfg.hub_quantile)
            hub_row = row_nnz > cut
        else:
            hub_row = np.zeros(n, dtype=bool)

        # per-shard split; columns live in packed-view coordinates
        col_map = np.zeros(n, dtype=np.int64)
        for j in range(p):
            s, t = part.block(j)
            col_map[s:t] = np.arange(j * bsize, j * bsize + (t - s))

        shard = []
        for i, b in enumerate(blocks):
            s, t = part.block(i)
            rows_g = b["row_ids"].astype(np.int64) + s
            is_hub = hub_row[rows_g]
            shard.append(dict(
                rows=b["row_ids"].astype(np.int64)[~is_hub],
                cols=col_map[b["src"].astype(np.int64)[~is_hub]],
                vals=np.asarray(b["weight"], dtype=np.float32)[~is_hub],
                h_rows=b["row_ids"].astype(np.int64)[is_hub],
                h_cols=col_map[b["src"].astype(np.int64)[is_hub]],
                h_vals=np.asarray(b["weight"], dtype=np.float32)[is_hub],
            ))

        # shared K budget across shards (static shapes under shard_map)
        nbc_g = n_pad // bm
        K = 1
        for sh in shard:
            key = np.unique((sh["rows"] // bm) * nbc_g + sh["cols"] // bm)
            if len(key):
                per = np.bincount((key // nbc_g).astype(np.int64),
                                  minlength=bsize // bm)
                K = max(K, int(per.max()))
        hmax = max(1, max(len(sh["h_rows"]) for sh in shard))

        nbr_l = bsize // bm
        blk = np.zeros((p, nbr_l, K, bm, bm), dtype=np.float32)
        bcols = np.zeros((p, nbr_l, K), dtype=np.int32)
        hrow = np.zeros((p, hmax), dtype=np.int32)
        hcol = np.zeros((p, hmax), dtype=np.int32)
        hval = np.zeros((p, hmax), dtype=np.float32)
        fills = []
        for i, sh in enumerate(shard):
            b = build_bsr(sh["rows"], sh["cols"], sh["vals"],
                          n_rows=bsize, n_cols=n_pad, bm=bm, bn=bm,
                          k_budget=K, unique_pairs=True)
            blk[i] = b.blocks
            bcols[i] = b.blk_cols
            e = len(sh["h_rows"])
            hrow[i, :e] = sh["h_rows"]
            hcol[i, :e] = sh["h_cols"]
            hval[i, :e] = sh["h_vals"]
            fills.append(b.fill_ratio)
        packed.update(blk=blk, bcols=bcols, hrow=hrow, hcol=hcol, hval=hval,
                      K=K, bm=bm, fill_ratio=float(np.mean(fills)))
    else:
        emax = max(b["src"].shape[0] for b in blocks)
        src = np.zeros((p, emax), dtype=np.int32)
        wgt = np.zeros((p, emax), dtype=dtype)
        rid = np.zeros((p, emax), dtype=np.int32)
        for i, b in enumerate(blocks):
            e = b["src"].shape[0]
            # sources also live in packed-view coordinates
            src[i, :e] = col_map_seg(part, bsize, b["src"])
            wgt[i, :e] = b["weight"]
            rid[i, :e] = b["row_ids"]
        packed.update(src=src, wgt=wgt, rid=rid, emax=emax)
    return packed


def col_map_seg(part: Partition, bsize: int, cols: np.ndarray) -> np.ndarray:
    """Map global column ids into packed-view coordinates (identity when
    fragments are unpadded, shifted when block-aligned)."""
    out = np.empty(len(cols), dtype=np.int32)
    owners = np.searchsorted(np.asarray(part.ends), cols, side="right")
    starts = np.asarray(part.starts)
    out[:] = owners * bsize + (cols - starts[owners])
    return out


def solve_spmd(op: GoogleOperator, cfg: SPMDConfig,
               mesh: Optional[Mesh] = None) -> SPMDResult:
    p = cfg.p
    n = op.n
    dtype = jnp.dtype(cfg.dtype)
    if mesh is None:
        devs = jax.devices()
        assert len(devs) >= p, f"need {p} devices, have {len(devs)}"
        mesh = jax.make_mesh((p,), ("ue",), devices=devs[:p])

    # uniform blocks (paper's ceil(n/p) scheme) padded to p * bsize
    part = block_rows(n, p)
    packed = _pack_blocks(op, part, np.dtype(cfg.dtype), cfg)
    bsize = packed["bsize"]
    n_pad = packed["n_pad"]

    alpha = float(op.alpha)
    linear = cfg.kind == "linear"
    tol = cfg.tol
    q = cfg.delivery_prob
    seed = cfg.seed
    use_bsr = cfg.backend == "bsr_pallas"
    if use_bsr:
        bm, bsr_impl = _resolve_bsr(cfg)

    # device inputs, sharded over 'ue'
    sh = lambda *spec: jax.NamedSharding(mesh, P(*spec))
    vblk = jax.device_put(packed["vblk"], sh("ue", None))
    valid = jax.device_put(packed["valid"], sh("ue", None))
    dang = jax.device_put(
        np.broadcast_to(packed["dang"], (p, n_pad)).copy(), sh("ue", None))
    x0_blocks = (np.full((p, bsize), 1.0 / n, dtype=cfg.dtype)
                 * packed["valid"].astype(cfg.dtype))
    x0 = jax.device_put(x0_blocks, sh("ue", None))

    if use_bsr:
        op_args = tuple(jax.device_put(packed[k], sh("ue", *([None] * nd)))
                        for k, nd in (("blk", 4), ("bcols", 2), ("hrow", 1),
                                      ("hcol", 1), ("hval", 1)))
    else:
        op_args = tuple(jax.device_put(packed[k], sh("ue", None))
                        for k in ("src", "wgt", "rid"))

    def body_fn(vblk, valid, dang, x0, *op_args):
        """Runs on one shard. vblk/valid/x0: (1, bsize), dang: (1, n_pad);
        op_args are the shard's operator slice (edge or block form)."""
        vb_, val_, dg_, myx = vblk[0], valid[0], dang[0], x0[0]
        i = jax.lax.axis_index("ue")

        if use_bsr:
            from ..kernels.bsr_spmv import bsr_matvec
            blk_, bcols_, hrow_, hcol_, hval_ = (a[0] for a in op_args)

            def pt_apply(view):
                xb = view.astype(jnp.float32).reshape(n_pad // bm, bm, 1)
                y = bsr_matvec(blk_, bcols_, xb, impl=bsr_impl)
                hub = jax.ops.segment_sum(
                    hval_ * view.astype(jnp.float32)[hcol_], hrow_,
                    num_segments=bsize)
                return (y.reshape(bsize) + hub).astype(view.dtype)
        else:
            src_, wgt_, rid_ = (a[0] for a in op_args)

            def pt_apply(view):
                contrib = wgt_ * view[src_]
                return jax.ops.segment_sum(contrib, rid_,
                                           num_segments=bsize)

        def local_update(view):
            """f_i: new own fragment from the (stale) full view. The scalar
            dangling/teleport corrections are masked so the block-aligned
            padding rows stay exactly zero."""
            y = alpha * pt_apply(view)
            dmass = jnp.sum(jnp.where(dg_, view, 0.0))
            y = y + alpha * dmass / n * val_
            if linear:
                y = y + (1.0 - alpha) * vb_
            else:
                y = y + (1.0 - alpha) * jnp.sum(view) * vb_
            return y * val_

        perm = [(j, (j + 1) % p) for j in range(p)]

        def superstep(carry):
            view, frag, ring, step, pc, mon_pc, done = carry
            newfrag = local_update(view)
            resid = jnp.max(jnp.abs(newfrag - frag))

            # ---- communication -------------------------------------------
            accept = _hash_uniform(seed, step, i) < q

            if cfg.schedule == "ring" and p > 1:
                ring_in = jax.lax.ppermute(ring, "ue", perm)
                # at superstep s (0-based), incoming fragment belongs to
                # UE (i - s - 1) mod p
                owner = jnp.mod(i - step - 1, p)
                # my own slot must always hold the fresh fragment
                view = jax.lax.dynamic_update_slice(
                    view, newfrag, (i * bsize,))
                updated = jax.lax.dynamic_update_slice(
                    view, ring_in, (owner * bsize,))
                view = jnp.where(
                    jnp.logical_and(accept, owner != i), updated, view)
                # forward own fragment afresh every p steps, else relay
                restart = jnp.mod(step + 1, p) == 0
                ring = jnp.where(restart, newfrag, ring_in)
            elif cfg.schedule == "allgather_k":
                do_sync = jnp.mod(step, cfg.sync_every) == cfg.sync_every - 1
                def gather(_):
                    allv = jax.lax.all_gather(newfrag, "ue")  # (p, bsize)
                    return allv.reshape(n_pad)
                def keep(_):
                    return jax.lax.dynamic_update_slice(
                        view, newfrag, (i * bsize,))
                sync_ok = jnp.logical_and(do_sync, accept)
                view = jax.lax.cond(sync_ok, gather, keep, operand=None)
            else:  # allgather (synchronous baseline)
                allv = jax.lax.all_gather(newfrag, "ue")
                view = allv.reshape(n_pad)

            # ---- in-loop Fig. 1 protocol ----------------------------------
            locally_conv = resid < tol
            pc = jnp.where(locally_conv, pc + 1, 0)
            flag = pc >= cfg.pc_max_compute
            nconv = jax.lax.psum(flag.astype(jnp.int32), "ue")
            all_conv = nconv == p
            mon_pc = jnp.where(all_conv, mon_pc + 1, 0)
            done = mon_pc >= cfg.pc_max_monitor
            return view, newfrag, ring, step + 1, pc, mon_pc, done

        def cond(carry):
            *_, step, pc, mon_pc, done = carry
            return jnp.logical_and(~done, step < cfg.max_supersteps)

        view0 = jax.lax.all_gather(myx, "ue").reshape(n_pad)
        carry = (view0, myx, myx, jnp.asarray(0), jnp.asarray(0),
                 jnp.asarray(0), jnp.asarray(False))
        view, frag, ring, step, pc, mon_pc, done = jax.lax.while_loop(
            cond, lambda c: superstep(c), carry)
        resid = jnp.max(jnp.abs(local_update(view) - frag))
        return frag[None], step[None], resid[None]

    mapped = shard_map(
        body_fn, mesh=mesh,
        in_specs=(P("ue", None),) * 4
        + tuple(P("ue", *([None] * (a.ndim - 1))) for a in op_args),
        out_specs=(P("ue", None), P("ue"), P("ue")),
        check_rep=False,
    )
    frags, steps, resids = jax.jit(mapped)(vblk, valid, dang, x0, *op_args)

    # un-pack: drop each fragment's block-alignment padding
    frag_mat = np.asarray(frags, dtype=np.float64)
    x = np.empty(n, dtype=np.float64)
    for i in range(p):
        s, t = part.block(i)
        x[s:t] = frag_mat[i, : t - s]
    s_ = x.sum()
    if s_ > 0:
        x = x / s_

    frag_bytes = bsize * np.dtype(cfg.dtype).itemsize
    if cfg.schedule == "ring":
        comm = p * frag_bytes                      # one permute stage
    elif cfg.schedule == "allgather_k":
        comm = p * (p - 1) * frag_bytes // cfg.sync_every
    else:
        comm = p * (p - 1) * frag_bytes            # full all-gather
    return SPMDResult(x=x, supersteps=int(steps.max()),
                      local_resid=np.asarray(resids),
                      comm_bytes_per_step=int(comm))
