"""Termination detection (paper §4.2, Figure 1).

Centralized protocol. Computing UEs run the left-column state machine and
emit edge-triggered CONVERGE / DIVERGE messages to a monitor UE, which runs
the right-column machine and broadcasts STOP once *persistent* global
convergence is observed. Persistence counters (pc, pcMax) on both sides give
in-flight messages time to arrive and destroy premature convergence.

The state machines below are pure functions over immutable dataclasses so
they can be unit- and property-tested in isolation, then driven by either
the DES event loop (message semantics) or the SPMD in-loop variant
(all-reduced convergence bits stand in for the messages).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional, Tuple


class Msg(enum.Enum):
    CONVERGE = 1
    DIVERGE = 2
    STOP = 3


@dataclasses.dataclass(frozen=True)
class ComputingUEState:
    """Left column of Fig. 1."""
    converged: bool = False
    pc: int = 0
    pc_max: int = 1
    stopped: bool = False

    def step(self, locally_converged: bool) -> Tuple["ComputingUEState", Optional[Msg]]:
        """One checkConvergence() evaluation after a local iteration.

        Returns (new state, message to send to monitor or None).

        Mirrors Fig. 1:
            if checkConvergence():
                if not converged: converged = True
                pc += 1
                if pc == pcMax: send(CONVERGE, monitor)
            else:
                if converged:
                    converged = False; send(DIVERGE, monitor); pc = 0
        """
        if self.stopped:
            return self, None
        if locally_converged:
            pc = self.pc + 1
            msg = Msg.CONVERGE if pc == self.pc_max else None
            return dataclasses.replace(self, converged=True, pc=pc), msg
        else:
            if self.converged:
                return dataclasses.replace(self, converged=False, pc=0), Msg.DIVERGE
            return dataclasses.replace(self, pc=0), None

    def stop(self) -> "ComputingUEState":
        return dataclasses.replace(self, stopped=True)


@dataclasses.dataclass(frozen=True)
class MonitorState:
    """Right column of Fig. 1. Tracks per-UE convergence flags; its own
    checkConvergence() is `all(flags)` with its own persistence counter."""
    flags: Tuple[bool, ...]
    converged: bool = False
    pc: int = 0
    pc_max: int = 1
    stop_issued: bool = False

    @staticmethod
    def create(p: int, pc_max: int = 1) -> "MonitorState":
        return MonitorState(flags=tuple([False] * p), pc_max=pc_max)

    def recv(self, ue: int, msg: Msg) -> "MonitorState":
        flags = list(self.flags)
        if msg == Msg.CONVERGE:
            flags[ue] = True
        elif msg == Msg.DIVERGE:
            flags[ue] = False
        return dataclasses.replace(self, flags=tuple(flags))

    def step(self) -> Tuple["MonitorState", bool]:
        """Evaluate monitor-side checkConvergence(); returns
        (new state, issue_stop)."""
        if self.stop_issued:
            return self, False
        if all(self.flags):
            pc = self.pc + 1
            if pc == self.pc_max:
                return dataclasses.replace(self, converged=True, pc=pc,
                                           stop_issued=True), True
            return dataclasses.replace(self, converged=True, pc=pc), False
        else:
            if self.converged:
                return dataclasses.replace(self, converged=False, pc=0), False
            return dataclasses.replace(self, pc=0), False


@dataclasses.dataclass
class CentralizedProtocol:
    """Convenience wrapper wiring p computing-UE machines to one monitor,
    with *immediate* message delivery. The DES engine instead routes the
    emitted messages through latency channels (the realistic case)."""

    p: int
    pc_max_compute: int = 1
    pc_max_monitor: int = 1

    def __post_init__(self):
        self.ues: List[ComputingUEState] = [
            ComputingUEState(pc_max=self.pc_max_compute) for _ in range(self.p)]
        self.monitor = MonitorState.create(self.p, pc_max=self.pc_max_monitor)
        self.stopped = False

    def report(self, ue: int, locally_converged: bool) -> bool:
        """UE `ue` finished an iteration; returns True iff STOP was issued."""
        if self.stopped:
            return True
        new_state, msg = self.ues[ue].step(locally_converged)
        self.ues[ue] = new_state
        if msg is not None:
            self.monitor = self.monitor.recv(ue, msg)
            self.monitor, issue_stop = self.monitor.step()
            if issue_stop:
                self.stopped = True
                self.ues = [s.stop() for s in self.ues]
                return True
        return False


# ---------------------------------------------------------------------------
# Decentralized (tree) termination detection — the paper's §4.2 alternative
# ("distributed protocols ... typically assume a specific underlying
# communication topology", e.g. the tree/leader-election scheme of [6]).
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TreeNodeState:
    """One UE in a binary-tree overlay. A node reports SUBTREE_CONVERGED to
    its parent once its own persistent flag and both children's reports are
    true; any local divergence (or a child's DIVERGE) retracts the report
    immediately. The root issues STOP, propagated down the tree."""
    ue: ComputingUEState
    child_ok: Tuple[bool, ...]          # one slot per child
    reported: bool = False              # last report sent upward

    @staticmethod
    def create(n_children: int, pc_max: int = 1) -> "TreeNodeState":
        return TreeNodeState(ue=ComputingUEState(pc_max=pc_max),
                             child_ok=tuple([False] * n_children))

    @property
    def subtree_ok(self) -> bool:
        return self.ue.converged and self.ue.pc >= self.ue.pc_max \
            and all(self.child_ok)

    def on_local_check(self, locally_converged: bool):
        """Returns (state, report) with report in {None, True, False}:
        True = send SUBTREE_CONVERGED up, False = send DIVERGE up."""
        new_ue, _ = self.ue.step(locally_converged)
        st = dataclasses.replace(self, ue=new_ue)
        return st._maybe_report()

    def on_child_report(self, child: int, ok: bool):
        ch = list(self.child_ok)
        ch[child] = ok
        st = dataclasses.replace(self, child_ok=tuple(ch))
        return st._maybe_report()

    def _maybe_report(self):
        ok = self.subtree_ok
        if ok and not self.reported:
            return dataclasses.replace(self, reported=True), True
        if not ok and self.reported:
            return dataclasses.replace(self, reported=False), False
        return self, None


class TreeProtocol:
    """p UEs on a binary tree (node i's children: 2i+1, 2i+2). Immediate
    message delivery; the DES engine can route the reports through its
    latency channels the same way it does for the centralized protocol."""

    def __init__(self, p: int, pc_max: int = 1):
        self.p = p
        kids = lambda i: [c for c in (2 * i + 1, 2 * i + 2) if c < p]
        self.children = {i: kids(i) for i in range(p)}
        self.parent = {c: i for i in range(p) for c in self.children[i]}
        self.nodes = {i: TreeNodeState.create(len(self.children[i]),
                                              pc_max=pc_max)
                      for i in range(p)}
        self.stopped = False

    def _route_up(self, i: int, report) -> bool:
        """Propagate a report from node i toward the root; True if the
        root observes full-tree convergence (STOP)."""
        while report is not None:
            if i == 0:
                return report is True and self.nodes[0].subtree_ok
            par = self.parent[i]
            slot = self.children[par].index(i)
            self.nodes[par], report = \
                self.nodes[par].on_child_report(slot, report is True)
            i = par
        return False

    def report(self, ue: int, locally_converged: bool) -> bool:
        if self.stopped:
            return True
        self.nodes[ue], rep = self.nodes[ue].on_local_check(locally_converged)
        if self._route_up(ue, rep):
            self.stopped = True
        return self.stopped
