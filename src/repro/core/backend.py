"""Pluggable matvec backends for the Google-operator hot path.

The paper's per-iteration cost is one application of

    G x = alpha P^T x + alpha w (d^T x) + (1 - alpha) v (e^T x)

and every solver in this repo funnels through it. Two backends implement it:

  segment_sum : gather + segment-sum over the CSR edge list (the portable
                default — exact in any dtype, fastest single-vector path on
                CPU).
  bsr_pallas  : hub-split block-CSR (kernels.bsr_spmv). The site-local mass
                runs as dense (bm, bn) block multiplies — the Pallas MXU
                kernel on TPU, the identical blocked-einsum contraction
                under XLA elsewhere — and the in-degree-tail rows go through
                a fused segment-sum side path. The iterate stays resident in
                the padded (nbr, bm, nv) block layout across the whole
                while_loop; nothing is repacked between iterations, and nv
                teleport lanes share every block load (batched personalized
                PageRank).

A backend is addressed by a hashable BackendSpec so the fused solver loop
can jit once per (spec, shapes) and dispatch statically.

Layout contract (bsr_pallas):
  * square blocks (bm == bn) so y has the same layout as x and the loop
    never leaves (nbr, bm, nv);
  * padded rows/cols beyond n are exactly zero and stay zero: blocks and
    the hub COO never touch them, the teleport vector and the scalar
    dangling-mass correction are masked by `valid`;
  * arithmetic is float32 (the MXU accumulates in f32) — L1 residuals
    bottom out around 1e-7; ask segment_sum/float64 for tighter tolerances.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from ..graph.google import GoogleOperator
from ..graph.csr import pt_matvec
from ..kernels.bsr_spmv import hybrid_matvec, pad_x

BACKENDS = ("segment_sum", "bsr_pallas")


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """Hashable backend selector (usable as a jit static argument)."""
    name: str = "segment_sum"
    impl: str = "auto"          # bsr_pallas only: auto | pallas | interpret | ref
    bm: int = 0                 # block edge; 0 = auto (128 on TPU, 8 on CPU)
    hub_quantile: float = 0.99  # rows above this row-nnz quantile bypass BSR

    def resolved(self) -> "BackendSpec":
        name = self.name
        if name not in BACKENDS:
            raise ValueError(f"unknown backend {name!r}; expected one of "
                             f"{BACKENDS}")
        impl, bm = self.impl, self.bm
        on_accel = jax.default_backend() in ("tpu", "gpu")
        on_tpu = jax.default_backend() == "tpu"
        if impl == "auto":
            # the *solver* auto policy: compiled Pallas on a real
            # accelerator, the fast blocked-einsum oracle on CPU (same
            # math; interpret mode is the kernel-faithful-but-slow lane
            # the kernel-level dispatch prefers — see
            # kernels.bsr_spmv.resolve_impl)
            impl = "pallas" if on_accel else "ref"
        if bm == 0:
            # the MXU wants 128x128 tiles; the XLA einsum path wants the
            # highest fill (fewest padded flops/pages), which small blocks
            # give — measured optimum on CPU is bm=8
            bm = 128 if on_tpu else 8
        return dataclasses.replace(self, impl=impl, bm=bm)


def as_spec(backend) -> BackendSpec:
    """Coerce a user-facing backend argument (str or spec) to a resolved
    BackendSpec."""
    if isinstance(backend, BackendSpec):
        return backend.resolved()
    return BackendSpec(name=str(backend)).resolved()


# --------------------------------------------------------------------------
# Preparation: operator -> device state + layout metadata
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class BackendMeta:
    """Static (hashable) layout info threaded through the jitted loop."""
    spec: BackendSpec
    n: int
    nv: int
    n_pad: int                  # nbr * bm for bsr, == n for segment_sum
    alpha: float


def _as_stack(a: np.ndarray, n: int, what: str) -> np.ndarray:
    a = np.asarray(a, dtype=np.float64)
    if a.ndim == 1:
        a = a[:, None]
    if a.shape[0] != n:
        raise ValueError(f"{what} has {a.shape[0]} rows, operator has {n}")
    return a


def seed_stack(n: int, seed_sets, weight_sets=None) -> np.ndarray:
    """Build an (n, nv) personalized-teleport stack from nv seed sets.

    Each column is a probability vector concentrated on that query's seeds
    (uniform over the set unless `weight_sets[i]` gives explicit weights,
    which are L1-normalized).  This is the lane layout `prepare` consumes:
    one fused solve over the stack amortizes every edge/block load across
    all nv personalized problems.
    """
    seed_sets = list(seed_sets)
    nv = len(seed_sets)
    if nv == 0:
        raise ValueError("seed_stack needs at least one seed set")
    v = np.zeros((n, nv), dtype=np.float64)
    for i, seeds in enumerate(seed_sets):
        seeds = np.asarray(seeds, dtype=np.int64).ravel()
        w = None if weight_sets is None else weight_sets[i]
        if w is None:
            v[seeds, i] = 1.0 / seeds.size
        else:
            w = np.asarray(w, dtype=np.float64).ravel()
            v[seeds, i] = w / w.sum()
    return v


def as_lane_tol(tol, nv: int) -> np.ndarray:
    """Coerce a scalar-or-per-lane tolerance to a validated (nv,) array.

    The fused solver loops accept a tolerance *per lane* so mixed-tol
    query batches share one solve: each lane stops (and may freeze out of
    the apply) at its own threshold instead of the whole stack running to
    the tightest one."""
    t = np.asarray(tol, dtype=np.float64).ravel()
    if t.size == 1:
        t = np.full(nv, float(t[0]))
    if t.size != nv:
        raise ValueError(f"tol has {t.size} entries for {nv} lanes")
    if not np.all(np.isfinite(t)) or np.any(t <= 0):
        raise ValueError("per-lane tol entries must be finite and > 0")
    return t


def prepare(op: GoogleOperator, spec: BackendSpec, dtype,
            v: Optional[np.ndarray] = None,
            x0: Optional[np.ndarray] = None
            ) -> Tuple[dict, BackendMeta, jax.Array]:
    """Build (device state, meta, x0 in backend layout) for a solve.

    `v`/`x0` may be (n,) vectors or (n, nv) stacks; lanes broadcast against
    each other. Structural state (edges, blocks, masks) is memoized on the
    operator; only the teleport stack is uploaded per call.
    """
    n = op.n
    v_stack = _as_stack(op.teleport() if v is None else v, n, "teleport v")
    nv = v_stack.shape[1]
    if x0 is None:
        x0_stack = np.full((n, nv), 1.0 / n, dtype=np.float64)
    else:
        x0_stack = _as_stack(x0, n, "x0")
    if x0_stack.shape[1] != nv:
        if x0_stack.shape[1] == 1:
            x0_stack = np.broadcast_to(x0_stack, (n, nv)).copy()
        elif nv == 1:
            v_stack = np.broadcast_to(v_stack, (n, x0_stack.shape[1])).copy()
            nv = v_stack.shape[1]
        else:
            raise ValueError(
                f"x0 has {x0_stack.shape[1]} lanes, v has {nv}")

    if spec.name == "segment_sum":
        dev = op.device_arrays(dtype=dtype)
        dev["v"] = jnp.asarray(v_stack, dtype=dtype)
        meta = BackendMeta(spec=spec, n=n, nv=nv, n_pad=n,
                           alpha=float(op.alpha))
        x0_dev = jnp.asarray(x0_stack, dtype=dtype)
        return dev, meta, x0_dev

    # ---- bsr_pallas ----------------------------------------------------
    bm = spec.bm
    hyb = op.hybrid_bsr(bm=bm, bn=bm, hub_quantile=spec.hub_quantile)
    cache = op._cache()
    key = ("bsr_dev", bm, spec.hub_quantile)
    dev_struct = cache.get(key)
    if dev_struct is None:
        dev_struct = hyb.device()
        nbr = hyb.bsr.nbr
        valid = np.zeros((nbr * bm, 1), dtype=np.float32)
        valid[:n] = 1.0
        dang = np.zeros((nbr * bm, 1), dtype=np.float32)
        dang[:n, 0] = op.pt.dangling.astype(np.float32)
        dev_struct["valid"] = jnp.asarray(valid.reshape(nbr, bm, 1))
        dev_struct["dang"] = jnp.asarray(dang.reshape(nbr, bm, 1))
        cache[key] = dev_struct
    dev = dict(dev_struct)
    nbr = hyb.bsr.nbr
    dev["v"] = jnp.asarray(pad_x(v_stack.astype(np.float32), n, bm))
    meta = BackendMeta(spec=spec, n=n, nv=nv, n_pad=nbr * bm,
                       alpha=float(op.alpha))
    x0_dev = jnp.asarray(pad_x(x0_stack.astype(np.float32), n, bm))
    return dev, meta, x0_dev


def from_layout(meta: BackendMeta, x_dev) -> np.ndarray:
    """Backend layout -> (n, nv) float64 numpy."""
    x = np.asarray(x_dev, dtype=np.float64)
    if meta.spec.name == "segment_sum":
        return x
    return x.reshape(meta.n_pad, meta.nv)[:meta.n]


# --------------------------------------------------------------------------
# The fused apply (jit-traceable; meta is static)
# --------------------------------------------------------------------------
def google_apply(meta: BackendMeta, dev: dict, x: jax.Array,
                 linear: bool) -> jax.Array:
    """One fused application of G (or R x + b for the linear form) in the
    backend's resident layout. Padding rows stay exactly zero."""
    alpha, n = meta.alpha, meta.n
    if meta.spec.name == "segment_sum":
        y = alpha * pt_matvec(dev, x, n)
        dmass = jnp.sum(jnp.where(dev["dangling"][:, None], x, 0.0), axis=0)
        y = y + alpha * dmass[None, :] / n
        if linear:
            y = y + (1.0 - alpha) * dev["v"]
        else:
            y = y + (1.0 - alpha) * jnp.sum(x, axis=0)[None, :] * dev["v"]
        return y

    # bsr_pallas: x is (nbr, bm, nv)
    y = alpha * hybrid_matvec(dev, x, impl=meta.spec.impl)
    dmass = jnp.sum(x * dev["dang"], axis=(0, 1))          # (nv,)
    y = y + (alpha / n) * dmass[None, None, :] * dev["valid"]
    if linear:
        y = y + (1.0 - alpha) * dev["v"]
    else:
        s = jnp.sum(x * dev["valid"], axis=(0, 1))         # (nv,)
        y = y + (1.0 - alpha) * s[None, None, :] * dev["v"]
    return y.astype(x.dtype)


def l1_residual(y: jax.Array, x: jax.Array) -> jax.Array:
    """Per-lane L1 residual ||y - x||_1, shape (nv,). Padding rows are zero
    in both layouts so no masking is needed."""
    d = jnp.abs(y - x)
    return jnp.sum(d, axis=tuple(range(d.ndim - 1)))


def take_lanes(meta: BackendMeta, dev: dict, x: jax.Array,
               idx: np.ndarray) -> Tuple[dict, BackendMeta, jax.Array]:
    """Slice the lane (last) axis of the per-solve state down to `idx`.

    Used by the per-lane-freezing driver: converged lanes are compacted out
    of the fused apply so the remaining lanes stop paying for them.  Only
    the teleport stack and the iterate carry a lane axis; the structural
    device state (edges, blocks, masks) is lane-invariant and shared.
    """
    idx = np.asarray(idx, dtype=np.int64)
    dev = dict(dev)
    dev["v"] = dev["v"][..., idx]
    meta = dataclasses.replace(meta, nv=int(idx.size))
    return dev, meta, x[..., idx]
