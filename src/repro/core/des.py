"""Discrete-event simulation of asynchronous iterative computation (eq. 5).

This is the *faithful* reproduction layer: per-UE clocks with heterogeneous
compute rates, a shared-medium network with per-message service times and
send-cancellation windows (the paper cancels send()/recv() threads that do
not complete in time, §6), the exact Fig. 1 termination protocol routed
through latency channels, and import accounting that reproduces the paper's
Table 2 (completed-imports percentages).

The substrate-independent pieces live in `repro.runtime`: per-UE state is a
`runtime.ShardState` (owned fragment + versioned stale views), the block
update is a `runtime.LocalSolver` (the backend-dispatched
`BlockLocalSolver` for PageRank, or any object satisfying the protocol —
e.g. the stale-gradient operator in repro.training.async_dp), message
targeting is a `runtime.ExchangePlan` (all_to_all / ring / adaptive plus
the §6 `sparsified` residual-mass targeting), and Fig. 1 is driven by a
`runtime.TerminationDriver` in its message-passing rendering.  This engine
owns what is DES-specific: the event queue, the clock and shared-medium
models, and the Table-2 accounting.

Semantics map (paper -> here):
  UE i owns fragment x_{i}                -> Partition block i
  x_{j}(tau_j^i(t)) stale imports         -> ShardState.view + version table
  compute phase                           -> "iter" events, duration ~ rate_i
  send threads (may be canceled)          -> Channel.send with cancel_window
  CONVERGE/DIVERGE/STOP (Fig. 1)          -> ctrl messages through the medium
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import List, Optional, Union

import numpy as np

from .partition import Partition
from ..runtime.state import ShardState
# submodule reference, not `from ..runtime.driver import TerminationDriver`:
# runtime.driver itself imports core.termination (which runs this package's
# __init__), so during an `import repro.runtime` the class attribute does
# not exist yet — the module object in sys.modules always does
from ..runtime import driver as _runtime_driver
from ..runtime.exchange import make_plan
from ..runtime.local import LocalSolver as BlockOperator
from ..runtime.local import BlockLocalSolver as PageRankBlockOperator
from ..graph.google import GoogleOperator

__all__ = ["AsyncDES", "DESConfig", "AsyncResult", "SyncResult",
           "BlockOperator", "PageRankBlockOperator"]


# --------------------------------------------------------------------------
# Config / result containers
# --------------------------------------------------------------------------
@dataclasses.dataclass
class DESConfig:
    tol: float = 1e-6
    norm: str = "inf"                 # local-convergence norm: "inf" | "l1"
    max_iters: int = 100_000
    # --- clock model ---
    # Calibrated to the paper's testbed (900 MHz Pentium, Java/MTJ SpMV).
    # Back-solved from Table 1: async p=2 runs ~68 iters in ~90 s on a
    # 1.16M-nnz half-block => ~9e5 edge-ops/s; with the shared-medium
    # exchange model this also reproduces the sync column (4.1/7.5/9.2 s
    # per iteration at p=2/4/6).
    base_flops_rate: float = 9e5      # "useful edge-ops per second" per UE
    ue_speed: Optional[List[float]] = None  # relative speeds (len p)
    jitter_sigma: float = 0.1         # lognormal per-iteration jitter
    # --- network model (shared medium, paper used 10 Mbps Ethernet) ---
    bandwidth: float = 1.25e6         # bytes/s on the shared medium
    msg_latency: float = 2e-3         # per message propagation latency (s)
    bytes_per_entry: int = 8
    ctrl_bytes: int = 64
    cancel_window: Optional[float] = 1.0  # cancel sends not started in time
    # --- per-UE message-handling costs (on the compute thread) ---
    # The paper's Java system serializes fragments into send buffers and
    # deserializes imports on arrival; back-solved from Table 1 this adds
    # ~0.8 s/iter at p=4 on top of 0.64 s of SpMV. Modeled as per-byte costs.
    send_cost_per_byte: float = 2e-7   # ~5 MB/s serialize
    recv_cost_per_byte: float = 2e-7   # ~5 MB/s deserialize
    iter_overhead: float = 0.02        # thread-pool/GC per-iteration cost
    # --- protocol ---
    pc_max_compute: int = 1
    pc_max_monitor: int = 1
    # --- ranking-aware termination (beyond-paper; operationalizes the
    # paper's §5.2 open question). The monitor periodically assembles the
    # owner fragments and STOPs once the top-k ordering is stable —
    # typically far earlier than a value-accuracy threshold. The assembly
    # channel is modeled out-of-band (idealization noted in EXPERIMENTS).
    rank_stop_k: Optional[int] = None
    rank_stop_tau: float = 0.999
    rank_stop_interval: float = 5.0   # sim seconds between assemblies
    rank_stop_patience: int = 2
    # --- communication policy (runtime.ExchangePlan) ---
    comm_policy: str = "all_to_all"   # all_to_all | ring | adaptive
    #                                 # | sparsified (§6 mass targeting)
    adaptive_cancel_limit: int = 3    # consecutive cancels before backoff
    adaptive_max_backoff: int = 16
    sparsify_thresh: float = 0.0      # L1 mass gate; 0 = auto (= tol)
    sparsify_refresh_every: int = 8   # forced full send every k local iters
    sparsify_top_k: Union[int, str, None] = None
    #                                 # rows per mass-gated payload: an
    #                                 # int, None (full fragments), or
    #                                 # "adaptive" (k picked from the
    #                                 # observed row-delta distribution,
    #                                 # EWMA-smoothed per pair; forced
    #                                 # refreshes always ship in full)
    # --- barrier model for the synchronous run ---
    barrier_overhead: float = 5e-3
    # power-form PageRank converges up to scale and is renormalized on
    # assembly; generic operators (e.g. stale-gradient SGD) must not be.
    normalize: bool = True
    seed: int = 0


@dataclasses.dataclass
class AsyncResult:
    p: int
    iters: np.ndarray                 # (p,) iterations executed at STOP
    local_conv_iter: np.ndarray       # (p,) iteration index of local conv.
    local_conv_time: np.ndarray       # (p,) sim time of local convergence
    stop_time: float                  # sim time STOP fully delivered
    imports: np.ndarray               # (p, p) delivered fragment counts
    attempts: np.ndarray              # (p, p) attempted sends
    completed_import_pct: np.ndarray  # (p,) row-average delivered/expected
    x: np.ndarray                     # assembled final iterate (normalized)
    global_resid_l1: float            # ||G x - x||_1 of the assembled vector
    global_resid_inf: float
    max_staleness: int                # max observed version lag (iterations)
    rank_stop_time: float = float("nan")  # when rank-stability fired


@dataclasses.dataclass
class SyncResult:
    p: int
    iters: int
    time: float
    x: np.ndarray
    global_resid_l1: float
    global_resid_inf: float


def _resid(delta: np.ndarray, norm: str) -> float:
    if norm == "inf":
        return float(np.abs(delta).max())
    if norm == "l2":
        return float(np.sqrt((delta * delta).sum()))
    return float(np.abs(delta).sum())


# --------------------------------------------------------------------------
# The simulator
# --------------------------------------------------------------------------
class AsyncDES:
    """Asynchronous run of eq. (5) under the DESConfig models."""

    def __init__(self, operator: BlockOperator, part: Partition,
                 cfg: DESConfig, x0: Optional[np.ndarray] = None,
                 check_operator: Optional[GoogleOperator] = None):
        self.opr = operator
        self.part = part
        self.cfg = cfg
        self.p = part.p
        self.n = part.n
        self.rng = np.random.default_rng(cfg.seed)
        self.x0 = (np.full(self.n, 1.0 / self.n) if x0 is None
                   else np.asarray(x0, dtype=np.float64))
        self.check_operator = check_operator

        speeds = cfg.ue_speed if cfg.ue_speed is not None else [1.0] * self.p
        assert len(speeds) == self.p
        self._compute_time = [
            operator.block_work(i) / (cfg.base_flops_rate * speeds[i])
            for i in range(self.p)
        ]

    # -- clock / network models ------------------------------------------
    def _iter_duration(self, i: int) -> float:
        j = self.rng.lognormal(mean=0.0, sigma=self.cfg.jitter_sigma)
        return self._compute_time[i] * j

    def _frag_bytes(self, i: int) -> int:
        return int(self.part.sizes()[i]) * self.cfg.bytes_per_entry

    def _make_plan(self):
        cfg = self.cfg
        thresh = cfg.sparsify_thresh if cfg.sparsify_thresh > 0 else cfg.tol
        return make_plan(cfg.comm_policy, self.p,
                         cancel_limit=cfg.adaptive_cancel_limit,
                         max_backoff=cfg.adaptive_max_backoff,
                         thresh=thresh,
                         refresh_every=cfg.sparsify_refresh_every,
                         top_k=cfg.sparsify_top_k)

    # -- main loop ----------------------------------------------------------
    def run(self) -> AsyncResult:
        cfg, p, n = self.cfg, self.p, self.n
        part = self.part

        # runtime substrate: per-UE shard state, exchange plan, Fig. 1 driver
        shards = [ShardState.create(i, part, self.x0) for i in range(p)]
        plan = self._make_plan()
        driver = _runtime_driver.TerminationDriver(
            p, pc_max_compute=cfg.pc_max_compute,
            pc_max_monitor=cfg.pc_max_monitor)

        iters = np.zeros(p, dtype=np.int64)
        local_conv_iter = np.full(p, -1, dtype=np.int64)
        local_conv_time = np.full(p, np.inf)
        imports = np.zeros((p, p), dtype=np.int64)
        attempts = np.zeros((p, p), dtype=np.int64)
        max_staleness = 0
        # unsent residual mass per (src, dst) pair (sparsified targeting);
        # an upper bound on ||frag_now - frag_last_sent||_1 by triangle ineq.
        pending_mass = np.zeros((p, p), dtype=np.float64)

        # message-handling time accrued on each UE's compute thread since its
        # last iteration (serialize on send, deserialize on import)
        handling = np.zeros(p, dtype=np.float64)

        medium_free = 0.0  # shared-medium FIFO
        events: list = []  # (time, seq, kind, payload)
        seq = 0

        def push(t, kind, payload):
            nonlocal seq
            heapq.heappush(events, (t, seq, kind, payload))
            seq += 1

        def send(t, src, dst, kind, payload, nbytes):
            """Route a message through the shared medium. Returns True if
            the send was accepted (not canceled)."""
            nonlocal medium_free
            start = max(t, medium_free)
            if (cfg.cancel_window is not None
                    and kind == "data"
                    and start - t > cfg.cancel_window):
                return False  # canceled: queueing delay exceeded the window
            medium_free = start + nbytes / cfg.bandwidth
            # small random propagation jitter decorrelates arrival order
            jit = cfg.msg_latency * (1.0 + self.rng.random())
            push(medium_free + jit, kind, (src, dst, payload))
            return True

        # bootstrap: all UEs start computing at t=0
        for i in range(p):
            push(self._iter_duration(i), "iter", i)

        stop_time = np.inf
        pending_stop_sent = False

        # ranking-aware termination state
        last_asm = None
        rank_stable = 0
        rank_stop_time = np.nan
        if cfg.rank_stop_k:
            push(cfg.rank_stop_interval, "assemble", None)

        def assemble_now():
            xa = np.empty(n)
            for j in range(p):
                sj, ej = part.block(j)
                xa[sj:ej] = shards[j].view[sj:ej]
            return xa

        while events:
            t, _, kind, payload = heapq.heappop(events)

            if kind == "iter":
                i = payload
                sh = shards[i]
                if sh.stopped:
                    continue
                s, e = part.block(i)
                old_frag = sh.fragment().copy()
                new_frag = self.opr.update_block(i, sh.view)
                version = sh.publish(new_frag)
                iters[i] = sh.iters
                delta_abs = np.abs(new_frag - old_frag)
                pending_mass[i, :] += float(delta_abs.sum())

                locally_conv = _resid(new_frag - old_frag, cfg.norm) < cfg.tol
                if locally_conv and local_conv_iter[i] < 0:
                    local_conv_iter[i] = iters[i]
                    local_conv_time[i] = t
                elif not locally_conv:
                    local_conv_iter[i] = -1
                    local_conv_time[i] = np.inf

                # Fig. 1 computing-UE machine (message rendering)
                msg = driver.ue_step(i, locally_conv)
                if msg is not None:
                    send(t, i, -1, "ctrl", msg, cfg.ctrl_bytes)

                # data sends to peers (random target order per iteration —
                # a fixed order lets low-id receivers capture the medium)
                targets = self.rng.permutation(p)
                for d in targets:
                    d = int(d)
                    if d == i:
                        continue
                    if not plan.wants(i, d, iters[i]):
                        continue
                    if not plan.gate_mass(i, d, iters[i],
                                          pending_mass[i, d]):
                        continue
                    attempts[i, d] += 1
                    # mass-gated sparsified sends ship only the top-k rows
                    # by this iteration's |delta| ((idx, value) pairs);
                    # forced refreshes — the bounded-delay guarantee —
                    # always ship the full fragment
                    rows_l = None
                    if not plan.refresh_due(i, d, iters[i]):
                        rows_l = plan.payload_rows(delta_abs, i, d)
                    if rows_l is None:
                        nbytes = self._frag_bytes(i)
                        payload = ("full", new_frag.copy(), version, s, e, i)
                    else:
                        nbytes = int(rows_l.size) * (cfg.bytes_per_entry + 4)
                        payload = ("rows", rows_l + s,
                                   new_frag[rows_l].copy(), version, i)
                    # serialize cost is paid whether or not the send later
                    # cancels (the buffer is built before the pool submit)
                    handling[i] += nbytes * cfg.send_cost_per_byte
                    ok = send(t, i, d, "data", payload, nbytes)
                    plan.on_result(i, d, ok)
                    if ok:
                        plan.note_sent(i, d, iters[i], full=rows_l is None)
                        if rows_l is None:
                            pending_mass[i, d] = 0.0
                        else:
                            # only the shipped rows' mass was communicated
                            pending_mass[i, d] = max(
                                0.0, pending_mass[i, d]
                                - float(delta_abs[rows_l].sum()))

                if iters[i] < cfg.max_iters:
                    dur = (self._iter_duration(i) + cfg.iter_overhead
                           + handling[i])
                    handling[i] = 0.0
                    push(t + dur, "iter", i)

            elif kind == "data":
                # version bookkeeping is keyed by the fragment OWNER (ring
                # relays deliver fragments the message sender does not own)
                src, dst, body = payload
                sh = shards[dst]
                if sh.stopped:
                    continue
                if body[0] == "rows":
                    # sparsified partial payload: refresh only the shipped
                    # rows (the plan's forced full refresh bounds how long
                    # the others stay stale)
                    _, rows_g, vals, version, owner = body
                    if sh.import_rows(owner, rows_g, vals, version):
                        lag = int(shards[owner].produced - version)
                        max_staleness = max(max_staleness, lag)
                        imports[dst, owner] += 1
                        handling[dst] += rows_g.size \
                            * (cfg.bytes_per_entry + 4) \
                            * cfg.recv_cost_per_byte
                    continue
                _, frag, version, s, e, owner = body
                if sh.import_fragment(owner, frag, version, s, e):
                    lag = int(shards[owner].produced - version)
                    max_staleness = max(max_staleness, lag)
                    imports[dst, owner] += 1
                    handling[dst] += (e - s) * cfg.bytes_per_entry \
                        * cfg.recv_cost_per_byte
                    # Ring relay: a freshly-accepted fragment is forwarded one
                    # hop, so each version circulates the ring once (<= p-1
                    # hops) and staleness stays O(p) without all-to-all sends.
                    if plan.name == "ring":
                        nxt = (dst + 1) % p
                        if nxt != owner:
                            send(t, dst, nxt, "data",
                                 ("full", frag.copy(), version, s, e, owner),
                                 self._frag_bytes(owner))

            elif kind == "assemble":
                xa = assemble_now()
                if last_asm is not None:
                    k = cfg.rank_stop_k
                    top_new = np.argsort(-xa)[:k]
                    top_old = np.argsort(-last_asm)[:k]
                    union = np.union1d(top_new, top_old)
                    import scipy.stats as _st
                    tau, _ = _st.kendalltau(xa[union], last_asm[union])
                    if np.isfinite(tau) and tau >= cfg.rank_stop_tau:
                        rank_stable += 1
                    else:
                        rank_stable = 0
                    if (rank_stable >= cfg.rank_stop_patience
                            and not pending_stop_sent):
                        pending_stop_sent = True
                        rank_stop_time = t
                        for d in range(p):
                            send(t, -1, d, "stop", None, cfg.ctrl_bytes)
                last_asm = xa
                if not pending_stop_sent:
                    push(t + cfg.rank_stop_interval, "assemble", None)

            elif kind == "ctrl":
                src, _, msg = payload
                issue_stop = driver.monitor_recv(src, msg)
                if issue_stop and not pending_stop_sent:
                    pending_stop_sent = True
                    for d in range(p):
                        send(t, -1, d, "stop", None, cfg.ctrl_bytes)

            elif kind == "stop":
                _, d, _ = payload
                shards[d].stopped = True
                driver.stop_shard(d)
                if all(sh.stopped for sh in shards):
                    stop_time = t
                    break

        # assemble the final vector from each owner's freshest fragment
        x = np.empty(n, dtype=np.float64)
        for i in range(p):
            s, e = part.block(i)
            x[s:e] = shards[i].view[s:e]
        norm1 = x.sum()
        if self.cfg.normalize and norm1 > 0:
            x_assembled = x / norm1  # power form converges up to scale [21]
        else:
            x_assembled = x

        resid_l1 = resid_inf = np.nan
        if self.check_operator is not None:
            y = self.check_operator.apply_numpy(x_assembled)
            resid_l1 = float(np.abs(y - x_assembled).sum())
            resid_inf = float(np.abs(y - x_assembled).max())

        # UEs that were mid-divergence when STOP arrived (the race the
        # persistence counters mitigate): credit them with the stop time.
        final_stop = float(stop_time if np.isfinite(stop_time)
                           else local_conv_time[np.isfinite(local_conv_time)].max()
                           if np.isfinite(local_conv_time).any() else 0.0)
        local_conv_time = np.where(np.isfinite(local_conv_time),
                                   local_conv_time, final_stop)
        local_conv_iter = np.where(local_conv_iter >= 0, local_conv_iter,
                                   iters)

        expected = np.maximum(iters[None, :].repeat(p, 0), 1)  # sender iters
        with np.errstate(divide="ignore", invalid="ignore"):
            pct = imports / expected
        off_diag = ~np.eye(p, dtype=bool)
        completed_pct = np.array([
            100.0 * pct[r][off_diag[r]].mean() for r in range(p)
        ])

        return AsyncResult(
            p=p, iters=iters, local_conv_iter=local_conv_iter,
            local_conv_time=local_conv_time,
            stop_time=float(stop_time if np.isfinite(stop_time) else
                            local_conv_time.max()),
            imports=imports, attempts=attempts,
            completed_import_pct=completed_pct,
            x=x_assembled, global_resid_l1=resid_l1,
            global_resid_inf=resid_inf, max_staleness=max_staleness,
            rank_stop_time=float(rank_stop_time),
        )

    # -- synchronous baseline ------------------------------------------------
    def run_sync(self) -> SyncResult:
        """Barrier-synchronous run under the same clock/network models.

        Per iteration: all UEs compute (barrier waits for the slowest), then
        the all-to-all fragment exchange is serialized over the shared
        medium (p*(p-1) messages), plus a barrier overhead.
        """
        cfg, p, n = self.cfg, self.p, self.n
        part = self.part
        x = self.x0.copy()
        t = 0.0
        total_bytes = sum(self._frag_bytes(i) for i in range(p)) * (p - 1)
        exchange = total_bytes / cfg.bandwidth + 2 * cfg.msg_latency

        # per-iteration serialize/deserialize on the slowest UE
        handling = max(
            (p - 1) * self._frag_bytes(i) * cfg.send_cost_per_byte
            + sum(self._frag_bytes(j) for j in range(p) if j != i)
            * cfg.recv_cost_per_byte
            for i in range(p))

        iters = 0
        while iters < cfg.max_iters:
            compute = max(self._iter_duration(i) for i in range(p))
            y = np.empty_like(x)
            for i in range(p):
                s, e = part.block(i)
                y[s:e] = self.opr.update_block(i, x)
            iters += 1
            t += compute + exchange + handling + cfg.barrier_overhead
            conv = _resid(y - x, cfg.norm) < cfg.tol
            x = y
            if conv:
                break

        norm1 = x.sum()
        x_out = x / norm1 if (self.cfg.normalize and norm1 > 0) else x
        resid_l1 = resid_inf = np.nan
        if self.check_operator is not None:
            gy = self.check_operator.apply_numpy(x_out)
            resid_l1 = float(np.abs(gy - x_out).sum())
            resid_inf = float(np.abs(gy - x_out).max())
        return SyncResult(p=p, iters=iters, time=t, x=x_out,
                          global_resid_l1=resid_l1, global_resid_inf=resid_inf)
