"""PageRank solvers (paper §3): synchronous power method (eq. 4) and the
linear-system Jacobi/Richardson iteration derived from eq. (2), in JAX.

These are the single-program (device-side) solvers; the asynchronous
counterparts live in core.des (faithful message-level simulation) and
core.spmd (TPU-native bounded-staleness shard_map flavor).

The per-iteration operator apply is delegated to a pluggable backend
(core.backend): `segment_sum` (default) or `bsr_pallas` (hub-split block-CSR
— the MXU kernel on TPU). Both solvers accept (n, nv) teleport/initial
stacks, solving nv personalized PageRank problems in one fused loop.
"""
from __future__ import annotations

import contextlib
import dataclasses
from functools import partial
from typing import Optional, Union

import numpy as np
import jax
import jax.numpy as jnp

from ..graph.google import GoogleOperator
from .backend import (BackendSpec, BackendMeta, as_spec, prepare,
                      from_layout, google_apply, l1_residual)


@dataclasses.dataclass
class SolveResult:
    x: np.ndarray                 # (n,) or (n, nv) normalized iterate(s)
    iters: int
    resid_l1: float               # max over lanes
    resid_per_vec: Optional[np.ndarray] = None  # (nv,) when nv > 1


@partial(jax.jit, static_argnames=("meta", "linear", "tol", "max_iters"))
def _solve_jit(dev: dict, x0: jax.Array, *, meta: BackendMeta, linear: bool,
               tol: float, max_iters: int):
    """Fused fixed-point loop: the iterate never leaves the backend layout
    (for bsr_pallas that is the padded (nbr, bm, nv) block layout — no
    repacking between iterations)."""
    def cond(state):
        _, resid, it = state
        return jnp.logical_and(jnp.max(resid) > tol, it < max_iters)

    def body(state):
        x, _, it = state
        y = google_apply(meta, dev, x, linear)
        resid = l1_residual(y, x)
        return y, resid, it + 1

    resid0 = jnp.full((meta.nv,), jnp.inf, x0.dtype)
    state = (x0, resid0, jnp.asarray(0))
    x, resid, iters = jax.lax.while_loop(cond, body, state)
    return x, resid, iters


def solve_power(op: GoogleOperator, x0: Optional[np.ndarray] = None,
                tol: float = 1e-9, max_iters: int = 1000,
                dtype=jnp.float64,
                backend: Union[str, BackendSpec] = "segment_sum",
                v: Optional[np.ndarray] = None,
                reorder: Optional[str] = None) -> SolveResult:
    """Normalization-free power method x <- G x (eq. 4).

    No per-step normalization is needed: G is column-stochastic so ||x||_1
    is invariant (paper §3) and there is no over/underflow risk.

    `v`/`x0` may be (n, nv) stacks — nv personalized PageRank problems share
    every operator load. `backend="bsr_pallas"` runs the hub-split BSR path
    (float32; L1 residuals floor near 1e-7). `reorder` ("rcm" | "indeg")
    solves in a block-densifying page permutation and maps the answer back.
    """
    return _solve(op, x0, tol, max_iters, linear=False, dtype=dtype,
                  backend=backend, v=v, reorder=reorder)


def solve_linear(op: GoogleOperator, x0: Optional[np.ndarray] = None,
                 tol: float = 1e-9, max_iters: int = 1000,
                 dtype=jnp.float64,
                 backend: Union[str, BackendSpec] = "segment_sum",
                 v: Optional[np.ndarray] = None,
                 reorder: Optional[str] = None) -> SolveResult:
    """Jacobi/Richardson on (I - R) x = b (eq. 2 / eq. 7 sync form)."""
    return _solve(op, x0, tol, max_iters, linear=True, dtype=dtype,
                  backend=backend, v=v, reorder=reorder)


def _reordered(op: GoogleOperator, method: str):
    """Memoized (reordered op, perm) so repeated solves do not re-permute
    the graph or re-pack its BSR blocks."""
    from ..graph.reorder import reorder_operator
    cache = op._cache()
    key = ("reorder", method)
    if key not in cache:
        cache[key] = reorder_operator(op, method)
    return cache[key]


def _solve(op, x0, tol, max_iters, linear, dtype, backend="segment_sum",
           v=None, reorder=None) -> SolveResult:
    spec = as_spec(backend)
    squeeze = ((x0 is None or np.ndim(x0) == 1)
               and (v is None or np.ndim(v) == 1)
               and (v is not None or op.v is None or np.ndim(op.v) == 1))

    perm = None
    if reorder is not None:
        op, perm = _reordered(op, reorder)
        if v is not None:
            v = np.asarray(v, dtype=np.float64)
            vp = np.empty_like(v)
            vp[perm] = v
            v = vp
        if x0 is not None:
            x0 = np.asarray(x0, dtype=np.float64)
            xp = np.empty_like(x0)
            xp[perm] = x0
            x0 = xp

    # scope x64 to this solve — flipping the global flag poisons later
    # bf16/f32 model code in the same process. The bsr path is float32
    # end to end, so it never needs the x64 scope.
    use_x64 = dtype == jnp.float64 and spec.name == "segment_sum"
    ctx = jax.experimental.enable_x64() if use_x64 else contextlib.nullcontext()
    with ctx:
        dev, meta, x0_dev = prepare(op, spec, dtype=dtype, v=v, x0=x0)
        x_dev, resid, iters = _solve_jit(dev, x0_dev, meta=meta,
                                         linear=linear, tol=tol,
                                         max_iters=max_iters)
        x = from_layout(meta, x_dev)
        resid = np.asarray(resid, dtype=np.float64)

    if perm is not None:
        x = x[perm]
    s = x.sum(axis=0)
    x = np.where(s > 0, x / np.where(s > 0, s, 1.0), x)
    nv = x.shape[1]
    if squeeze and nv == 1:
        x = x[:, 0]
    return SolveResult(x=x, iters=int(iters), resid_l1=float(resid.max()),
                       resid_per_vec=resid if nv > 1 else None)


def rank_of(x: np.ndarray) -> np.ndarray:
    """Page ranking (descending PageRank value) — what actually matters for
    retrieval (paper §5.2: 'what is important are not the accurate values
    ... but their relative ranking')."""
    return np.argsort(-x, kind="stable")


def kendall_tau_topk(x: np.ndarray, y: np.ndarray, k: int = 1000) -> float:
    """Kendall-tau-b between two rankings restricted to the union of their
    top-k pages. Quantifies the paper's open question about relaxed
    thresholds vs rank quality."""
    import scipy.stats as st
    top = np.union1d(rank_of(x)[:k], rank_of(y)[:k])
    tau, _ = st.kendalltau(x[top], y[top])
    return float(tau)
