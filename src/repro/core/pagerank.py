"""PageRank solvers (paper §3): synchronous power method (eq. 4) and the
linear-system Jacobi/Richardson iteration derived from eq. (2), in JAX.

These are the single-program (device-side) solvers; the asynchronous
counterparts live in core.des (faithful message-level simulation) and
core.spmd (TPU-native bounded-staleness shard_map flavor).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from ..graph.google import GoogleOperator
from ..graph.csr import pt_matvec


@dataclasses.dataclass
class SolveResult:
    x: np.ndarray
    iters: int
    resid_l1: float


def _google_apply(dev: dict, x: jax.Array, alpha: float, n: int,
                  linear: bool) -> jax.Array:
    y = alpha * pt_matvec(dev, x, n)
    dangling_mass = jnp.sum(jnp.where(dev["dangling"], x, 0.0))
    y = y + alpha * dangling_mass / n
    if linear:
        y = y + (1.0 - alpha) * dev["v"]
    else:
        y = y + (1.0 - alpha) * jnp.sum(x) * dev["v"]
    return y


@partial(jax.jit, static_argnames=("n", "alpha", "linear", "tol", "max_iters"))
def _solve_jit(dev: dict, x0: jax.Array, *, n: int, alpha: float,
               linear: bool, tol: float, max_iters: int):
    def cond(state):
        _, resid, it = state
        return jnp.logical_and(resid > tol, it < max_iters)

    def body(state):
        x, _, it = state
        y = _google_apply(dev, x, alpha, n, linear)
        resid = jnp.sum(jnp.abs(y - x))
        return y, resid, it + 1

    x0 = x0.astype(dev["v"].dtype)
    state = (x0, jnp.asarray(jnp.inf, dev["v"].dtype), jnp.asarray(0))
    x, resid, iters = jax.lax.while_loop(cond, body, state)
    return x, resid, iters


def solve_power(op: GoogleOperator, x0: Optional[np.ndarray] = None,
                tol: float = 1e-9, max_iters: int = 1000,
                dtype=jnp.float64) -> SolveResult:
    """Normalization-free power method x <- G x (eq. 4).

    No per-step normalization is needed: G is column-stochastic so ||x||_1
    is invariant (paper §3) and there is no over/underflow risk.
    """
    return _solve(op, x0, tol, max_iters, linear=False, dtype=dtype)


def solve_linear(op: GoogleOperator, x0: Optional[np.ndarray] = None,
                 tol: float = 1e-9, max_iters: int = 1000,
                 dtype=jnp.float64) -> SolveResult:
    """Jacobi/Richardson on (I - R) x = b (eq. 2 / eq. 7 sync form)."""
    return _solve(op, x0, tol, max_iters, linear=True, dtype=dtype)


def _solve(op, x0, tol, max_iters, linear, dtype) -> SolveResult:
    import contextlib
    # scope x64 to this solve — flipping the global flag poisons later
    # bf16/f32 model code in the same process
    ctx = (jax.experimental.enable_x64() if dtype == jnp.float64
           else contextlib.nullcontext())
    with ctx:
        n = op.n
        dev = op.device_arrays(dtype=dtype)
        if x0 is None:
            x0 = jnp.full((n,), 1.0 / n, dtype=dtype)
        else:
            x0 = jnp.asarray(x0, dtype=dtype)
        x, resid, iters = _solve_jit(dev, x0, n=n, alpha=float(op.alpha),
                                     linear=linear, tol=tol,
                                     max_iters=max_iters)
    x = np.asarray(x, dtype=np.float64)
    s = x.sum()
    if s > 0:
        x = x / s
    return SolveResult(x=x, iters=int(iters), resid_l1=float(resid))


def rank_of(x: np.ndarray) -> np.ndarray:
    """Page ranking (descending PageRank value) — what actually matters for
    retrieval (paper §5.2: 'what is important are not the accurate values
    ... but their relative ranking')."""
    return np.argsort(-x, kind="stable")


def kendall_tau_topk(x: np.ndarray, y: np.ndarray, k: int = 1000) -> float:
    """Kendall-tau-b between two rankings restricted to the union of their
    top-k pages. Quantifies the paper's open question about relaxed
    thresholds vs rank quality."""
    import scipy.stats as st
    top = np.union1d(rank_of(x)[:k], rank_of(y)[:k])
    tau, _ = st.kendalltau(x[top], y[top])
    return float(tau)
