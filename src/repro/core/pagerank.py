"""PageRank solvers (paper §3): synchronous power method (eq. 4) and the
linear-system Jacobi/Richardson iteration derived from eq. (2), in JAX.

These are the single-program (device-side) solvers; the asynchronous
counterparts live in core.des (faithful message-level simulation) and
core.spmd (TPU-native bounded-staleness shard_map flavor).

The per-iteration operator apply is delegated to a pluggable backend
(core.backend): `segment_sum` (default) or `bsr_pallas` (hub-split block-CSR
— the MXU kernel on TPU). Both solvers accept (n, nv) teleport/initial
stacks, solving nv personalized PageRank problems in one fused loop.
"""
from __future__ import annotations

import contextlib
import dataclasses
from functools import partial
from typing import Optional, Union

import numpy as np
import jax
import jax.numpy as jnp

from ..graph.google import GoogleOperator
from .backend import (BackendSpec, BackendMeta, as_lane_tol, as_spec,
                      prepare, from_layout, google_apply, l1_residual,
                      take_lanes)


@dataclasses.dataclass
class SolveResult:
    x: np.ndarray                 # (n,) or (n, nv) normalized iterate(s)
    iters: int
    resid_l1: float               # max over lanes
    resid_per_vec: Optional[np.ndarray] = None  # (nv,) when nv > 1
    lane_iters: Optional[np.ndarray] = None     # (nv,) iterations per lane
                                                # (differs under freezing)


@partial(jax.jit, static_argnames=("meta", "linear", "max_iters"))
def _solve_jit(dev: dict, x0: jax.Array, tol: jax.Array, *,
               meta: BackendMeta, linear: bool, max_iters: int):
    """Fused fixed-point loop: the iterate never leaves the backend layout
    (for bsr_pallas that is the padded (nbr, bm, nv) block layout — no
    repacking between iterations).  `tol` is a traced (nv,) per-lane
    residual threshold (mixed-tol query batches share one compiled loop;
    a scalar tol also no longer triggers a recompile per value)."""
    def cond(state):
        _, resid, it = state
        return jnp.logical_and(jnp.any(resid > tol), it < max_iters)

    def body(state):
        x, _, it = state
        y = google_apply(meta, dev, x, linear)
        resid = l1_residual(y, x)
        return y, resid, it + 1

    resid0 = jnp.full((meta.nv,), jnp.inf, x0.dtype)
    state = (x0, resid0, jnp.asarray(0))
    x, resid, iters = jax.lax.while_loop(cond, body, state)
    return x, resid, iters


def _pow2(k: int) -> int:
    return 1 << max(k - 1, 0).bit_length()


# recheck cadences the adaptive driver may pick — `max_iters` is a static
# jit arg, so arbitrary chunk lengths would each compile a fresh fused
# loop; a pow2 menu bounds that axis to 6 entries shared across solves
_CHUNK_MENU = (8, 16, 32, 64, 128, 256)


def _adapt_chunk(prev_resid, resid, it: int, tol,
                 fallback: int) -> int:
    """Next recheck cadence from the observed per-lane convergence spread.

    Each surviving lane's geometric decay rate over the last chunk
    extrapolates to a predicted iterations-to-tol; the next host recheck
    lands just past the *fastest* survivor's predicted crossing — that is
    the earliest moment a freeze (and possibly a pow2 compaction) can
    pay.  Tightly-clustered lanes thus get long chunks (few host syncs),
    a wide spread gets short ones (fast lanes shed early).  `tol` may be
    a scalar or the survivors' per-lane threshold array.
    """
    with np.errstate(divide="ignore", invalid="ignore", over="ignore"):
        rate = (resid / prev_resid) ** (1.0 / max(it, 1))
        need = np.log(tol / resid) / np.log(rate)
    need = need[np.isfinite(need) & (need > 0)]
    if need.size == 0:              # stalled / non-contracting estimates
        return fallback
    k = 1.25 * float(need.min()) + 1.0   # margin: rates drift chunk-to-chunk
    for c in _CHUNK_MENU:
        if c >= k:
            return c
    return _CHUNK_MENU[-1]


def _solve_frozen(dev, x_dev, meta: BackendMeta, linear: bool,
                  tol: np.ndarray, max_iters: int, chunk):
    """Chunked driver that freezes converged lanes out of the fused apply.

    The fused while_loop only ever guarantees each lane's residual <= tol
    (it stops at max-over-lanes), so freezing a lane once its residual
    crosses tol preserves the solver contract exactly — fast lanes just
    stop paying for the slowest one.  Lanes are compacted at power-of-two
    stack widths (padding duplicates an active lane), bounding recompiles
    of the fused loop to log2(nv).

    `chunk` is the host recheck cadence: an int pins a fixed count, the
    default ``"auto"`` adapts it to the observed per-lane iteration
    spread (see `_adapt_chunk`) — the first chunk is a fixed probe, every
    later one is scheduled at the fastest survivor's predicted tol
    crossing.
    """
    nv = meta.nv
    n = meta.n
    adaptive = chunk == "auto"
    cur = 32 if adaptive else max(int(chunk), 1)
    x_out = np.empty((n, nv))
    resid_out = np.full(nv, np.inf)
    lane_iters = np.zeros(nv, dtype=np.int64)
    active = np.arange(nv)          # lane ids at stack positions 0..k-1
    width = _pow2(nv)
    stack_tol = tol.copy()          # per-lane threshold at stack positions
    if width > nv:
        pad = np.concatenate([np.arange(nv),
                              np.zeros(width - nv, np.int64)])
        dev, meta, x_dev = take_lanes(meta, dev, x_dev, pad)
        stack_tol = stack_tol[pad]
    it_total = 0
    prev_resid = None               # survivors' residuals a chunk ago
    while True:
        step = min(cur, max_iters - it_total)
        x_dev, resid_dev, it = _solve_jit(
            dev, x_dev, jnp.asarray(stack_tol, x_dev.dtype), meta=meta,
            linear=linear, max_iters=step)
        it = int(it)
        it_total += it
        lane_iters[active] += it
        resid_np = np.asarray(resid_dev, dtype=np.float64)[:active.size]
        done = resid_np <= tol[active]
        if done.all() or it_total >= max_iters:
            x_np = from_layout(meta, x_dev)
            x_out[:, active] = x_np[:, :active.size]
            resid_out[active] = resid_np
            break
        if adaptive and it > 0:
            if prev_resid is not None:
                cur = _adapt_chunk(prev_resid[~done], resid_np[~done],
                                   it, tol[active][~done], cur)
            prev_resid = resid_np
        new_width = _pow2(int((~done).sum()))
        if done.any() and new_width < width:
            # freeze + compact: record the converged lanes, keep the rest
            frozen = active[done]
            x_np = from_layout(meta, x_dev)
            x_out[:, frozen] = x_np[:, :active.size][:, done]
            resid_out[frozen] = resid_np[done]
            keep_pos = np.flatnonzero(~done)
            active = active[~done]
            if prev_resid is not None:
                prev_resid = prev_resid[~done]
            idx = np.concatenate([keep_pos,
                                  np.full(new_width - keep_pos.size,
                                          keep_pos[0], np.int64)])
            dev, meta, x_dev = take_lanes(meta, dev, x_dev, idx)
            stack_tol = stack_tol[idx]
            width = new_width
        # lanes at <= tol that do not trigger a compaction stay in the
        # stack (their slots exist anyway) and keep improving for free
    return x_out, resid_out, it_total, lane_iters


def solve_power(op: GoogleOperator, x0: Optional[np.ndarray] = None,
                tol: float = 1e-9, max_iters: int = 1000,
                dtype=jnp.float64,
                backend: Union[str, BackendSpec] = "segment_sum",
                v: Optional[np.ndarray] = None,
                reorder: Optional[str] = None,
                freeze_lanes: Union[bool, str] = "auto",
                freeze_chunk: Union[int, str] = "auto") -> SolveResult:
    """Normalization-free power method x <- G x (eq. 4).

    No per-step normalization is needed: G is column-stochastic so ||x||_1
    is invariant (paper §3) and there is no over/underflow risk.

    `v`/`x0` may be (n, nv) stacks — nv personalized PageRank problems share
    every operator load. `backend="bsr_pallas"` runs the hub-split BSR path
    (float32; L1 residuals floor near 1e-7). `reorder` ("rcm" | "indeg")
    solves in a block-densifying page permutation and maps the answer back.
    `tol` may be a scalar or an (nv,) per-lane array (mixed-tolerance query
    batches: each lane stops — and under freezing drops out of the fused
    apply — at its own threshold).

    `freeze_lanes` masks already-converged lanes out of the fused apply
    (chunked driver, power-of-two lane compaction) so large teleport
    batches stop paying for their slowest lane; "auto" enables it from
    nv >= 8.  Every lane still stops at residual <= tol.  `freeze_chunk`
    sets the host recheck cadence: an int pins a fixed count, "auto"
    (default) adapts it to the observed per-lane iteration spread — the
    next recheck is scheduled at the fastest unconverged lane's predicted
    tol crossing, so clustered lanes pay few host syncs and spread-out
    lanes freeze early.
    """
    return _solve(op, x0, tol, max_iters, linear=False, dtype=dtype,
                  backend=backend, v=v, reorder=reorder,
                  freeze_lanes=freeze_lanes, freeze_chunk=freeze_chunk)


def solve_linear(op: GoogleOperator, x0: Optional[np.ndarray] = None,
                 tol: float = 1e-9, max_iters: int = 1000,
                 dtype=jnp.float64,
                 backend: Union[str, BackendSpec] = "segment_sum",
                 v: Optional[np.ndarray] = None,
                 reorder: Optional[str] = None,
                 freeze_lanes: Union[bool, str] = "auto",
                 freeze_chunk: Union[int, str] = "auto") -> SolveResult:
    """Jacobi/Richardson on (I - R) x = b (eq. 2 / eq. 7 sync form)."""
    return _solve(op, x0, tol, max_iters, linear=True, dtype=dtype,
                  backend=backend, v=v, reorder=reorder,
                  freeze_lanes=freeze_lanes, freeze_chunk=freeze_chunk)


def _reordered(op: GoogleOperator, method: str):
    """Memoized (reordered op, perm) so repeated solves do not re-permute
    the graph or re-pack its BSR blocks."""
    from ..graph.reorder import reorder_operator
    cache = op._cache()
    key = ("reorder", method)
    if key not in cache:
        cache[key] = reorder_operator(op, method)
    return cache[key]


def _solve(op, x0, tol, max_iters, linear, dtype, backend="segment_sum",
           v=None, reorder=None, freeze_lanes="auto",
           freeze_chunk="auto") -> SolveResult:
    spec = as_spec(backend)
    squeeze = ((x0 is None or np.ndim(x0) == 1)
               and (v is None or np.ndim(v) == 1)
               and (v is not None or op.v is None or np.ndim(op.v) == 1))

    perm = None
    if reorder is not None:
        op, perm = _reordered(op, reorder)
        if v is not None:
            v = np.asarray(v, dtype=np.float64)
            vp = np.empty_like(v)
            vp[perm] = v
            v = vp
        if x0 is not None:
            x0 = np.asarray(x0, dtype=np.float64)
            xp = np.empty_like(x0)
            xp[perm] = x0
            x0 = xp

    # scope x64 to this solve — flipping the global flag poisons later
    # bf16/f32 model code in the same process. The bsr path is float32
    # end to end, so it never needs the x64 scope.
    use_x64 = dtype == jnp.float64 and spec.name == "segment_sum"
    ctx = jax.experimental.enable_x64() if use_x64 else contextlib.nullcontext()
    with ctx:
        dev, meta, x0_dev = prepare(op, spec, dtype=dtype, v=v, x0=x0)
        tol_vec = as_lane_tol(tol, meta.nv)
        freeze = (meta.nv >= 8 if freeze_lanes == "auto"
                  else bool(freeze_lanes)) and meta.nv > 1
        if freeze:
            x, resid, iters, lane_iters = _solve_frozen(
                dev, x0_dev, meta, linear, tol_vec, max_iters, freeze_chunk)
        else:
            x_dev, resid, iters = _solve_jit(
                dev, x0_dev, jnp.asarray(tol_vec, x0_dev.dtype), meta=meta,
                linear=linear, max_iters=max_iters)
            x = from_layout(meta, x_dev)
            resid = np.asarray(resid, dtype=np.float64)
            iters = int(iters)
            lane_iters = np.full(meta.nv, iters, dtype=np.int64)

    if perm is not None:
        x = x[perm]
    s = x.sum(axis=0)
    x = np.where(s > 0, x / np.where(s > 0, s, 1.0), x)
    nv = x.shape[1]
    if squeeze and nv == 1:
        x = x[:, 0]
    return SolveResult(x=x, iters=int(iters), resid_l1=float(resid.max()),
                       resid_per_vec=resid if nv > 1 else None,
                       lane_iters=lane_iters)


def rank_of(x: np.ndarray) -> np.ndarray:
    """Page ranking (descending PageRank value) — what actually matters for
    retrieval (paper §5.2: 'what is important are not the accurate values
    ... but their relative ranking')."""
    return np.argsort(-x, kind="stable")


def kendall_tau_topk(x: np.ndarray, y: np.ndarray, k: int = 1000) -> float:
    """Kendall-tau-b between two rankings restricted to the union of their
    top-k pages. Quantifies the paper's open question about relaxed
    thresholds vs rank quality."""
    import scipy.stats as st
    top = np.union1d(rank_of(x)[:k], rank_of(y)[:k])
    tau, _ = st.kendalltau(x[top], y[top])
    return float(tau)
