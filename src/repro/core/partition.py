"""Row partitioning of the iterate across UEs.

The paper distributes blocks of consecutive ceil(n/p) rows (§5.2). We also
provide a balanced-nnz partitioner (equalizes per-UE SpMV work, which the
paper's uniform block scheme does not) — used by the beyond-paper
experiments.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from ..graph.csr import TransitionT


@dataclasses.dataclass(frozen=True)
class Partition:
    n: int
    starts: np.ndarray  # (p,) int64
    ends: np.ndarray    # (p,) int64

    @property
    def p(self) -> int:
        return len(self.starts)

    def block(self, i: int) -> Tuple[int, int]:
        return int(self.starts[i]), int(self.ends[i])

    def sizes(self) -> np.ndarray:
        return self.ends - self.starts

    def owner_of(self, row: int) -> int:
        return int(np.searchsorted(self.ends, row, side="right"))


def block_rows(n: int, p: int) -> Partition:
    """Paper's scheme: blocks of consecutive ceil(n/p) rows."""
    size = -(-n // p)
    starts = np.arange(p, dtype=np.int64) * size
    ends = np.minimum(starts + size, n)
    starts = np.minimum(starts, n)
    return Partition(n=n, starts=starts, ends=ends)


def balanced_nnz(pt: TransitionT, p: int) -> Partition:
    """Split rows of P^T so each UE gets ~nnz/p in-edges (work balance)."""
    nnz_per_row = np.diff(pt.indptr)
    cum = np.concatenate([[0], np.cumsum(nnz_per_row)])
    total = cum[-1]
    targets = (np.arange(1, p, dtype=np.float64) * total / p)
    cuts = np.searchsorted(cum, targets, side="left")
    bounds = np.concatenate([[0], cuts, [pt.n]]).astype(np.int64)
    # guarantee monotone non-decreasing bounds
    bounds = np.maximum.accumulate(bounds)
    return Partition(n=pt.n, starts=bounds[:-1], ends=bounds[1:])


def slice_transition(pt: TransitionT, part: Partition, i: int) -> dict:
    """Edge slice of P^T for UE i's rows, with row ids rebased to the block.

    The returned dict feeds graph.csr.pt_matvec_block; everything is numpy
    (the DES engine) — callers move to device as needed.
    """
    s, e = part.block(i)
    lo, hi = pt.indptr[s], pt.indptr[e]
    return dict(
        src=pt.src[lo:hi],
        weight=pt.weight[lo:hi],
        row_ids=(pt.row_ids[lo:hi] - s).astype(np.int32),
        block_size=int(e - s),
        row_offset=int(s),
    )
