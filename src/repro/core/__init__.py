"""Core: asynchronous iterative fixed-point computation (the paper's
contribution) — engine facade, DES + SPMD flavors, termination protocol."""
from .engine import AsyncFixedPoint
from .backend import BackendSpec, BACKENDS
from .des import AsyncDES, DESConfig, AsyncResult, SyncResult, \
    PageRankBlockOperator
from .partition import Partition, block_rows, balanced_nnz
from .pagerank import solve_power, solve_linear, SolveResult, rank_of, \
    kendall_tau_topk
from .spmd import solve_spmd, SPMDConfig, SPMDResult
from .termination import ComputingUEState, MonitorState, Msg, \
    CentralizedProtocol, TreeProtocol, TreeNodeState

__all__ = [
    "AsyncFixedPoint", "BackendSpec", "BACKENDS",
    "AsyncDES", "DESConfig", "AsyncResult", "SyncResult",
    "PageRankBlockOperator", "Partition", "block_rows", "balanced_nnz",
    "solve_power", "solve_linear", "SolveResult", "rank_of",
    "kendall_tau_topk", "solve_spmd", "SPMDConfig", "SPMDResult",
    "ComputingUEState", "MonitorState", "Msg", "CentralizedProtocol",
    "TreeProtocol", "TreeNodeState",
]
