"""AsyncFixedPoint — the public facade of the paper's contribution.

One object, three execution flavors:

  solve_sync()  : eq. (4) — synchronous power method / Jacobi on device.
  solve_des()   : eq. (5) — faithful asynchronous message-level simulation
                  (heterogeneous UEs, Fig. 1 termination, import accounting).
  solve_spmd()  : TPU-native bounded-staleness shard_map iteration with
                  sparsified collective schedules (the deployable form).

All three render the same substrate-independent cycle — ShardState /
LocalSolver / ExchangePlan / TerminationDriver — factored into
repro.runtime (see docs/runtime.md).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .des import AsyncDES, DESConfig, AsyncResult, SyncResult, \
    PageRankBlockOperator
from .partition import Partition, block_rows, balanced_nnz
from .pagerank import solve_power, solve_linear, SolveResult
from .spmd import solve_spmd, SPMDConfig, SPMDResult
from ..graph.google import GoogleOperator


@dataclasses.dataclass
class AsyncFixedPoint:
    op: GoogleOperator
    kind: str = "power"            # power (eq. 6) | linear (eq. 7)
    partition: str = "block"       # block (paper) | balanced_nnz
    backend: str = "segment_sum"   # segment_sum | bsr_pallas (see
                                   # docs/backends.md for the tradeoff)

    def make_partition(self, p: int) -> Partition:
        if self.partition == "balanced_nnz":
            return balanced_nnz(self.op.pt, p)
        return block_rows(self.op.n, p)

    def solve_sync(self, tol: float = 1e-9, max_iters: int = 1000,
                   dtype="float64", **kw) -> SolveResult:
        import jax.numpy as jnp
        dt = jnp.float64 if dtype == "float64" else jnp.float32
        fn = solve_power if self.kind == "power" else solve_linear
        return fn(self.op, tol=tol, max_iters=max_iters, dtype=dt,
                  backend=self.backend, **kw)

    def solve_des(self, p: int, cfg: Optional[DESConfig] = None
                  ) -> AsyncResult:
        cfg = cfg or DESConfig()
        part = self.make_partition(p)
        opr = PageRankBlockOperator(self.op, part, kind=self.kind,
                                    matvec=self._des_matvec())
        return AsyncDES(opr, part, cfg, check_operator=self.op).run()

    def solve_des_sync(self, p: int, cfg: Optional[DESConfig] = None
                       ) -> SyncResult:
        cfg = cfg or DESConfig()
        part = self.make_partition(p)
        opr = PageRankBlockOperator(self.op, part, kind=self.kind,
                                    matvec=self._des_matvec())
        return AsyncDES(opr, part, cfg, check_operator=self.op).run_sync()

    def solve_spmd(self, cfg: SPMDConfig) -> SPMDResult:
        cfg = dataclasses.replace(cfg, kind=self.kind,
                                  backend=self.backend)
        return solve_spmd(self.op, cfg)

    def _des_matvec(self) -> str:
        # the DES engine is host-side numpy/scipy; scipy's native BSR
        # matvec is the closest CPU analogue of the blocked device path
        return "bsr" if self.backend == "bsr_pallas" else "csr"
