from .flash_attention import flash_attention
from .ref import mha_ref
from .ops import attention
