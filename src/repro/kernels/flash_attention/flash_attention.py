"""Flash attention Pallas TPU kernel (prefill hot spot).

Online-softmax tiling: the (S x T) score matrix never materializes; per
(batch, head, q-block) we stream kv-blocks through VMEM keeping running
max/denominator/accumulator scratch. Causal q-blocks skip kv-blocks that
are entirely in the future (triangular grid pruning via pl.when).

GQA is handled in-kernel: the kv BlockSpec index_map divides the q-head
grid coordinate by the group size, so kv heads are never materialized per
q-head in HBM.

Block sizes default to (128, 128): MXU-aligned on the contraction and
lane dimensions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, bq: int, bk: int, nk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal pruning: skip kv blocks strictly in the future of this q block
    run = True
    if causal:
        run = ki * bk <= qi * bq + bq - 1

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        if causal:
            rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(cols <= rows, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_prev * alpha + p.sum(axis=-1)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _flush():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, H, S, D); k, v: (B, Hkv, T, D). Returns (B, H, S, D)."""
    B, H, S, D = q.shape
    _, Hkv, T, _ = k.shape
    assert H % Hkv == 0
    G = H // Hkv
    bq = min(block_q, S)
    bk = min(block_k, T)
    assert S % bq == 0 and T % bk == 0, (S, T, bq, bk)
    nq = S // bq
    nk = T // bk
    scale_v = (D ** -0.5) if scale is None else scale

    kernel = functools.partial(_kernel, scale=scale_v, causal=causal,
                               bq=bq, bk=bk, nk=nk)
    grid = (B, H, nq, nk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
