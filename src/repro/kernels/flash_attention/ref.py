"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax.numpy as jnp


def mha_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
            causal: bool = True, scale: float | None = None) -> jnp.ndarray:
    """q: (B, H, S, D); k, v: (B, Hkv, T, D) with H % Hkv == 0.

    Full-precision reference attention (f32 softmax)."""
    B, H, S, D = q.shape
    _, Hkv, T, _ = k.shape
    G = H // Hkv
    kq = jnp.repeat(k, G, axis=1)
    vq = jnp.repeat(v, G, axis=1)
    scale = (D ** -0.5) if scale is None else scale
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                   kq.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, T), dtype=bool), k=T - S)
        s = jnp.where(mask, s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhst,bhtd->bhsd", p, vq.astype(jnp.float32)
                      ).astype(q.dtype)
