"""Jit'd public wrapper for flash attention with a CPU-safe fallback.

On TPU (the target), `attention(...)` lowers to the Pallas kernel. On this
CPU container the kernel runs under interpret=True in tests; the production
model code path uses `chunked_attention_ref` (pure jnp, O(S * block) memory)
so dry-run lowering stays tractable at 32k/500k sequence lengths.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention
from .ref import mha_ref


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, scale: float | None = None,
              use_kernel: bool = True, interpret: bool = False) -> jax.Array:
    if use_kernel:
        return flash_attention(q, k, v, causal=causal, scale=scale,
                               interpret=interpret)
    return mha_ref(q, k, v, causal=causal, scale=scale)
