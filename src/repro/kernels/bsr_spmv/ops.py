"""Jit'd wrapper + host-side BSR construction for the SpMV kernel."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .bsr_spmv import bsr_spmv, DEFAULT_BM, DEFAULT_BN
from .ref import bsr_spmv_ref
from ...graph.csr import TransitionT


@dataclasses.dataclass(frozen=True)
class BSRMatrix:
    """Host container: block-CSR with a fixed blocks-per-row budget."""
    n_rows: int                 # logical (unpadded) rows
    n_cols: int
    bm: int
    bn: int
    blocks: np.ndarray          # (nbr, K, bm, bn) float32
    blk_cols: np.ndarray        # (nbr, K) int32
    fill_ratio: float           # nnz / dense-block capacity actually used

    @property
    def nbr(self) -> int:
        return self.blocks.shape[0]

    @property
    def K(self) -> int:
        return self.blocks.shape[1]

    def device(self) -> Tuple[jax.Array, jax.Array]:
        return jnp.asarray(self.blocks), jnp.asarray(self.blk_cols)


def build_bsr(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
              n_rows: int, n_cols: int, bm: int = DEFAULT_BM,
              bn: int = DEFAULT_BN, k_budget: Optional[int] = None
              ) -> BSRMatrix:
    """Pack COO triplets into the fixed-budget BSR layout.

    If a block-row holds more distinct nonzero block-columns than k_budget,
    the budget is raised to the max (the kernel needs a static K).
    """
    nbr = -(-n_rows // bm)
    nbc = -(-n_cols // bn)
    brow = rows // bm
    bcol = cols // bn
    key = brow.astype(np.int64) * nbc + bcol
    uniq, inv = np.unique(key, return_inverse=True)
    ub_row = (uniq // nbc).astype(np.int64)
    ub_col = (uniq % nbc).astype(np.int32)

    per_row = np.bincount(ub_row, minlength=nbr)
    K = int(per_row.max()) if k_budget is None else max(k_budget,
                                                        int(per_row.max()))
    K = max(K, 1)

    # slot of each unique block within its block-row
    order = np.argsort(ub_row, kind="stable")
    slot_sorted = np.arange(len(uniq)) - np.concatenate(
        [[0], np.cumsum(per_row)])[ub_row[order]]
    slot = np.empty(len(uniq), dtype=np.int64)
    slot[order] = slot_sorted

    est = nbr * K * bm * bn * 4
    if est > 8 << 30:
        raise MemoryError(
            f"BSR dense-block array would be {est/1e9:.1f} GB "
            f"(K={K}); use balanced partitioning or larger blocks")
    blocks = np.zeros((nbr, K, bm, bn), dtype=np.float32)
    blk_cols = np.zeros((nbr, K), dtype=np.int32)
    blk_cols[ub_row, slot] = ub_col

    # scatter values into the dense blocks
    b_of_edge = inv
    np.add.at(
        blocks,
        (ub_row[b_of_edge], slot[b_of_edge], rows % bm, cols % bn),
        vals.astype(np.float32),
    )
    fill = len(rows) / float(len(uniq) * bm * bn)
    return BSRMatrix(n_rows=n_rows, n_cols=n_cols, bm=bm, bn=bn,
                     blocks=blocks, blk_cols=blk_cols, fill_ratio=fill)


def bsr_from_transition(pt: TransitionT, bm: int = DEFAULT_BM,
                        bn: int = DEFAULT_BN) -> BSRMatrix:
    """BSR of P^T (rows = destination pages, cols = source pages)."""
    return build_bsr(rows=pt.row_ids.astype(np.int64),
                     cols=pt.src.astype(np.int64),
                     vals=np.asarray(pt.weight, dtype=np.float32),
                     n_rows=pt.n, n_cols=pt.n, bm=bm, bn=bn)


def pad_x(x: np.ndarray, n_cols: int, bn: int) -> np.ndarray:
    """(n, nv) or (n,) -> (nbc, bn, nv) padded block layout."""
    if x.ndim == 1:
        x = x[:, None]
    n, nv = x.shape
    nbc = -(-n_cols // bn)
    xp = np.zeros((nbc * bn, nv), dtype=x.dtype)
    xp[:n] = x
    return xp.reshape(nbc, bn, nv)


def unpad_y(y: np.ndarray, n_rows: int) -> np.ndarray:
    """(nbr, bm, nv) -> (n_rows, nv)."""
    nbr, bm, nv = y.shape
    return y.reshape(nbr * bm, nv)[:n_rows]


def spmv(bsr: BSRMatrix, x: jax.Array, interpret: bool = False,
         use_ref: bool = False) -> jax.Array:
    """y = PT @ x in the padded block layout (device arrays in/out)."""
    blocks, blk_cols = bsr.device()
    if use_ref:
        return bsr_spmv_ref(blocks, blk_cols, x)
    return bsr_spmv(blocks, blk_cols, x, interpret=interpret)
