"""Jit'd wrappers + host-side BSR construction for the SpMV kernel.

Two host containers:

  * BSRMatrix   — the kernel's fixed-budget block-CSR layout (every block-row
                  padded to K nonzero blocks).
  * HybridBSR   — solve-grade layout for real web graphs: rows whose in-links
                  span many block columns ("hub" pages, the in-degree tail)
                  are split out into a COO side structure evaluated with
                  gather + segment-sum, and only the site-local remainder is
                  blocked. Without the split, one hub row drives K up to the
                  full number of block columns and the dense-block array
                  explodes (50k-node power-law graph: K = nbc, ~10 GB; after
                  a 99th-percentile split: K ~ 43, ~0.3 GB).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .bsr_spmv import bsr_spmv, DEFAULT_BM, DEFAULT_BN
from .ref import bsr_spmv_ref
from ...graph.csr import TransitionT


@dataclasses.dataclass(frozen=True)
class BSRMatrix:
    """Host container: block-CSR with a fixed blocks-per-row budget."""
    n_rows: int                 # logical (unpadded) rows
    n_cols: int
    bm: int
    bn: int
    blocks: np.ndarray          # (nbr, K, bm, bn) float32
    blk_cols: np.ndarray        # (nbr, K) int32
    fill_ratio: float           # nnz / dense-block capacity actually used

    @property
    def nbr(self) -> int:
        return self.blocks.shape[0]

    @property
    def nbc(self) -> int:
        return -(-self.n_cols // self.bn)

    @property
    def K(self) -> int:
        return self.blocks.shape[1]

    def device(self) -> Tuple[jax.Array, jax.Array]:
        return jnp.asarray(self.blocks), jnp.asarray(self.blk_cols)


def _ravel_index(blocks, ub_row, slot, inv, rows, cols, bm, bn):
    K = blocks.shape[1]
    # one base offset per unique block (tiny array), then a single gather
    # per edge; bit-masked intra-block coordinates for power-of-two blocks
    base = (ub_row * K + slot) * (bm * bn)
    r = rows & (bm - 1) if (bm & (bm - 1)) == 0 else rows % bm
    c = cols & (bn - 1) if (bn & (bn - 1)) == 0 else cols % bn
    return base[inv] + r * bn + c


def _scatter_blocks_bincount(blocks, ub_row, slot, inv, rows, cols, vals,
                             bm, bn, unique_pairs):
    """Scatter COO values through a raveled index into the blocks buffer.

    unique_pairs=True (every (row, col) occurs once — guaranteed for edges
    coming out of CSRGraph/TransitionT): one vectorized fancy assignment,
    no per-element loop at all. Otherwise duplicates are accumulated with
    np.bincount over the *compacted* raveled-index domain (np.unique
    compresses the index space so bincount never allocates the full dense
    raster)."""
    flat = _ravel_index(blocks, ub_row, slot, inv, rows, cols, bm, bn)
    bf = blocks.reshape(-1)
    if unique_pairs:
        bf[flat] = np.asarray(vals, dtype=np.float32)
        return
    uniq_flat, inv2 = np.unique(flat, return_inverse=True)
    sums = np.bincount(inv2, weights=vals.astype(np.float64),
                       minlength=len(uniq_flat))
    bf[uniq_flat] = sums.astype(np.float32)


def _scatter_blocks_add_at(blocks, ub_row, slot, inv, rows, cols, vals,
                           bm, bn, unique_pairs):
    """The original np.add.at scatter — kept only as the micro-benchmark
    baseline (np.add.at with a 4-tuple fancy index is notoriously slow)."""
    np.add.at(
        blocks,
        (ub_row[inv], slot[inv], rows % bm, cols % bn),
        vals.astype(np.float32),
    )


def build_bsr(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
              n_rows: int, n_cols: int, bm: int = DEFAULT_BM,
              bn: int = DEFAULT_BN, k_budget: Optional[int] = None,
              scatter: str = "bincount",
              unique_pairs: bool = False) -> BSRMatrix:
    """Pack COO triplets into the fixed-budget BSR layout.

    If a block-row holds more distinct nonzero block-columns than k_budget,
    the budget is raised to the max (the kernel needs a static K).
    Set unique_pairs=True when no (row, col) repeats (graph edge lists) —
    the scatter then skips duplicate accumulation entirely.
    """
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    nbr = -(-n_rows // bm)
    nbc = -(-n_cols // bn)
    brow = rows // bm
    bcol = cols // bn
    key = brow * nbc + bcol
    uniq, inv = np.unique(key, return_inverse=True)
    ub_row = (uniq // nbc).astype(np.int64)
    ub_col = (uniq % nbc).astype(np.int32)

    per_row = np.bincount(ub_row, minlength=nbr)
    K = int(per_row.max()) if k_budget is None else max(k_budget,
                                                        int(per_row.max()))
    K = max(K, 1)

    # slot of each unique block within its block-row
    order = np.argsort(ub_row, kind="stable")
    slot_sorted = np.arange(len(uniq)) - np.concatenate(
        [[0], np.cumsum(per_row)])[ub_row[order]]
    slot = np.empty(len(uniq), dtype=np.int64)
    slot[order] = slot_sorted

    est = nbr * K * bm * bn * 4
    if est > 8 << 30:
        raise MemoryError(
            f"BSR dense-block array would be {est/1e9:.1f} GB "
            f"(K={K}); use build_hybrid_bsr (hub split), reordering, or "
            f"larger blocks")
    blocks = np.zeros((nbr, K, bm, bn), dtype=np.float32)
    blk_cols = np.zeros((nbr, K), dtype=np.int32)
    blk_cols[ub_row, slot] = ub_col

    scatter_fn = {"bincount": _scatter_blocks_bincount,
                  "add_at": _scatter_blocks_add_at}[scatter]
    scatter_fn(blocks, ub_row, slot, inv, rows, cols, vals, bm, bn,
               unique_pairs)
    # len(uniq) == 0 is reachable (hub split can route every edge to the
    # COO side); an all-zero-block BSR with fill 0 is the right answer
    fill = len(rows) / float(len(uniq) * bm * bn) if len(uniq) else 0.0
    return BSRMatrix(n_rows=n_rows, n_cols=n_cols, bm=bm, bn=bn,
                     blocks=blocks, blk_cols=blk_cols, fill_ratio=fill)


# --------------------------------------------------------------------------
# Hub-split hybrid layout (solve-grade)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class HybridBSR:
    """BSR over site-local mass + COO over hub rows (in-degree tail).

    The COO side is evaluated as gather + segment-sum over the *padded* row
    space, so a fused Google-apply can stay entirely in the kernel's
    (n_blocks, block, nv) layout.
    """
    bsr: BSRMatrix
    hub_rows: np.ndarray      # int32 (hub_nnz,) destination row of each edge
    hub_cols: np.ndarray      # int32 (hub_nnz,) source column
    hub_vals: np.ndarray      # float32 (hub_nnz,)
    hub_nnz_frac: float       # fraction of nnz routed through the COO side

    @property
    def n_rows(self) -> int:
        return self.bsr.n_rows

    @property
    def n_cols(self) -> int:
        return self.bsr.n_cols

    def device(self) -> dict:
        blocks, blk_cols = self.bsr.device()
        return dict(blocks=blocks, blk_cols=blk_cols,
                    hub_rows=jnp.asarray(self.hub_rows),
                    hub_cols=jnp.asarray(self.hub_cols),
                    hub_vals=jnp.asarray(self.hub_vals))


def build_hybrid_bsr(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
                     n_rows: int, n_cols: int, bm: int = DEFAULT_BM,
                     bn: int = DEFAULT_BN, hub_quantile: float = 0.99,
                     k_budget: Optional[int] = None,
                     scatter: str = "bincount",
                     unique_pairs: bool = False) -> HybridBSR:
    """Split rows above the `hub_quantile` of row-nnz into the COO side and
    block the remainder. hub_quantile=1.0 disables the split."""
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    vals = np.asarray(vals)
    row_nnz = np.bincount(rows, minlength=n_rows)
    if hub_quantile < 1.0 and len(rows):
        cut = np.quantile(row_nnz, hub_quantile)
        hub_mask_row = row_nnz > cut
    else:
        hub_mask_row = np.zeros(n_rows, dtype=bool)
    is_hub = hub_mask_row[rows]
    keep = ~is_hub
    bsr = build_bsr(rows[keep], cols[keep], vals[keep], n_rows, n_cols,
                    bm=bm, bn=bn, k_budget=k_budget, scatter=scatter,
                    unique_pairs=unique_pairs)
    return HybridBSR(
        bsr=bsr,
        hub_rows=rows[is_hub].astype(np.int32),
        hub_cols=cols[is_hub].astype(np.int32),
        hub_vals=vals[is_hub].astype(np.float32),
        hub_nnz_frac=float(is_hub.mean()) if len(rows) else 0.0,
    )


def bsr_from_transition(pt: TransitionT, bm: int = DEFAULT_BM,
                        bn: int = DEFAULT_BN) -> BSRMatrix:
    """BSR of P^T (rows = destination pages, cols = source pages)."""
    return build_bsr(rows=pt.row_ids.astype(np.int64),
                     cols=pt.src.astype(np.int64),
                     vals=np.asarray(pt.weight, dtype=np.float32),
                     n_rows=pt.n, n_cols=pt.n, bm=bm, bn=bn,
                     unique_pairs=True)


def hybrid_from_transition(pt: TransitionT, bm: int = DEFAULT_BM,
                           bn: int = DEFAULT_BN,
                           hub_quantile: float = 0.99) -> HybridBSR:
    """Solve-grade hybrid layout of P^T."""
    return build_hybrid_bsr(rows=pt.row_ids.astype(np.int64),
                            cols=pt.src.astype(np.int64),
                            vals=np.asarray(pt.weight, dtype=np.float32),
                            n_rows=pt.n, n_cols=pt.n, bm=bm, bn=bn,
                            hub_quantile=hub_quantile, unique_pairs=True)


def pad_x(x: np.ndarray, n_cols: int, bn: int) -> np.ndarray:
    """(n, nv) or (n,) -> (nbc, bn, nv) padded block layout."""
    if x.ndim == 1:
        x = x[:, None]
    n, nv = x.shape
    nbc = -(-n_cols // bn)
    xp = np.zeros((nbc * bn, nv), dtype=x.dtype)
    xp[:n] = x
    return xp.reshape(nbc, bn, nv)


def unpad_y(y: np.ndarray, n_rows: int) -> np.ndarray:
    """(nbr, bm, nv) -> (n_rows, nv)."""
    nbr, bm, nv = y.shape
    return y.reshape(nbr * bm, nv)[:n_rows]


IMPLS = ("auto", "pallas", "interpret", "ref")


def resolve_impl(impl: str = "auto") -> str:
    """Kernel-dispatch policy: "auto" picks the compiled Pallas kernel on a
    real TPU/GPU and interpret mode elsewhere (the faithful kernel
    semantics on hosts with no Mosaic backend); an explicit impl is passed
    through untouched — the kernel tests' override.  (The *solver* policy,
    which prefers the fast blocked-einsum oracle on CPU, lives in
    core.backend.BackendSpec.resolved() — same math, different speed
    trade.)"""
    if impl not in IMPLS:
        raise ValueError(f"unknown impl {impl!r}; expected one of {IMPLS}")
    if impl != "auto":
        return impl
    return "pallas" if jax.default_backend() in ("tpu", "gpu") \
        else "interpret"


def bsr_matvec(blocks: jax.Array, blk_cols: jax.Array, x: jax.Array,
               impl: str = "auto", accum: str = "f32") -> jax.Array:
    """Dispatch the block multiply: Pallas kernel, interpret mode, or the
    jnp blocked-einsum oracle (same math, XLA-compiled — the CPU path).
    impl="auto" resolves via `resolve_impl` (pallas on real TPU/GPU,
    interpret elsewhere).  `accum` selects the accumulation lane: "f32"
    (default), "kahan" (compensated summation — on the kernel paths a
    scratch-carried Kahan sum, on the ref path the f64-accumulate limit
    cast back to f32), or "f64" (ref path only: full f64 accumulate,
    result in x's dtype; the kernel paths render it as "kahan" — the MXU
    has no f64)."""
    impl = resolve_impl(impl)
    if impl == "pallas":
        return bsr_spmv(blocks, blk_cols, x, interpret=False,
                        accum="f32" if accum == "f32" else "kahan")
    if impl == "interpret":
        return bsr_spmv(blocks, blk_cols, x, interpret=True,
                        accum="f32" if accum == "f32" else "kahan")
    return bsr_spmv_ref(blocks, blk_cols, x, accum=accum)


def hybrid_matvec(dev: dict, x: jax.Array, impl: str = "ref",
                  accum: str = "f32") -> jax.Array:
    """y = PT @ x in the padded block layout for a HybridBSR device dict.

    x: (nbc, bn, nv) -> y: (nbr, bm, nv). The hub COO side is a gather +
    segment-sum over the padded row space, fused into the same jit scope
    (accumulated in the same lane as the block side: f64 when accum
    requests it and x64 is live).
    """
    y = bsr_matvec(dev["blocks"], dev["blk_cols"], x, impl=impl,
                   accum=accum)
    nbr, bm, nv = y.shape
    xf = x.reshape(-1, nv)
    if accum == "f32":
        contrib = dev["hub_vals"][:, None] * xf[dev["hub_cols"]]
    else:
        wide = jax.dtypes.canonicalize_dtype(jnp.float64)
        contrib = (dev["hub_vals"].astype(wide)[:, None]
                   * xf.astype(wide)[dev["hub_cols"]])
    hub = jax.ops.segment_sum(contrib, dev["hub_rows"],
                              num_segments=nbr * bm)
    return y + hub.reshape(nbr, bm, nv).astype(y.dtype)


def spmv(bsr: BSRMatrix, x: jax.Array, interpret: bool = False,
         use_ref: bool = False, impl: Optional[str] = None,
         accum: str = "f32") -> jax.Array:
    """y = PT @ x in the padded block layout (device arrays in/out).

    The historic boolean knobs (`interpret`/`use_ref`) are kept as the
    kernel tests' explicit override; pass `impl=` ("auto"/"pallas"/
    "interpret"/"ref") to go through the auto-detecting dispatch instead.
    """
    blocks, blk_cols = bsr.device()
    if impl is not None:
        return bsr_matvec(blocks, blk_cols, x, impl=impl, accum=accum)
    if use_ref:
        return bsr_spmv_ref(blocks, blk_cols, x, accum=accum)
    return bsr_spmv(blocks, blk_cols, x, interpret=interpret,
                    accum="f32" if accum == "f32" else "kahan")
