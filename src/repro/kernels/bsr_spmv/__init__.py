from .ops import (BSRMatrix, build_bsr, bsr_from_transition, pad_x, unpad_y,
                  spmv)
from .bsr_spmv import bsr_spmv
from .ref import bsr_spmv_ref
