from .ops import (BSRMatrix, HybridBSR, build_bsr, build_hybrid_bsr,
                  bsr_from_transition, hybrid_from_transition, pad_x,
                  unpad_y, spmv, bsr_matvec, hybrid_matvec, resolve_impl)
from .bsr_spmv import bsr_spmv, DEFAULT_BM, DEFAULT_BN
from .ref import bsr_spmv_ref
