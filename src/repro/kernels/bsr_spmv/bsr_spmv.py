"""Block-CSR SpMV Pallas TPU kernel — the paper's per-iteration hot spot.

Hardware adaptation (DESIGN.md §3): a GPU CSR SpMV is a gather-heavy,
warp-per-row pattern with no TPU analogue; the MXU wants dense 128x128
tiles. We therefore store P^T (or any G-block) as *block*-CSR with dense
(bm, bn) = (128, 128) blocks and give every block-row a fixed budget of K
nonzero blocks (padding with zero blocks keeps the grid static — XLA/Pallas
needs static shapes). Web graphs with strong intra-site locality put most
mass near the diagonal, so real K is small.

Kernel structure:
  grid = (n_block_rows, K); the x block consumed by grid step (i, k) is
  selected by the *scalar-prefetched* blk_cols[i, k] — Pallas loads it
  HBM->VMEM ahead of the MXU multiply. Accumulation over k happens in the
  output VMEM block (revisited across the K inner steps).

  x carries nv lanes (n_block_cols, bn, nv): multi-vector SpMV amortizes the
  block loads over several teleportation vectors — the paper's
  personalization use-case ([17]) — and gives the MXU a (128, 128) @
  (128, nv) shape instead of a mat-vec.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BM = 128
DEFAULT_BN = 128


def _kernel(blk_cols_ref, blocks_ref, x_ref, o_ref):
    """One (block-row i, slot k) step: o[i] += blocks[i,k] @ x[cols[i,k]]."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    blk = blocks_ref[0, 0]          # (bm, bn)
    xb = x_ref[0]                   # (bn, nv)
    o_ref[0] += jnp.dot(blk, xb, preferred_element_type=jnp.float32
                        ).astype(o_ref.dtype)


def _kernel_kahan(blk_cols_ref, blocks_ref, x_ref, o_ref, c_ref):
    """Compensated (Kahan) accumulation over the K inner slots.

    The f32 MXU products carry a per-element running compensation term in a
    VMEM scratch block that persists across the K grid steps revisiting this
    output block, so the K-term summation error drops from O(K * eps) to
    O(eps) — the accumulation-noise half of the f32 residual floor.  (The
    other half, the f32 *representation* of blocks and x, is unchanged: ask
    the ref/einsum lane with accum="f64" for genuinely tighter arithmetic.)
    """
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        c_ref[...] = jnp.zeros_like(c_ref)

    blk = blocks_ref[0, 0]          # (bm, bn)
    xb = x_ref[0]                   # (bn, nv)
    prod = jnp.dot(blk, xb, preferred_element_type=jnp.float32)
    y = prod - c_ref[...]
    t = o_ref[0] + y
    c_ref[...] = (t - o_ref[0]) - y
    o_ref[0] = t


@functools.partial(jax.jit, static_argnames=("interpret", "accum"))
def bsr_spmv(blocks: jax.Array, blk_cols: jax.Array, x: jax.Array,
             interpret: bool = False, accum: str = "f32") -> jax.Array:
    """y[i] = sum_k blocks[i, k] @ x[blk_cols[i, k]].

    blocks:   (nbr, K, bm, bn)
    blk_cols: (nbr, K) int32 — zero-padded slots MUST point at a valid block
              column (use 0) with an all-zero data block.
    x:        (nbc, bn, nv)
    accum:    "f32" (plain f32 accumulate, the MXU default) or "kahan"
              (compensated summation across the K slots — the tight-residual
              lane for relaxed-tolerance async device runs).
    returns   (nbr, bm, nv) float32
    """
    if accum not in ("f32", "kahan"):
        raise ValueError(f"unknown accum {accum!r}; the kernel renders "
                         "'f32' or 'kahan' (f64 accumulate is the ref lane)")
    nbr, K, bm, bn = blocks.shape
    nbc, bn2, nv = x.shape
    assert bn == bn2, (bn, bn2)

    grid = (nbr, K)
    out_shape = jax.ShapeDtypeStruct((nbr, bm, nv), jnp.float32)
    kernel = _kernel if accum == "f32" else _kernel_kahan
    scratch = [] if accum == "f32" else [pltpu.VMEM((bm, nv), jnp.float32)]

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, bm, bn), lambda i, k, cols: (i, k, 0, 0)),
                pl.BlockSpec((1, bn, nv), lambda i, k, cols: (cols[i, k], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, bm, nv), lambda i, k, cols: (i, 0, 0)),
            scratch_shapes=scratch,
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(blk_cols, blocks, x)
