"""Pure-jnp oracle for the block-CSR SpMV kernel.

Layout (see bsr_spmv.py for the rationale):
  blocks:   (n_block_rows, K, bm, bn)  dense nonzero blocks, zero-padded
  blk_cols: (n_block_rows, K) int32    block-column index of each block
  x:        (n_block_cols, bn, nv)     the iterate(s); nv > 1 computes
                                        several personalized PageRank
                                        vectors simultaneously
  out:      (n_block_rows, bm, nv)
"""
from __future__ import annotations

import jax.numpy as jnp


def bsr_spmv_ref(blocks: jnp.ndarray, blk_cols: jnp.ndarray,
                 x: jnp.ndarray, accum: str = "f32") -> jnp.ndarray:
    """accum selects the accumulation lane of the contraction:

      "f32"   — f32 accumulate (bitwise the historic oracle).
      "f64"   — inputs upcast, contraction accumulated in float64, result
                returned in x's dtype (float64 under enable_x64): the
                segment-sum-grade reference the compensated kernel lane is
                equivalence-tested against.
      "kahan" — the compensated-summation *limit*: f64 accumulate cast back
                to float32 (what an exactly-compensated f32 sum converges
                to; the Pallas kernel's accum="kahan" approximates this).
    """
    nbr, K, bm, bn = blocks.shape
    # gather the x block for every (row, k): (nbr, K, bn, nv)
    xg = x[blk_cols]
    if accum == "f32":
        # (nbr, K, bm, bn) @ (nbr, K, bn, nv) -> sum over K -> (nbr, bm, nv)
        return jnp.einsum("rkmn,rknv->rmv", blocks, xg,
                          preferred_element_type=jnp.float32)
    if accum not in ("f64", "kahan"):
        raise ValueError(f"unknown accum {accum!r}; expected 'f32', "
                         "'f64' or 'kahan'")
    import jax
    # canonicalize: float64 with x64 live, a silent float32 degrade (no
    # warning spam) when the caller never enabled it
    wide = jax.dtypes.canonicalize_dtype(jnp.float64)
    y = jnp.einsum("rkmn,rknv->rmv", blocks.astype(wide), xg.astype(wide),
                   preferred_element_type=wide)
    return y.astype(jnp.float32 if accum == "kahan" else x.dtype)
