"""Pure-jnp oracle for the block-CSR SpMV kernel.

Layout (see bsr_spmv.py for the rationale):
  blocks:   (n_block_rows, K, bm, bn)  dense nonzero blocks, zero-padded
  blk_cols: (n_block_rows, K) int32    block-column index of each block
  x:        (n_block_cols, bn, nv)     the iterate(s); nv > 1 computes
                                        several personalized PageRank
                                        vectors simultaneously
  out:      (n_block_rows, bm, nv)
"""
from __future__ import annotations

import jax.numpy as jnp


def bsr_spmv_ref(blocks: jnp.ndarray, blk_cols: jnp.ndarray,
                 x: jnp.ndarray) -> jnp.ndarray:
    nbr, K, bm, bn = blocks.shape
    # gather the x block for every (row, k): (nbr, K, bn, nv)
    xg = x[blk_cols]
    # (nbr, K, bm, bn) @ (nbr, K, bn, nv) -> sum over K -> (nbr, bm, nv)
    return jnp.einsum("rkmn,rknv->rmv", blocks, xg,
                      preferred_element_type=jnp.float32)
