"""Pallas TPU kernels for the compute hot spots (DESIGN.md §3):
bsr_spmv (the paper's SpMV) and flash_attention (LM prefill)."""
