"""Loss + train step shared by the launcher, the dry-run, and the examples."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.transformer import forward
from ..models.sharding import constrain
from .optimizer import OptConfig, adamw_update

AUX_LOSS_WEIGHT = 0.01


def _chunked_softmax_xent(params, cfg: ModelConfig, hidden: jax.Array,
                          labels: jax.Array, weights: jax.Array,
                          chunk: int = 1024) -> jax.Array:
    """Cross-entropy without a full (B, S, V) f32 logits buffer: scan over
    sequence chunks; each (checkpointed) chunk recomputes its logits in the
    backward pass. Peak CE memory drops from O(S*V) to O(chunk*V)."""
    from ..models.blocks import logits_out
    B, S, D = hidden.shape
    c = min(chunk, S)
    if S % c:
        pad = c - S % c
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        weights = jnp.pad(weights, ((0, 0), (0, pad)))
        S += pad
    nc = S // c
    hc = hidden.reshape(B, nc, c, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nc, c).transpose(1, 0, 2)
    wc = weights.reshape(B, nc, c).transpose(1, 0, 2)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def chunk_ce(h, l, w):
        from ..models.blocks import rmsnorm
        h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
        logits = logits_out(params, h, cfg)            # (B, c, V)
        logits = constrain(logits, "dp", None, "tp")
        logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(
            logits, l[..., None], axis=-1)[..., 0].astype(jnp.float32)
        return jnp.sum((logz - gold) * w)

    def body(acc, xs):
        h, l, w = xs
        return acc + chunk_ce(h, l, w), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, lc, wc))
    return total / jnp.maximum(weights.sum(), 1.0)


def lm_loss(params, cfg: ModelConfig, batch: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Causal LM loss. batch: tokens (B, S) [+ enc_inputs / prefix_embeds].

    Labels are tokens shifted left; the final position is dropped. Padded
    vocab tail can never be a label (tokens < vocab_size)."""
    kwargs = {}
    if cfg.is_encdec:
        kwargs["enc_inputs"] = batch["enc_inputs"]
    if cfg.prefix_len:
        kwargs["prefix_embeds"] = batch["prefix_embeds"]
    hidden, aux = forward(params, cfg, batch["tokens"], return_hidden=True,
                          **kwargs)

    if cfg.prefix_len:
        hidden = hidden[:, cfg.prefix_len:]

    pred_h = hidden[:, :-1]
    labels = batch["tokens"][:, 1:]
    if "loss_mask" in batch:
        w = batch["loss_mask"][:, 1:].astype(jnp.float32)
    else:
        w = jnp.ones(labels.shape, jnp.float32)
    ce = _chunked_softmax_xent(params, cfg, pred_h, labels, w)
    loss = ce + AUX_LOSS_WEIGHT * aux
    return loss, {"ce": ce, "aux": aux}


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig):
    """Returns train_step(state, batch) -> (state, metrics).

    opt_cfg.accum_steps > 1 splits the global batch into microbatches and
    accumulates gradients in a lax.scan — activation peak drops by the
    accumulation factor (how a 671B train step fits a 16 GB chip)."""

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: lm_loss(p, cfg, batch), has_aux=True)(params)

    def train_step(state, batch):
        A = opt_cfg.accum_steps
        if A > 1:
            adt = jnp.dtype(opt_cfg.accum_dtype)
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape((A, x.shape[0] // A) + x.shape[1:]),
                batch)

            def body(carry, mb):
                g_acc, loss_acc, aux_acc = carry
                (loss, parts), g = grads_of(state["params"], mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(adt), g_acc, g)
                return (g_acc, loss_acc + loss, aux_acc + parts["aux"]), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, adt), state["params"])
            (grads, loss_sum, aux_sum), _ = jax.lax.scan(
                body, (g0, jnp.zeros((), jnp.float32),
                       jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree_util.tree_map(lambda g: g / A, grads)
            loss = loss_sum / A
            parts = {"ce": loss, "aux": aux_sum / A}
        else:
            (loss, parts), grads = grads_of(state["params"], batch)
        new_params, new_opt, opt_metrics = adamw_update(
            state["params"], grads, state["opt"], opt_cfg)
        metrics = {"loss": loss, **parts, **opt_metrics}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        loss, parts = lm_loss(params, cfg, batch)
        return {"loss": loss, **parts}
    return eval_step
