"""AdamW + schedules + ZeRO-1 sharding rules (no optax in the container —
and a framework should own its optimizer anyway).

ZeRO-1: first/second moments shard over the DP axis along the largest
param axis divisible by |dp| that the param itself does not already shard;
otherwise they inherit the param's TP sharding. This keeps optimizer state
at ~1/|dp| per device without changing the numerics.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..models.param import ParamDef, tree_map_defs, resolve_axis


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    end_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    opt_dtype: str = "float32"   # bf16 for deepseek-v3-671b (DESIGN §6)
    accum_steps: int = 1         # gradient-accumulation microbatches
    accum_dtype: str = "float32"
    # update_chunk: scan the elementwise AdamW math over the leading axis of
    # large stacked-layer leaves — caps the f32 temporaries at 1/leading_dim
    # (a 671B stacked-expert leaf otherwise needs ~10 GB of f32 scratch)
    update_chunk_min_dim: int = 8


def lr_schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.end_lr_frac + (1 - cfg.end_lr_frac) * cos
    return cfg.peak_lr * warm * frac


def init_opt_state(params, cfg: OptConfig):
    dt = jnp.dtype(cfg.opt_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_opt_state(abstract_params, cfg: OptConfig):
    dt = jnp.dtype(cfg.opt_dtype)
    mk = lambda p: jax.ShapeDtypeStruct(p.shape, dt)
    return {
        "m": jax.tree_util.tree_map(mk, abstract_params),
        "v": jax.tree_util.tree_map(mk, abstract_params),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def global_norm(tree, chunk_min_dim: int = 8) -> jax.Array:
    """Chunk the square-sum of large stacked leaves (lax.map over the layer
    axis) so no whole-leaf f32 temporary materializes."""
    def sq(l):
        if l.ndim >= 3 and l.shape[0] >= chunk_min_dim:
            per = jax.lax.map(
                lambda s: jnp.sum(jnp.square(s.astype(jnp.float32))), l)
            return per.sum()
        return jnp.sum(jnp.square(l.astype(jnp.float32)))
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(sq(l) for l in leaves))


def adamw_update(params, grads, state, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    dt = jnp.dtype(cfg.opt_dtype)

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * g * g
        mhat = m32 / c1
        vhat = v32 / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m32.astype(dt), v32.astype(dt)

    def upd_leaf(p, g, m, v):
        if p.ndim >= 3 and p.shape[0] >= cfg.update_chunk_min_dim:
            return jax.lax.map(lambda a: upd(*a), (p, g, m, v))
        return upd(p, g, m, v)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    out = [upd_leaf(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics


# ------------------------------------------------------------- ZeRO-1 ------
def zero1_spec(d: ParamDef, dp_size: int, multi_pod: bool) -> P:
    """Moment sharding for one param (see module docstring)."""
    spec = list(d.spec or (None,) * len(d.shape))
    # pick the largest axis divisible by dp and currently unsharded
    best, best_dim = -1, 0
    for ax, (dim, s) in enumerate(zip(d.shape, spec)):
        if s is None and dim % dp_size == 0 and dim > best_dim:
            best, best_dim = ax, dim
    if best >= 0:
        spec[best] = "dp"
    return P(*[resolve_axis(s, multi_pod) for s in spec])


def opt_state_pspecs(defs, cfg: OptConfig, dp_size: int,
                     multi_pod: bool = False):
    moments = tree_map_defs(
        lambda d: zero1_spec(d, dp_size, multi_pod), defs)
    return {"m": moments, "v": moments, "step": P()}
