"""The paper's asynchronous iteration applied to TRAINING (DESIGN §4).

Mapping eq. (5) onto SGD: the global state is the parameter vector w,
block-partitioned across UEs exactly like the PageRank iterate; UE i owns
w_{i} and repeats
    w_{i}(t+1) = w_{i}(t) - eta * grad_i L(w(tau^i(t)); minibatch_i)
using *stale* imports of the other fragments. This is asynchronous
parameter-sharded SGD (Hogwild-with-fragments), the direct analogue of the
paper's scheme — and it reuses the exact same DES engine, clock/network
models, and Fig. 1 termination protocol.

Two flavors:
  * DES (faithful): TrainStaleOperator plugs into core.des.AsyncDES. Used by
    the straggler-mitigation benchmark: sync DP waits for the slowest UE,
    async iterates through it.
  * SPMD (deployable): local-update data parallelism under shard_map — each
    data shard runs `sync_every` local optimizer steps between parameter
    averages (bounded staleness k), cutting DP collective bytes by k.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..core.des import AsyncDES, DESConfig
from ..core.partition import Partition, block_rows


# ---------------------------------------------------------------------------
# DES flavor: a small two-layer MLP regression, parameters as the iterate
# ---------------------------------------------------------------------------
class MLPTask:
    """y = W2 tanh(W1 x); squared loss on a fixed synthetic dataset."""

    def __init__(self, d_in=16, d_hidden=32, n_data=2048, seed=0,
                 noise=0.01):
        rng = np.random.default_rng(seed)
        self.d_in, self.d_h = d_in, d_hidden
        w1t = rng.standard_normal((d_hidden, d_in)) / np.sqrt(d_in)
        w2t = rng.standard_normal((1, d_hidden)) / np.sqrt(d_hidden)
        self.X = rng.standard_normal((n_data, d_in))
        self.Y = (np.tanh(self.X @ w1t.T) @ w2t.T
                  + noise * rng.standard_normal((n_data, 1)))
        self.n_params = d_hidden * d_in + d_hidden

    def unpack(self, w: np.ndarray):
        k = self.d_h * self.d_in
        w1 = w[:k].reshape(self.d_h, self.d_in)
        w2 = w[k:].reshape(1, self.d_h)
        return w1, w2

    def loss(self, w: np.ndarray) -> float:
        w1, w2 = self.unpack(w)
        pred = np.tanh(self.X @ w1.T) @ w2.T
        return float(np.mean((pred - self.Y) ** 2))

    def grad(self, w: np.ndarray, batch_idx: np.ndarray) -> np.ndarray:
        w1, w2 = self.unpack(w)
        X, Y = self.X[batch_idx], self.Y[batch_idx]
        h = np.tanh(X @ w1.T)                      # (b, H)
        pred = h @ w2.T                            # (b, 1)
        e = 2.0 * (pred - Y) / len(batch_idx)      # (b, 1)
        g2 = e.T @ h                               # (1, H)
        dh = (e @ w2) * (1 - h * h)                # (b, H)
        g1 = dh.T @ X                              # (H, in)
        return np.concatenate([g1.reshape(-1), g2.reshape(-1)])


class TrainStaleOperator:
    """BlockOperator over the parameter vector: f_i = SGD on block i.

    lr decays 1/(1 + t/t0) per-UE so the weight-delta convergence criterion
    (the paper's local threshold) is meaningful under minibatch noise."""

    def __init__(self, task: MLPTask, part: Partition, lr: float = 0.2,
                 batch: int = 256, lr_decay_t0: float = 150.0,
                 seed: int = 0):
        self.task = task
        self.part = part
        self.lr = lr
        self.batch = batch
        self.t0 = lr_decay_t0
        self.rng = np.random.default_rng(seed)
        self._t = np.zeros(part.p, dtype=np.int64)

    def update_block(self, i: int, w_full: np.ndarray) -> np.ndarray:
        s, e = self.part.block(i)
        idx = self.rng.integers(0, len(self.task.X), size=self.batch)
        g = self.task.grad(w_full, idx)
        lr = self.lr / (1.0 + self._t[i] / self.t0)
        self._t[i] += 1
        return w_full[s:e] - lr * g[s:e]

    def block_work(self, i: int) -> float:
        # gradient cost is the full model per UE (data-parallel-like cost)
        return float(self.task.n_params * self.batch) / self.part.p


@dataclasses.dataclass
class AsyncTrainResult:
    sync_loss: float
    sync_time: float
    sync_iters: int
    async_loss: float
    async_time: float
    async_iters_min: int
    async_iters_max: int
    speedup: float


def run_async_training_sim(p: int = 4, tol: float = 2e-3,
                           ue_speed: Optional[list] = None,
                           cfg: Optional[DESConfig] = None,
                           seed: int = 0) -> AsyncTrainResult:
    """Sync vs async parameter-sharded SGD under the paper's models."""
    task = MLPTask(seed=seed)
    part = block_rows(task.n_params, p)
    cfg = cfg or DESConfig(
        tol=tol, norm="l2", base_flops_rate=2e6, bandwidth=2e5,
        msg_latency=1e-3, cancel_window=0.5, max_iters=3000,
        ue_speed=ue_speed, normalize=False, seed=seed)
    w0 = np.random.default_rng(seed + 1).standard_normal(
        task.n_params) * 0.3

    opr = TrainStaleOperator(task, part, seed=seed)
    des = AsyncDES(opr, part, cfg, x0=w0)
    sync = des.run_sync()
    opr2 = TrainStaleOperator(task, part, seed=seed)
    des2 = AsyncDES(opr2, part, cfg, x0=w0)
    res = des2.run()

    return AsyncTrainResult(
        sync_loss=task.loss(sync.x),
        sync_time=sync.time, sync_iters=sync.iters,
        async_loss=task.loss(res.x),
        async_time=float(res.local_conv_time.max()),
        async_iters_min=int(res.iters.min()),
        async_iters_max=int(res.iters.max()),
        speedup=float(sync.time / max(res.local_conv_time.max(), 1e-9)),
    )


# ---------------------------------------------------------------------------
# SPMD flavor: local-update DP (bounded staleness k) under shard_map
# ---------------------------------------------------------------------------
def make_local_sgd_step(loss_fn: Callable, lr: float, sync_every: int,
                        mesh: Mesh, axis: str = "data"):
    """Returns step(params, batches) running `sync_every` local SGD steps on
    each data shard then averaging parameters over `axis` — the deployable
    bounded-staleness form: DP collective volume drops by sync_every.

    loss_fn(params, batch) -> scalar; params: replicated pytree;
    batches: leading dims (n_shards, sync_every, ...)."""

    def shard_body(params, batches):
        params = jax.tree_util.tree_map(lambda x: x[0], params)

        def local_step(p, batch):
            g = jax.grad(loss_fn)(p, batch)
            p = jax.tree_util.tree_map(lambda w, gw: w - lr * gw, p, g)
            return p, None

        bb = jax.tree_util.tree_map(lambda x: x[0], batches)
        params, _ = jax.lax.scan(local_step, params, bb)
        # parameter average == gradient sync with staleness <= sync_every
        params = jax.tree_util.tree_map(
            lambda w: jax.lax.pmean(w, axis), params)
        return jax.tree_util.tree_map(lambda x: x[None], params)

    n = mesh.shape[axis]
    mapped = shard_map(
        shard_body, mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=P(axis),
        check_rep=False)

    def step(params, batches):
        # params enter replicated: tile across the axis for shard_map
        tiled = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), params)
        out = mapped(tiled, batches)
        return jax.tree_util.tree_map(lambda x: x[0], out)

    return step
