"""Checkpointing: atomic, async, last-k retention, elastic restore.

Fault-tolerance contract (DESIGN §6):
  * atomic    — write to step_NNN.tmp/, fsync, rename; a crash mid-write
                never corrupts the latest checkpoint.
  * async     — a writer thread drains a depth-1 queue so the train loop
                never blocks on disk (newer snapshots supersede queued ones).
  * last-k    — bounded disk usage; restart picks the newest *complete*
                checkpoint (manifest written last).
  * elastic   — state is saved with its logical tree structure + dtype/shape
                manifest; restore reshards onto whatever mesh/DP degree the
                new job brings up (gather on save, device_put with the new
                sharding on load).

On a real pod each host writes only its addressable shards; in this
single-process container the gather is the identity.
"""
from __future__ import annotations

import json
import queue
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import numpy as np
import jax


_SEP = "/"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        flat[key] = leaf
    return flat


def _unflatten_into(template, flat: Dict[str, Any]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3,
                 async_write: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        self._q: "queue.Queue" = queue.Queue(maxsize=1)
        self._worker: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------- save ---
    def save(self, step: int, state, blocking: bool = False):
        host_state = jax.tree_util.tree_map(np.asarray, state)
        if not self.async_write or blocking:
            self._write(step, host_state)
            return
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()
        # depth-1 queue: a newer snapshot supersedes an unqueued older one
        try:
            self._q.put_nowait((step, host_state))
        except queue.Full:
            try:
                self._q.get_nowait()
                self._q.task_done()  # account for the discarded item —
                # without this, wait()'s queue.join() deadlocks
            except queue.Empty:
                pass
            self._q.put_nowait((step, host_state))

    def wait(self):
        self._q.join()
        if self._error:
            raise self._error

    def _drain(self):
        while True:
            step, state = self._q.get()
            try:
                self._write(step, state)
            except BaseException as e:  # surfaced on wait()
                self._error = e
            finally:
                self._q.task_done()

    def _write(self, step: int, host_state):
        flat = _flatten(host_state)
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {}
        for i, (key, arr) in enumerate(sorted(flat.items())):
            arr = np.asarray(arr)
            fname = f"arr_{i:05d}.npy"
            orig_dtype = str(arr.dtype)
            if arr.dtype.kind == "V" or orig_dtype in ("bfloat16",):
                # numpy can't round-trip ml_dtypes; bf16 -> f32 is exact
                arr = arr.astype(np.float32)
            np.save(tmp / fname, arr)
            manifest[key] = dict(file=fname, shape=list(arr.shape),
                                 dtype=orig_dtype)
        # manifest is written LAST: its presence marks completeness
        (tmp / "manifest.json").write_text(json.dumps(
            dict(step=step, time=time.time(), leaves=manifest)))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        self._gc()

    def _gc(self):
        ckpts = self.all_steps()
        for s in ckpts[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ---------------------------------------------------------- restore ---
    def all_steps(self):
        steps = []
        for d in self.dir.glob("step_*"):
            if d.suffix == ".tmp" or not (d / "manifest.json").exists():
                continue  # incomplete (crashed mid-write): ignored
            steps.append(int(d.name.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: Optional[int] = None,
                shardings=None) -> Tuple[Any, int]:
        """Restore into `template`'s tree structure. `shardings` (optional
        matching tree of NamedSharding) reshards onto the *current* mesh —
        the elastic-scaling path."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat = {}
        for key, meta in manifest["leaves"].items():
            arr = np.load(d / meta["file"])
            if str(arr.dtype) != meta["dtype"]:
                import ml_dtypes  # shipped with jax
                arr = arr.astype(np.dtype(meta["dtype"]))
            flat[key] = arr
        state = _unflatten_into(template, flat)
        if shardings is not None:
            state = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        else:
            state = jax.tree_util.tree_map(jax.numpy.asarray, state)
        return state, step
