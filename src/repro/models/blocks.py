"""Shared building blocks: norms, rope, activations, MLP, embedding."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .param import ParamDef
from .config import ModelConfig


# ---------------------------------------------------------------- norms ----
def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def rmsnorm_def(dim: int, dtype) -> ParamDef:
    # stored as offset from 1 (gemma convention); init zeros
    return ParamDef((dim,), dtype, (None,), init="zeros")


# ----------------------------------------------------------------- rope ----
def rope(x: jax.Array, positions: jax.Array, theta: float,
         rot_dim: Optional[int] = None) -> jax.Array:
    """x: (..., S, D) with positions (..., S) or (S,)."""
    d = x.shape[-1] if rot_dim is None else rot_dim
    half = d // 2
    freqs = jnp.exp(
        -jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:d]
    rx1 = x1 * cos - x2 * sin
    rx2 = x2 * cos + x1 * sin
    out = jnp.concatenate([rx1, rx2], axis=-1).astype(x.dtype)
    if rot_dim is not None and rot_dim < x.shape[-1]:
        out = jnp.concatenate([out, x[..., d:]], axis=-1)
    return out


# ------------------------------------------------------------------ mlp ----
def mlp_defs(cfg: ModelConfig, d_in: int, d_ff: int) -> dict:
    dt = cfg.pdtype()
    if cfg.act.endswith("_glu"):
        return {
            "w_gate": ParamDef((d_in, d_ff), dt, (None, "tp")),
            "w_up": ParamDef((d_in, d_ff), dt, (None, "tp")),
            "w_down": ParamDef((d_ff, d_in), dt, ("tp", None)),
        }
    return {
        "w_up": ParamDef((d_in, d_ff), dt, (None, "tp")),
        "w_down": ParamDef((d_ff, d_in), dt, ("tp", None)),
    }


def mlp_apply(p: dict, x: jax.Array, act: str) -> jax.Array:
    if act.endswith("_glu"):
        g = x @ p["w_gate"]
        u = x @ p["w_up"]
        g = jax.nn.silu(g) if act.startswith("silu") else jax.nn.gelu(g)
        return (g * u) @ p["w_down"]
    h = x @ p["w_up"]
    h = jax.nn.gelu(h)
    return h @ p["w_down"]


# ------------------------------------------------------------ embedding ----
def embed_defs(cfg: ModelConfig) -> dict:
    dt = cfg.pdtype()
    # ~N(0, 1/sqrt(d)) so the sqrt(d) lookup scaling yields unit-variance
    # activations and tied logits stay O(1) at init
    d = {"tok": ParamDef((cfg.padded_vocab, cfg.d_model), dt,
                         ("tp", None), scale=cfg.d_model ** -0.5)}
    if not cfg.tie_embeddings:
        d["out"] = ParamDef((cfg.d_model, cfg.padded_vocab), dt,
                            (None, "tp"))
    return d


def embed_lookup(emb: jax.Array, tokens: jax.Array, d_model: int
                 ) -> jax.Array:
    # gather rows; with the table sharded on vocab, GSPMD turns this into
    # a sharded gather + collective. Scaled by sqrt(d) (gemma convention
    # is harmless for the others).
    return emb[tokens] * jnp.asarray(d_model ** 0.5, emb.dtype)


def logits_out(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        w = params["embed"]["tok"]          # (V, D)
        out = jnp.einsum("...d,vd->...v", x, w)
    else:
        out = x @ params["embed"]["out"]
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        out = jnp.tanh(out / c) * c
    return out
