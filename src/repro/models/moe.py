"""Mixture-of-Experts: GShard-style grouped dispatch/combine einsums.

Tokens are reshaped into (G groups, tg tokens) so the dispatch tensors stay
bounded; groups shard over the DP axis, experts over the model axis (EP).
XLA inserts the all-to-alls at the group<->expert einsum boundaries.

Routing: softmax over experts, top-k, renormalized (Qwen2-MoE style; the
DeepSeek-V3 sigmoid+bias-update router is approximated by the same softmax
top-k — deviation noted in DESIGN.md). Capacity-factor token dropping
matches GShard; an auxiliary load-balance loss is returned.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .param import ParamDef
from .config import ModelConfig
from .blocks import mlp_defs, mlp_apply
from .sharding import constrain


def moe_defs(cfg: ModelConfig) -> dict:
    dt = cfg.pdtype()
    D, E, F = cfg.d_model, cfg.n_experts, cfg.expert_d_ff
    d = {
        "router": ParamDef((D, E), jnp.float32, (None, None), scale=0.02),
        "w_gate": ParamDef((E, D, F), dt, ("tp", None, None)),
        "w_up": ParamDef((E, D, F), dt, ("tp", None, None)),
        "w_down": ParamDef((E, F, D), dt, ("tp", None, None)),
    }
    if cfg.n_shared_experts:
        d["shared"] = mlp_defs(cfg, D, cfg.n_shared_experts * F)
    return d


def capacity(cfg: ModelConfig) -> int:
    tg = cfg.moe_group_size
    c = int(tg * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(4, -(-c // 4) * 4)


def moe_apply(p: dict, x: jax.Array, cfg: ModelConfig
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    tg = min(cfg.moe_group_size, B * S)
    G = (B * S) // tg
    C = capacity(cfg)
    xg = x.reshape(G, tg, D)

    logits = (xg.astype(jnp.float32) @ p["router"])          # (G, t, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, K)                 # (G, t, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # expert one-hot per assignment slot: (G, t, K, E)
    mask = jax.nn.one_hot(idx, E, dtype=jnp.float32)
    # position of each assignment within its expert, in (t, k) raster order
    flat = mask.reshape(G, tg * K, E)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(G, tg, K, E)
    fits = pos < C
    mask = mask * fits

    # aux load-balance loss (Switch): E * sum_e f_e * P_e
    frac_tokens = mask.sum(axis=(1, 2)) / tg                 # (G, E)
    frac_probs = probs.mean(axis=1)                          # (G, E)
    aux = E * jnp.mean(jnp.sum(frac_tokens * frac_probs, axis=-1))

    slot = jax.nn.one_hot(jnp.sum(pos * mask, axis=-1).astype(jnp.int32),
                          C, dtype=jnp.float32)              # (G, t, K, C)
    present = mask.max(axis=-1, keepdims=True)               # (G, t, K, 1)
    # dispatch/combine: (G, t, E, C) — groups shard over dp, experts over
    # tp so the O(G*t*E*C) routing tensors cost 1/(|dp|*|tp|) per device
    dispatch = jnp.einsum("gtke,gtkc->gtec", mask, slot * present)
    combine = jnp.einsum("gtke,gtkc,gtk->gtec", mask, slot * present,
                         gate_vals)
    dispatch = constrain(dispatch, "dp", None, "tp", None)
    combine = constrain(combine, "dp", None, "tp", None)

    dt = x.dtype
    ei = jnp.einsum("gtec,gtd->egcd", dispatch.astype(dt), xg)  # EP boundary
    ei = constrain(ei, "tp", "dp", None, None)
    h_g = jnp.einsum("egcd,edf->egcf", ei, p["w_gate"])
    h_u = jnp.einsum("egcd,edf->egcf", ei, p["w_up"])
    act = jax.nn.silu(h_g) if cfg.act.startswith("silu") else jax.nn.gelu(h_g)
    eo = jnp.einsum("egcf,efd->egcd", act * h_u, p["w_down"])
    eo = constrain(eo, "tp", "dp", None, None)
    out = jnp.einsum("gtec,egcd->gtd", combine.astype(dt), eo)

    if cfg.n_shared_experts:
        out = out + mlp_apply(p["shared"], xg, cfg.act)
    return out.reshape(B, S, D), aux.astype(jnp.float32)
