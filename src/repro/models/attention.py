"""Attention: GQA/MQA/MHA, MLA (DeepSeek), local windows, prefix-LM masks.

Two compute paths:
  * `flash_attn_jnp` — pure-jnp double-scan online-softmax (O(cq*ck) score
    memory). This is the path the dry-run lowers (CPU backend); on TPU the
    Pallas kernel in repro.kernels.flash_attention replaces it 1:1 for the
    causal/full cases.
  * `decode_attn` — one-token attention over a KV cache (einsum over T with
    masking; sharding of the cache is the caller's concern).

Masks are position-based so sequence-sharded (context-parallel) callers can
pass global offsets.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .param import ParamDef
from .config import ModelConfig
from .blocks import rope, rmsnorm, rmsnorm_def

NEG_INF = -1e30


# --------------------------------------------------------------- params ----
def attn_defs(cfg: ModelConfig) -> dict:
    dt = cfg.pdtype()
    D, H, Hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    d = {
        "wq": ParamDef((D, H * dh), dt, (None, "tp")),
        "wk": ParamDef((D, Hkv * dh), dt, (None, "tp")),
        "wv": ParamDef((D, Hkv * dh), dt, (None, "tp")),
        "wo": ParamDef((H * dh, D), dt, ("tp", None)),
    }
    if cfg.qkv_bias:
        d["bq"] = ParamDef((H * dh,), dt, ("tp",), init="zeros")
        d["bk"] = ParamDef((Hkv * dh,), dt, ("tp",), init="zeros")
        d["bv"] = ParamDef((Hkv * dh,), dt, ("tp",), init="zeros")
    return d


def mla_defs(cfg: ModelConfig) -> dict:
    dt = cfg.pdtype()
    D, H = cfg.d_model, cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "w_dq": ParamDef((D, cfg.q_lora_rank), dt, (None, "tp")),
        "q_norm": rmsnorm_def(cfg.q_lora_rank, dt),
        "w_uq": ParamDef((cfg.q_lora_rank, H * qk), dt, (None, "tp")),
        "w_dkv": ParamDef((D, cfg.kv_lora_rank), dt, (None, None)),
        "kv_norm": rmsnorm_def(cfg.kv_lora_rank, dt),
        "w_kr": ParamDef((D, cfg.qk_rope_dim), dt, (None, None)),
        "w_ukv": ParamDef(
            (cfg.kv_lora_rank, H * (cfg.qk_nope_dim + cfg.v_head_dim)),
            dt, (None, "tp")),
        "wo": ParamDef((H * cfg.v_head_dim, D), dt, ("tp", None)),
    }


# ---------------------------------------------------------------- masks ----
def _mask(rows: jax.Array, cols: jax.Array, causal: bool,
          window: Optional[int], prefix_len: int) -> jax.Array:
    """rows/cols: global positions, broadcastable. True = attend."""
    ok = jnp.ones(jnp.broadcast_shapes(rows.shape, cols.shape), bool)
    if causal:
        ok = cols <= rows
        if prefix_len:
            ok = ok | (cols < prefix_len)
    if window is not None:
        ok = ok & (cols > rows - window)
    return ok


# ----------------------------------------------- jnp flash (train/prefill) -
def flash_attn_jnp(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool = True, window: Optional[int] = None,
                   prefix_len: int = 0, q_offset: int = 0,
                   scale: Optional[float] = None,
                   chunk_q: int = 512, chunk_k: int = 512) -> jax.Array:
    """q: (B, H, Sq, Dk); k: (B, Hkv, T, Dk); v: (B, Hkv, T, Dv).

    Double-scan online softmax; returns (B, H, Sq, Dv) in q.dtype."""
    B, H, Sq, Dk = q.shape
    _, Hkv, T, _ = k.shape
    Dv = v.shape[-1]
    G = H // Hkv
    scale = (Dk ** -0.5) if scale is None else scale

    cq = min(chunk_q, Sq)
    ck = min(chunk_k, T)
    # pad to chunk multiples; padded kv columns are masked off below
    Sq_p = -(-Sq // cq) * cq
    T_p = -(-T // ck) * ck
    valid_t = T
    if Sq_p != Sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Sq_p - Sq), (0, 0)))
    if T_p != T:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, T_p - T), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, T_p - T), (0, 0)))
    Sq_orig, Sq, T = Sq, Sq_p, T_p
    nq, nk = Sq // cq, T // ck

    qg = q.reshape(B, Hkv, G, nq, cq, Dk)
    kc = k.reshape(B, Hkv, nk, ck, Dk)
    vc = v.reshape(B, Hkv, nk, ck, Dv)

    def q_step(_, qi):
        qblk, qidx = qi                       # (B,Hkv,G,cq,Dk), scalar
        rows = q_offset + qidx * cq + jnp.arange(cq)

        # rematerialized in the backward pass: without this, AD saves the
        # (cq, ck) probability blocks of EVERY scan step (O(S*T) residuals
        # per layer — tens of GB at 4k/32k)
        @functools.partial(jax.checkpoint, prevent_cse=False)
        def kv_step(carry, kv):
            m, l, acc = carry
            kblk, vblk, kidx = kv             # (B,Hkv,ck,Dk/_Dv)
            cols = kidx * ck + jnp.arange(ck)
            s = jnp.einsum("bhgqd,bhkd->bhgqk",
                           qblk.astype(jnp.float32),
                           kblk.astype(jnp.float32)) * scale
            ok = _mask(rows[:, None], cols[None, :], causal, window,
                       prefix_len)
            ok = ok & (cols < valid_t)[None, :]
            s = jnp.where(ok, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vblk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, cq), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, cq, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kc.transpose(2, 0, 1, 3, 4), vc.transpose(2, 0, 1, 3, 4),
             jnp.arange(nk)))
        l = jnp.where(l == 0.0, 1.0, l)
        return None, (acc / l[..., None]).astype(q.dtype)

    _, out = jax.lax.scan(
        q_step, None, (qg.transpose(3, 0, 1, 2, 4, 5), jnp.arange(nq)))
    # out: (nq, B, Hkv, G, cq, Dv)
    out = out.transpose(1, 2, 3, 0, 4, 5).reshape(B, H, Sq, Dv)
    return out[:, :, :Sq_orig]


# ----------------------------------------------------------- decode step ---
def decode_attn(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, *,
                cache_len: jax.Array, window: Optional[int] = None,
                scale: Optional[float] = None) -> jax.Array:
    """q: (B, H, 1, Dk); caches: (B, Hkv, T, D*). cache_len: filled length
    (the new token is at position cache_len - 1)."""
    B, H, _, Dk = q.shape
    _, Hkv, T, _ = k_cache.shape
    G = H // Hkv
    scale = (Dk ** -0.5) if scale is None else scale
    qg = q.reshape(B, Hkv, G, Dk)
    s = jnp.einsum("bhgd,bhtd->bhgt", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    pos = jnp.arange(T)
    row = cache_len - 1
    ok = pos <= row
    if window is not None:
        ok = ok & (pos > row - window)
    s = jnp.where(ok[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgt,bhtd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, H, 1, -1).astype(q.dtype)


# ---------------------------------------------------------- GQA wrapper ----
def gqa_project(p: dict, x: jax.Array, cfg: ModelConfig):
    """x: (B, S, D) -> q (B,H,S,dh), k/v (B,Hkv,S,dh) with rope applied by
    the caller (positions differ between train and decode)."""
    B, S, _ = x.shape
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, S, H, dh).transpose(0, 2, 1, 3)
    k = k.reshape(B, S, Hkv, dh).transpose(0, 2, 1, 3)
    v = v.reshape(B, S, Hkv, dh).transpose(0, 2, 1, 3)
    return q, k, v


def gqa_attention(p: dict, x: jax.Array, cfg: ModelConfig, *,
                  positions: jax.Array, causal: bool = True,
                  window: Optional[int] = None, prefix_len: int = 0
                  ) -> jax.Array:
    """Full training/prefill self-attention for one layer."""
    from .sharding import constrain, current_tp
    B, S, D = x.shape
    q, k, v = gqa_project(p, x, cfg)
    q = rope(q, positions[None, None, :], cfg.rope_theta)
    k = rope(k, positions[None, None, :], cfg.rope_theta)

    chunk_q = cfg.attn_chunk_q
    if cfg.attn_explicit_sharding:
        tp = current_tp()
        if tp:
            if cfg.n_heads % tp == 0:
                # Megatron-style: q heads sharded; kv heads sharded when
                # they divide, else replicated (GQA with few kv heads)
                q = constrain(q, "dp", "tp", None, None)
                kv_ax = "tp" if cfg.n_kv_heads % tp == 0 else None
                k = constrain(k, "dp", kv_ax, None, None)
                v = constrain(v, "dp", kv_ax, None, None)
            else:
                # context parallel: sequence sharded, KV gathered. One q
                # chunk (no q-scan) so the score rows shard cleanly on S.
                q = constrain(q, "dp", None, "tp", None)
                k = constrain(k, "dp", None, None, None)
                v = constrain(v, "dp", None, None, None)
                chunk_q = S

    o = flash_attn_jnp(q, k, v, causal=causal, window=window,
                       prefix_len=prefix_len, chunk_q=chunk_q)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * cfg.head_dim_)
    return o @ p["wo"]


# ------------------------------------------------------------------ MLA ----
def mla_attention(p: dict, x: jax.Array, cfg: ModelConfig, *,
                  positions: jax.Array) -> jax.Array:
    """DeepSeek multi-head latent attention, training/prefill form."""
    B, S, D = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim

    cq = rmsnorm(x @ p["w_dq"], p["q_norm"], cfg.norm_eps)
    q = (cq @ p["w_uq"]).reshape(B, S, H, dn + dr).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions[None, None, :], cfg.rope_theta)

    c_kv = rmsnorm(x @ p["w_dkv"], p["kv_norm"], cfg.norm_eps)  # (B,S,r)
    k_rope = rope((x @ p["w_kr"])[:, None, :, :], positions[None, None, :],
                  cfg.rope_theta)                                # (B,1,S,dr)
    kv = (c_kv @ p["w_ukv"]).reshape(B, S, H, dn + dv).transpose(0, 2, 1, 3)
    k_nope, v = kv[..., :dn], kv[..., dn:]

    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, H, S, dr))], axis=-1)
    qh = jnp.concatenate([q_nope, q_rope], axis=-1)
    o = flash_attn_jnp(qh, k, v, causal=True, scale=(dn + dr) ** -0.5,
                       chunk_q=cfg.attn_chunk_q)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, H * dv)
    return o @ p["wo"]


def mla_decode(p: dict, x: jax.Array, cfg: ModelConfig, *,
               c_cache: jax.Array, kr_cache: jax.Array,
               cache_len: jax.Array, position: jax.Array):
    """Absorbed-matrix MLA decode: attention runs in the latent space, the
    cache stores (kv_lora_rank + qk_rope_dim) per token (DESIGN §5).

    x: (B, 1, D); c_cache: (B, T, r); kr_cache: (B, T, dr).
    Returns (out (B,1,D), new_c (B,1,r), new_kr (B,1,dr))."""
    B, _, D = x.shape
    H = cfg.n_heads
    dn, dr, dv, r = (cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim,
                     cfg.kv_lora_rank)

    cq = rmsnorm(x @ p["w_dq"], p["q_norm"], cfg.norm_eps)
    q = (cq @ p["w_uq"]).reshape(B, 1, H, dn + dr).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, position[None, None, :], cfg.rope_theta)

    w_ukv = p["w_ukv"].reshape(r, H, dn + dv)
    w_uk = w_ukv[..., :dn]                    # (r, H, dn)
    w_uv = w_ukv[..., dn:]                    # (r, H, dv)

    # absorb W_uk into the query: q_lat = q_nope @ W_uk^T  -> (B,H,1,r)
    q_lat = jnp.einsum("bhqd,rhd->bhqr", q_nope, w_uk)

    new_c = rmsnorm(x @ p["w_dkv"], p["kv_norm"], cfg.norm_eps)  # (B,1,r)
    new_kr = rope((x @ p["w_kr"]), position[None, :], cfg.rope_theta)

    c_cache = jax.lax.dynamic_update_slice(
        c_cache, new_c.astype(c_cache.dtype), (0, cache_len - 1, 0))
    kr_cache = jax.lax.dynamic_update_slice(
        kr_cache, new_kr.astype(kr_cache.dtype), (0, cache_len - 1, 0))

    s = (jnp.einsum("bhqr,btr->bhqt", q_lat.astype(jnp.float32),
                    c_cache.astype(jnp.float32))
         + jnp.einsum("bhqd,btd->bhqt", q_rope.astype(jnp.float32),
                      kr_cache.astype(jnp.float32))) * ((dn + dr) ** -0.5)
    pos = jnp.arange(c_cache.shape[1])
    s = jnp.where((pos < cache_len)[None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhqt,btr->bhqr", w, c_cache.astype(jnp.float32))
    o = jnp.einsum("bhqr,rhd->bhqd", o_lat.astype(x.dtype), w_uv)
    o = o.transpose(0, 2, 1, 3).reshape(B, 1, H * dv)
    return o @ p["wo"], c_cache, kr_cache
