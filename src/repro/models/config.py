"""Unified model configuration covering the 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


def pad_to_multiple(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"   # dense | moe | ssm | hybrid | encdec | vlm

    # --- trunk ---
    n_layers: int = 12
    d_model: int = 512
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: Optional[int] = None        # default d_model // n_heads
    d_ff: int = 2048
    vocab_size: int = 32_000
    vocab_round_to: int = 128             # pad so TP=16 divides (DESIGN §5)
    act: str = "silu_glu"                 # silu_glu | gelu_glu | gelu
    qkv_bias: bool = False                # qwen1.5
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    logit_softcap: Optional[float] = None

    # layer pattern, cycled across n_layers: "attn", "local_attn",
    # "rglru", "ssd"
    block_pattern: Tuple[str, ...] = ("attn",)
    local_window: int = 2048

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 2
    expert_d_ff: int = 0
    first_dense_layers: int = 0           # deepseek: first k layers dense
    capacity_factor: float = 1.25
    moe_group_size: int = 512             # tokens per dispatch group

    # --- MLA (deepseek) ---
    use_mla: bool = False
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # --- SSM (mamba2 SSD) ---
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_state: int = 128
    ssm_chunk: int = 256
    ssm_conv: int = 4

    # --- hybrid (recurrentgemma) ---
    lru_width: Optional[int] = None       # default d_model

    # --- encoder-decoder (whisper) ---
    n_enc_layers: int = 0                 # >0 => encoder-decoder
    enc_seq_ratio: float = 1.0            # encoder frames per decoder token

    # --- vlm (paligemma) ---
    prefix_len: int = 0                   # image-patch prefix (stub frontend)

    # --- dtypes ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # --- distribution knobs (see DESIGN §6) ---
    scan_layers: bool = True
    remat: bool = True
    attn_chunk_q: int = 512               # jnp chunked-attention q block
    # FSDP/ZeRO-3: additionally shard params over the DP axis (needed when
    # params/chip exceeds HBM under TP-only sharding, e.g. 671B)
    fsdp: bool = False
    # how attention weights/compute shard over the model axis:
    #   auto -> "heads" when n_heads % tp == 0 and n_kv_heads % tp == 0,
    #   else "seq" (context-parallel with KV all-gather)
    attn_sharding: str = "auto"
    # explicit q/k/v activation constraints (§Perf hillclimb): heads-sharded
    # q with replicated KV when kv-heads don't divide tp, else context
    # parallel — replaces whatever GSPMD infers
    attn_explicit_sharding: bool = False

    # ---------------- derived ----------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return pad_to_multiple(self.vocab_size, self.vocab_round_to)

    @property
    def d_inner(self) -> int:             # ssd
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def lru_width_(self) -> int:
        return self.lru_width if self.lru_width else self.d_model

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    def layer_kinds(self) -> Tuple[str, ...]:
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(self.n_layers))

    def moe_layer(self, idx: int) -> bool:
        return (self.n_experts > 0) and (idx >= self.first_dense_layers)

    def dtype(self):
        return jnp.dtype(self.compute_dtype)

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def attn_mode(self, tp: int) -> str:
        if self.attn_sharding != "auto":
            return self.attn_sharding
        if self.n_heads % tp == 0 and self.n_kv_heads % tp == 0:
            return "heads"
        return "seq"

    def supports_shape(self, shape_name: str) -> Tuple[bool, str]:
        """Which benchmark shapes run for this arch (DESIGN §5 skips)."""
        if shape_name == "long_500k":
            subquad = all(k in ("ssd", "rglru", "local_attn")
                          for k in self.layer_kinds())
            if not subquad:
                return False, ("full-attention arch: 500k dense-KV decode "
                               "is quadratic-history; skipped per DESIGN §5")
        return True, ""
