"""Unified transformer assembly for the 10 assigned architectures:
decoder-only (dense/MoE/MLA), SSM, hybrid (RG-LRU + local attention),
encoder-decoder (whisper), and prefix-LM VLM (paligemma).

Layers with identical signatures are stacked and scanned (small HLO, fast
512-device compiles); `first_dense_layers` (DeepSeek) and pattern
remainders fall out of the scan as explicitly-unrolled layers.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .param import ParamDef, tree_map_defs
from .config import ModelConfig
from .blocks import (rmsnorm, rmsnorm_def, mlp_defs, mlp_apply, embed_defs,
                     embed_lookup, logits_out, rope)
from .attention import (attn_defs, mla_defs, gqa_attention, mla_attention,
                        gqa_project, decode_attn, mla_decode)
from .moe import moe_defs, moe_apply
from .ssm import (ssd_defs, ssd_apply, ssd_step, ssd_init_cache, SSDCache)
from .rglru import (rglru_defs, rglru_apply, rglru_step, rglru_init_cache,
                    LRUCache)
from .sharding import constrain


# ===================================================================== defs
def _sig(cfg: ModelConfig, idx: int) -> Tuple[str, bool]:
    return (cfg.layer_kinds()[idx], cfg.moe_layer(idx))


def layer_defs(cfg: ModelConfig, kind: str, is_moe: bool,
               cross: bool = False) -> dict:
    dt = cfg.pdtype()
    d: Dict[str, Any] = {"norm1": rmsnorm_def(cfg.d_model, dt)}
    if kind in ("attn", "local_attn"):
        d["attn"] = mla_defs(cfg) if cfg.use_mla else attn_defs(cfg)
    elif kind == "rglru":
        d["rglru"] = rglru_defs(cfg)
    elif kind == "ssd":
        d["ssd"] = ssd_defs(cfg)
    else:
        raise ValueError(kind)
    if cross:
        d["norm_cross"] = rmsnorm_def(cfg.d_model, dt)
        d["cross"] = attn_defs(cfg)
    if is_moe:
        d["norm2"] = rmsnorm_def(cfg.d_model, dt)
        d["moe"] = moe_defs(cfg)
    elif cfg.d_ff > 0:
        d["norm2"] = rmsnorm_def(cfg.d_model, dt)
        d["mlp"] = mlp_defs(cfg, cfg.d_model, cfg.d_ff)
    return d


def _stack_defs(defs, r: int):
    return tree_map_defs(
        lambda p: dataclasses.replace(
            p, shape=(r,) + p.shape,
            spec=(None,) + tuple(p.spec or (None,) * len(p.shape))),
        defs)


@dataclasses.dataclass(frozen=True)
class StackPlan:
    """How n_layers maps onto scanned/unrolled groups."""
    head: Tuple[int, ...]          # unrolled layer indices (prefix)
    repeats: int                   # scan length
    pattern: Tuple[int, ...]       # layer idx offsets inside one scan step
    tail: Tuple[int, ...]          # unrolled layer indices (suffix)


def stack_plan(cfg: ModelConfig, n_layers: int, first_dense: int) -> StackPlan:
    pat = len(cfg.block_pattern)
    head = tuple(range(first_dense))
    rest = n_layers - first_dense
    r = rest // pat if cfg.scan_layers else 0
    tail_start = first_dense + r * pat
    return StackPlan(
        head=head, repeats=r, pattern=tuple(range(pat)),
        tail=tuple(range(tail_start, n_layers)))


def _decoder_defs(cfg: ModelConfig, n_layers: int, cross: bool) -> dict:
    plan = stack_plan(cfg, n_layers, cfg.first_dense_layers)
    out: Dict[str, Any] = {"head": {}, "stack": {}, "tail": {}}
    for i in plan.head:
        k, _ = _sig(cfg, i)
        out["head"][f"layer{i}"] = layer_defs(cfg, k, False, cross)
    if plan.repeats:
        base = len(plan.head)
        for j in plan.pattern:
            k, m = _sig(cfg, base + j)
            out["stack"][f"pos{j}"] = _stack_defs(
                layer_defs(cfg, k, m, cross), plan.repeats)
    for i in plan.tail:
        k, m = _sig(cfg, i)
        out["tail"][f"layer{i}"] = layer_defs(cfg, k, m, cross)
    return out


def model_defs(cfg: ModelConfig) -> dict:
    dt = cfg.pdtype()
    d: Dict[str, Any] = {
        "embed": embed_defs(cfg),
        "decoder": _decoder_defs(cfg, cfg.n_layers, cross=cfg.is_encdec),
        "final_norm": rmsnorm_def(cfg.d_model, dt),
    }
    if cfg.is_encdec:
        enc_cfg = dataclasses.replace(
            cfg, block_pattern=("attn",), n_experts=0, first_dense_layers=0)
        d["encoder"] = _decoder_defs(enc_cfg, cfg.n_enc_layers, cross=False)
        d["enc_norm"] = rmsnorm_def(cfg.d_model, dt)
    return d


# ================================================================== forward
def _mix(pl: dict, x: jax.Array, cfg: ModelConfig, kind: str, *,
         positions, causal, prefix_len, enc_out) -> jax.Array:
    h = rmsnorm(x, pl["norm1"], cfg.norm_eps)
    if kind in ("attn", "local_attn"):
        window = cfg.local_window if kind == "local_attn" else None
        if cfg.use_mla:
            h = mla_attention(pl["attn"], h, cfg, positions=positions)
        else:
            h = gqa_attention(pl["attn"], h, cfg, positions=positions,
                              causal=causal, window=window,
                              prefix_len=prefix_len)
    elif kind == "rglru":
        h = rglru_apply(pl["rglru"], h, cfg)
    elif kind == "ssd":
        h = ssd_apply(pl["ssd"], h, cfg)
    x = x + h
    if enc_out is not None and "cross" in pl:
        h = rmsnorm(x, pl["norm_cross"], cfg.norm_eps)
        h = _cross_attention(pl["cross"], h, enc_out, cfg)
        x = x + h
    return x


def _cross_attention(p: dict, x: jax.Array, enc_out: jax.Array,
                     cfg: ModelConfig) -> jax.Array:
    from .attention import flash_attn_jnp
    B, S, D = x.shape
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = (x @ p["wq"]).reshape(B, S, H, dh).transpose(0, 2, 1, 3)
    k = (enc_out @ p["wk"]).reshape(B, -1, Hkv, dh).transpose(0, 2, 1, 3)
    v = (enc_out @ p["wv"]).reshape(B, -1, Hkv, dh).transpose(0, 2, 1, 3)
    o = flash_attn_jnp(q, k, v, causal=False, chunk_q=cfg.attn_chunk_q)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, H * dh)
    return o @ p["wo"]


def _constrain_params_for_use(pl: dict, cfg: ModelConfig, kind: str,
                              is_moe: bool) -> dict:
    """FSDP: annotate the layer's params with their TP 'use' sharding.

    Forward: forces the dp all-gather to happen per layer inside the scan
    (not hoisted). Backward: with_sharding_constraint transposes to itself,
    so the per-layer gradient cotangents are reduce-scattered back to the
    FSDP layout INSIDE the loop — without this, the scan accumulates
    dp-replicated grads for every layer (~80 GB/device at 671B)."""
    defs = layer_defs(cfg, kind, is_moe, cross="cross" in pl)

    def one(p, d):
        spec = d.spec or (None,) * len(d.shape)
        return constrain(p, *spec)

    return jax.tree_util.tree_map(
        one, pl, defs, is_leaf=lambda n: isinstance(n, ParamDef))


def _apply_layer(pl: dict, x: jax.Array, cfg: ModelConfig, kind: str,
                 is_moe: bool, *, positions, causal=True, prefix_len=0,
                 enc_out=None) -> Tuple[jax.Array, jax.Array]:
    # layer-boundary activations shard (dp, None, tp): the scan-over-layers
    # carry (the remat-saved residual stream) costs 1/|tp| per device.
    # d_model divides 16 for every assigned arch; seq stays whole so the
    # SSD/RG-LRU time scans stay local.
    if cfg.fsdp:
        pl = _constrain_params_for_use(pl, cfg, kind, is_moe)
    x = constrain(x, "dp", None, "tp")
    x = _mix(pl, x, cfg, kind, positions=positions, causal=causal,
             prefix_len=prefix_len, enc_out=enc_out)
    aux = jnp.zeros((), jnp.float32)
    if is_moe:
        h = rmsnorm(x, pl["norm2"], cfg.norm_eps)
        h, aux = moe_apply(pl["moe"], h, cfg)
        x = x + h
    elif cfg.d_ff > 0:
        h = rmsnorm(x, pl["norm2"], cfg.norm_eps)
        x = x + mlp_apply(pl["mlp"], h, cfg.act)
    return x, aux


def _run_stack(params: dict, x: jax.Array, cfg: ModelConfig,
               n_layers: int, first_dense: int, *, positions, causal=True,
               prefix_len=0, enc_out=None) -> Tuple[jax.Array, jax.Array]:
    plan = stack_plan(cfg, n_layers, first_dense)
    aux_total = jnp.zeros((), jnp.float32)

    def one(pl, x, idx_sig):
        k, m = idx_sig
        f = functools.partial(
            _apply_layer, cfg=cfg, kind=k, is_moe=m, positions=positions,
            causal=causal, prefix_len=prefix_len, enc_out=enc_out)
        if cfg.remat:
            return jax.checkpoint(lambda p_, x_: f(p_, x=x_))(pl, x)
        return f(pl, x=x)

    for i in plan.head:
        x, a = one(params["head"][f"layer{i}"], x, (_sig(cfg, i)[0], False))
        aux_total += a

    if plan.repeats:
        base = len(plan.head)
        sigs = [_sig(cfg, base + j) for j in plan.pattern]
        stack_params = [params["stack"][f"pos{j}"] for j in plan.pattern]

        def body(carry, layer_params):
            x, aux = carry
            for j, pl in enumerate(layer_params):
                x, a = one(pl, x, sigs[j])
                aux = aux + a
            return (x, aux), None

        (x, aux_total), _ = jax.lax.scan(
            body, (x, aux_total), tuple(stack_params))

    for i in plan.tail:
        x, a = one(params["tail"][f"layer{i}"], x, _sig(cfg, i))
        aux_total += a
    return x, aux_total


def forward(params: dict, cfg: ModelConfig, tokens: jax.Array, *,
            enc_inputs: Optional[jax.Array] = None,
            prefix_embeds: Optional[jax.Array] = None,
            return_hidden: bool = False
            ) -> Tuple[jax.Array, jax.Array]:
    """Training/prefill forward.

    tokens: (B, S) int32.
    enc_inputs: (B, S_enc, D) precomputed frame embeddings (whisper stub).
    prefix_embeds: (B, P, D) precomputed patch embeddings (paligemma stub).
    Returns (logits (B, S_total, V), aux_loss)."""
    x = embed_lookup(params["embed"]["tok"], tokens, cfg.d_model)
    x = x.astype(cfg.dtype())
    prefix_len = 0
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(cfg.dtype()), x], axis=1)
        prefix_len = prefix_embeds.shape[1]
    x = constrain(x, "dp", None, "tp")
    S = x.shape[1]
    positions = jnp.arange(S)

    enc_out = None
    if cfg.is_encdec:
        assert enc_inputs is not None
        e = constrain(enc_inputs.astype(cfg.dtype()), "dp", None, None)
        e_pos = jnp.arange(e.shape[1])
        e, _ = _run_stack(params["encoder"], e, cfg, cfg.n_enc_layers, 0,
                          positions=e_pos, causal=False)
        enc_out = rmsnorm(e, params["enc_norm"], cfg.norm_eps)

    x, aux = _run_stack(params["decoder"], x, cfg, cfg.n_layers,
                        cfg.first_dense_layers, positions=positions,
                        prefix_len=prefix_len, enc_out=enc_out)
    if return_hidden:
        # PRE-final-norm: the chunked-CE path applies final_norm per chunk
        # (a full-sequence f32 rmsnorm buffer costs GBs at 4k x 7k)
        return x, aux
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_out(params, x, cfg)
    logits = constrain(logits, "dp", None, "tp")
    return logits, aux
