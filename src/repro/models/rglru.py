"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Temporal-mixing block: two width-W branches; the recurrent branch runs a
causal conv then the Real-Gated LRU; the gate branch is GeLU; merged by
elementwise product and projected out. The recurrence is a first-order
linear scan -> jax.lax.associative_scan (log-depth, TPU-friendly).
Features shard over the model axis (2560 / 16 = 160 lanes per shard).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .param import ParamDef
from .config import ModelConfig

_C = 8.0  # Griffin's fixed gate exponent


class LRUCache(NamedTuple):
    h: jax.Array          # (B, W)
    conv: jax.Array       # (B, k-1, W)


def rglru_defs(cfg: ModelConfig) -> dict:
    dt = cfg.pdtype()
    D, W = cfg.d_model, cfg.lru_width_
    k = cfg.ssm_conv
    return {
        "w_in": ParamDef((D, W), dt, (None, "tp")),
        "w_gate_branch": ParamDef((D, W), dt, (None, "tp")),
        "conv": ParamDef((k, W), dt, (None, "tp"), scale=0.5),
        "w_a": ParamDef((W, W), dt, (None, "tp"), scale=0.02),
        "b_a": ParamDef((W,), jnp.float32, ("tp",), init="zeros"),
        "w_i": ParamDef((W, W), dt, (None, "tp"), scale=0.02),
        "b_i": ParamDef((W,), jnp.float32, ("tp",), init="zeros"),
        "lam": ParamDef((W,), jnp.float32, ("tp",), init="ones"),
        "w_out": ParamDef((W, D), dt, ("tp", None)),
    }


def _lru_coeffs(p: dict, u: jax.Array):
    """u: (B, S, W) conv output. Returns (a, b) of h_t = a_t h + b_t."""
    r = jax.nn.sigmoid((u @ p["w_a"]).astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid((u @ p["w_i"]).astype(jnp.float32) + p["b_i"])
    log_a0 = jax.nn.log_sigmoid(p["lam"])          # log a in (-inf, 0)
    log_a = _C * r * log_a0                        # (B, S, W)
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * (i * u.astype(jnp.float32))
    return a, b


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + pad[:, i:i + x.shape[1], :] * w[i]
    return out


def rglru_apply(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Training/prefill. x: (B, S, D) -> (B, S, D)."""
    u = _causal_conv(x @ p["w_in"], p["conv"])
    a, b = _lru_coeffs(p, u)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    gate = jax.nn.gelu((x @ p["w_gate_branch"]).astype(jnp.float32))
    y = (h * gate).astype(x.dtype)
    return y @ p["w_out"]


def rglru_init_cache(cfg: ModelConfig, batch: int, dtype) -> LRUCache:
    W, k = cfg.lru_width_, cfg.ssm_conv
    return LRUCache(h=jnp.zeros((batch, W), jnp.float32),
                    conv=jnp.zeros((batch, k - 1, W), dtype))


def rglru_step(p: dict, x: jax.Array, cache: LRUCache, cfg: ModelConfig
               ) -> Tuple[jax.Array, LRUCache]:
    """O(1) decode. x: (B, 1, D)."""
    xt = x[:, 0]
    u_raw = xt @ p["w_in"]
    win = jnp.concatenate([cache.conv, u_raw[:, None]], axis=1)
    u = jnp.einsum("bkc,kc->bc", win, p["conv"])
    a, b = _lru_coeffs(p, u[:, None, :])
    a, b = a[:, 0], b[:, 0]
    h = a * cache.h + b
    gate = jax.nn.gelu((xt @ p["w_gate_branch"]).astype(jnp.float32))
    y = (h * gate).astype(x.dtype)
    return (y @ p["w_out"])[:, None, :], LRUCache(h=h, conv=win[:, 1:])
