"""Activation-sharding helpers.

Model code annotates activations with *logical* axes; the launcher activates
resolution (single- vs multi-pod). Outside an active context (unit tests on
one device) the constraints are no-ops, so the same model code runs
everywhere.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import PartitionSpec as P

from .param import resolve_pspec

_state = threading.local()


def _active() -> Optional[bool]:
    return getattr(_state, "multi_pod", None)


@contextlib.contextmanager
def activation_sharding(multi_pod: bool, tp: int = 16):
    prev = _active()
    prev_tp = getattr(_state, "tp", None)
    _state.multi_pod = multi_pod
    _state.tp = tp
    try:
        yield
    finally:
        _state.multi_pod = prev
        _state.tp = prev_tp


def current_tp() -> Optional[int]:
    """Model-axis size, or None outside an activation_sharding context."""
    if _active() is None:
        return None
    return getattr(_state, "tp", None)


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """constrain(x, 'dp', 'tp', None) — logical axes per dim."""
    mp = _active()
    if mp is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, resolve_pspec(logical, multi_pod=mp))
