"""Mamba-2 SSD (state-space duality) block — chunked training form and O(1)
recurrent decode form.

Within a chunk of length Q the token mixing is the quadratic 'attention-like'
masked form; across chunks a (H, P, N) state is carried by a scan. Heads
shard over the model axis (80 heads / 16 = 5 for mamba2-2.7b); B/C are
group-shared (n_groups=1) and replicated.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .param import ParamDef
from .config import ModelConfig
from .blocks import rmsnorm


class SSDCache(NamedTuple):
    h: jax.Array          # (B, H, P, N) inter-chunk state
    conv_x: jax.Array     # (B, k-1, d_inner)
    conv_b: jax.Array     # (B, k-1, N)
    conv_c: jax.Array     # (B, k-1, N)


def ssd_defs(cfg: ModelConfig) -> dict:
    dt = cfg.pdtype()
    D, DI, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    k = cfg.ssm_conv
    return {
        "w_z": ParamDef((D, DI), dt, (None, "tp")),
        "w_x": ParamDef((D, DI), dt, (None, "tp")),
        "w_b": ParamDef((D, N), dt, (None, None)),
        "w_c": ParamDef((D, N), dt, (None, None)),
        "w_dt": ParamDef((D, H), dt, (None, "tp")),
        "dt_bias": ParamDef((H,), jnp.float32, ("tp",), init="zeros"),
        "a_log": ParamDef((H,), jnp.float32, ("tp",), init="zeros"),
        "d_skip": ParamDef((H,), jnp.float32, ("tp",), init="ones"),
        "conv_x": ParamDef((k, DI), dt, (None, "tp"), scale=0.5),
        "conv_b": ParamDef((k, N), dt, (None, None), scale=0.5),
        "conv_c": ParamDef((k, N), dt, (None, None), scale=0.5),
        "norm": ParamDef((DI,), dt, ("tp",), init="zeros"),
        "w_out": ParamDef((DI, D), dt, ("tp", None)),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv: x (B, S, C), w (k, C)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + pad[:, i:i + x.shape[1], :] * w[i]
    return out


def _ssd_scan(xh, bh, ch, dt_h, a_log, chunk: int):
    """Chunked SSD. xh: (B,S,H,P); bh/ch: (B,S,N); dt_h: (B,S,H) (post-
    softplus); a_log: (H,) (A = -exp(a_log)). Returns (B,S,H,P)."""
    B, S, H, P = xh.shape
    N = bh.shape[-1]
    Q = min(chunk, S)
    S_orig = S
    if S % Q:
        pad = Q - S % Q
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bh = jnp.pad(bh, ((0, 0), (0, pad), (0, 0)))
        ch = jnp.pad(ch, ((0, 0), (0, pad), (0, 0)))
        dt_h = jnp.pad(dt_h, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q
    A = -jnp.exp(a_log.astype(jnp.float32))                   # (H,)

    xq = xh.reshape(B, nc, Q, H, P)
    bq = bh.reshape(B, nc, Q, N).astype(jnp.float32)
    cq = ch.reshape(B, nc, Q, N).astype(jnp.float32)
    dtq = dt_h.reshape(B, nc, Q, H).astype(jnp.float32)

    lq = dtq * A                                              # log-decays
    cum = jnp.cumsum(lq, axis=2)                              # (B,nc,Q,H)

    def chunk_step(h, inp):
        xc, bc, cc, dtc, lc, cumc = inp
        # intra-chunk: scores[t,s] = (C_t . B_s) * exp(cum_t - cum_s) * dt_s
        seg = cumc[:, :, None, :] - cumc[:, None, :, :]       # (B,Q,Q,H)
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        decay = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)
        cb = jnp.einsum("btn,bsn->bts", cc, bc)               # (B,Q,Q)
        w = cb[..., None] * decay * dtc[:, None, :, :]        # (B,Q,Q,H)
        y_intra = jnp.einsum("btsh,bshp->bthp",
                             w, xc.astype(jnp.float32))
        # inter-chunk: y_t += C_t . h_in * exp(cum_t)
        y_inter = jnp.einsum("btn,bhpn,bth->bthp",
                             cc, h, jnp.exp(cumc))
        # state update: h_out = h_in*exp(cum_Q) + sum_s exp(cum_Q-cum_s)*dt_s x_s B_s
        tail = jnp.exp(cumc[:, -1:, :] - cumc) * dtc          # (B,Q,H)
        dh = jnp.einsum("bsh,bshp,bsn->bhpn",
                        tail, xc.astype(jnp.float32), bc)
        h_new = h * jnp.exp(cumc[:, -1, :])[:, :, None, None] + dh
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    inputs = (xq.transpose(1, 0, 2, 3, 4), bq.transpose(1, 0, 2, 3),
              cq.transpose(1, 0, 2, 3), dtq.transpose(1, 0, 2, 3),
              lq.transpose(1, 0, 2, 3), cum.transpose(1, 0, 2, 3))
    h_last, ys = jax.lax.scan(chunk_step, h0, inputs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    return y[:, :S_orig].astype(xh.dtype), h_last


def ssd_apply(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Training/prefill form. x: (B, S, D) -> (B, S, D)."""
    B, S, D = x.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state

    z = x @ p["w_z"]
    xi = _causal_conv(x @ p["w_x"], p["conv_x"])
    xi = jax.nn.silu(xi)
    b = jax.nn.silu(_causal_conv(x @ p["w_b"], p["conv_b"]))
    c = jax.nn.silu(_causal_conv(x @ p["w_c"], p["conv_c"]))
    dt_h = jax.nn.softplus(
        (x @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"])

    xh = xi.reshape(B, S, H, P)
    y, _ = _ssd_scan(xh, b, c, dt_h, p["a_log"], cfg.ssm_chunk)
    y = y + xh * p["d_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(B, S, H * P)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["w_out"]


def ssd_init_cache(cfg: ModelConfig, batch: int, dtype) -> SSDCache:
    H, P, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    k = cfg.ssm_conv
    return SSDCache(
        h=jnp.zeros((batch, H, P, N), jnp.float32),
        conv_x=jnp.zeros((batch, k - 1, cfg.d_inner), dtype),
        conv_b=jnp.zeros((batch, k - 1, N), dtype),
        conv_c=jnp.zeros((batch, k - 1, N), dtype),
    )


def ssd_step(p: dict, x: jax.Array, cache: SSDCache, cfg: ModelConfig
             ) -> Tuple[jax.Array, SSDCache]:
    """O(1) decode. x: (B, 1, D)."""
    B, _, D = x.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    xt = x[:, 0]

    z = xt @ p["w_z"]

    def conv_step(prev, new, w):
        # prev: (B, k-1, C); new: (B, C); w: (k, C)
        win = jnp.concatenate([prev, new[:, None]], axis=1)   # (B, k, C)
        out = jnp.einsum("bkc,kc->bc", win, w)
        return out, win[:, 1:]

    xi_raw = xt @ p["w_x"]
    xi, cx = conv_step(cache.conv_x, xi_raw, p["conv_x"])
    xi = jax.nn.silu(xi)
    b_raw = xt @ p["w_b"]
    b, cb = conv_step(cache.conv_b, b_raw, p["conv_b"])
    b = jax.nn.silu(b)
    c_raw = xt @ p["w_c"]
    c, cc = conv_step(cache.conv_c, c_raw, p["conv_c"])
    c = jax.nn.silu(c)
    dt_h = jax.nn.softplus(
        (xt @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"])  # (B, H)

    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt_h * A)                                 # (B, H)
    xh = xi.reshape(B, H, P).astype(jnp.float32)
    h = (cache.h * decay[:, :, None, None]
         + jnp.einsum("bh,bhp,bn->bhpn", dt_h, xh,
                      b.astype(jnp.float32)))
    y = jnp.einsum("bn,bhpn->bhp", c.astype(jnp.float32), h)
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(B, H * P).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = (y @ p["w_out"])[:, None, :]
    return out, SSDCache(h=h, conv_x=cx, conv_b=cb, conv_c=cc)
