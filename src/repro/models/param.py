"""Parameter specification DSL — one source of truth for init, abstract
(dry-run) params, and sharding.

Every parameter leaf is declared once as a ParamDef (shape, dtype, logical
partition spec, init scale). From the same tree of ParamDefs we derive:
  * init_params   — materialized random params (smoke tests, real training)
  * abstract      — jax.ShapeDtypeStruct stand-ins (dry-run: no allocation)
  * pspecs        — PartitionSpec tree (pjit in_shardings)

Logical axis names used in specs:
  "tp"   -> the tensor/model axis of the mesh ("model")
  "dp"   -> the data axis; params themselves never use it (ZeRO-1 optimizer
            state resharding happens in training/optimizer.py)
  None   -> replicated
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

LOGICAL_TO_PHYSICAL = {
    "tp": "model",
    "dp": "data",          # ("pod", "data") when multi_pod — see resolve()
}


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    dtype: Any = jnp.bfloat16
    spec: Tuple[Optional[str], ...] = ()   # logical names, len == ndim
    init: str = "normal"                   # normal | zeros | ones
    scale: Optional[float] = None          # None -> 1/sqrt(fan_in)

    def __post_init__(self):
        if self.spec and len(self.spec) != len(self.shape):
            raise ValueError(f"spec {self.spec} vs shape {self.shape}")


def resolve_axis(name: Optional[str], multi_pod: bool):
    if name is None:
        return None
    if name == "dp":
        return ("pod", "data") if multi_pod else "data"
    return LOGICAL_TO_PHYSICAL.get(name, name)


def resolve_pspec(spec: Sequence[Optional[str]], multi_pod: bool) -> P:
    return P(*[resolve_axis(s, multi_pod) for s in spec])


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def tree_map_defs(fn, defs):
    return jax.tree_util.tree_map(fn, defs, is_leaf=_is_def)


def abstract_params(defs):
    return tree_map_defs(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs)


def pspec_tree(defs, multi_pod: bool = False, fsdp_dp: int = 0):
    """fsdp_dp > 0: additionally shard each param's largest free
    dp-divisible axis over the DP axis (ZeRO-3 / FSDP). Required for
    params that exceed HBM under TP-only sharding (deepseek-v3-671b)."""
    def one(d: ParamDef):
        spec = list(d.spec or (None,) * len(d.shape))
        if fsdp_dp:
            best, best_dim = -1, 0
            for ax, (dim, s) in enumerate(zip(d.shape, spec)):
                if s is None and dim % fsdp_dp == 0 and dim > best_dim:
                    best, best_dim = ax, dim
            if best >= 0:
                spec[best] = "dp"
        return resolve_pspec(spec, multi_pod)
    return tree_map_defs(one, defs)


def init_params(defs, key: jax.Array):
    leaves, treedef = jax.tree_util.tree_flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = []
    for d, k in zip(leaves, keys):
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, d.dtype))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, d.dtype))
        else:
            fan_in = d.shape[-2] if len(d.shape) >= 2 else max(d.shape[-1], 1)
            scale = d.scale if d.scale is not None else fan_in ** -0.5
            out.append(
                (jax.random.normal(k, d.shape, jnp.float32) * scale
                 ).astype(d.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def count_params(defs) -> int:
    leaves = jax.tree_util.tree_leaves(
        tree_map_defs(lambda d: int(np.prod(d.shape)), defs))
    return int(sum(leaves))
