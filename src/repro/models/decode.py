"""Autoregressive decode: caches + single-token step for every family.

serve_step contract (the dry-run lowers exactly this):
    logits, cache = decode_step(params, cfg, token, cache)
with `cache.length` counting tokens *including* the current one.

Cache kinds:
  attn        full KV cache (B, Hkv, T_max, dh), rope'd keys
  local_attn  ring KV cache of size window + slot-position vector
  mla         latent cache (B, T_max, r) + rope cache (B, T_max, dr)
  ssd / rglru O(1) recurrent states
  cross       precomputed encoder K/V (whisper), never updated
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .blocks import rmsnorm, embed_lookup, logits_out, rope
from .attention import gqa_project, decode_attn, mla_decode, NEG_INF
from .ssm import ssd_init_cache, ssd_step
from .rglru import rglru_init_cache, rglru_step
from .moe import moe_apply
from .blocks import mlp_apply
from .transformer import stack_plan, _sig
from .sharding import constrain


# ------------------------------------------------------------- factories ---
def _attn_cache(cfg: ModelConfig, batch: int, t_max: int, kind: str):
    dt = cfg.dtype()
    if cfg.use_mla:
        return {
            "c": jnp.zeros((batch, t_max, cfg.kv_lora_rank), dt),
            "kr": jnp.zeros((batch, t_max, cfg.qk_rope_dim), dt),
        }
    t = min(t_max, cfg.local_window) if kind == "local_attn" else t_max
    return {
        "k": jnp.zeros((batch, cfg.n_kv_heads, t, cfg.head_dim_), dt),
        "v": jnp.zeros((batch, cfg.n_kv_heads, t, cfg.head_dim_), dt),
        "slot_pos": jnp.full((t,), -1, jnp.int32),
    }


def _layer_cache(cfg: ModelConfig, kind: str, batch: int, t_max: int):
    if kind in ("attn", "local_attn"):
        return _attn_cache(cfg, batch, t_max, kind)
    if kind == "ssd":
        return ssd_init_cache(cfg, batch, cfg.dtype())._asdict()
    if kind == "rglru":
        return rglru_init_cache(cfg, batch, cfg.dtype())._asdict()
    raise ValueError(kind)


def _stack_tree(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def init_cache(cfg: ModelConfig, batch: int, t_max: int,
               enc_out: Optional[jax.Array] = None,
               params: Optional[dict] = None) -> dict:
    """Build the decode cache pytree (mirrors the decoder param layout)."""
    plan = stack_plan(cfg, cfg.n_layers, cfg.first_dense_layers)
    kinds = cfg.layer_kinds()
    cache: Dict[str, Any] = {"head": {}, "stack": {}, "tail": {},
                             "length": jnp.zeros((), jnp.int32)}
    for i in plan.head:
        cache["head"][f"layer{i}"] = _layer_cache(cfg, kinds[i], batch, t_max)
    base = len(plan.head)
    for j in plan.pattern:
        if plan.repeats:
            per = [_layer_cache(cfg, kinds[base + j], batch, t_max)
                   for _ in range(plan.repeats)]
            cache["stack"][f"pos{j}"] = _stack_tree(per)
    for i in plan.tail:
        cache["tail"][f"layer{i}"] = _layer_cache(cfg, kinds[i], batch, t_max)

    if cfg.is_encdec:
        assert enc_out is not None and params is not None
        cross = {}
        plan_layers = (
            [("head", f"layer{i}") for i in plan.head]
            + [("stack", f"pos{j}") for j in plan.pattern]
            + [("tail", f"layer{i}") for i in plan.tail])
        B, Se, D = enc_out.shape
        Hkv, dh = cfg.n_kv_heads, cfg.head_dim_

        def kv_of(p):
            k = (enc_out @ p["wk"]).reshape(B, Se, Hkv, dh)
            v = (enc_out @ p["wv"]).reshape(B, Se, Hkv, dh)
            return {"k": k.transpose(0, 2, 1, 3), "v": v.transpose(0, 2, 1, 3)}

        for grp, name in plan_layers:
            pl = params["decoder"][grp][name]
            if grp == "stack" and plan.repeats:
                kv = jax.vmap(lambda c: kv_of(c))(pl["cross"])
                cross.setdefault(grp, {})[name] = kv
            else:
                cross.setdefault(grp, {})[name] = kv_of(pl["cross"])
        cache["cross"] = cross
    return cache


# ------------------------------------------------------------ layer step ---
def _attn_step(pl: dict, h: jax.Array, cache_l: dict, cfg: ModelConfig,
               kind: str, length: jax.Array):
    """h: (B, 1, D) normed input. Returns (out, new cache)."""
    B = h.shape[0]
    pos = length - 1                                    # current position
    if cfg.use_mla:
        out, c, kr = mla_decode(pl["attn"], h, cfg, c_cache=cache_l["c"],
                                kr_cache=cache_l["kr"], cache_len=length,
                                position=pos[None])
        return out, {"c": c, "kr": kr}

    q, k, v = gqa_project(pl["attn"], h, cfg)           # (B,*,1,dh)
    q = rope(q, pos[None, None, None], cfg.rope_theta)
    k = rope(k, pos[None, None, None], cfg.rope_theta)
    t_cache = cache_l["k"].shape[2]
    slot = jnp.where(kind == "local_attn", pos % t_cache,
                     jnp.minimum(pos, t_cache - 1)) if kind == "local_attn" \
        else pos
    slot = pos % t_cache if kind == "local_attn" else pos
    kc = jax.lax.dynamic_update_slice(
        cache_l["k"], k.astype(cache_l["k"].dtype), (0, 0, slot, 0))
    vc = jax.lax.dynamic_update_slice(
        cache_l["v"], v.astype(cache_l["v"].dtype), (0, 0, slot, 0))
    slot_pos = jax.lax.dynamic_update_slice(
        cache_l["slot_pos"], pos[None].astype(jnp.int32), (slot,))

    # mask from absolute slot positions (handles the ring buffer)
    dh = cfg.head_dim_
    qg = q.reshape(B, cfg.n_kv_heads, -1, dh)
    s = jnp.einsum("bhgd,bhtd->bhgt", qg.astype(jnp.float32),
                   kc.astype(jnp.float32)) * (dh ** -0.5)
    ok = (slot_pos >= 0) & (slot_pos <= pos)
    if kind == "local_attn":
        ok = ok & (slot_pos > pos - cfg.local_window)
    s = jnp.where(ok[None, None, None, :], s, NEG_INF)
    p_att = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgt,bhtd->bhgd", p_att, vc.astype(jnp.float32))
    o = o.reshape(B, 1, cfg.n_heads * dh).astype(h.dtype)
    out = o @ pl["attn"]["wo"]
    return out, {"k": kc, "v": vc, "slot_pos": slot_pos}


def _cross_step(p: dict, h: jax.Array, kv: dict, cfg: ModelConfig):
    B = h.shape[0]
    H, dh = cfg.n_heads, cfg.head_dim_
    q = (h @ p["wq"]).reshape(B, 1, H, dh).transpose(0, 2, 1, 3)
    o = decode_attn(q, kv["k"], kv["v"],
                    cache_len=jnp.asarray(kv["k"].shape[2], jnp.int32))
    o = o.reshape(B, 1, H * dh).astype(h.dtype)
    return o @ p["wo"]


def _layer_step(pl: dict, cache_l, x: jax.Array, cfg: ModelConfig,
                kind: str, is_moe: bool, length: jax.Array,
                cross_kv: Optional[dict] = None):
    h = rmsnorm(x, pl["norm1"], cfg.norm_eps)
    if kind in ("attn", "local_attn"):
        h, cache_l = _attn_step(pl, h, cache_l, cfg, kind, length)
    elif kind == "ssd":
        from .ssm import SSDCache
        h, new = ssd_step(pl["ssd"], h, SSDCache(**cache_l), cfg)
        cache_l = new._asdict()
    elif kind == "rglru":
        from .rglru import LRUCache
        h, new = rglru_step(pl["rglru"], h, LRUCache(**cache_l), cfg)
        cache_l = new._asdict()
    x = x + h
    if cross_kv is not None and "cross" in pl:
        h = rmsnorm(x, pl["norm_cross"], cfg.norm_eps)
        x = x + _cross_step(pl["cross"], h, cross_kv, cfg)
    if is_moe:
        h = rmsnorm(x, pl["norm2"], cfg.norm_eps)
        h, _ = moe_apply(pl["moe"], h, cfg)
        x = x + h
    elif cfg.d_ff > 0:
        h = rmsnorm(x, pl["norm2"], cfg.norm_eps)
        x = x + mlp_apply(pl["mlp"], h, cfg.act)
    return x, cache_l


# -------------------------------------------------------------- the step ---
def decode_step(params: dict, cfg: ModelConfig, token: jax.Array,
                cache: dict) -> Tuple[jax.Array, dict]:
    """token: (B,) int32. Returns (logits (B, V), new cache)."""
    plan = stack_plan(cfg, cfg.n_layers, cfg.first_dense_layers)
    kinds = cfg.layer_kinds()
    length = cache["length"] + 1
    pos = length - 1

    x = embed_lookup(params["embed"]["tok"], token[:, None], cfg.d_model)
    x = x.astype(cfg.dtype())
    x = constrain(x, "dp", None, None)

    new_cache: Dict[str, Any] = {"head": {}, "stack": {}, "tail": {},
                                 "length": length}
    if "cross" in cache:
        new_cache["cross"] = cache["cross"]

    def cross_of(grp, name, j=None):
        if "cross" not in cache:
            return None
        kv = cache["cross"][grp][name]
        return kv

    for i in plan.head:
        nm = f"layer{i}"
        x, c = _layer_step(params["decoder"]["head"][nm], cache["head"][nm],
                           x, cfg, kinds[i], False, length,
                           cross_of("head", nm))
        new_cache["head"][nm] = c

    if plan.repeats:
        base = len(plan.head)
        # scan jointly over the stacked params and caches of each position
        def body(x, per_layer):
            pls, cls, crs = per_layer
            for j in plan.pattern:
                nm = f"pos{j}"
                kind, m = _sig(cfg, base + j)
                x, cnew = _layer_step(pls[nm], cls[nm], x, cfg, kind, m,
                                      length,
                                      crs[nm] if crs is not None else None)
                cls = {**cls, nm: cnew}
            return x, cls

        pls = {f"pos{j}": params["decoder"]["stack"][f"pos{j}"]
               for j in plan.pattern}
        cls = {f"pos{j}": cache["stack"][f"pos{j}"] for j in plan.pattern}
        crs = (None if "cross" not in cache else
               {f"pos{j}": cache["cross"]["stack"][f"pos{j}"]
                for j in plan.pattern})
        xs = (pls, cls, crs) if crs is not None else (pls, cls, None)
        if crs is None:
            x, new_stack = jax.lax.scan(
                lambda x_, pc: body(x_, (pc[0], pc[1], None)), x, (pls, cls))
        else:
            x, new_stack = jax.lax.scan(body, x, (pls, cls, crs))
        new_cache["stack"] = new_stack

    for i in plan.tail:
        nm = f"layer{i}"
        x, c = _layer_step(params["decoder"]["tail"][nm], cache["tail"][nm],
                           x, cfg, kinds[i], _sig(cfg, i)[1], length,
                           cross_of("tail", nm))
        new_cache["tail"][nm] = c

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_out(params, x, cfg)[:, 0]
    logits = constrain(logits, "dp", "tp")
    return logits, new_cache
