"""Certified-staleness PPR result cache.

A personalized query is a pure function of (seed set, weights, alpha,
graph version) — but invalidating on every version bump throws away
almost every entry in the update-while-serve steady state, where a small
delta barely moves the mass near most seed sets.  This cache keeps an
entry *across* graph versions by maintaining the one thing that certifies
it: the entry's exact linear-system residual

    r = b + alpha S x - x,      ||x - x*||_1 <= ||r||_1 / (1 - alpha)

against the CURRENT graph.  A graph delta perturbs only the transition
columns of sources whose out-row changed, so the residual advances by the
same sparse seeding rule `update_ranks` uses on the global rank state:

    r += alpha * sum_{u touched, x[u] != 0}
             x[u] * (col_new(u) - col_old(u))

— O(degree) work per touched source that actually carries cached mass,
and the resulting bound is *exact*, not a drift estimate: an entry
survives any number of versions whose deltas never touch its mass, and
dies precisely when real drift pushes ||r||_1/(1-alpha) past its tol.
(A naive Lipschitz drift bound ||x*_new - x*_old||_1 <=
2 alpha/(1-alpha) * sum_T |x*_old[u]| compounds its own slack by
~12x per version at alpha=0.85 and evicts everything after one update —
maintaining the residual is what makes cross-version caching work.)

Eviction/flush rules: node-count changes and version gaps (deltas the
cache never saw) flush everything; a touched source that flips dangling
status while carrying cached mass evicts that entry (its column change
is dense — not worth the correction); an entry whose bound exceeds its
own solve tol is dropped eagerly.

`note_update(receipt)` runs on the updater thread (under the server's
update lock, BEFORE the new snapshot publishes); `get`/`put` run on
query threads.  One internal lock serializes the table.  Memory is two
dense (n,) float64 vectors per entry — size `capacity` accordingly
(64 entries * 50k nodes ~ 50 MB).
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Optional, Tuple

import numpy as np

from ..streaming.incremental import validate_seeds


@dataclasses.dataclass
class CacheHitStats:
    """Stats stand-in for a personalized() answer served from cache."""
    path: str            # "cache"
    cert: float          # the exact residual bound returned as cert
    solved_version: int  # graph version the entry was solved at
    served_version: int  # graph version it was served at (certified gap)


@dataclasses.dataclass
class _Entry:
    x: np.ndarray        # (n,) read-only PPR vector
    r: np.ndarray        # (n,) exact residual vs the CURRENT graph
    bound: float         # ||r||_1 / (1 - alpha), kept in sync with r
    tol: float           # tol it was solved at (eager-eviction threshold)
    solved_version: int


class PPRCache:
    """LRU cache of personalized PageRank results with exact
    residual-maintained certification across graph versions (see module
    docstring)."""

    def __init__(self, alpha: float = 0.85, capacity: int = 64):
        self.alpha = float(alpha)
        self.capacity = int(capacity)
        self._entries: "OrderedDict[bytes, _Entry]" = OrderedDict()
        self._version: Optional[int] = None
        self._n: Optional[int] = None
        self._lock = threading.Lock()
        # telemetry
        self.hits = 0
        self.misses = 0
        self.puts = 0
        self.drift_rejects = 0   # entry present but bound > query tol
        self.evictions = 0
        self.flushes = 0
        self.survivals = 0       # entry crossed a version and stayed valid

    # ------------------------------------------------------------------
    @staticmethod
    def _key(n: int, seeds, weights) -> bytes:
        s, w = validate_seeds(n, seeds, weights)
        return s.tobytes() + b"|" + w.tobytes()

    def _flush_locked(self) -> None:
        if self._entries:
            self.flushes += 1
        self._entries.clear()

    # ------------------------------------------------------------------
    def note_update(self, receipt) -> None:
        """Advance every entry's exact residual across one applied delta
        (`DeltaReceipt`).  Called by the updater before it publishes the
        new snapshot, so fresh-snapshot queries can already hit."""
        if receipt is None:
            return
        alpha = self.alpha
        with self._lock:
            if receipt.n_new != receipt.n_old or (
                    self._version is not None
                    and receipt.version != self._version + 1):
                # shape change, or a version gap we never accounted for:
                # no certificate survives an unobserved delta
                self._flush_locked()
            elif self._entries:
                touched = receipt.touched
                dead = []
                for key, e in self._entries.items():
                    xt = e.x[touched]
                    live = np.flatnonzero(xt)
                    ok = True
                    for i in live:
                        xu = xt[i]
                        od, nd = receipt.old_deg[i], receipt.new_deg[i]
                        if od == 0 or nd == 0:
                            # dangling flip under cached mass: the
                            # column change is dense — evict
                            ok = False
                            break
                        e.r[receipt.old_rows[i]] -= alpha * xu / od
                        e.r[receipt.new_rows[i]] += alpha * xu / nd
                    if not ok:
                        dead.append(key)
                        continue
                    if live.size:
                        e.bound = float(np.abs(e.r).sum()) / (1.0 - alpha)
                    if e.bound > e.tol:
                        # it can never again answer the query it was
                        # solved for — drop now instead of at lookup
                        dead.append(key)
                    else:
                        self.survivals += 1
                for key in dead:
                    del self._entries[key]
                    self.evictions += 1
            self._version = receipt.version
            self._n = receipt.n_new

    # ------------------------------------------------------------------
    def get(self, snap, seeds, weights, tol: float
            ) -> Optional[Tuple[np.ndarray, float, CacheHitStats]]:
        """Certified lookup against snapshot `snap`: returns
        (x, bound, stats) only when the entry's exact residual bound
        clears `tol` at the snapshot's version, else None."""
        key = self._key(snap.n, seeds, weights)
        with self._lock:
            if self._version is not None and snap.version != self._version:
                self.misses += 1
                return None
            e = self._entries.get(key)
            if e is None:
                self.misses += 1
                return None
            if e.bound > tol:
                self.drift_rejects += 1
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return e.x, float(e.bound), CacheHitStats(
                path="cache", cert=float(e.bound),
                solved_version=e.solved_version,
                served_version=int(snap.version))

    def put(self, snap, seeds, weights, tol: float,
            x: np.ndarray, cert: float) -> bool:
        """Insert a freshly solved result, deriving its exact residual
        from the snapshot's captured operator (one host spmv).  Rejected
        (returns False) when the snapshot carries no operator
        (`snapshot_ops` off) or is not at the cache's accounted version —
        a result solved against a version whose deltas we already
        advanced past cannot be re-certified."""
        if snap.op is None or snap.pt_sp is None:
            return False
        s, w = validate_seeds(snap.n, seeds, weights)
        key = s.tobytes() + b"|" + w.tobytes()
        x = np.asarray(x, dtype=np.float64)
        from ..graph.google import GoogleOperator
        v = np.zeros(snap.n)
        v[s] = w
        op = GoogleOperator(pt=snap.op.pt, alpha=self.alpha, v=v)
        r = op.apply_linear_numpy(x, pt_sp=snap.pt_sp) - x
        bound = float(np.abs(r).sum()) / (1.0 - self.alpha)
        with self._lock:
            if self._version is None:
                self._version = int(snap.version)
                self._n = int(snap.n)
            if snap.version != self._version or snap.n != self._n \
                    or bound > tol:
                return False
            xr = x.copy()
            xr.setflags(write=False)
            self._entries[key] = _Entry(
                x=xr, r=r, bound=bound, tol=float(tol),
                solved_version=int(snap.version))
            self._entries.move_to_end(key)
            self.puts += 1
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.evictions += 1
            return True

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def stats(self) -> dict:
        with self._lock:
            return dict(
                entries=len(self._entries), hits=self.hits,
                misses=self.misses, puts=self.puts,
                drift_rejects=self.drift_rejects,
                evictions=self.evictions, flushes=self.flushes,
                survivals=self.survivals,
                version=self._version)
