"""Query-tier scale-out over the streaming `RankServer`.

Three composable pieces (see docs/serving.md):

  * `QueryBatcher`  — fuses concurrent `personalized()` calls into the
                      (n, nv) lane solve `ppr_push_batched`;
  * `QueryRouter` / `ReadReplica` — N snapshot holders behind
                      staleness-bounded reads with atomic publish fan-out;
  * `PPRCache`      — (seed set, version)-keyed result cache with
                      certified-staleness invalidation.

`attach_query_tier(server)` wires all three.  The LLM serving engine
(`serving.engine`) is a separate subsystem and is deliberately NOT
imported here — import it as `repro.serving.engine` directly.
"""
from .batcher import QueryBatcher
from .ppr_cache import CacheHitStats, PPRCache
from .router import QueryRouter, ReadReplica, StalenessBoundExceeded

__all__ = [
    "QueryBatcher", "PPRCache", "CacheHitStats",
    "QueryRouter", "ReadReplica", "StalenessBoundExceeded",
    "attach_query_tier",
]


def attach_query_tier(server, *, max_batch: int = 16,
                      max_delay_s: float = 0.002,
                      cache_capacity: int = 64, replicas: int = 0,
                      max_version_lag: int = 0, on_stale: str = "redirect",
                      backend: str = "auto"):
    """Wire a full query tier onto a `RankServer`.

    Returns (batcher, cache, router); router is None when replicas == 0.
    The batcher is attached and running; stop it with `batcher.stop()`.
    """
    cache = PPRCache(alpha=server.alpha, capacity=cache_capacity)
    server._ppr_cache = cache
    batcher = QueryBatcher(server, max_batch=max_batch,
                           max_delay_s=max_delay_s,
                           backend=backend).attach()
    router = None
    if replicas > 0:
        router = QueryRouter(server, replicas,
                             max_version_lag=max_version_lag,
                             on_stale=on_stale)
    return batcher, cache, router
