"""Replica router: staleness-bounded reads over snapshot fan-out.

Scaling reads means many holders of the stable buffer, and the
`RankSnapshot` is built for that: immutable, certified, version-stamped.
A `ReadReplica` is nothing but an atomic reference to the latest snapshot
it received — replicas never copy the rank vector, never lock, and serve
`top_k`/`scores`/`personalized` straight off their reference.  The
updating `RankServer` fans each publish out through `subscribe()`
(`_cut_snapshot` → every replica's `install`), so replica installs are
reference swaps on the updater thread.

The `QueryRouter` fronts N replicas with *staleness-bounded reads*: a
replica may answer only while its snapshot is admissible against the
bounds —

    version lag  <= max_version_lag   (graph versions behind dg.version)
    cert         <= max_cert          (published L1 certificate), optional
    age          <= max_age_s         (wall-clock since publish), optional

A read landing on an inadmissible replica either raises
`StalenessBoundExceeded` (on_stale="reject") or is redirected to the
freshest admissible replica (on_stale="redirect", the default) and only
raises when no replica qualifies.  Replicas can be `pause()`d (stop
installing publishes) to simulate a partitioned or lagging holder — the
router routes around it.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional

import numpy as np

from ..streaming.incremental import ppr_push


class StalenessBoundExceeded(RuntimeError):
    """No admissible replica could serve the read within the bounds."""


class ReadReplica:
    """An atomic holder of the latest installed `RankSnapshot`."""

    def __init__(self, name: str):
        self.name = name
        self._snap = None
        self._paused = False
        self.installs = 0
        self.served = 0

    def install(self, snap) -> None:
        """Publish fan-out target (runs on the updater thread)."""
        if not self._paused:
            self._snap = snap    # atomic reference swap
            self.installs += 1

    def pause(self) -> None:
        """Stop accepting installs (simulates a partitioned replica)."""
        self._paused = True

    def resume(self) -> None:
        self._paused = False

    @property
    def snapshot(self):
        return self._snap


class QueryRouter:
    """Round-robin router with staleness-bounded reads over replicas."""

    def __init__(self, server, replicas: int = 2, *,
                 max_version_lag: int = 0,
                 max_cert: Optional[float] = None,
                 max_age_s: Optional[float] = None,
                 on_stale: str = "redirect"):
        if replicas < 1:
            raise ValueError("need at least one replica")
        if on_stale not in ("redirect", "reject"):
            raise ValueError(f"unknown on_stale {on_stale!r}; expected "
                             "'redirect' or 'reject'")
        self.server = server
        self.max_version_lag = int(max_version_lag)
        self.max_cert = max_cert
        self.max_age_s = max_age_s
        self.on_stale = on_stale
        self.replicas: List[ReadReplica] = [
            ReadReplica(f"replica-{i}") for i in range(replicas)]
        for rep in self.replicas:
            server.subscribe(rep.install)
        self._rr = 0
        self._lock = threading.Lock()
        # telemetry
        self.routed = 0
        self.redirects = 0
        self.rejects = 0

    # ------------------------------------------------------------------
    def _admissible(self, snap) -> bool:
        if snap is None:
            return False
        lag = self.server.dg.version - snap.version
        if lag > self.max_version_lag:
            return False
        if self.max_cert is not None and snap.cert > self.max_cert:
            return False
        if self.max_age_s is not None \
                and time.time() - snap.published_at > self.max_age_s:
            return False
        return True

    def _pick(self) -> "tuple[ReadReplica, object]":
        """Round-robin pick, then enforce the staleness bound: redirect
        to the freshest admissible replica or raise."""
        with self._lock:
            rep = self.replicas[self._rr % len(self.replicas)]
            self._rr += 1
            self.routed += 1
        snap = rep.snapshot
        if self._admissible(snap):
            rep.served += 1
            return rep, snap
        if self.on_stale == "reject":
            with self._lock:
                self.rejects += 1
            raise StalenessBoundExceeded(
                f"{rep.name} snapshot (version "
                f"{None if snap is None else snap.version}) violates the "
                f"staleness bound (graph at {self.server.dg.version})")
        best, best_snap = None, None
        for cand in self.replicas:
            s = cand.snapshot
            if self._admissible(s) and (
                    best_snap is None or s.version > best_snap.version
                    or (s.version == best_snap.version
                        and s.seq > best_snap.seq)):
                best, best_snap = cand, s
        if best is None:
            with self._lock:
                self.rejects += 1
            raise StalenessBoundExceeded(
                "no replica within the staleness bound "
                f"(graph at version {self.server.dg.version})")
        with self._lock:
            self.redirects += 1
        best.served += 1
        return best, best_snap

    # ------------------------------------------------------------------
    # staleness-bounded reads
    # ------------------------------------------------------------------
    def top_k(self, k: int = 10):
        _, snap = self._pick()
        return snap.top_k(k)

    def scores(self, ids) -> np.ndarray:
        _, snap = self._pick()
        return snap.scores(ids)

    def personalized(self, seeds, weights=None, tol: float = 1e-4):
        """Replica-local PPR: pushed against the chosen replica's frozen
        view, so the certificate is against that snapshot's version (the
        one the staleness bound just admitted)."""
        _, snap = self._pick()
        return ppr_push(snap.view, seeds, weights=weights,
                        alpha=self.server.alpha, tol=tol)

    def stats(self) -> Dict[str, object]:
        return dict(
            routed=self.routed, redirects=self.redirects,
            rejects=self.rejects,
            replicas=[dict(name=r.name, installs=r.installs,
                           served=r.served, paused=r._paused,
                           version=(None if r.snapshot is None
                                    else int(r.snapshot.version)))
                      for r in self.replicas])
