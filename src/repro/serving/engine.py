"""Batched serving engine: prefill (token-by-token through the cache —
exactly consistent with decode by construction) + sampled generation."""
from __future__ import annotations

from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.decode import init_cache, decode_step
from ..models.transformer import _run_stack
from ..models.blocks import rmsnorm


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, max_len: int = 512,
                 enc_inputs: Optional[jax.Array] = None):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.enc_out = None
        if cfg.is_encdec:
            if enc_inputs is None:
                enc_inputs = jnp.zeros((1, 16, cfg.d_model), cfg.dtype())
            e, _ = _run_stack(params["encoder"],
                              enc_inputs.astype(cfg.dtype()), cfg,
                              cfg.n_enc_layers, 0,
                              positions=jnp.arange(enc_inputs.shape[1]),
                              causal=False)
            self.enc_out = rmsnorm(e, params["enc_norm"], cfg.norm_eps)
        self._step = jax.jit(
            lambda p, t, c: decode_step(p, self.cfg, t, c))

    def new_cache(self, batch: int):
        enc = self.enc_out
        if enc is not None and enc.shape[0] != batch:
            enc = jnp.broadcast_to(enc, (batch,) + enc.shape[1:])
        return init_cache(self.cfg, batch, self.max_len,
                          enc_out=enc, params=self.params)

    def prefill(self, tokens: jax.Array, cache=None):
        """tokens: (B, S). Feeds the prompt through the decode path."""
        B, S = tokens.shape
        cache = cache or self.new_cache(B)
        logits = None
        for t in range(S):
            logits, cache = self._step(self.params, tokens[:, t], cache)
        return logits, cache

    def generate(self, prompts: jax.Array, n_tokens: int,
                 temperature: float = 1.0, seed: int = 0) -> jax.Array:
        B, S = prompts.shape
        logits, cache = self.prefill(prompts)
        key = jax.random.PRNGKey(seed)
        out = []
        tok = self._sample(logits, key, temperature)
        out.append(tok)
        for i in range(n_tokens - 1):
            key = jax.random.fold_in(key, i)
            logits, cache = self._step(self.params, tok, cache)
            tok = self._sample(logits, key, temperature)
            out.append(tok)
        return jnp.stack(out, axis=1)

    def _sample(self, logits: jax.Array, key, temperature: float):
        # mask padded vocab tail
        v = self.cfg.vocab_size
        neg = jnp.full_like(logits, -1e30)
        logits = jnp.where(jnp.arange(logits.shape[-1]) < v, logits, neg)
        if temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / temperature, axis=-1).astype(jnp.int32)
