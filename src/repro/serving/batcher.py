"""Query batcher: fuse concurrent personalized() calls into lane solves.

One personalized query is a push solve that walks the graph alone; nv
concurrent queries through `ppr_push_batched` share every CSR/BSR block
load across the (n, nv) teleport lanes of `core.backend` — the same
multi-vector machinery the randomized-update solvers use, pointed at the
query path.  The batcher is the admission window that turns independent
callers into those lanes:

  * callers enqueue and block on a per-query event;
  * a collector thread dispatches a batch when either `max_batch` queries
    are waiting or the oldest has waited `max_delay_s` (the classic
    size-or-deadline window: bounded added latency, unbounded fusion
    opportunity under load);
  * the batch is solved against ONE snapshot (the stable buffer at
    dispatch), so every answer in a batch certifies against the same
    graph version — mixed per-query tolerances ride the solver's
    per-lane tol, and lane freezing keeps loose queries from paying for
    tight ones;
  * a single waiting query skips the lane solve and takes the plain
    push path (localized seeds beat a full-vector solve at nv=1).

Attach with `QueryBatcher(server).attach()` (or
`serving.attach_query_tier`): attaching flips the server to
`snapshot_ops=True` so every published snapshot carries the
GoogleOperator + host P^T the fused solve and its exact certification
consume.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import List, Optional

import numpy as np

from ..streaming.incremental import ppr_push, ppr_push_batched, validate_seeds


@dataclasses.dataclass
class _Pending:
    seeds: np.ndarray
    weights: np.ndarray
    tol: float
    done: threading.Event
    result: Optional[tuple] = None
    error: Optional[BaseException] = None


class QueryBatcher:
    """Size-or-deadline admission window over `ppr_push_batched`."""

    def __init__(self, server, max_batch: int = 16,
                 max_delay_s: float = 0.002,
                 backend: str = "auto", method: str = "linear",
                 freeze_lanes="auto", freeze_chunk="auto"):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.server = server
        self.max_batch = int(max_batch)
        self.max_delay_s = float(max_delay_s)
        self.backend = backend
        self.method = method
        self.freeze_lanes = freeze_lanes
        self.freeze_chunk = freeze_chunk
        self._pending: List[_Pending] = []
        self._cv = threading.Condition()
        self._thread: Optional[threading.Thread] = None
        self._stop = False
        # telemetry
        self.queries = 0
        self.batches = 0
        self.fused_lanes = 0     # queries that went through a >1 batch
        self.max_batch_seen = 0

    # ------------------------------------------------------------------
    def attach(self) -> "QueryBatcher":
        """Register on the server (personalized() starts routing here)
        and start the collector."""
        self.server.enable_snapshot_ops()
        self.server._ppr_batcher = self
        self.start()
        return self

    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop = False
        self._thread = threading.Thread(
            target=self._run, name="ppr-batcher", daemon=True)
        self._thread.start()

    def stop(self) -> None:
        """Detach from the server, dispatch whatever is still waiting,
        and stop the collector."""
        if self.server._ppr_batcher is self:
            self.server._ppr_batcher = None
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=30.0)
            self._thread = None

    # ------------------------------------------------------------------
    def submit(self, seeds, weights, tol: float) -> tuple:
        """Block until the batch containing this query is solved; returns
        (x, cert, stats, snapshot_used).  Validation errors raise here,
        synchronously, in the caller's thread."""
        n = self.server.snapshot().n
        s, w = validate_seeds(n, seeds, weights)
        item = _Pending(seeds=s, weights=w, tol=float(tol),
                        done=threading.Event())
        with self._cv:
            if self._stop or self._thread is None:
                raise RuntimeError("QueryBatcher is not running")
            self._pending.append(item)
            self.queries += 1
            self._cv.notify_all()
        item.done.wait()
        if item.error is not None:
            raise item.error
        return item.result

    def flush(self) -> None:
        """Dispatch anything currently waiting without waiting out the
        delay window (tests and shutdown)."""
        with self._cv:
            batch = self._pending
            self._pending = []
        if batch:
            self._solve(batch)

    # ------------------------------------------------------------------
    def _run(self) -> None:
        while True:
            with self._cv:
                while not self._pending and not self._stop:
                    self._cv.wait()
                if self._stop and not self._pending:
                    return
                deadline = time.monotonic() + self.max_delay_s
                while (len(self._pending) < self.max_batch
                       and not self._stop):
                    left = deadline - time.monotonic()
                    if left <= 0:
                        break
                    self._cv.wait(timeout=left)
                batch = self._pending[:self.max_batch]
                self._pending = self._pending[self.max_batch:]
            if batch:
                self._solve(batch)

    def _solve(self, batch: List[_Pending]) -> None:
        snap = self.server.snapshot()
        try:
            if len(batch) == 1 or snap.op is None:
                # nv=1 (or an op-less snapshot from before attach):
                # localized pushes win — no reason to touch every node
                for it in batch:
                    x, cert, stats = ppr_push(
                        snap.view, it.seeds, weights=it.weights,
                        alpha=self.server.alpha, tol=it.tol)
                    it.result = (x, cert, stats, snap)
            else:
                X, certs, stats = ppr_push_batched(
                    snap.view, [it.seeds for it in batch],
                    [it.weights for it in batch],
                    alpha=self.server.alpha,
                    tol=np.array([it.tol for it in batch]),
                    op=snap.op, pt_sp=snap.pt_sp,
                    backend=self.backend, method=self.method,
                    freeze_lanes=self.freeze_lanes,
                    freeze_chunk=self.freeze_chunk)
                for i, it in enumerate(batch):
                    it.result = (X[:, i], float(certs[i]), stats, snap)
                self.fused_lanes += len(batch)
        except BaseException as exc:   # wake every waiter, never deadlock
            for it in batch:
                it.error = exc
        finally:
            self.batches += 1
            self.max_batch_seen = max(self.max_batch_seen, len(batch))
            for it in batch:
                it.done.set()

    def stats(self) -> dict:
        return dict(queries=self.queries, batches=self.batches,
                    fused_lanes=self.fused_lanes,
                    max_batch_seen=self.max_batch_seen,
                    mean_batch=(self.queries / self.batches
                                if self.batches else 0.0))
