"""Shard runtime — the substrate-independent core of the paper's
asynchronous iteration (see docs/runtime.md).

The paper's cycle — local fragment updates over stale views (eq. 5),
flexible message targeting (§6), and the Fig. 1 termination protocol — is
independent of the execution substrate.  This package factors it out of the
three substrates that used to hand-roll it (`core.des`, `core.spmd`,
`streaming`):

  state    — ShardState: one shard's owned fragment + versioned stale views.
  local    — LocalSolver protocol + the backend-dispatched block update
             (eq. 6/7 restricted to a partition block) every substrate
             shares.
  exchange — ExchangePlan: who messages whom, when, and with what fragment
             subset.  Covers all_to_all / ring / adaptive / allgather_k and
             the §6 `sparsified` plan (residual-mass targeting + top-k row
             payloads), in both the host/event rendering (DES, streaming)
             and the bulk-synchronous jax rendering (SPMD shard_map).
  driver   — TerminationDriver: drives the pure Fig. 1 machines
             (core.termination) in the message-passing, all-reduced-value,
             and all-reduced-bit renderings.
  transport— the transport-agnostic shard-worker layer: the per-shard
             cycle (`shard_worker_loop`) written once against the
             `TransportContext`/`Channel` seam, with two host renderings —
             threads (PairMailbox accumulators, driver lock) and procpool
             (worker processes over a ShardArena, mailboxes and Fig. 1
             messages on lock-free shared rings).
  step     — ShardStep: the cycle one level deeper, as a per-shard step —
             `HostShardStep` (the worker-loop round, verbatim) plus the
             jax-traceable builders (`shard_pt_apply` /
             `shard_local_update` / `shard_superstep_fns`) that core.spmd
             and the device transport assemble into one traced body, and
             `comm_bytes_model`, the shared exchange byte accounting.
  device   — DeviceShardTransport: the third transport rendering — p
             shard programs over a `ue` device mesh running the traced
             ShardStep (Pallas BSR or segment-sum drain, collective
             exchange, all-reduced Fig. 1 bits), float64 end-to-end for
             1e-8 certificates.
  executor — AsyncShardExecutor: the thread rendering's public face — one
             thread per shard, per-pair boundary-residual mailboxes (no
             superstep barrier), ExchangePlan consulted per local update,
             termination through the driver's message rendering.
  faults   — FaultPlan / FaultyContext: deterministic seeded fault
             injection (worker kill/hang, exchange drop/dup/delay, slow
             shards) at the TransportContext seam, for both renderings.
  supervisor — ShardSupervisor: self-healing for the procpool rendering —
             supervised worker restart with capped backoff, checkpoint
             restore, ledger reconciliation, conservative Fig. 1 re-entry.
  observe  — ShardObserver: lock-cheap per-shard metrics registry,
             ring-buffered event tracing at the cycle seams (Chrome
             trace_event export), and push-inflation attribution — the
             same arrays work in-process and as ShardArena views, and
             everything is zero-cost when off (docs/observability.md).
  schedule — DrainSchedule: pluggable update ordering for the drain hot
             paths — priority (D-Iteration fluid retention),
             boundary-batched exchange coalescing, seeded randomized
             control — selected by `ScheduleSpec` and threaded through
             `update_ranks_sharded(schedule=)` / `WorkerConfig.schedule` /
             `RankServer(drain_schedule=)`; mass accounting and the L1
             certificate are schedule-independent by construction.
"""
from .state import (ArenaHandle, ShardArena, ShardState,
                    sweep_stale_segments)
from .local import LocalSolver, BlockLocalSolver
from .exchange import (ExchangePlan, AllToAllPlan, RingPlan, AdaptivePlan,
                       SparsifiedPlan, make_plan, spmd_exchange)
from .driver import TerminationDriver
from .faults import (FaultPlan, FaultState, FaultyContext,
                     InjectedWorkerKill)
from .observe import (EV_NAMES, OBS_COUNTERS, ShardObserver,
                      attribute_frontier, chrome_trace, render_prometheus,
                      write_chrome_trace)
from .schedule import (DEFAULT_SCHEDULE, SCHEDULES, DrainOrder,
                       ExchangeGate, PriorityOrder, RandomizedOrder,
                       ScheduleSpec, make_schedule)
from .supervisor import BackoffPolicy, RestartEvent, ShardSupervisor
from .transport import (Channel, HostAllReduce, ProcPoolShardExecutor,
                        ReductionChannel, ShmRing, ThreadedShardTransport,
                        TransportContext, WorkerConfig, default_pool_size,
                        mesh_psum, shard_worker_loop)
from .step import (HostShardStep, comm_bytes_model, shard_local_update,
                   shard_pt_apply, shard_superstep_fns)
from .device import DeviceRunResult, DeviceShardTransport
from .executor import (AsyncRunResult, AsyncShardExecutor, PairMailbox,
                       UniformAccumulator)

__all__ = [
    "ShardState", "ShardArena", "ArenaHandle", "sweep_stale_segments",
    "LocalSolver", "BlockLocalSolver",
    "ExchangePlan", "AllToAllPlan", "RingPlan", "AdaptivePlan",
    "SparsifiedPlan", "make_plan", "spmd_exchange",
    "TerminationDriver",
    "FaultPlan", "FaultState", "FaultyContext", "InjectedWorkerKill",
    "BackoffPolicy", "RestartEvent", "ShardSupervisor",
    "ShardObserver", "EV_NAMES", "OBS_COUNTERS", "attribute_frontier",
    "chrome_trace", "write_chrome_trace", "render_prometheus",
    "ScheduleSpec", "SCHEDULES", "DEFAULT_SCHEDULE", "make_schedule",
    "DrainOrder", "PriorityOrder", "RandomizedOrder", "ExchangeGate",
    "Channel", "TransportContext", "WorkerConfig", "shard_worker_loop",
    "ThreadedShardTransport", "ProcPoolShardExecutor", "ShmRing",
    "default_pool_size", "ReductionChannel", "HostAllReduce", "mesh_psum",
    "HostShardStep", "shard_pt_apply", "shard_local_update",
    "shard_superstep_fns", "comm_bytes_model",
    "DeviceShardTransport", "DeviceRunResult",
    "AsyncRunResult", "AsyncShardExecutor", "PairMailbox",
    "UniformAccumulator",
]
