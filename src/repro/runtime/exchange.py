"""ExchangePlan — who messages whom, when, with what fragment subset.

The paper's §6 observation is that asynchronous iterations leave "a choice
on the targets of produced messages".  An ExchangePlan encodes that choice
once, in two renderings:

  host/event rendering (DES engine, sharded streaming updater)
      `wants(i, d, it)`      — topology/cadence gate: does shard i message
                               peer d after its it-th local update?
      `gate_mass(i, d, it, mass)` — §6 residual-mass gate: is the payload
                               worth sending right now?  A forced full
                               refresh every `refresh_every` local updates
                               keeps delays bounded (Frommer-Szyld
                               convergence needs every fragment refreshed
                               within a finite window).
      `payload_rows(delta_abs)` — optional top-k row selection so payloads
                               shrink as the sender converges.
      `on_result(i, d, ok)`  — feedback (delivered / canceled), used by the
                               adaptive backoff policy.

  bulk-synchronous rendering (SPMD shard_map) — `spmd_exchange` returns the
      (init_state, comm_step) pair for the jax while_loop: allgather,
      allgather_k, ring (collective_permute relay), and sparsified (top-k
      rows by |delta| above a residual threshold, all-gathered as (idx,
      val) pairs, with the same forced-full-refresh bound).

Both renderings of `sparsified` satisfy the bounded-delay condition by
construction: whatever the threshold, shard d's copy of fragment i is
refreshed in full at least every `refresh_every` sender updates (property-
tested in tests/test_runtime.py).  In the SPMD rendering the forced
refresh bypasses the delivery-drop gate (it models a reliable
synchronization epoch), so the bound holds for any delivery_prob; sparse
payloads between refreshes may still drop.
"""
from __future__ import annotations

from typing import Optional

import numpy as np


# ---------------------------------------------------------------------------
# host/event rendering
# ---------------------------------------------------------------------------
class ExchangePlan:
    """Base plan: all-to-all every local update, full fragments."""

    name = "all_to_all"

    def __init__(self, p: int):
        self.p = p

    def wants(self, i: int, d: int, it: int) -> bool:
        """Topology/cadence gate for a message i -> d after i's it-th local
        update (callers have already excluded d == i)."""
        return True

    def gate_mass(self, i: int, d: int, it: int, mass: float) -> bool:
        """Residual-mass gate (§6): True = send now. Default sends always."""
        return True

    def refresh_due(self, i: int, d: int, it: int) -> bool:
        """True when the payload i -> d must ship as a *full* fragment
        (engines skip `payload_rows` then).  Plans without partial payloads
        always ship full."""
        return True

    def payload_rows(self, delta_abs: np.ndarray,
                     i: Optional[int] = None,
                     d: Optional[int] = None) -> Optional[np.ndarray]:
        """Local row ids to include in the payload (None = full fragment).
        `i`/`d` identify the (src, dst) pair for plans that keep per-pair
        payload statistics (the adaptive sparsified k)."""
        return None

    def on_result(self, i: int, d: int, ok: bool) -> None:
        """Feedback: the send was delivered (ok) or canceled (not ok)."""

    def note_sent(self, i: int, d: int, it: int, full: bool = True) -> None:
        """Bookkeeping hook: a payload for d actually left shard i."""


class AllToAllPlan(ExchangePlan):
    pass


class RingPlan(ExchangePlan):
    """Each shard messages only its successor; receivers relay accepted
    fragments one hop (the engine owns the relay — versions circulate the
    ring in <= p-1 hops, so staleness stays O(p))."""

    name = "ring"

    def wants(self, i: int, d: int, it: int) -> bool:
        return d == (i + 1) % self.p


class AdaptivePlan(ExchangePlan):
    """Cancel-feedback backoff: consecutive canceled sends to a peer double
    that peer's send period (up to max_backoff); a delivered send halves
    it.  This is the DES comm_policy="adaptive" behavior, verbatim."""

    name = "adaptive"

    def __init__(self, p: int, cancel_limit: int = 3, max_backoff: int = 16):
        super().__init__(p)
        self.cancel_limit = cancel_limit
        self.max_backoff = max_backoff
        self.consec_cancels = np.zeros((p, p), dtype=np.int64)
        self.backoff = np.ones((p, p), dtype=np.int64)

    def wants(self, i: int, d: int, it: int) -> bool:
        return it % self.backoff[i, d] == 0

    def on_result(self, i: int, d: int, ok: bool) -> None:
        if ok:
            self.consec_cancels[i, d] = 0
            self.backoff[i, d] = max(1, self.backoff[i, d] // 2)
        else:
            self.consec_cancels[i, d] += 1
            if self.consec_cancels[i, d] >= self.cancel_limit:
                self.backoff[i, d] = min(self.backoff[i, d] * 2,
                                         self.max_backoff)
                self.consec_cancels[i, d] = 0


class SparsifiedPlan(ExchangePlan):
    """§6 message targeting: send to a peer only when the sender-side
    residual mass (||delta||_1 since the last send to that peer) exceeds
    `thresh`, with a forced full refresh every `refresh_every` local
    updates so delays stay bounded; `payload_rows` keeps only the top-k
    rows by |delta|, so payloads shrink as the sender converges.

    `top_k` may be a fixed row count, None (full payloads), or
    ``"adaptive"``: k is then *read off the observed row-delta
    distribution* — the smallest k whose top rows cover `cover_frac` of
    the payload's |delta| mass — and EWMA-smoothed per (src, dst) pair
    (`ewma` is the new-observation weight), so a sender whose residual
    concentrates ships a few heavy rows while a sender with flat deltas
    ships proportionally more.  The forced full refresh is untouched
    (`refresh_due` payloads skip `payload_rows` entirely), so the
    bounded-delay property holds for any adaptive trajectory."""

    name = "sparsified"

    def __init__(self, p: int, thresh: float, refresh_every: int = 8,
                 top_k=None, cover_frac: float = 0.9, ewma: float = 0.5):
        super().__init__(p)
        assert refresh_every >= 1
        if top_k == "adaptive":
            assert 0.0 < cover_frac <= 1.0 and 0.0 < ewma <= 1.0
        elif top_k is not None:
            top_k = int(top_k)
        self.thresh = float(thresh)
        self.refresh_every = int(refresh_every)
        self.top_k = top_k
        self.cover_frac = float(cover_frac)
        self.ewma = float(ewma)
        # iteration of the last *full* send per (src, dst) pair
        self.last_full = np.zeros((p, p), dtype=np.int64)
        # per-pair EWMA of the mass-coverage row count (0 = no data yet)
        self._k_ewma = np.zeros((p, p))

    def refresh_due(self, i: int, d: int, it: int) -> bool:
        return it - self.last_full[i, d] >= self.refresh_every

    def gate_mass(self, i: int, d: int, it: int, mass: float) -> bool:
        return mass > self.thresh or self.refresh_due(i, d, it)

    def payload_rows(self, delta_abs: np.ndarray,
                     i: Optional[int] = None,
                     d: Optional[int] = None) -> Optional[np.ndarray]:
        if self.top_k is None:
            return None
        if self.top_k == "adaptive":
            total = float(delta_abs.sum())
            if total <= 0.0:
                return None
            order = np.argsort(-delta_abs, kind="stable")
            csum = np.cumsum(delta_abs[order])
            k_now = int(np.searchsorted(
                csum, self.cover_frac * total, side="left")) + 1
            if i is None or d is None:
                k = k_now                # pair-less call: no profile state
            else:
                prev = self._k_ewma[i, d]
                cur = (float(k_now) if prev == 0.0
                       else self.ewma * k_now + (1.0 - self.ewma) * prev)
                self._k_ewma[i, d] = cur
                # ceil so the smoothed k never under-covers by rounding
                k = int(np.ceil(cur))
            k = max(1, min(k, delta_abs.size))
            if k >= delta_abs.size:
                return None
            return np.sort(order[:k])
        if self.top_k >= delta_abs.size:
            return None
        idx = np.argpartition(-delta_abs, self.top_k - 1)[: self.top_k]
        return np.sort(idx)

    def note_sent(self, i: int, d: int, it: int, full: bool = True) -> None:
        if full:
            self.last_full[i, d] = it


def make_plan(policy: str, p: int, *, cancel_limit: int = 3,
              max_backoff: int = 16, thresh: float = 0.0,
              refresh_every: int = 8,
              top_k=None) -> ExchangePlan:
    """Plan factory keyed by the DES comm_policy names."""
    if policy == "all_to_all":
        return AllToAllPlan(p)
    if policy == "ring":
        return RingPlan(p)
    if policy == "adaptive":
        return AdaptivePlan(p, cancel_limit=cancel_limit,
                            max_backoff=max_backoff)
    if policy == "sparsified":
        return SparsifiedPlan(p, thresh=thresh, refresh_every=refresh_every,
                              top_k=top_k)
    raise ValueError(f"unknown exchange policy {policy!r}")


# ---------------------------------------------------------------------------
# bulk-synchronous rendering (SPMD shard_map)
# ---------------------------------------------------------------------------
SPMD_SCHEDULES = ("allgather", "allgather_k", "ring", "sparsified")


def spmd_exchange(schedule: str, *, p: int, bsize: int, n_pad: int,
                  sync_every: int = 4, sparsify_k: int = 0,
                  sparsify_row_thresh: float = 0.0,
                  sparsify_refresh_every: int = 16,
                  sparsify_adaptive: bool = False,
                  sparsify_cover_frac: float = 0.9,
                  sparsify_ewma: float = 0.5,
                  sparsify_endgame_mass: float = 0.0):
    """Build the jax rendering of an ExchangePlan for one shard_map loop.

    Returns ``(init_state, comm)``:

      init_state(myfrag) -> comm_state pytree carried through the loop
          (ring: the relay buffer; sparsified: the last-sent fragment;
          otherwise an empty tuple);
      comm(i, view, newfrag, comm_state, step, accept)
          -> (view, comm_state, rows_sent, full_sent)
          where `view` is the (n_pad, nv) stale view after this superstep's
          exchange, `rows_sent` counts sparse payload rows this shard
          shipped (0 for the dense schedules — their byte model is static),
          and `full_sent` is 1 when a full-fragment refresh happened.

    All functions are traced inside shard_map: `i` is the shard's axis
    index, `accept` the per-shard delivery draw, and collectives run on the
    "ue" axis.  The sparsified plan mirrors the host rendering: top-k rows
    by per-row |delta| (summed over lanes) above `sparsify_row_thresh`,
    all-gathered as (idx, val) pairs, plus a forced full all-gather every
    `sparsify_refresh_every` supersteps (the bounded-delay guarantee).

    With ``sparsify_adaptive=True`` the per-payload row count is picked
    from the observed row-delta distribution instead of the fixed k:
    `sparsify_k` (auto: ~bsize/8) becomes a static *budget* (XLA needs
    static shapes), and within it the effective count is the smallest m
    whose top rows cover `sparsify_cover_frac` of the shard's total
    |delta| mass, EWMA-smoothed across supersteps (`sparsify_ewma` is the
    new-observation weight, carried in comm_state).  Rows beyond the
    adaptive m are masked out of the payload; the forced full refresh is
    unchanged, so the bounded-delay property is preserved verbatim.
    `sparsify_endgame_mass` guards the endgame: once a shard's total
    |delta| falls to that scale (callers pass ~bsize * nv * tol), the
    payload reverts to the full budget — a coverage fraction of a
    tolerance-sized mass would otherwise withhold exactly the rows the
    persistence counters need to see settle, stalling termination.
    """
    import jax
    import jax.numpy as jnp

    if schedule not in SPMD_SCHEDULES:
        raise ValueError(f"unknown schedule {schedule!r}; expected one of "
                         f"{SPMD_SCHEDULES}")

    zero = jnp.asarray(0, dtype=jnp.int32)
    one = jnp.asarray(1, dtype=jnp.int32)

    def place_own(view, newfrag, i):
        # both indices pinned to int32: under enable_x64 (the device
        # transport) a bare 0 literal canonicalizes to int64 and
        # dynamic_update_slice rejects the mixed-dtype index tuple
        return jax.lax.dynamic_update_slice(
            view, newfrag, ((i * bsize).astype(jnp.int32), zero))

    if schedule == "allgather":
        def init_state(myfrag):
            return ()

        def comm(i, view, newfrag, state, step, accept):
            allv = jax.lax.all_gather(newfrag, "ue")       # (p, bsize, nv)
            view = allv.reshape(n_pad, -1)
            return view, state, zero, one
        return init_state, comm

    if schedule == "allgather_k":
        def init_state(myfrag):
            return ()

        def comm(i, view, newfrag, state, step, accept):
            do_sync = jnp.mod(step, sync_every) == sync_every - 1

            def gather(_):
                allv = jax.lax.all_gather(newfrag, "ue")
                return allv.reshape(n_pad, -1)

            def keep(_):
                return place_own(view, newfrag, i)

            sync_ok = jnp.logical_and(do_sync, accept)
            view = jax.lax.cond(sync_ok, gather, keep, operand=None)
            return view, state, zero, sync_ok.astype(jnp.int32)
        return init_state, comm

    if schedule == "ring":
        perm = [(j, (j + 1) % p) for j in range(p)]

        def init_state(myfrag):
            return myfrag

        def comm(i, view, newfrag, ring, step, accept):
            ring_in = jax.lax.ppermute(ring, "ue", perm)
            # at superstep s (0-based), incoming fragment belongs to
            # UE (i - s - 1) mod p
            owner = jnp.mod(i - step - 1, p)
            # my own slot must always hold the fresh fragment
            view = place_own(view, newfrag, i)
            updated = jax.lax.dynamic_update_slice(
                view, ring_in, ((owner * bsize).astype(jnp.int32), zero))
            view = jnp.where(
                jnp.logical_and(accept, owner != i), updated, view)
            # forward own fragment afresh every p steps, else relay
            restart = jnp.mod(step + 1, p) == 0
            ring = jnp.where(restart, newfrag, ring_in)
            return view, ring, zero, one
        return init_state, comm

    # ---- sparsified -----------------------------------------------------
    k = int(sparsify_k) if sparsify_k > 0 else max(min(bsize, 128),
                                                   bsize // 8)
    k = min(k, bsize)
    row_thresh = float(sparsify_row_thresh)
    refresh = max(int(sparsify_refresh_every), 1)
    owner_off = np.arange(p, dtype=np.int32)[:, None] * bsize   # (p, 1)
    cover = float(sparsify_cover_frac)
    ewma_w = float(sparsify_ewma)
    endgame_mass = float(sparsify_endgame_mass)

    def init_state(myfrag):
        if sparsify_adaptive:
            # (last-shipped fragment, EWMA of the mass-coverage count —
            # start at the full budget so the first payloads are not
            # under-sized before any profile exists)
            return (myfrag, jnp.asarray(float(k), jnp.float32))
        return myfrag            # the fragment as last shipped to peers

    def comm(i, view, newfrag, state, step, accept):
        if sparsify_adaptive:
            last_sent, k_ewma = state
        else:
            last_sent, k_ewma = state, None
        delta = jnp.sum(jnp.abs(newfrag - last_sent), axis=-1)  # (bsize,)
        top_vals, top_idx = jax.lax.top_k(delta, k)
        row_ok = top_vals > row_thresh                          # (k,)
        if sparsify_adaptive:
            # adaptive k: smallest m whose top rows cover `cover` of the
            # shard's total |delta| mass (k stays the static budget);
            # EWMA-smoothed so one spiky superstep doesn't whip the
            # payload size around
            total = jnp.sum(delta)
            csum = jnp.cumsum(top_vals)
            m_now = jnp.sum((csum < cover * total).astype(jnp.int32)) + 1
            m_now = jnp.minimum(m_now, k).astype(jnp.float32)
            k_ewma = jnp.where(total > 0,
                               ewma_w * m_now + (1.0 - ewma_w) * k_ewma,
                               k_ewma)
            m_eff = jnp.ceil(k_ewma).astype(jnp.int32)
            # endgame: a tolerance-scale delta mass ships at full budget
            # (withholding any of it stalls the persistence counters)
            m_eff = jnp.where(total <= endgame_mass, k, m_eff)
            row_ok = jnp.logical_and(row_ok, jnp.arange(k) < m_eff)
        nrows = jnp.sum(row_ok.astype(jnp.int32))
        due = jnp.mod(step, refresh) == refresh - 1

        view = place_own(view, newfrag, i)

        def full(_):
            allv = jax.lax.all_gather(newfrag, "ue")
            return allv.reshape(n_pad, -1), newfrag

        def sparse(_):
            idx_all = jax.lax.all_gather(top_idx, "ue")         # (p, k)
            ok_all = jax.lax.all_gather(row_ok, "ue")           # (p, k)
            val_all = jax.lax.all_gather(newfrag[top_idx], "ue")  # (p,k,nv)
            flat = (owner_off + idx_all).reshape(-1)            # (p*k,)
            vals = val_all.reshape(p * k, -1)
            ok = ok_all.reshape(-1)
            cur = view[flat]
            upd = view.at[flat].set(jnp.where(ok[:, None], vals, cur))
            sent = last_sent.at[top_idx].set(
                jnp.where(row_ok[:, None], newfrag[top_idx],
                          last_sent[top_idx]))
            return upd, sent

        updated, last_sent = jax.lax.cond(due, full, sparse, operand=None)
        # The forced refresh is the bounded-delay guarantee, so it must be
        # delivery-reliable: a dropped sparse payload advances the sender's
        # last_sent (those rows read as zero-delta and are never re-sent
        # sparsely), which is only safe because the next `due` step repairs
        # the receiver unconditionally.  Gating the refresh on `accept`
        # would let a shard converge on a stale view.
        view = jnp.where(jnp.logical_or(accept, due), updated, view)
        rows_sent = jnp.where(due, zero, nrows)
        state = (last_sent, k_ewma) if sparsify_adaptive else last_sent
        return view, state, rows_sent, due.astype(jnp.int32)
    return init_state, comm
