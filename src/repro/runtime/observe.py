"""Unified runtime observability: metrics registry, event tracing,
push-inflation attribution.

Three pieces, all built on plain numpy arrays so the *same* code runs
over in-process arrays (threads transport) and over `ShardArena` views
(procpool transport, where worker-written slots must survive the
process boundary and supervisor respawns):

  * a lock-cheap **metrics registry** — a fixed schema of per-shard
    counter slots (`OBS_COUNTERS`) plus one fixed-bucket histogram
    (drain seconds).  Every slot is single-writer (shard i writes row i;
    the parent/supervisor writes only while no worker incarnation is
    alive), so there are no locks anywhere on the hot path — one float
    add per count, exactly the idiom the control arena already uses for
    `rounds`/`pushes`.

  * **structured event tracing** — per-shard ring buffers of fixed-width
    monotonic-clock records emitted at the eq. (5) cycle seams of
    `shard_worker_loop` (intake, drain with rows + pre-drain mass +
    attribution deltas, exchange with rows/bytes/generation, Fig. 1
    CONVERGE/DIVERGE/STOP transitions, fault injections, supervisor
    recoveries).  `time.perf_counter()` is CLOCK_MONOTONIC on Linux and
    therefore comparable across the procpool's processes.  Rings
    overwrite oldest-first; the cumulative write counter makes drops
    explicit.  `chrome_trace()` exports the stream as Chrome
    `trace_event` JSON (one track per shard, instant events for
    termination/fault/recovery) loadable in Perfetto / chrome://tracing.

  * **push-inflation attribution** — per-row `pushed`/`foreign` flags
    (uint8, disjoint row ownership keeps them single-writer) classify
    every drained row as a *first* push, a *local* re-push (the row's
    own sweep order re-crossed the threshold), or a *boundary* re-push
    (foreign mass folded at intake re-activated it).  Intake folds mark
    `foreign`; the drain clears both flags and bumps a per-shard
    (first, local, boundary) count row.  DRAIN events carry the deltas
    together with the exchange generation, so the bench can attribute
    the p>=1 push inflation (ROADMAP item 1) to exchange cadence vs
    drain order vs boundary re-activation.

Everything is **zero-cost when off**: the observer default is `None`
and every hook is behind an `if obs is not None` — no registry object,
no ring allocation, no arena slots (the control-arena spec only grows
when observing).
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# event schema
# ---------------------------------------------------------------------------
# fixed-width record: t, dur, kind, shard, gen, a, b, c, d, spare
EV_WIDTH = 10

EV_INTAKE = 1     # a = progressed (0/1)
EV_DRAIN = 2      # a = rows pushed, b = pre-drain own |r|_1 (pushed mass
                  # upper bound), c = local re-push delta, d = boundary
                  # re-push delta; gen = exchange generation (updates)
EV_EXCHANGE = 3   # a = destination shard, b = rows shipped, c = bytes
EV_CONVERGE = 4   # local verdict flipped to converged (Fig. 1)
EV_DIVERGE = 5    # local verdict flipped to diverged (Fig. 1)
EV_STOP = 6       # shard observed the global STOP and exited
EV_KILL = 7       # fault injection: kill fired (a = round)
EV_HANG = 8       # fault injection: hang fired (a = seconds)
EV_RECOVERY = 9   # supervisor recovery (a = pool slot / worker,
                  # b = exitcode, c = restored-from-checkpoint (0/1);
                  # dur = detection -> recovered seconds)
EV_CAPPED = 10    # push budget hit (a = round)
EV_CHUNK = 11     # SPMD compact-lanes chunk (a = lanes, b = steps,
                  # c = rows, d = bytes)

EV_NAMES = {
    EV_INTAKE: "INTAKE", EV_DRAIN: "DRAIN", EV_EXCHANGE: "EXCHANGE",
    EV_CONVERGE: "CONVERGE", EV_DIVERGE: "DIVERGE", EV_STOP: "STOP",
    EV_KILL: "KILL", EV_HANG: "HANG", EV_RECOVERY: "RECOVERY",
    EV_CAPPED: "CAPPED", EV_CHUNK: "CHUNK",
}

# events rendered as Chrome "X" (complete, with duration) vs "i" (instant)
_EV_SPAN = (EV_INTAKE, EV_DRAIN, EV_EXCHANGE)

DEFAULT_EVENT_CAP = 2048

# ---------------------------------------------------------------------------
# metrics registry schema (per-shard counter slots, single writer per row)
# ---------------------------------------------------------------------------
OBS_COUNTERS = (
    "intakes", "uniform_folds",
    "drains", "drain_rows", "drain_mass",
    "exchanges", "exchange_rows", "exchange_bytes",
    "converges", "diverges", "stops", "capped",
    "kills", "hangs", "recoveries",
)
OBS_NC = len(OBS_COUNTERS)
_CIDX = {name: k for k, name in enumerate(OBS_COUNTERS)}

# hot-path integer indices (shard_worker_loop uses these directly:
# `obs.ctr[i, C_DRAINS] += 1` is the whole registry write path)
C_INTAKES = _CIDX["intakes"]
C_UNIFORM_FOLDS = _CIDX["uniform_folds"]
C_DRAINS = _CIDX["drains"]
C_DRAIN_ROWS = _CIDX["drain_rows"]
C_DRAIN_MASS = _CIDX["drain_mass"]
C_EXCHANGES = _CIDX["exchanges"]
C_EXCHANGE_ROWS = _CIDX["exchange_rows"]
C_EXCHANGE_BYTES = _CIDX["exchange_bytes"]
C_CONVERGES = _CIDX["converges"]
C_DIVERGES = _CIDX["diverges"]
C_STOPS = _CIDX["stops"]
C_CAPPED = _CIDX["capped"]
C_KILLS = _CIDX["kills"]
C_HANGS = _CIDX["hangs"]
C_RECOVERIES = _CIDX["recoveries"]

# drain-duration histogram: fixed upper bounds in seconds, +inf last
HIST_BOUNDS = (1e-4, 1e-3, 1e-2, 1e-1, 1.0)
OBS_NB = len(HIST_BOUNDS) + 1


def obs_ctl_entries(p: int, n: int, event_cap: int = DEFAULT_EVENT_CAP,
                    attribution: bool = True) -> Dict[str, Tuple]:
    """Arena-spec entries for the observability slots (merged into the
    control-arena spec by `_ctl_spec(..., observe=True)`; allocated as
    plain numpy by `ShardObserver.alloc` for the threads transport)."""
    spec = {
        "obs_buf": ((p, int(event_cap), EV_WIDTH), np.float64),
        "obs_n": ((p,), np.int64),
        "obs_ctr": ((p, OBS_NC), np.float64),
        "obs_hist": ((p, OBS_NB), np.float64),
    }
    if attribution:
        spec.update({
            "obs_pushed": ((n,), np.uint8),
            "obs_foreign": ((n,), np.uint8),
            "obs_attr": ((p, 3), np.int64),   # first / local / boundary
        })
    return spec


class ShardObserver:
    """Bundle of the registry + trace + attribution arrays for one run.

    Arrays may be plain numpy (threads transport, allocated by `alloc`)
    or `ShardArena` views (procpool: the executor adds the `obs_*` slots
    to the control segment and each side wraps its own views) — the
    observer itself holds no locks and no process state.  `pushed` /
    `foreign` / `attr` are optional: synthetic drains that don't do
    attribution leave them None.
    """

    __slots__ = ("p", "cap", "buf", "n_ev", "ctr", "hist",
                 "pushed", "foreign", "attr")

    def __init__(self, buf: np.ndarray, n_ev: np.ndarray, ctr: np.ndarray,
                 hist: Optional[np.ndarray] = None,
                 pushed: Optional[np.ndarray] = None,
                 foreign: Optional[np.ndarray] = None,
                 attr: Optional[np.ndarray] = None):
        self.buf = buf
        self.n_ev = n_ev
        self.ctr = ctr
        self.hist = hist
        self.pushed = pushed
        self.foreign = foreign
        self.attr = attr
        self.p = int(buf.shape[0])
        self.cap = int(buf.shape[1])

    # -- construction ------------------------------------------------------
    @classmethod
    def alloc(cls, p: int, n: Optional[int] = None,
              event_cap: int = DEFAULT_EVENT_CAP) -> "ShardObserver":
        """Plain-numpy observer (threads / in-process).  Attribution
        arrays are allocated when `n` is given."""
        obs = cls(
            buf=np.zeros((p, int(event_cap), EV_WIDTH)),
            n_ev=np.zeros(p, dtype=np.int64),
            ctr=np.zeros((p, OBS_NC)),
            hist=np.zeros((p, OBS_NB)),
        )
        if n is not None:
            obs.pushed = np.zeros(int(n), dtype=np.uint8)
            obs.foreign = np.zeros(int(n), dtype=np.uint8)
            obs.attr = np.zeros((p, 3), dtype=np.int64)
        return obs

    @classmethod
    def from_views(cls, views) -> "ShardObserver":
        """Wrap arena (or dict) views produced from `obs_ctl_entries`;
        attribution arrays picked up when present."""
        ks = set(views.keys())

        def get(k):
            return views[k] if k in ks else None
        return cls(buf=views["obs_buf"], n_ev=views["obs_n"],
                   ctr=views["obs_ctr"], hist=get("obs_hist"),
                   pushed=get("obs_pushed"), foreign=get("obs_foreign"),
                   attr=get("obs_attr"))

    # -- hot path ----------------------------------------------------------
    @staticmethod
    def now() -> float:
        return time.perf_counter()

    def emit(self, kind: int, shard: int, t: float, dur: float = 0.0,
             gen: float = 0.0, a: float = 0.0, b: float = 0.0,
             c: float = 0.0, d: float = 0.0) -> None:
        """Append one record to shard's ring (single writer per shard)."""
        k = int(self.n_ev[shard])
        rec = self.buf[shard, k % self.cap]
        rec[0] = t
        rec[1] = dur
        rec[2] = kind
        rec[3] = shard
        rec[4] = gen
        rec[5] = a
        rec[6] = b
        rec[7] = c
        rec[8] = d
        self.n_ev[shard] = k + 1    # count bumped after the record lands

    def inc(self, name: str, shard: int, v: float = 1.0) -> None:
        self.ctr[shard, _CIDX[name]] += v

    def observe_drain_s(self, shard: int, seconds: float) -> None:
        if self.hist is None:
            return
        for k, ub in enumerate(HIST_BOUNDS):
            if seconds <= ub:
                self.hist[shard, k] += 1.0
                return
        self.hist[shard, OBS_NB - 1] += 1.0

    # -- read-back (parent side, after/outside the hot loop) ---------------
    def events(self) -> List[dict]:
        """Decode all rings into dicts, globally sorted by time.  Within
        one shard the order is exactly the writer's program order (one
        monotonic clock per writer)."""
        out: List[dict] = []
        for i in range(self.p):
            n = int(self.n_ev[i])
            for k in range(max(0, n - self.cap), n):
                rec = self.buf[i, k % self.cap]
                kind = int(rec[2])
                out.append({
                    "t": float(rec[0]), "dur": float(rec[1]),
                    "kind": kind, "name": EV_NAMES.get(kind, str(kind)),
                    "shard": int(rec[3]), "gen": float(rec[4]),
                    "a": float(rec[5]), "b": float(rec[6]),
                    "c": float(rec[7]), "d": float(rec[8]),
                })
        out.sort(key=lambda ev: ev["t"])
        return out

    def counters(self) -> Dict[str, List[float]]:
        return {name: [float(v) for v in self.ctr[:, k]]
                for k, name in enumerate(OBS_COUNTERS)}

    def attribution(self) -> Optional[Dict[str, object]]:
        if self.attr is None:
            return None
        tot = self.attr.sum(axis=0)
        return {
            "first": int(tot[0]), "local": int(tot[1]),
            "boundary": int(tot[2]),
            "per_shard": [[int(v) for v in row] for row in self.attr],
        }

    def snapshot(self) -> Dict[str, object]:
        """JSON-friendly roll-up: counters + histogram + ring accounting
        + attribution (when armed).  This is what lands in
        `AsyncRunResult.observed` / `ShardedUpdateStats.observed`."""
        written = [int(v) for v in self.n_ev]
        snap: Dict[str, object] = {
            "counters": self.counters(),
            "events_written": written,
            "events_dropped": [max(0, w - self.cap) for w in written],
            "event_cap": self.cap,
        }
        if self.hist is not None:
            snap["drain_s_hist"] = {
                "bounds": list(HIST_BOUNDS) + ["+inf"],
                "counts": [[float(v) for v in row] for row in self.hist],
            }
        attr = self.attribution()
        if attr is not None:
            snap["attribution"] = attr
        return snap

    def observed(self) -> Dict[str, object]:
        """snapshot() + the decoded event stream (the full payload)."""
        out = self.snapshot()
        out["events"] = self.events()
        return out


# ---------------------------------------------------------------------------
# push-inflation attribution (called from the drain, frontier in hand)
# ---------------------------------------------------------------------------
def attribute_frontier(pushed: np.ndarray, foreign: np.ndarray,
                       cnt: np.ndarray, frontier: np.ndarray) -> None:
    """Classify one drained frontier (global row ids) into first /
    local re-push / boundary re-push counts (`cnt` is the shard's
    (3,) int64 row — single writer) and advance the per-row flags:
    every pushed row becomes `pushed`, and its `foreign` mark — set by
    intake folds since the last push — is consumed."""
    if frontier.size == 0:
        return
    first = pushed[frontier] == 0
    nf = int(first.sum())
    nb = int((~first & (foreign[frontier] != 0)).sum())
    cnt[0] += nf
    cnt[2] += nb
    cnt[1] += frontier.size - nf - nb
    pushed[frontier] = 1
    foreign[frontier] = 0


# ---------------------------------------------------------------------------
# Chrome trace_event export (Perfetto / chrome://tracing)
# ---------------------------------------------------------------------------
def chrome_trace(events: Sequence[dict], p: Optional[int] = None,
                 pid_name: str = "async-shard-runtime") -> Dict[str, object]:
    """Render a decoded event stream (from `ShardObserver.events()` or
    `observed["events"]`) as a Chrome `trace_event` JSON object: one
    track (tid) per shard, "X" complete events for the spans (intake /
    drain / exchange), "i" instant events for Fig. 1 transitions,
    faults and recoveries.  Timestamps are microseconds relative to the
    earliest event."""
    shards = sorted({int(ev["shard"]) for ev in events})
    if p is not None:
        shards = sorted(set(shards) | set(range(int(p))))
    t0 = min((ev["t"] for ev in events), default=0.0)
    tev: List[dict] = [
        {"ph": "M", "name": "process_name", "pid": 0, "tid": 0,
         "args": {"name": pid_name}},
    ]
    for i in shards:
        tev.append({"ph": "M", "name": "thread_name", "pid": 0, "tid": i,
                    "args": {"name": "shard %d" % i}})
    for ev in events:
        kind = int(ev["kind"])
        name = EV_NAMES.get(kind, str(kind))
        args = {"gen": ev["gen"], "a": ev["a"], "b": ev["b"],
                "c": ev["c"], "d": ev["d"]}
        base = {"name": name, "pid": 0, "tid": int(ev["shard"]),
                "ts": (ev["t"] - t0) * 1e6, "cat": "runtime", "args": args}
        if kind in _EV_SPAN:
            base["ph"] = "X"
            base["dur"] = max(ev["dur"], 0.0) * 1e6
        else:
            base["ph"] = "i"
            base["s"] = "t"     # thread-scoped instant
        tev.append(base)
    return {"traceEvents": tev, "displayTimeUnit": "ms"}


def write_chrome_trace(path, events: Sequence[dict],
                       p: Optional[int] = None) -> None:
    with open(path, "w") as fh:
        json.dump(chrome_trace(events, p=p), fh)


# ---------------------------------------------------------------------------
# Prometheus text exposition (shared by RankServer.metrics_text and tools)
# ---------------------------------------------------------------------------
def render_prometheus(families: Sequence[Tuple[str, str, object]],
                      prefix: str = "repro") -> str:
    """Render `(name, type, value)` families in the Prometheus text
    format.  `value` is a scalar, or a dict of `labels-dict -> scalar`
    (labels rendered sorted, values escaped), e.g.::

        render_prometheus([
            ("queries_served", "counter", 12),
            ("shard_pushes", "counter",
             {(("shard", "0"),): 41, (("shard", "1"),): 7}),
        ])
    """
    def fmt(v) -> str:
        f = float(v)
        if f == int(f) and abs(f) < 1e15:
            return str(int(f))
        return repr(f)

    lines: List[str] = []
    for name, typ, value in families:
        full = "%s_%s" % (prefix, name) if prefix else name
        lines.append("# TYPE %s %s" % (full, typ))
        if isinstance(value, dict):
            for labels, v in value.items():
                lab = ",".join(
                    '%s="%s"' % (k, str(lv).replace("\\", r"\\")
                                 .replace('"', r'\"').replace("\n", r"\n"))
                    for k, lv in labels)
                lines.append("%s{%s} %s" % (full, lab, fmt(v)))
        else:
            lines.append("%s %s" % (full, fmt(value)))
    return "\n".join(lines) + "\n"


def counters_to_families(counters: Dict[str, List[float]]
                         ) -> List[Tuple[str, str, object]]:
    """Per-shard counter dict (from `ShardObserver.counters()`) ->
    Prometheus families with a `shard` label."""
    return [
        (name, "counter",
         {(("shard", str(i)),): v for i, v in enumerate(vals)})
        for name, vals in counters.items()
    ]
