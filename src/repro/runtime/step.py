"""ShardStep — the eq. (5) cycle as a per-shard step, in two renderings.

PR 5 wrote the paper's intake / hysteresis-gated drain / §6-gated exchange /
Fig. 1 report cycle once, as `transport.shard_worker_loop`, behind the
`TransportContext` seam.  That made the cycle transport-agnostic but left it
a *host* loop: a Python `while` driving numpy, which no accelerator can
run.  This module splits the cycle one level deeper — into a per-shard
**step** with two renderings:

  `HostShardStep`      — the host rendering: one `round()` is exactly one
                         iteration of the PR 5 worker loop (the loop body
                         was transplanted verbatim; tests/test_executor.py,
                         tests/test_transport.py and tests/test_runtime.py
                         golden-gate the threads/procpool behavior
                         bit-for-bit).  `transport.shard_worker_loop` is now
                         a thin driver over it.
  device step builders — the jax-traceable rendering: `shard_pt_apply` /
                         `shard_local_update` build one shard's eq. (5)
                         local update over the Pallas BSR path
                         (kernels/bsr_spmv, with the compensated/f64
                         accumulation lanes) or the segment-sum path;
                         `shard_superstep_fns` fuses it with an
                         `exchange.spmd_exchange` collective schedule (the
                         §6 sparsified top-k + forced-refresh rendering
                         included) and the all-reduced Fig. 1
                         `TerminationDriver.bits_step` into one traced
                         superstep body.  `core.spmd.solve_spmd` and
                         `runtime.device.DeviceShardTransport` both run
                         THIS body — the bulk-synchronous solver and the
                         async streaming drain share one traced function,
                         so every future kernel or collective win lands in
                         one place.

The device rendering's convergence test is pluggable (`conv=`):

  "linf"     — per-lane inf-norm of the fragment delta vs `tol` (the SPMD
               solver's historic criterion, bit-identical to pre-refactor).
  "l1_psum"  — the all-reduced L1 of the fragment delta vs `tol` (a global
               scalar, identical on every shard).  For the *linear* form
               (eq. 7) the fragment delta IS the local residual of the
               previous iterate, so the psum'd delta is ||r||_1 up to view
               staleness — the device transport's drain-to-target test, with
               the host-side exact recompute as the published certificate.

`comm_bytes_model` is the one byte-accounting model both the SPMD solver
and the device transport report through (checked against each other by
benchmarks/check_device_transport.py).
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np

from ..core.partition import Partition
from .exchange import ExchangePlan
from .observe import (C_CAPPED, C_CONVERGES, C_DIVERGES, C_DRAIN_MASS,
                      C_DRAIN_ROWS, C_DRAINS, C_EXCHANGE_BYTES,
                      C_EXCHANGE_ROWS, C_EXCHANGES, C_INTAKES, C_STOPS,
                      EV_CAPPED, EV_CONVERGE, EV_DIVERGE, EV_DRAIN,
                      EV_EXCHANGE, EV_INTAKE, EV_STOP, ShardObserver)


# ---------------------------------------------------------------------------
# host rendering — one round() == one iteration of the PR 5 worker loop
# ---------------------------------------------------------------------------
class HostShardStep:
    """One shard's eq. (5) cycle as a resumable step object.

    Construction captures everything the PR 5 loop hoisted above its
    `while`: the block geometry, the per-shard convergence target and drain
    floor, the boundary-batched exchange gate, and the cached L1s of the
    two O(n) structures this worker owns.  `round()` then runs exactly one
    loop iteration — intake, hysteresis-gated drain, §6-gated exchange,
    value publish, Fig. 1 report, idle backoff — and returns False on the
    loop's exit paths (observed STOP, round cap, push cap, own STOP).

    The body is the PR 5 `shard_worker_loop` body transplanted verbatim
    (split at the seam comments); the soundness argument is unchanged and
    lives in transport.py's module docstring.
    """

    def __init__(self, i: int, r: np.ndarray, part: Partition,
                 plan: ExchangePlan, cfg, ctx, drain_fn,
                 obs: Optional[ShardObserver] = None):
        self.i = i
        self.r = r
        self.part = part
        self.plan = plan
        self.cfg = cfg
        self.ctx = ctx
        self.drain_fn = drain_fn
        self.obs = obs

        self.p = part.p
        self.s, self.e = part.block(i)
        self.bs = self.e - self.s
        self.n = part.n
        self.conv_target = (cfg.l1_target * (self.bs / self.n)
                            if self.n else cfg.l1_target)
        self.drain_floor = 0.5 * self.conv_target
        self.outbox = ctx.outbox(i)
        self.peers = [d for d in range(self.p) if d != i]
        # boundary-batched DrainSchedule: pair shipments coalesce behind
        # this gate (None for every other schedule — the zero-cost default)
        self.gate = cfg.schedule.gate(self.p)
        # cached L1s of the two O(n) structures this worker owns — only
        # intake/drain/exchange can change them, so idle rounds cost O(p)
        # instead of O(n)
        self.own_l1 = float(np.abs(r[self.s:self.e]).sum())
        # a restarted worker can inherit a non-empty outbox (plan-withheld
        # or backpressured mass from the dead incarnation) — seed the cache
        # from the structure itself, never assume empty
        self.outbox_l1 = float(np.abs(self.outbox).sum())
        self.own_dirty = False
        self.outbox_dirty = False
        self.it = 0            # raw rounds (spin included): caps, telemetry
        self.updates = 0       # *local updates*: the ExchangePlan's clock
        self.tick_pending = False
        self.idle_total = 0.0
        self.prev_verdict: Optional[bool] = None  # Fig. 1 flip edge detector

    # -- the four seams, each a method so renderings/tests can drive them
    #    individually; round() composes them in the PR 5 order ------------
    def intake(self) -> bool:
        """Fold incoming mail + my uniform share; retract convergence
        BEFORE the mass leaves the sender's books (see transport.py)."""
        i, obs = self.i, self.obs
        progressed = False
        if self.ctx.intake_ready(i):
            t_ev = obs.now() if obs is not None else 0.0
            self.ctx.retract(i)
            if self.ctx.fold_intake(i, self.r, self.s, self.e):
                progressed = True
                self.own_dirty = True
            if obs is not None:
                obs.ctr[i, C_INTAKES] += 1
                obs.emit(EV_INTAKE, i, t_ev, dur=obs.now() - t_ev,
                         gen=self.updates, a=float(progressed))
        return progressed

    def drain(self, step_target: float) -> bool:
        """Hysteresis-gated local update: drain own rows to the sliding
        target, foreign contributions into the outbox."""
        i, cfg, obs = self.i, self.cfg, self.obs
        if self.own_dirty:
            self.own_l1 = float(np.abs(self.r[self.s:self.e]).sum())
            self.own_dirty = False
        did_drain = False
        if self.own_l1 > (cfg.hysteresis * step_target
                          if step_target > self.drain_floor
                          else self.drain_floor):
            if obs is None:
                got, c_add = self.drain_fn(i, self.s, self.e, step_target,
                                           self.outbox)
            else:
                t_ev = obs.now()
                a0 = (obs.attr[i].copy()
                      if obs.attr is not None else None)
                got, c_add = self.drain_fn(i, self.s, self.e, step_target,
                                           self.outbox)
                dt_ev = obs.now() - t_ev
                da_local = da_boundary = 0.0
                if a0 is not None:
                    da = obs.attr[i] - a0
                    da_local, da_boundary = float(da[1]), float(da[2])
                obs.ctr[i, C_DRAINS] += 1
                obs.ctr[i, C_DRAIN_ROWS] += got
                obs.ctr[i, C_DRAIN_MASS] += max(self.own_l1 - step_target,
                                                0.0)
                obs.observe_drain_s(i, dt_ev)
                obs.emit(EV_DRAIN, i, t_ev, dur=dt_ev, gen=self.updates,
                         a=float(got), b=self.own_l1, c=da_local,
                         d=da_boundary)
            self.ctx.uniform_add(i, c_add)
            self.own_dirty = self.outbox_dirty = True
            did_drain = True
            self._drain_got = got
        return did_drain

    def exchange(self, step_target: float) -> bool:
        """§6-gated exchange: plan consulted per *local update*; the
        boundary-batched gate and mass gates may withhold (mass stays in
        the counted outbox)."""
        i, cfg, obs = self.i, self.cfg, self.obs
        plan, gate, ctx = self.plan, self.gate, self.ctx
        progressed = False
        self.updates += 1
        self.tick_pending = False
        if self.outbox_dirty:
            self.outbox_l1 = float(np.abs(self.outbox).sum())
            self.outbox_dirty = False
        for d in self.peers:
            if not plan.wants(i, d, self.updates):
                continue
            if self.outbox_l1 == 0.0:
                # nothing pending anywhere: the receiver's copy already
                # reflects everything this shard produced, so the epoch
                # counts as a (zero-byte) refresh — quiet pairs must not
                # bank forced-refresh debt
                plan.note_sent(i, d, self.updates)
                if gate is not None:
                    gate.note_quiet(d, self.updates)
                continue
            sd, ed = self.part.block(d)
            box = self.outbox[sd:ed]
            mass = float(np.abs(box).sum())
            if mass == 0.0:
                plan.note_sent(i, d, self.updates)
                if gate is not None:
                    gate.note_quiet(d, self.updates)
                continue
            if gate is not None and not gate.ready(
                    d, self.updates, mass, step_target):
                # boundary-batched: the pair's mass keeps folding in the
                # outbox (still counted in this shard's value) until the
                # batch window expires or the coalesced payload is worth
                # a generation
                continue
            if not plan.gate_mass(i, d, self.updates, mass):
                continue
            t_ev = obs.now() if obs is not None else 0.0
            nz = ctx.send(i, d, box)
            if nz < 0:
                # channel backpressure (a full procpool ring): the mass
                # stays in the outbox — still counted in this shard's
                # value — and ships on a later update
                continue
            if obs is not None:
                nbytes = nz * (4 + cfg.bytes_per_entry)
                obs.ctr[i, C_EXCHANGES] += 1
                obs.ctr[i, C_EXCHANGE_ROWS] += nz
                obs.ctr[i, C_EXCHANGE_BYTES] += nbytes
                obs.emit(EV_EXCHANGE, i, t_ev,
                         dur=obs.now() - t_ev, gen=self.updates,
                         a=float(d), b=float(nz), c=float(nbytes))
            self.outbox_dirty = True
            plan.note_sent(i, d, self.updates)
            plan.on_result(i, d, True)
            if gate is not None:
                gate.note_sent(d, self.updates)
            ctx.note_exchange(i, nz)
            progressed = True
        return progressed

    def value(self) -> float:
        """Everything this shard is accountable for right now (the
        conservation invariant): own rows, undelivered outbox, channel
        mass *I* put in flight, and my rows' share of the pending
        uniform."""
        if self.own_dirty:
            self.own_l1 = float(np.abs(self.r[self.s:self.e]).sum())
            self.own_dirty = False
        if self.outbox_dirty:
            self.outbox_l1 = float(np.abs(self.outbox).sum())
            self.outbox_dirty = False
        return (self.own_l1 + self.outbox_l1
                + abs(self.ctx.uniform_pending(self.i)) * self.bs
                + self.ctx.inflight_l1(self.i))

    def report(self, value: float) -> bool:
        """Fig. 1, message rendering: publish the verdict; True = STOP."""
        i, obs = self.i, self.obs
        verdict = value <= self.conv_target
        if obs is not None and verdict != self.prev_verdict:
            if verdict:
                obs.ctr[i, C_CONVERGES] += 1
                obs.emit(EV_CONVERGE, i, obs.now(), gen=self.updates,
                         a=value)
            else:
                obs.ctr[i, C_DIVERGES] += 1
                obs.emit(EV_DIVERGE, i, obs.now(), gen=self.updates,
                         a=value)
            self.prev_verdict = verdict
        self._verdict = verdict
        return self.ctx.report(i, verdict, self.it)

    # -- one full round ---------------------------------------------------
    def round(self) -> bool:
        """Run one cycle round; False means the worker loop should exit."""
        i, cfg, ctx, obs = self.i, self.cfg, self.ctx, self.obs
        if ctx.stopped():
            # the other clean exit: a peer's report chain stamped the
            # global STOP and this shard observed it at the round top —
            # trace it so every shard's stream ends in exactly one STOP
            # (the report()-True path below emits its own)
            if obs is not None:
                obs.ctr[i, C_STOPS] += 1
                obs.emit(EV_STOP, i, obs.now(), gen=self.updates,
                         a=float(self.it))
            return False
        if self.it >= cfg.max_rounds:
            if obs is not None:
                obs.ctr[i, C_CAPPED] += 1
                obs.emit(EV_CAPPED, i, obs.now(), gen=self.updates,
                         a=float(self.it))
            ctx.note_capped()
            return False
        self.it += 1
        progressed = False

        # -- receive ------------------------------------------------------
        if self.intake():
            progressed = True

        # -- local update: drain own rows to a sliding target -------------
        approx_total = ctx.values_total()
        step_target = max(self.drain_floor,
                          cfg.drain_frac * approx_total / self.p)
        did_drain = self.drain(step_target)
        if did_drain and self._drain_got:
            ctx.add_pushes(i, self._drain_got)
            progressed = True
        if (cfg.max_total_pushes is not None
                and ctx.total_pushes() > cfg.max_total_pushes):
            if obs is not None:
                obs.ctr[i, C_CAPPED] += 1
                obs.emit(EV_CAPPED, i, obs.now(), gen=self.updates,
                         a=float(self.it))
            ctx.note_capped()
            return False

        # -- exchange: plan consulted per *local update*, not per spin
        #    round — idle-converged rounds must not tick the §6 refresh
        #    clock.  A blocked-but-unconverged round (tick_pending) still
        #    ticks: mass parked above the convergence target keeps the
        #    bounded-delay escape hatch live. -----------------------------
        if did_drain or self.tick_pending:
            if self.exchange(step_target):
                progressed = True

        # -- value + Fig. 1 report ----------------------------------------
        if self.report(self.value_and_publish()):
            if obs is not None:
                obs.ctr[i, C_STOPS] += 1
                obs.emit(EV_STOP, i, obs.now(), gen=self.updates,
                         a=float(self.it))
            return False
        if not self._verdict and not progressed:
            # parked above target with the plan withholding: count the
            # next round as a local update so the forced refresh can fire
            # (no livelock)
            self.tick_pending = True

        # -- idle backoff: park until mail can have arrived ---------------
        if not progressed:
            t_idle = time.perf_counter()
            ctx.idle_wait(cfg.idle_sleep)
            self.idle_total += time.perf_counter() - t_idle
        return True

    def value_and_publish(self) -> float:
        v = self.value()
        self.ctx.publish_value(self.i, v)
        return v


# ---------------------------------------------------------------------------
# device rendering — the jax-traceable step (shared by SPMD + DeviceShard)
# ---------------------------------------------------------------------------
def hash_uniform(seed: int, step, lane):
    """Counter-based uniform in [0, 1): a SplitMix-style integer mix of
    (seed, superstep, shard). jax.random inside shard_map lowers to a
    PartitionId instruction XLA's SPMD partitioner rejects; this hash is
    deterministic, partitionable, and plenty for a drop model."""
    import jax.numpy as jnp
    z = (step.astype(jnp.uint32) * jnp.uint32(0x9E3779B9)
         + lane.astype(jnp.uint32) * jnp.uint32(0x85EBCA6B)
         + jnp.uint32(seed & 0xFFFFFFFF))
    z = (z ^ (z >> 16)) * jnp.uint32(0x7FEB352D)
    z = (z ^ (z >> 15)) * jnp.uint32(0x846CA68B)
    z = z ^ (z >> 16)
    return z.astype(jnp.float32) * jnp.float32(2.0 ** -32)


def shard_pt_apply(op_slice: tuple, *, use_bsr: bool, bsize: int,
                   nv: int, n_pad: int = 0, bm: int = 0,
                   impl: str = "ref", accum: str = "f32"):
    """One shard's P^T apply over its operator slice.

    op_slice: (blk, bcols, hrow, hcol, hval) for the BSR backend — the
    Pallas block kernel plus the hub segment-sum side path — or
    (src, wgt, rid) for the segment-sum backend.  `accum` threads the
    kernel's accumulation lane through (f32 | kahan | f64): with "f32" the
    view is cast to float32 on entry (the historic MXU contract); the
    tight lanes keep the view's own dtype so an x64 device program stays
    f64 end to end.
    """
    import jax
    import jax.numpy as jnp

    if use_bsr:
        from ..kernels.bsr_spmv import bsr_matvec
        blk_, bcols_, hrow_, hcol_, hval_ = op_slice

        def pt_apply(view):
            cast = view.astype(jnp.float32) if accum == "f32" else view
            xb = cast.reshape(n_pad // bm, bm, nv)
            y = bsr_matvec(blk_, bcols_, xb, impl=impl, accum=accum)
            hub = jax.ops.segment_sum(
                hval_.astype(cast.dtype)[:, None] * cast[hcol_],
                hrow_, num_segments=bsize)
            return (y.reshape(bsize, nv) + hub).astype(view.dtype)
        return pt_apply

    src_, wgt_, rid_ = op_slice

    def pt_apply(view):
        contrib = wgt_[:, None] * view[src_]
        return jax.ops.segment_sum(contrib, rid_, num_segments=bsize)
    return pt_apply


def shard_local_update(pt_apply, *, alpha: float, linear: bool, n: int,
                       vb, val, dang):
    """f_i: one shard's eq. (5) local update — the new own fragment from
    the (stale) full view, per lane.  The scalar dangling/teleport
    corrections are masked so block-aligned padding rows stay exactly
    zero.  `vb` (bsize, nv) teleport fragment, `val` (bsize,) valid-row
    mask, `dang` (n_pad,) dangling mask in packed-view coordinates."""
    import jax.numpy as jnp

    def local_update(view):
        y = alpha * pt_apply(view)
        dmass = jnp.sum(jnp.where(dang[:, None], view, 0.0), axis=0)
        y = y + alpha * dmass[None, :] / n * val[:, None]
        if linear:
            y = y + (1.0 - alpha) * vb
        else:
            y = y + (1.0 - alpha) * jnp.sum(view, axis=0)[None, :] \
                * vb
        return y * val[:, None]
    return local_update


def shard_superstep_fns(local_update, comm, *, i, p: int, tol: float,
                        pc_max_compute: int, pc_max_monitor: int,
                        seed: int, q: float, freeze_lanes: bool,
                        max_steps, compact_exit: bool = False,
                        exit_k: int = 0, conv: str = "linf",
                        axis: str = "ue"):
    """The one traced superstep body + loop condition.

    Fuses the shard's local update, the collective exchange schedule
    (`exchange.spmd_exchange` — §6 sparsified targeting included) and the
    all-reduced Fig. 1 protocol (`TerminationDriver.bits_step` over the
    transport layer's mesh psum) into one function of the loop carry:

      (view, frag, comm_state, step, pc, mon_pc, lane_done, lane_step,
       rows_sent, fulls)

    `conv` picks the convergence criterion (see module docstring); both
    run through the identical bits_step persistence machinery.
    """
    import jax.numpy as jnp
    from . import driver as _driver
    from . import transport as _transport

    def superstep(carry):
        (view, frag, comm_state, step, pc, mon_pc, lane_done,
         lane_step, rows_sent, fulls) = carry
        newfrag = local_update(view)
        if freeze_lanes:
            # frozen lanes keep their fragment — the monitor already
            # observed persistent global convergence
            newfrag = jnp.where(lane_done[None, :], frag, newfrag)
        delta = jnp.abs(newfrag - frag)
        if conv == "linf":
            locally_conv = jnp.max(delta, axis=0) < tol       # (nv,)
        else:
            # "l1_psum": the all-reduced L1 of the fragment delta — for
            # the linear form this is ||r||_1 of the previous iterate up
            # to view staleness, identical on every shard (the
            # value-rendering of Fig. 1 mapped onto the bit machinery)
            total = _transport.mesh_psum(axis)(jnp.sum(delta, axis=0))
            locally_conv = total <= tol                       # (nv,)

        # ---- communication (ExchangePlan, bulk-sync) ---------------------
        accept = hash_uniform(seed, step, i) < q
        view, comm_state, nsent, nfull = comm(
            i, view, newfrag, comm_state, step, accept)

        # ---- in-loop Fig. 1 protocol (all-reduced bits) ------------------
        # the reduction channel comes from the transport layer: the mesh
        # psum is the bulk-synchronous rendering of the same seam the
        # host drivers reduce through
        pc, mon_pc, done_now = _driver.TerminationDriver.bits_step(
            locally_conv, pc, mon_pc, p=p,
            pc_max_compute=pc_max_compute,
            pc_max_monitor=pc_max_monitor,
            psum=_transport.mesh_psum(axis))
        lane_step = jnp.where(done_now & (lane_step < 0),
                              step + 1, lane_step)
        # counter dtypes pinned: under enable_x64 the schedule closures'
        # counts can come back int64 and silently widen the carry
        return (view, newfrag, comm_state, step + 1, pc, mon_pc,
                done_now, lane_step,
                rows_sent + jnp.asarray(nsent, rows_sent.dtype),
                fulls + jnp.asarray(nfull, fulls.dtype))

    def cond(carry):
        _, _, _, step, _, _, lane_done, *_ = carry
        keep = jnp.logical_and(~jnp.all(lane_done), step < max_steps)
        if compact_exit:
            # the pow2-compaction hook: once exit_k lanes are frozen,
            # hand control back to the host so the stack can shrink
            # instead of masking dead lanes
            keep = jnp.logical_and(
                keep, jnp.sum(lane_done.astype(jnp.int32)) < exit_k)
        return keep

    return superstep, cond


def init_carry(myx, init_comm, *, nv: int, n_pad: int, axis: str = "ue"):
    """The loop carry at step 0: full view all-gathered from the shard
    fragments, fresh protocol counters, zeroed comm telemetry."""
    import jax
    import jax.numpy as jnp
    view0 = jax.lax.all_gather(myx, axis).reshape(n_pad, nv)
    # the step counter is pinned to int32 — under enable_x64 a bare
    # jnp.asarray(0) would turn int64 and ripple into the schedule
    # closures' index arithmetic
    return (view0, myx, init_comm(myx), jnp.asarray(0, jnp.int32),
            jnp.zeros(nv, jnp.int32), jnp.zeros(nv, jnp.int32),
            jnp.zeros(nv, bool), jnp.full(nv, -1, jnp.int32),
            jnp.asarray(0, jnp.int32), jnp.asarray(0, jnp.int32))


def comm_bytes_model(schedule: str, *, p: int, bsize: int, itemsize: int,
                     nv: int, steps: int, rows: int, fulls: int,
                     sync_every: int = 4) -> int:
    """Payload bytes moved by one shard_map loop segment — the single
    byte-accounting model for every device-side exchange schedule (the
    static schedules scale with the lane count; sparsified uses the
    honest in-loop (rows, fulls) counters)."""
    frag_bytes = bsize * itemsize
    if schedule == "ring":
        return p * frag_bytes * nv * steps
    if schedule == "allgather_k":
        return (p * (p - 1) * frag_bytes * nv // sync_every) * steps
    if schedule == "sparsified":
        # (idx, value-lanes) pairs to p-1 peers per sparse payload row,
        # plus the forced full refreshes (each due step is one full
        # all-gather)
        entry = 4 + itemsize * nv
        return (rows * (p - 1) * entry
                + fulls * (p - 1) * frag_bytes * nv)
    return p * (p - 1) * frag_bytes * nv * steps
