"""DrainSchedule — pluggable update ordering for the eq. (5) drain cycle.

The paper's free-steering iteration leaves the update order entirely open,
and PR 7's attribution measured order as the #1 perf lever: fine-grained
async at p >= 4 inflates pushes 1.2-1.6x over p=1 (BENCH_PR7.json
`observe.inflation`).  The tax splits by transport — threads lose
half-or-more to *local* cadence (GIL-interleaved drains re-cross the
threshold ladder), procpool ~90% to *boundary* re-activation (every
exchange generation re-lifts the same foreign rows over eps).  This module
is the schedule seam that attacks each regime without touching the mass
accounting: a `ScheduleSpec` selects how the three drain hot paths order
work —

  * ``priority`` — D-Iteration-style drains (Hong et al.,
    arXiv:1501.06350): the coarse-to-fine ladder already pops
    largest-residual-first in bucketed sweeps; this rendering adds the
    *fluid retention* half of the algorithm.  A sweep at level eps drains
    only rows whose fluid clears ``retain_boost * eps``; a row below the
    bar retains the sub-threshold mass its neighbors diffuse back and
    re-enters when the ladder descends far enough for its fluid to
    matter, so the local cadence tax (re-pushing a row for an eps/10
    trickle) collapses into one bigger push per level.  Targets the
    *threads* regime.
  * ``boundary`` (alias ``boundary-batched``) — exchange-cadence
    coalescing: boundary mass destined for one foreign row accumulates
    (folds) in the sender's outbox across ``batch_updates`` local updates
    before the pair ships, so the receiver sees one folded record per
    (pair, row) per generation instead of one re-activation per trickle.
    Significant mass (>= ``batch_mass_frac`` of the sender's sliding
    drain target) ships immediately, and the gate force-opens every
    ``batch_updates`` local updates, so the §6 bounded-delay guarantee
    survives with the bound ``batch_updates + refresh_every`` (the two
    delays compose additively; tests/test_schedule.py pins it).  Targets
    the *procpool* regime.
  * ``randomized`` — seeded Ishii-Tempo random orders (arXiv:1203.6599):
    each sweep drains a uniformly chosen subset of the threshold frontier
    (never empty when the frontier is not), and the superstep loop visits
    shards in a per-step seeded permutation.  Expected convergence follows
    from every sweep still moving >= 1 row with |r| >= eps; this is the
    control arm the priority/boundary wins are measured against.
  * ``priority+boundary`` — both levers at once (the drain-order state
    and the exchange gate are independent).

Soundness is untouched by construction: a schedule only *reorders or
delays* pushes and shipments — retained fluid stays in ``r`` (counted by
its shard), batched boundary mass stays in the sender's outbox (counted in
the sender's published value) — so the mass-conservation invariant and the
exact post-fold certificate recompute are schedule-independent.  The win
must show up in PR 7's attribution counters (reduced ``pushes_local`` /
``pushes_boundary``), which is what `benchmarks/check_schedule_inflation.py`
gates.

Wiring: `streaming.update_ranks(schedule=)` /
`streaming.update_ranks_sharded(schedule=)` / `transport.WorkerConfig
.schedule` / `streaming.RankServer(drain_schedule=)`.  See
docs/runtime.md "Drain scheduling".
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import numpy as np

#: the selectable renderings (aliases: "boundary-batched" -> "boundary")
SCHEDULES = ("default", "priority", "boundary", "randomized",
             "priority+boundary")

_ALIASES = {
    "boundary-batched": "boundary",
    "boundary_batched": "boundary",
    "priority-boundary": "priority+boundary",
}


@dataclasses.dataclass(frozen=True)
class ScheduleSpec:
    """A drain schedule and its knobs — frozen, hashable and picklable, so
    it rides `WorkerConfig` across the procpool fork/spawn boundary
    unchanged."""

    name: str = "default"
    # --- priority (D-Iteration fluid retention) ---
    retain_boost: float = 2.0   # a sweep at ladder level eps drains only
    #                           # rows with |r| >= retain_boost*eps; rows
    #                           # below the bar retain their fluid until a
    #                           # finer level (boost=2 measured best on
    #                           # the 50k acceptance workload, BENCH_PR8)
    retain_rounds: int = 0      # 0 (default): the boost bar applies to
    #                           # every row (bucket sharpening); > 0: it
    #                           # applies only to rows drained within the
    #                           # last retain_rounds drain calls (the
    #                           # classic per-row retention rendering)
    # --- boundary-batched exchange coalescing ---
    batch_updates: int = 4      # local updates a pair's boundary mass
    #                           # coalesces before the gate force-opens
    batch_mass_frac: float = 0.5  # ship early when the pair's mass
    #                             # reaches this fraction of the sliding
    #                             # drain target (big mass must not wait)
    # --- randomized (Ishii-Tempo) ---
    seed: int = 0
    select_frac: float = 0.5    # expected fraction of the threshold
    #                           # frontier drained per sweep
    # --- drain-call granularity (any schedule, async transports) ---
    drain_frac: Optional[float] = None  # override the executor's sliding
    #                           # per-call drain target fraction
    #                           # (drain_frac * total / p); None keeps the
    #                           # transport default (threads 0.05,
    #                           # procpool 0.25).  Coarser calls re-cross
    #                           # the threshold ladder fewer times — the
    #                           # #1 local-cadence lever on threads
    #                           # (BENCH_PR8) — at the cost of staler
    #                           # exchange/termination checks between
    #                           # calls.  Clamped by the caller to keep
    #                           # hysteresis * drain_frac < 1 (livelock
    #                           # guard).

    def __post_init__(self):
        name = _ALIASES.get(self.name, self.name)
        if name not in SCHEDULES:
            raise ValueError(f"unknown schedule {self.name!r}; expected "
                             f"one of {SCHEDULES} (or alias "
                             f"{tuple(_ALIASES)})")
        object.__setattr__(self, "name", name)
        if self.batch_updates < 1:
            raise ValueError("batch_updates must be >= 1")
        if not (0.0 < self.select_frac <= 1.0):
            raise ValueError("select_frac must be in (0, 1]")
        if self.retain_boost < 1.0:
            raise ValueError("retain_boost must be >= 1 (a boost below 1 "
                             "would re-push below the current level)")
        if self.drain_frac is not None and not (0.0 < self.drain_frac <= 1.0):
            raise ValueError("drain_frac must be in (0, 1] (or None for "
                             "the transport default)")

    # -- which seams this spec actually activates ----------------------
    @property
    def drain_kind(self) -> str:
        """Frontier-selection rendering: default | priority | randomized."""
        if self.name in ("priority", "priority+boundary"):
            return "priority"
        if self.name == "randomized":
            return "randomized"
        return "default"

    @property
    def batch_exchange(self) -> bool:
        """Whether the boundary exchange gate is armed."""
        return self.name in ("boundary", "priority+boundary")

    def order(self, m: int, shard: int = 0) -> Optional["DrainOrder"]:
        """Per-shard frontier-selection state over `m` local rows, or None
        when this spec leaves the default ladder untouched (the zero-cost
        path: callers skip every hook on None)."""
        kind = self.drain_kind
        if kind == "priority":
            return PriorityOrder(self, m)
        if kind == "randomized":
            return RandomizedOrder(self, m, shard)
        return None

    def gate(self, p: int) -> Optional["ExchangeGate"]:
        """Per-shard exchange-coalescing state over `p` peers, or None
        when the spec ships on the plan's own cadence."""
        return ExchangeGate(self, p) if self.batch_exchange else None


DEFAULT_SCHEDULE = ScheduleSpec()


def make_schedule(schedule: Union[None, str, ScheduleSpec]) -> ScheduleSpec:
    """Normalize a user-facing ``schedule=`` value (None, a name, or a
    full spec) to a ScheduleSpec."""
    if schedule is None:
        return DEFAULT_SCHEDULE
    if isinstance(schedule, ScheduleSpec):
        return schedule
    return ScheduleSpec(name=str(schedule))


# ---------------------------------------------------------------------------
# frontier-selection state (one per shard per drain site)
# ---------------------------------------------------------------------------
class DrainOrder:
    """How one shard's coarse-to-fine ladder picks its next sweep.

    The contract with the drain hot paths (`incremental._push`,
    `sharded._drain_shard`):

      * ``begin_round()`` once per drain call (the retention clock);
      * ``refine(absr, frontier, eps, at_floor)`` maps the raw threshold
        frontier (all local rows with |r| >= eps; `absr` aligned with it)
        to the rows this sweep actually drains.  May return an *empty*
        selection at eps above the floor (the ladder then descends one
        level — that is how retention defers a row to the level where its
        fluid matters), but with ``at_floor=True`` a non-empty input must
        stay non-empty: an empty frontier at the floor is the drain's
        certificate that nothing above eps_floor remains, and no schedule
        is allowed to fake it;
      * ``note_drained(frontier)`` after the sweep moved the mass.

    Orderings only reorder/defer pushes; they never touch x/r themselves.
    """

    def begin_round(self) -> None:  # pragma: no cover - trivial default
        pass

    def refine(self, absr: np.ndarray, frontier: np.ndarray, eps: float,
               at_floor: bool) -> np.ndarray:
        return frontier

    def note_drained(self, frontier: np.ndarray) -> None:
        pass


class PriorityOrder(DrainOrder):
    """D-Iteration largest-fluid-first: a sweep at ladder level eps drains
    only rows whose fluid clears ``retain_boost * eps`` — rows below the
    bar *retain* their fluid and re-enter when the ladder descends to the
    level where it matters (or sooner, if neighbors re-fill them past the
    bar).  An empty refined sweep just descends the ladder, so with /8
    level steps the boost is a sub-level offset of the threshold grid
    (boost 8 reproduces the default grid exactly); boost 2 halves the
    small-trickle re-pushes that dominate the threads-regime local
    cadence tax (BENCH_PR8).  At the floor every row >= eps_floor drains
    unconditionally — deferral there would break the certificate.

    ``retain_rounds > 0`` switches to the classic per-row rendering: the
    boost bar applies only to rows drained within the last
    ``retain_rounds`` drain calls (everyone else drains at eps).  Measured
    worse here — deferring exactly the hottest rows is anti-greedy — but
    kept as the comparison arm the docs discuss."""

    def __init__(self, spec: ScheduleSpec, m: int):
        self.boost = float(spec.retain_boost)
        self.keep_rounds = int(spec.retain_rounds)
        # round index of the last drain per local row; -inf sentinel means
        # "never drained" (always eligible)
        self.last = np.full(m, np.iinfo(np.int64).min, dtype=np.int64)
        self.round = 0

    def begin_round(self) -> None:
        self.round += 1

    def refine(self, absr, frontier, eps, at_floor):
        if at_floor or frontier.size == 0:
            return frontier
        keep = absr >= self.boost * eps
        if self.keep_rounds > 0:
            # comparison, not subtraction: the never-drained sentinel is
            # int64.min and `round - last` would wrap
            recent = self.last[frontier] >= self.round - self.keep_rounds
            keep |= ~recent
        return frontier[keep]

    def note_drained(self, frontier) -> None:
        self.last[frontier] = self.round


class RandomizedOrder(DrainOrder):
    """Seeded Ishii-Tempo subsetting: each sweep drains a uniform random
    subset of the threshold frontier (never empty when the input is not,
    so every sweep makes progress and the expected-convergence argument
    goes through).  The stream is a deterministic function of (seed,
    shard, call sequence): the superstep mode replays bit-for-bit."""

    def __init__(self, spec: ScheduleSpec, m: int, shard: int = 0):
        self.frac = float(spec.select_frac)
        self.rng = np.random.default_rng(
            np.random.SeedSequence(entropy=int(spec.seed),
                                   spawn_key=(int(shard),)))

    def refine(self, absr, frontier, eps, at_floor):
        if frontier.size <= 1 or self.frac >= 1.0:
            return frontier
        keep = self.rng.random(frontier.size) < self.frac
        if not keep.any():
            keep[int(self.rng.integers(frontier.size))] = True
        return frontier[keep]


# ---------------------------------------------------------------------------
# exchange-coalescing state (one per shard; peers indexed 0..p-1)
# ---------------------------------------------------------------------------
class ExchangeGate:
    """The boundary-batched shipping gate, consulted *in front of* the
    ExchangePlan: a pair ships only when its coalesced mass is significant
    or the pair's batch window expired.  Sits strictly on the sender side
    — withheld mass stays in the outbox, which the sender's published
    value already counts, so the certificate never sees the gate.

    Bounded delay: ``ready`` is monotone in `updates` and force-opens at
    ``batch_updates`` updates past the last shipment (or past the last
    time the pair was empty — an empty pair "ships" vacuously), so the
    §6 forced-refresh bound degrades additively, never breaks."""

    def __init__(self, spec: ScheduleSpec, p: int):
        self.every = int(spec.batch_updates)
        self.mass_frac = float(spec.batch_mass_frac)
        # last update at which the pair was shipped-or-empty; batching
        # windows are measured from here
        self.last = np.zeros(p, dtype=np.int64)

    def ready(self, d: int, updates: int, mass: float,
              step_target: float) -> bool:
        if updates - self.last[d] >= self.every:
            return True
        return mass >= self.mass_frac * step_target

    def note_sent(self, d: int, updates: int) -> None:
        self.last[d] = updates

    def note_quiet(self, d: int, updates: int) -> None:
        # nothing pending for this pair: restart the window so the first
        # trickle of a new generation coalesces for a full batch_updates
        self.last[d] = updates
