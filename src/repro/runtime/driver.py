"""TerminationDriver — the Fig. 1 protocol over every transport rendering.

The protocol itself lives in `core.termination` as pure state machines
(ComputingUEState / MonitorState).  This driver owns p computing-shard
machines plus the monitor and exposes the three renderings the substrates
need:

  message-passing (DES)     : `ue_step` returns the edge-triggered
                              CONVERGE/DIVERGE message for the caller to
                              route through its latency channels;
                              `monitor_recv` ingests it at delivery time.
  all-reduced value         : `allreduce_step` takes per-shard scalars
  (sharded streaming)         (e.g. ||r_i||_1), forms the global sum — the
                              all-reduce — and runs every shard machine
                              against the shared verdict in one superstep.
                              The certificate the caller publishes is this
                              driver's reduced value, not a centralized
                              residual recomputation.
  all-reduced bits (SPMD)   : `bits_step` is the pure, jax-traceable
                              rendering (persistence counters over
                              all-reduced convergence bits) used inside
                              shard_map while_loops; pass `psum` bound to
                              the mesh axis (or `lambda a: a.sum()` to run
                              the same function in numpy tests).
"""
from __future__ import annotations

from typing import Callable, List, Optional, Tuple

import numpy as np

from ..core.termination import ComputingUEState, MonitorState, Msg


class TerminationDriver:
    """p computing-shard Fig. 1 machines + one monitor."""

    def __init__(self, p: int, pc_max_compute: int = 1,
                 pc_max_monitor: int = 1):
        self.p = p
        self.pc_max_compute = pc_max_compute
        self.pc_max_monitor = pc_max_monitor
        self.ues: List[ComputingUEState] = [
            ComputingUEState(pc_max=pc_max_compute) for _ in range(p)]
        self.monitor = MonitorState.create(p, pc_max=pc_max_monitor)
        self.stopped = False

    # -- message-passing rendering (DES) --------------------------------
    def ue_step(self, i: int, locally_converged: bool) -> Optional[Msg]:
        """One checkConvergence() on shard i; returns the CONVERGE/DIVERGE
        message to route to the monitor (None if no edge fired)."""
        self.ues[i], msg = self.ues[i].step(locally_converged)
        return msg

    def monitor_recv(self, src: int, msg: Msg) -> bool:
        """Deliver a routed message to the monitor; True iff STOP fires."""
        self.monitor = self.monitor.recv(src, msg)
        self.monitor, issue_stop = self.monitor.step()
        if issue_stop:
            self.stopped = True
        return issue_stop

    def stop_shard(self, i: int) -> None:
        self.ues[i] = self.ues[i].stop()

    def restart_shard(self, i: int) -> None:
        """Conservative Fig. 1 re-entry for a recovered shard worker: a
        fresh computing machine plus a DIVERGE delivered on its behalf,
        so a stale CONVERGE flag from the dead incarnation can never ride
        into STOP while the shard re-derives its value.  (DIVERGE clears
        the monitor's flag and resets its persistence counter; the
        follow-up step can therefore never issue STOP.)"""
        self.ues[i] = ComputingUEState(pc_max=self.pc_max_compute)
        self.monitor = self.monitor.recv(i, Msg.DIVERGE)
        self.monitor, _ = self.monitor.step()

    # -- all-reduced value rendering (sharded streaming) -----------------
    def allreduce_step(self, values, target: float) -> Tuple[float, bool]:
        """One superstep of the value rendering: all-reduce the per-shard
        scalars, evaluate the shared convergence verdict (sum <= target) on
        every shard machine, deliver the emitted messages to the monitor
        immediately (the all-reduce IS the channel), and report whether the
        monitor issued STOP.  Persistence counters on both sides still gate
        the stop, so mass still in flight between shards (counted in its
        sender's value) gets time to land and retract convergence."""
        values = np.asarray(values, dtype=np.float64)
        if values.shape != (self.p,):
            raise ValueError(f"expected {self.p} per-shard values, got "
                             f"shape {values.shape}")
        total = float(values.sum())          # the all-reduce
        verdict = total <= target
        for i in range(self.p):
            msg = self.ue_step(i, verdict)
            if msg is not None:
                self.monitor = self.monitor.recv(i, msg)
        # unlike the message rendering (where the monitor evaluates on
        # every arrival), the monitor rides the all-reduce: its persistence
        # counter advances once per superstep while all flags hold — the
        # same cadence as the SPMD bit rendering's mon_pc
        self.monitor, issue_stop = self.monitor.step()
        if issue_stop:
            self.stopped = True
        return total, issue_stop

    # -- all-reduced bit rendering (SPMD, jax-traceable) -----------------
    @staticmethod
    def bits_step(locally_conv, pc, mon_pc, *, p: int, pc_max_compute: int,
                  pc_max_monitor: int, psum: Callable):
        """Pure-function rendering of one Fig. 1 superstep over all-reduced
        convergence bits.  Shapes broadcast, so `locally_conv`/`pc`/`mon_pc`
        may be scalars (single iterate) or (nv,) lanes.  `psum` must reduce
        across shards (jax.lax.psum bound to the mesh axis inside
        shard_map; a plain sum for host-side tests)."""
        import jax.numpy as jnp
        pc = jnp.where(locally_conv, pc + 1, 0)
        flag = pc >= pc_max_compute
        nconv = psum(flag.astype(jnp.int32))
        all_conv = nconv == p
        mon_pc = jnp.where(all_conv, mon_pc + 1, 0)
        done = mon_pc >= pc_max_monitor
        return pc, mon_pc, done
