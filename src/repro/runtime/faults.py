"""FaultPlan — deterministic fault injection at the TransportContext seam.

The paper's case for asynchronous iteration is made on *unreliable*
platforms: workers die, links drop or duplicate messages, some machines
are simply slow.  Asynchronous fixed-point theory absorbs all of it under
bounded-delay assumptions (eq. 5's tau tables don't care why a view is
stale), and Ishii–Tempo's randomized PageRank shows convergence survives
unreliable per-link communication — so the runtime must be able to
*inject* these faults on demand, deterministically, in every transport.

`FaultyContext` wraps any `TransportContext` by delegation — the
`shard_worker_loop` happy path is untouched; the wrapper intercepts the
seam calls where each fault class physically lives:

  kill   — `report()`: at the scheduled round the worker dies for real
           (SIGKILL of its own process in the procpool rendering; an
           `InjectedWorkerKill` raise in the thread rendering).  A shared
           fired-flag array keeps a restarted worker from re-firing.
  hang   — `report()`: one blocking sleep; peers keep iterating (the
           bounded-delay tolerance the paper claims), recovery is just
           the hung worker waking up.
  slow   — `add_pushes()`: a pushes/second throttle, the heterogeneous-
           platform knob.
  drop   — `send()`: the payload never leaves the sender.  Modeled as the
           channel's existing backpressure result (-1), so the mass stays
           in the outbox, stays counted in the sender's reported value,
           and retries on a later update: a *lossy link with sender
           retention*.  With drop_rate < 1 every payload eventually
           delivers — mass conservation and the certificate survive any
           drop schedule.
  dup    — `send()`: the payload is delivered twice at the wire level
           with the same sequence number; the receiving Channel
           (`PairMailbox` / `ShmRing`) folds it exactly once (seq-deduped
           intake), so duplication never mints residual mass.
  delay  — `send()`: the payload is diverted into a held buffer (counted
           via the sender's `inflight_l1`, so values never under-count)
           and delivered at least `max_delay_rounds` rounds later —
           genuinely reordered against younger payloads.

All randomness is drawn from per-(src, dst) `numpy` generators seeded by
`(seed, src, dst)`: a given plan produces the same per-link fault
schedule regardless of thread/process interleaving.

Soundness note (docs/runtime.md "Fault model"): every injected fault
leaves the maintained residual either exact or *approximate in a bounded
way* (a killed worker can lose held/mid-sweep mass).  The streaming
caller therefore re-derives the residual with an exact O(nnz) recompute
whenever faults were injected or recoveries happened, and re-enters the
drain until the exact residual meets the target — published certificates
are always sound.
"""
from __future__ import annotations

import dataclasses
import os
import signal
import time
from typing import Dict, Mapping, Optional, Tuple

import numpy as np

from .observe import C_HANGS, C_KILLS, EV_HANG, EV_KILL, ShardObserver


class InjectedWorkerKill(Exception):
    """Raised inside a thread-rendered shard worker at its scheduled kill
    round (the procpool rendering SIGKILLs the worker process instead).
    The supervising transport treats it as a crash to recover from, not
    an error to propagate."""

    def __init__(self, shard: int):
        super().__init__(f"injected kill of shard worker {shard}")
        self.shard = shard


class FaultState:
    """Mutable fired-flags shared across drain attempts of one update (a
    kill/hang schedule fires once per *update*, not once per executor
    run).  Row 0 gates kills, row 1 gates hangs.  The procpool executor
    mirrors it through the control arena so restarted workers see it."""

    __slots__ = ("fired",)

    def __init__(self, p: int):
        self.fired = np.zeros((2, p), dtype=np.int64)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Deterministic seeded fault schedule (picklable; crosses into
    procpool workers).

    kill:  shard -> round at which its worker dies (>= that round, once).
    hang:  shard -> (round, seconds) one blocking stall.
    slow:  shard -> sustained pushes/second throttle.
    drop_rate / dup_rate / delay_rate: per-send probabilities, drawn from
    a per-(src, dst) seeded stream; their sum must stay < 1 so some sends
    deliver (drop_rate < 1 is the Ishii–Tempo condition for eventual
    delivery under sender retention).
    """

    seed: int = 0
    kill: Mapping[int, int] = dataclasses.field(default_factory=dict)
    hang: Mapping[int, Tuple[int, float]] = dataclasses.field(
        default_factory=dict)
    slow: Mapping[int, float] = dataclasses.field(default_factory=dict)
    drop_rate: float = 0.0
    dup_rate: float = 0.0
    delay_rate: float = 0.0
    max_delay_rounds: int = 8

    def __post_init__(self):
        for nm in ("drop_rate", "dup_rate", "delay_rate"):
            v = float(getattr(self, nm))
            if not 0.0 <= v < 1.0:
                raise ValueError(f"{nm}={v} must be in [0, 1)")
        if self.drop_rate + self.dup_rate + self.delay_rate >= 1.0:
            raise ValueError(
                "drop_rate + dup_rate + delay_rate must sum < 1: some "
                "sends must actually deliver or mass can never move")
        for i, rate in self.slow.items():
            if rate <= 0:
                raise ValueError(f"slow[{i}]={rate}: pushes/s must be > 0")
        for i, (rnd, secs) in self.hang.items():
            if secs < 0:
                raise ValueError(f"hang[{i}] seconds must be >= 0")
        if self.max_delay_rounds < 1:
            raise ValueError("max_delay_rounds must be >= 1")

    @property
    def active(self) -> bool:
        return bool(self.kill or self.hang or self.slow or self.drop_rate
                    or self.dup_rate or self.delay_rate)

    def state(self, p: int) -> FaultState:
        return FaultState(p)


class FaultyContext:
    """TransportContext wrapper injecting a FaultPlan at the seam.

    Pure delegation except at the call sites listed in the module
    docstring; thread-safe the same way the inner context is (each shard
    worker touches only its own (i, *) fault state)."""

    def __init__(self, inner, plan: FaultPlan, part, fired: np.ndarray,
                 kill_mode: str, obs: Optional[ShardObserver] = None):
        if kill_mode not in ("process", "thread"):
            raise ValueError(f"unknown kill_mode {kill_mode!r}")
        self.inner = inner
        self.plan = plan
        self.part = part
        self.fired = fired              # (2, p), shared across restarts
        self.kill_mode = kill_mode
        self._obs = obs                 # KILL/HANG instants when tracing
        p = part.p
        self._rng: Dict[Tuple[int, int], np.random.Generator] = {}
        self._held: Dict[Tuple[int, int], np.ndarray] = {}
        self._held_l1 = np.zeros((p, p))
        self._held_round = np.zeros((p, p), dtype=np.int64)
        self._round = np.zeros(p, dtype=np.int64)
        for i in range(p):
            for d in range(p):
                if d != i:
                    self._rng[(i, d)] = np.random.default_rng(
                        [int(plan.seed) & 0x7FFFFFFF, i, d])
                    sd, ed = part.block(d)
                    self._held[(i, d)] = np.zeros(ed - sd)

    # -- the intercepted seam calls -------------------------------------
    def send(self, i: int, d: int, box: np.ndarray, dup: bool = False
             ) -> int:
        plan = self.plan
        if self._held_l1[i, d] != 0.0:
            # a younger payload caught up with the held one: merge so the
            # delayed mass rides the next delivery decision
            box += self._held[(i, d)]
            self._held[(i, d)][:] = 0.0
            self._held_l1[i, d] = 0.0
        u = float(self._rng[(i, d)].random())
        if u < plan.drop_rate:
            # lossy link with sender retention: the loop sees channel
            # backpressure, keeps the mass in the outbox (still counted
            # in this shard's value) and retries on a later update
            return -1
        u -= plan.drop_rate
        if u < plan.dup_rate:
            return self.inner.send(i, d, box, dup=True)
        u -= plan.dup_rate
        if u < plan.delay_rate:
            nz = int(np.count_nonzero(box))
            self._held[(i, d)][:] = box
            self._held_l1[i, d] = float(np.abs(box).sum())
            self._held_round[i, d] = self._round[i]
            box[:] = 0.0        # held mass is counted via inflight_l1
            return nz
        return self.inner.send(i, d, box, dup=dup)

    def _flush_due(self, i: int, it: int, force: bool = False) -> None:
        for d in range(self.part.p):
            if d == i or self._held_l1[i, d] == 0.0:
                continue
            if force or it - self._held_round[i, d] \
                    >= self.plan.max_delay_rounds:
                held = self._held[(i, d)]
                if self.inner.send(i, d, held) >= 0:
                    self._held_l1[i, d] = 0.0
                else:
                    # channel backpressure mid-flush: recount whatever a
                    # partial push left behind and try again next round
                    self._held_l1[i, d] = float(np.abs(held).sum())

    def report(self, i: int, verdict: bool, it: int) -> bool:
        self._round[i] = it
        ka = self.plan.kill.get(i)
        if ka is not None and it >= ka and not self.fired[0, i]:
            if self._obs is not None:
                # the event must be in the (shared) ring before the
                # process SIGKILLs itself — it survives the incarnation
                self._obs.ctr[i, C_KILLS] += 1
                self._obs.emit(EV_KILL, i, self._obs.now(), a=float(it))
            self.fired[0, i] = 1    # shared store lands before the kill
            if self.kill_mode == "process":
                os.kill(os.getpid(), signal.SIGKILL)
            raise InjectedWorkerKill(i)
        ha = self.plan.hang.get(i)
        if ha is not None and it >= ha[0] and not self.fired[1, i]:
            self.fired[1, i] = 1
            if self._obs is not None:
                self._obs.ctr[i, C_HANGS] += 1
                self._obs.emit(EV_HANG, i, self._obs.now(), a=float(ha[1]))
            time.sleep(float(ha[1]))
        self._flush_due(i, it)
        return self.inner.report(i, verdict, it)

    def add_pushes(self, i: int, k: int) -> None:
        rate = self.plan.slow.get(i)
        if rate:
            time.sleep(min(k / float(rate), 0.05))
        self.inner.add_pushes(i, k)

    def inflight_l1(self, i: int) -> float:
        return (self.inner.inflight_l1(i)
                + float(self._held_l1[i].sum()))

    def record_rounds(self, i: int, it: int) -> None:
        # final flush: delayed payloads must not evaporate at loop exit.
        # If the channel refuses even now (full ring at teardown), park
        # the remainder in the outbox — the transport's fold-back
        # conserves outbox mass.
        self._flush_due(i, it, force=True)
        if float(self._held_l1[i].sum()) != 0.0:
            box = self.inner.outbox(i)
            for d in range(self.part.p):
                if d != i and self._held_l1[i, d] != 0.0:
                    sd, ed = self.part.block(d)
                    box[sd:ed] += self._held[(i, d)]
                    self._held[(i, d)][:] = 0.0
                    self._held_l1[i, d] = 0.0
        self.inner.record_rounds(i, it)

    # -- pure delegation -------------------------------------------------
    def stopped(self) -> bool:
        return self.inner.stopped()

    def note_capped(self) -> None:
        self.inner.note_capped()

    def outbox(self, i: int) -> np.ndarray:
        return self.inner.outbox(i)

    def intake_ready(self, i: int) -> bool:
        return self.inner.intake_ready(i)

    def retract(self, i: int) -> None:
        self.inner.retract(i)

    def fold_intake(self, i: int, r: np.ndarray, s: int, e: int) -> bool:
        return self.inner.fold_intake(i, r, s, e)

    def uniform_add(self, i: int, v: float) -> None:
        self.inner.uniform_add(i, v)

    def uniform_pending(self, i: int) -> float:
        return self.inner.uniform_pending(i)

    def values_total(self) -> float:
        return self.inner.values_total()

    def publish_value(self, i: int, v: float) -> None:
        self.inner.publish_value(i, v)

    def total_pushes(self) -> int:
        return self.inner.total_pushes()

    def note_exchange(self, i: int, nz: int) -> None:
        self.inner.note_exchange(i, nz)

    def idle_wait(self, seconds: float) -> None:
        self.inner.idle_wait(seconds)

    def record_idle(self, i: int, seconds: float) -> None:
        self.inner.record_idle(i, seconds)
