"""DeviceShardTransport — the eq. (5) cycle as p device programs.

The third rendering of the shard transport seam (threads and procpool are
in transport.py): the per-shard cycle runs as a jax `shard_map` program —
one shard program per device along a `ue` mesh axis — built from the SAME
traced ShardStep the SPMD solver runs (runtime/step.py):

  drain     — `shard_local_update` over the shard's operator slice (the
              Pallas BSR block path with its compensated/f64 accumulation
              lanes, or the segment-sum slice).
  exchange  — an `exchange.spmd_exchange` collective schedule:
              `ppermute` ring, strided all-gathers, or the §6 sparsified
              plan (top-k |delta| rows as (idx, value) payloads with the
              forced-full-refresh bounded-delay escape hatch).
  report    — the all-reduced Fig. 1 bits (`TerminationDriver.bits_step`
              over `transport.mesh_psum`), fed by the *value* criterion:
              the psum'd L1 of the fragment delta, which for the linear
              form (eq. 7) is ||r||_1 of the previous iterate up to view
              staleness.

On CPU, p shard programs are exercised with
`XLA_FLAGS=--xla_force_host_platform_device_count=p` (the forced-host-
device idiom the multidevice tests use); on TPU/GPU the mesh maps onto
real devices.

Numerics contract: the streaming updater certifies ||x - x*||_1 <= tol at
tol = 1e-8 scales, below the float32 representation floor (~n * eps32) —
so the transport runs the whole program under `jax.experimental.
enable_x64` when `dtype="float64"` (the default), with the segment-sum
backend whose operator slices are packed in the run dtype.  The BSR
backend keeps its blocks in float32 (the MXU layout); it is the TPU
rendering for looser tolerances and carries the compensated-summation
lane (`accum="kahan"`) to tighten accumulation error.

The transport reports its in-loop (rows, fulls) exchange counters through
`step.comm_bytes_model` — the identical accounting the SPMD solver uses,
cross-checked by benchmarks/check_device_transport.py.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class DeviceRunResult:
    """One device-program drain: the new iterate plus honest telemetry."""
    x: np.ndarray                # (n,) float64, NOT renormalized
    supersteps: int
    rows_sent: int               # sparsified: sparse payload rows shipped
    fulls: int                   # sparsified: forced full refreshes
    comm_bytes_total: int        # via step.comm_bytes_model
    device_resid: float          # final psum'd fragment-delta L1 (device view)
    converged: bool              # in-loop Fig. 1 fired before the step cap
    p: int = 0
    schedule: str = ""


class DeviceShardTransport:
    """p shard programs over a `ue` device mesh, one ShardStep each.

    Unlike the host transports this rendering is bulk-synchronous inside
    (XLA collectives are), so "async" means what §6 says it means:
    sparsified, delayed, bounded-staleness exchange — not unblocked
    threads.  Determinism follows: a run is a pure function of
    (operator, x0, config), which neither host transport can promise.

    Parameters mirror the SPMD solver's exchange/backend knobs; `mesh`
    overrides the default first-p-devices mesh.
    """

    def __init__(self, p: int, *, exchange: str = "sparsified",
                 dtype: str = "float64", backend: str = "segment_sum",
                 bsr_bm: int = 0, bsr_impl: str = "auto",
                 accum: Optional[str] = None, sync_every: int = 4,
                 sparsify_k: int = 0, sparsify_thresh: float = 0.0,
                 sparsify_refresh_every: int = 4,
                 sparsify_adaptive: bool = False,
                 pc_max_compute: int = 1, pc_max_monitor: int = 1,
                 seed: int = 0, mesh=None):
        if exchange not in ("allgather", "allgather_k", "ring",
                            "sparsified"):
            raise ValueError(f"unknown exchange schedule {exchange!r}")
        if backend not in ("segment_sum", "bsr_pallas"):
            raise ValueError(f"unknown backend {backend!r}")
        self.p = int(p)
        self.exchange = exchange
        self.dtype = str(dtype)
        self.backend = backend
        self.bsr_bm = bsr_bm
        self.bsr_impl = bsr_impl
        # the accumulation lane: wide accumulate whenever the run itself
        # is wide, the plain f32 contract otherwise (callers may pin
        # "kahan" for the compensated kernel lane on f32 runs)
        self.accum = accum if accum is not None else (
            "f64" if self.dtype == "float64" else "f32")
        self.sync_every = sync_every
        self.sparsify_k = sparsify_k
        self.sparsify_thresh = sparsify_thresh
        self.sparsify_refresh_every = sparsify_refresh_every
        self.sparsify_adaptive = sparsify_adaptive
        self.pc_max_compute = pc_max_compute
        self.pc_max_monitor = pc_max_monitor
        self.seed = seed
        self.mesh = mesh

    # -- mesh ------------------------------------------------------------
    def _mesh(self):
        import jax
        if self.mesh is not None:
            return self.mesh
        devs = jax.devices()
        if len(devs) < self.p:
            raise RuntimeError(
                f"device transport needs {self.p} devices, have "
                f"{len(devs)}; on CPU launch with XLA_FLAGS="
                f"--xla_force_host_platform_device_count={self.p}")
        return jax.make_mesh((self.p,), ("ue",), devices=devs[: self.p])

    # -- the drain -------------------------------------------------------
    def run(self, op, x0: np.ndarray, *, target: float,
            max_supersteps: int = 2000,
            v: Optional[np.ndarray] = None) -> DeviceRunResult:
        """Drain `op`'s linear form (eq. 7) from warm start `x0` until the
        all-reduced fragment-delta L1 holds <= `target` for the Fig. 1
        persistence window, or `max_supersteps` elapse.

        `target` is an *absolute* L1 threshold on the device-visible
        delta; the streaming caller derives it from its l1_target with a
        margin and publishes only the host-side exact-residual
        certificate (incremental._exact_residual), never this loop's own
        criterion.
        """
        if self.dtype == "float64":
            from jax.experimental import enable_x64
            with enable_x64():
                return self._run(op, x0, target=target,
                                 max_supersteps=max_supersteps, v=v)
        return self._run(op, x0, target=target,
                         max_supersteps=max_supersteps, v=v)

    def _run(self, op, x0: np.ndarray, *, target: float,
             max_supersteps: int, v: Optional[np.ndarray]
             ) -> DeviceRunResult:
        import jax
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map

        from ..core.partition import block_rows
        from ..core.spmd import SPMDConfig, _pack_blocks, _resolve_bsr
        from . import step as _step
        from .exchange import spmd_exchange

        p = self.p
        n = op.n
        alpha = float(op.alpha)
        np_dtype = np.dtype(self.dtype)
        mesh = self._mesh()

        v_stack = np.asarray(op.teleport() if v is None else v,
                             dtype=np.float64)
        if v_stack.ndim == 1:
            v_stack = v_stack[:, None]
        if v_stack.shape != (n, 1):
            raise ValueError(f"device transport is single-lane; teleport "
                             f"has shape {v_stack.shape}")

        # reuse the SPMD packer verbatim (one packing layout to maintain);
        # only the schedule/backend fields are consulted by _pack_blocks
        cfg = SPMDConfig(p=p, schedule=self.exchange, dtype=self.dtype,
                         backend=self.backend, bsr_bm=self.bsr_bm,
                         bsr_impl=self.bsr_impl)
        part = block_rows(n, p)
        packed = _pack_blocks(op, part, np_dtype, cfg, v_stack)
        bsize, n_pad = packed["bsize"], packed["n_pad"]
        use_bsr = self.backend == "bsr_pallas"
        if use_bsr:
            bm, bsr_impl = _resolve_bsr(cfg)

        x0 = np.asarray(x0, dtype=np.float64)
        if x0.shape != (n,):
            raise ValueError(f"x0 has shape {x0.shape}, expected ({n},)")
        x0_blocks = np.zeros((p, bsize, 1), dtype=np_dtype)
        for i in range(p):
            s, t = part.block(i)
            x0_blocks[i, : t - s, 0] = x0[s:t]

        init_comm, comm = spmd_exchange(
            self.exchange, p=p, bsize=bsize, n_pad=n_pad,
            sync_every=self.sync_every, sparsify_k=self.sparsify_k,
            sparsify_row_thresh=self.sparsify_thresh,
            sparsify_refresh_every=self.sparsify_refresh_every,
            sparsify_adaptive=self.sparsify_adaptive,
            # endgame guard at the drain target's scale: near-converged
            # delta mass ships full payloads so the persistence window
            # can settle
            sparsify_endgame_mass=target)

        sh = lambda *spec: jax.NamedSharding(mesh, P(*spec))
        valid = jax.device_put(packed["valid"], sh("ue", None))
        dang = jax.device_put(
            np.broadcast_to(packed["dang"], (p, n_pad)).copy(),
            sh("ue", None))
        vblk = jax.device_put(packed["vblk"].astype(np_dtype),
                              sh("ue", None, None))
        x0_dev = jax.device_put(x0_blocks, sh("ue", None, None))
        if use_bsr:
            op_args = tuple(
                jax.device_put(packed[k], sh("ue", *([None] * nd)))
                for k, nd in (("blk", 4), ("bcols", 2), ("hrow", 1),
                              ("hcol", 1), ("hval", 1)))
        else:
            op_args = tuple(jax.device_put(packed[k], sh("ue", None))
                            for k in ("src", "wgt", "rid"))

        accum = self.accum

        def body_fn(vblk, valid, dang, x0, *op_args):
            vb_, val_, dg_, myx = vblk[0], valid[0], dang[0], x0[0]
            i = jax.lax.axis_index("ue")
            op_slice = tuple(a[0] for a in op_args)
            if use_bsr:
                pt_apply = _step.shard_pt_apply(
                    op_slice, use_bsr=True, bsize=bsize, nv=1,
                    n_pad=n_pad, bm=bm, impl=bsr_impl, accum=accum)
            else:
                pt_apply = _step.shard_pt_apply(
                    op_slice, use_bsr=False, bsize=bsize, nv=1)
            local_update = _step.shard_local_update(
                pt_apply, alpha=alpha, linear=True, n=n,
                vb=vb_, val=val_, dang=dg_)
            superstep, cond = _step.shard_superstep_fns(
                local_update, comm, i=i, p=p, tol=target,
                pc_max_compute=self.pc_max_compute,
                pc_max_monitor=self.pc_max_monitor,
                seed=self.seed, q=1.0, freeze_lanes=False,
                max_steps=max_supersteps, conv="l1_psum", axis="ue")

            carry = _step.init_carry(myx, init_comm, nv=1, n_pad=n_pad,
                                     axis="ue")
            (view, frag, _, step, pc, mon_pc, lane_done, lane_step,
             rows_sent, fulls) = jax.lax.while_loop(
                cond, lambda c: superstep(c), carry)
            # final device-visible delta L1 (telemetry only — the caller
            # certifies with the host-side exact residual)
            from . import transport as _transport
            dl1 = _transport.mesh_psum("ue")(
                jnp.sum(jnp.abs(local_update(view) - frag)))
            return (frag[None], step[None], dl1[None],
                    lane_done[None], rows_sent[None], fulls[None])

        mapped = shard_map(
            body_fn, mesh=mesh,
            in_specs=(P("ue", None, None), P("ue", None), P("ue", None),
                      P("ue", None, None))
            + tuple(P("ue", *([None] * (a.ndim - 1))) for a in op_args),
            out_specs=(P("ue", None, None), P("ue"), P("ue"),
                       P("ue", None), P("ue"), P("ue")),
            check_rep=False,
        )
        frags, steps, dl1, lane_done, rows_sent, fulls = \
            jax.jit(mapped)(vblk, valid, dang, x0_dev, *op_args)

        frag_mat = np.asarray(frags, dtype=np.float64)
        supersteps = int(np.asarray(steps).max())
        x = np.empty(n, dtype=np.float64)
        for i in range(p):
            s, t = part.block(i)
            x[s:t] = frag_mat[i, : t - s, 0]
        rows_total = int(np.asarray(rows_sent).sum())
        fulls_total = int(np.asarray(fulls).sum())
        comm_total = _step.comm_bytes_model(
            self.exchange, p=p, bsize=bsize, itemsize=np_dtype.itemsize,
            nv=1, steps=supersteps, rows=rows_total, fulls=fulls_total,
            sync_every=self.sync_every)
        return DeviceRunResult(
            x=x, supersteps=supersteps, rows_sent=rows_total,
            fulls=fulls_total, comm_bytes_total=comm_total,
            device_resid=float(np.asarray(dl1)[0]),
            converged=bool(np.asarray(lane_done).all()),
            p=p, schedule=self.exchange)
