"""LocalSolver — the f_i of eq. (5): update one owned fragment from a
(stale) full view.

Every substrate funnels its per-shard update through this protocol:

  * the DES engine calls it from "iter" events (host numpy/scipy);
  * the sharded streaming updater drains residuals against the same row
    partition;
  * the SPMD loop runs the device rendering of the same block update
    (core.backend.google_apply restricted to the shard's rows — see
    core.spmd, which packs per-shard operator slices through the identical
    BackendSpec policy).

`BlockLocalSolver` is the shared host implementation: eq. (6) power form or
eq. (7) linear form restricted to rows of a Partition block, with the
matvec dispatched per backend ("csr" scipy rows, or "bsr" — scipy BSR with
(bm, bm) dense blocks, the host-side analogue of the bsr_pallas device
layout).
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Protocol, runtime_checkable

import numpy as np

from ..graph.google import GoogleOperator

if TYPE_CHECKING:                    # annotation-only (see state.py: a
    from ..core.partition import Partition   # module-level import would
    # recreate the runtime -> core -> des -> runtime cycle)


@runtime_checkable
class LocalSolver(Protocol):
    """f_i of eq. (5): update one fragment from a (stale) full view."""

    def update_block(self, i: int, x_full: np.ndarray) -> np.ndarray: ...

    def block_work(self, i: int) -> float:
        """Relative compute cost of block i (for clock models)."""
        ...


def _gcd_block(dim: int, bm: int) -> int:
    """Largest block edge <= bm that divides dim (scipy BSR needs the
    blocksize to tile the matrix exactly)."""
    for b in range(min(bm, max(dim, 1)), 0, -1):
        if dim % b == 0:
            return b
    return 1


class BlockLocalSolver:
    """Eq. (6) power form (`kind='power'`) or eq. (7) linear form
    (`kind='linear'`) restricted to rows of a partition block.

    matvec="bsr" stores each block's rows in scipy BSR with (bm, bm) dense
    blocks — the host-side analogue of the device block-CSR path (faster on
    site-local graphs, and keeps the host flavor layout-consistent with the
    bsr_pallas backend)."""

    def __init__(self, op: GoogleOperator, part: Partition,
                 kind: str = "power", matvec: str = "csr", bm: int = 32):
        assert kind in ("power", "linear")
        assert matvec in ("csr", "bsr")
        self.op = op
        self.part = part
        self.kind = kind
        self.matvec = matvec
        self.n = op.n
        pt_sp = op.to_scipy_pt()
        v = op.teleport()
        self._blocks = []
        for i in range(part.p):
            s, e = part.block(i)
            rows = pt_sp[s:e]
            nnz = pt_sp.indptr[e] - pt_sp.indptr[s]
            if matvec == "bsr":
                rows = rows.tobsr(blocksize=(
                    _gcd_block(e - s, bm), _gcd_block(self.n, bm)))
            self._blocks.append(dict(
                pt_rows=rows,                # rows of P^T for this block
                v=v[s:e],
                rows=(s, e),
                nnz=nnz,
            ))
        self._dangling = op.pt.dangling
        self._alpha = op.alpha

    def update_block(self, i: int, x_full: np.ndarray) -> np.ndarray:
        blk = self._blocks[i]
        dangling_mass = float(x_full[self._dangling].sum())
        y = self._alpha * (blk["pt_rows"] @ x_full)
        y += self._alpha * dangling_mass / self.n
        if self.kind == "power":
            y += (1.0 - self._alpha) * float(x_full.sum()) * blk["v"]
        else:
            y += (1.0 - self._alpha) * blk["v"]
        return y

    def block_work(self, i: int) -> float:
        return float(max(self._blocks[i]["nnz"], 1))
