"""Transport-agnostic shard workers — the eq. (5) cycle behind one seam.

PR 4's AsyncShardExecutor made the paper's asynchrony real, but only as
threads inside one Python process: the mailboxes were lock-protected numpy
buffers, the Fig. 1 messages were routed under a shared driver lock, and
raw wall-clock scaling stayed bounded by the GIL-held numpy gather/scatter
ops in the drain kernel.  This module splits the executor into the parts
that ARE the paper's cycle and the parts that were merely the thread
rendering of it:

  `shard_worker_loop`   — one shard's intake / hysteresis-gated local
                          update / §6-gated exchange / Fig. 1 report cycle,
                          written once against the `TransportContext`
                          protocol.  Every rendering runs this exact loop.
  `Channel`             — the boundary-residual conduit protocol: deposits
                          on the sender side, folds on the owner side, and
                          a stale-readable in-flight L1 for the sender-side
                          mass accounting.  `PairMailbox` is the
                          shared-address-space rendering; `ShmRing` is the
                          cross-process one (an SPSC ring of sparse payload
                          records over `multiprocessing.shared_memory`).
  `TransportContext`    — everything the loop needs from its substrate:
                          stop/cap flags, intake folding, uniform scalar,
                          value table, telemetry, and Fig. 1 routing.
                          `ThreadContext` renders it over locks + Events
                          (behavior-identical to PR 4, golden-gated by
                          tests/test_executor.py); `ProcContext` renders it
                          over a `ShardArena` control block + rings, with
                          the monitor machine pumped by the parent.
  `ThreadedShardTransport` / `ProcPoolShardExecutor`
                        — the two executors.  A future device-program or
                          RPC rendering is a third TransportContext, not
                          another rewrite.

Soundness is transport-independent and unchanged from PR 4 (see
runtime/executor.py's module docstring for the full argument): every unit
of residual mass lives in exactly one structure and is counted in exactly
one shard's reported value; in-flight mass is counted by the *sender*
until the receiver has folded it into rows the receiver itself counts.
The procpool rendering keeps the sender-side invariant with a pair of
single-writer cumulative L1 counters (`sent_abs` bumped *before* the ring
push, `recv_abs` bumped *after* the fold), so the reported value can
transiently over-count but never under-count.  The procpool Fig. 1
messages ride SPSC rings to the parent's monitor machine, which adds
delivery latency the thread rendering didn't have — the same premature-
STOP races as before are covered by the caller's exact-recompute-and-
re-enter loop (streaming/sharded.py publishes only exactly recomputed
certificates in async mode, under either transport).

Memory-model note: the SPSC rings rely on release/acquire-ish ordering of
aligned 8-byte stores (data written before the tail bump, tail read before
the data).  CPython's eval loop plus x86-TSO give this for free; exotic
weakly-ordered hosts would need explicit fences.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
import warnings
from typing import Callable, Dict, List, Optional, Protocol, Tuple

import numpy as np

from typing import TYPE_CHECKING

from ..core.partition import Partition
from ..core.termination import ComputingUEState, Msg
from .exchange import ExchangePlan
from .faults import FaultPlan, FaultState, FaultyContext, InjectedWorkerKill
from .observe import (C_CAPPED, C_CONVERGES, C_DIVERGES, C_DRAIN_MASS,
                      C_DRAIN_ROWS, C_DRAINS, C_EXCHANGE_BYTES,
                      C_EXCHANGE_ROWS, C_EXCHANGES, C_INTAKES, C_RECOVERIES,
                      C_STOPS, C_UNIFORM_FOLDS, DEFAULT_EVENT_CAP, EV_CAPPED,
                      EV_CONVERGE, EV_DIVERGE, EV_DRAIN, EV_EXCHANGE,
                      EV_INTAKE, EV_RECOVERY, EV_STOP, ShardObserver,
                      obs_ctl_entries)
from .schedule import DEFAULT_SCHEDULE, ScheduleSpec
from .state import ArenaHandle, ShardArena
from .supervisor import BackoffPolicy, ShardSupervisor

if TYPE_CHECKING:      # annotation-only: core/spmd.py imports this module
    from .driver import TerminationDriver   # while runtime.driver is still
    # mid-import (the runtime <-> core cycle the des.py submodule-reference
    # comment documents); a module-level class import here would break
    # `import repro.runtime`

# drain_fn(i, s, e, step_target, outbox) -> (pushes, dangling_mass):
# drain shard i's own rows [s, e) until their L1 is <= step_target,
# accumulating foreign-row contributions into `outbox` (addressed by
# global row id) and returning any mass destined for the dense uniform
# column as `dangling_mass` (the transport owns the shared scalar).
DrainFn = Callable[[int, int, int, float, np.ndarray], Tuple[int, float]]

# DrainFactory builds a DrainFn *inside a worker process* from the shared
# views of a ShardArena (key -> ndarray).  It must be picklable (a
# module-level class or function) when the start method is "spawn"; under
# "fork" closures also work.
DrainFactory = Callable[[Dict[str, np.ndarray]], DrainFn]


# ---------------------------------------------------------------------------
# Channel protocol + shared-address-space rendering
# ---------------------------------------------------------------------------
class Channel(Protocol):
    """One (src, dst) boundary-residual conduit: the sender deposits, the
    owner folds, and `l1()` is a stale-readable view of the mass currently
    in flight (stale reads may over-count mass just drained, never
    under-count mass deposited before the last deposit returned)."""

    def drain_into(self, r: np.ndarray, s: int, e: int) -> float: ...

    def l1(self) -> float: ...


class PairMailbox:
    """Lock-protected boundary-residual accumulator for one (src, dst)
    pair — the shared-address-space Channel.  Deposits add the sender's
    outbox block; the owner folds the buffer into its own rows.  `l1()` is
    a lock-free read of the last computed mass (stale reads only ever
    *over*-count mass that was just drained, never under-count mass that
    was deposited before the last `deposit` returned — deposits publish
    the new l1 under the lock).

    Deposits may carry a sender-assigned sequence number: a deposit whose
    seq is <= the highest already folded is a duplicated (or reordered
    stale) delivery and is dropped — the idempotent-intake hardening that
    lets `FaultPlan.dup_rate` re-deliver payloads at the wire level
    without ever minting residual mass.  Unsequenced deposits (seq=None,
    the default) keep the original always-fold semantics."""

    __slots__ = ("lock", "buf", "_l1", "_last_seq")

    def __init__(self, block_size: int):
        self.lock = threading.Lock()
        self.buf = np.zeros(block_size)
        self._l1 = 0.0
        self._last_seq = 0

    def deposit(self, block: np.ndarray, seq: Optional[int] = None) -> None:
        with self.lock:
            if seq is not None:
                if seq <= self._last_seq:
                    return              # duplicate/stale redelivery
                self._last_seq = seq
            self.buf += block
            self._l1 = float(np.abs(self.buf).sum())

    def drain_into(self, r: np.ndarray, s: int, e: int,
                   mark: Optional[np.ndarray] = None) -> float:
        """Fold the buffer into r[s:e] (the owner's rows); returns the L1
        mass moved (0.0 on the lock-free empty fast path).  When `mark`
        (a full-length uint8 row-flag array) is given, rows that received
        foreign mass are flagged — the push-inflation attribution's
        "boundary re-activation" marker (runtime/observe.py)."""
        if self._l1 == 0.0:
            return 0.0
        with self.lock:
            moved = self._l1
            if moved != 0.0:
                r[s:e] += self.buf
                if mark is not None:
                    mark[s:e][self.buf != 0.0] = 1
                self.buf[:] = 0.0
                self._l1 = 0.0
        return moved

    def l1(self) -> float:
        return self._l1


class UniformAccumulator:
    """The shared uniform-column scalar (dangling pushes smear column e/n).

    Senders `add` mass as they drain; each shard `take`s the delta since it
    last looked and applies it densely to its own rows only — the dense
    fold is sharded too, so no thread ever touches foreign rows.  Pending
    (added but not yet taken) mass is part of the sender-side residual
    accounting: `pending(i) * block_size` joins shard i's reported value.
    """

    def __init__(self, p: int):
        self._lock = threading.Lock()
        self._total = 0.0
        self._seen = np.zeros(p)

    def add(self, v: float) -> None:
        if v != 0.0:
            with self._lock:
                self._total += v

    def take(self, i: int) -> float:
        with self._lock:
            d = self._total - float(self._seen[i])
            self._seen[i] = self._total
        return d

    def pending(self, i: int) -> float:
        return self._total - float(self._seen[i])


# ---------------------------------------------------------------------------
# the cross-process Channel: an SPSC ring of sparse payload records
# ---------------------------------------------------------------------------
class ShmRing:
    """Single-producer single-consumer ring of (rows, values) payload
    records over shared-memory views.  Lock-free by construction: the
    producer owns `tail`, the consumer owns `head`, and a record's data is
    fully written before the tail bump publishes it.

    `head`/`tail` are (1,)-shaped int64 views; `cnt` is (depth,) int64;
    `idx`/`val` are (depth, cap) payload slots.  Row ids are local to the
    consumer's block.

    Optionally sequence-numbered (`seq` a (depth,) int64 slot array,
    `next_seq`/`last_seq` (1,)-shaped producer/consumer counters, all
    shared-memory views so they survive a worker restart): the producer
    stamps every record with a monotonically increasing seq, a duplicated
    delivery (`push(..., dup=True)`) re-publishes the *same* seq, and
    `pop_into` folds each seq at most once — the idempotent-intake
    hardening that makes `FaultPlan.dup_rate` and crash-replayed folds
    safe.  The five-argument form (no seq views) keeps the original
    always-fold semantics."""

    __slots__ = ("head", "tail", "cnt", "idx", "val", "depth", "cap",
                 "seq", "next_seq", "last_seq")

    def __init__(self, head, tail, cnt, idx, val, seq=None, next_seq=None,
                 last_seq=None):
        self.head, self.tail = head, tail
        self.cnt, self.idx, self.val = cnt, idx, val
        self.depth = int(cnt.shape[0])
        self.cap = int(idx.shape[1])
        self.seq, self.next_seq, self.last_seq = seq, next_seq, last_seq

    def push(self, rows: np.ndarray, vals: np.ndarray,
             dup: bool = False) -> bool:
        """Publish one record; False when the ring is full (the caller
        keeps the mass in its outbox and retries on a later update).
        `dup=True` re-publishes the previous record's sequence number (a
        wire-level duplicate the consumer will drop)."""
        h, t = int(self.head[0]), int(self.tail[0])
        if t - h >= self.depth:
            return False
        k = int(rows.size)
        slot = t % self.depth
        self.cnt[slot] = k
        self.idx[slot, :k] = rows
        self.val[slot, :k] = vals
        if self.seq is not None:
            s = int(self.next_seq[0])
            if s == 0:
                s = 1               # seq 0 is the consumer's "nothing
                # folded yet" sentinel; a zero-initialized producer
                # counter starts at 1 (single-writer, so lazy-init races
                # with nobody)
            if dup:
                s -= 1              # same seq as the record just pushed
            self.seq[slot] = s
            if not dup:
                self.next_seq[0] = s + 1
        self.tail[0] = t + 1        # publish AFTER the data is in place
        return True

    def pop_into(self, out: np.ndarray,
                 mark: Optional[np.ndarray] = None) -> float:
        """Fold every pending record into `out` (the owner's block view);
        returns the |payload| L1 folded.  Sequence-numbered records are
        folded at most once (duplicates and crash-replays are skipped);
        `last_seq` advances *before* the fold, so a consumer killed
        mid-fold can at worst lose one record (a bounded under-count the
        caller's exact recompute covers) but never double-fold.  `mark`
        (a block-shaped uint8 flag view) tags every row that received
        foreign mass — the push-inflation attribution's boundary
        re-activation marker (runtime/observe.py)."""
        moved = 0.0
        h, t = int(self.head[0]), int(self.tail[0])
        dedupe = self.seq is not None
        while h < t:
            slot = h % self.depth
            if dedupe:
                s = int(self.seq[slot])
                if s <= int(self.last_seq[0]):
                    h += 1
                    self.head[0] = h
                    continue
                self.last_seq[0] = s
            k = int(self.cnt[slot])
            ix = self.idx[slot, :k]
            v = self.val[slot, :k]
            out[ix] += v            # rows within one record are unique
            if mark is not None:
                mark[ix] = 1
            moved += float(np.abs(v).sum())
            h += 1
            self.head[0] = h        # free the slot before the next read
        return moved

    def pending_l1(self) -> float:
        """|payload| L1 of the records the consumer has not folded yet
        (seq-deduped view), WITHOUT consuming them — the supervisor's
        ground truth when it reconciles the in-flight ledgers after a
        worker death (see ShardSupervisor._recover_shard)."""
        total = 0.0
        h, t = int(self.head[0]), int(self.tail[0])
        last = int(self.last_seq[0]) if self.seq is not None else None
        while h < t:
            slot = h % self.depth
            if last is None or int(self.seq[slot]) > last:
                k = int(self.cnt[slot])
                total += float(np.abs(self.val[slot, :k]).sum())
                if last is not None:
                    last = int(self.seq[slot])  # count dups once
            h += 1
        return total

    def empty(self) -> bool:
        return int(self.tail[0]) == int(self.head[0])


# ---------------------------------------------------------------------------
# run transcript + worker configuration
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class AsyncRunResult:
    """Transcript of one transport run (telemetry only — the residual
    itself is folded back into `r` before run() returns)."""

    stopped: bool                   # the monitor issued STOP
    capped: bool                    # a round/push cap fired first
    rounds_per_shard: np.ndarray    # local updates each worker executed
    pushes_per_shard: np.ndarray
    exchanges: int                  # channel deposits that actually shipped
    bytes_moved: int                # modeled payload bytes ((idx, value))
    stop_round: int                 # issuing shard's round at STOP (-1)
    idle_s_per_shard: np.ndarray    # time spent parked waiting for mail
    wall_s: float
    recoveries: int = 0             # supervised worker restarts
    recovery_s: float = 0.0         # total death-detection -> respawned
    observed: Optional[dict] = None  # ShardObserver.observed() payload
    # (events + counters + attribution) when the run was traced; None
    # when observability was off (the zero-cost default)


@dataclasses.dataclass(frozen=True)
class WorkerConfig:
    """Per-run knobs of the shard worker loop (transport-independent).

    `drain_frac` sets the sliding per-round drain target
    (drain_frac * reported_total / p) and `hysteresis` how far above it
    own mass must rise before a drain fires.  Their product is bounded:
    with balanced shards each holds ~total/p, so
    ``hysteresis * drain_frac >= 1`` means no shard can ever clear its
    own gate — a livelock (every worker parks until the round cap).
    Found the hard way in the PR 5 procpool tuning sweep; rejected here.

    `schedule` is the DrainSchedule spec (runtime/schedule.py): the loop
    builds its boundary-batched exchange gate from it, and because the
    config is pickled into procpool workers whole, the same spec reaches
    every incarnation of every worker unchanged.  (The drain-order half of
    a spec lives in the DrainFn — built by the caller's drain factory —
    not here: the loop never looks inside a drain.)
    """

    l1_target: float
    bytes_per_entry: int = 8
    max_rounds: int = 1_000_000
    max_total_pushes: Optional[int] = None
    idle_sleep: float = 2e-4
    drain_frac: float = 0.05
    hysteresis: float = 2.0
    schedule: ScheduleSpec = DEFAULT_SCHEDULE

    def __post_init__(self):
        if self.hysteresis * self.drain_frac >= 1.0:
            raise ValueError(
                f"hysteresis ({self.hysteresis}) * drain_frac "
                f"({self.drain_frac}) >= 1: balanced shards could never "
                "clear the drain gate (livelock)")


# ---------------------------------------------------------------------------
# TransportContext — what one shard's loop needs from its substrate
# ---------------------------------------------------------------------------
class TransportContext(Protocol):
    """The seam between the paper's cycle and its execution substrate.
    All methods are called from the worker that owns shard `i` only,
    except where noted; implementations decide what is a lock, a shared
    Event, or a shared-memory cell."""

    def stopped(self) -> bool: ...

    def note_capped(self) -> None: ...

    def outbox(self, i: int) -> np.ndarray: ...

    def intake_ready(self, i: int) -> bool: ...

    def retract(self, i: int) -> None: ...

    def fold_intake(self, i: int, r: np.ndarray, s: int, e: int) -> bool: ...

    def uniform_add(self, i: int, v: float) -> None: ...

    def uniform_pending(self, i: int) -> float: ...

    def values_total(self) -> float: ...

    def publish_value(self, i: int, v: float) -> None: ...

    def add_pushes(self, i: int, k: int) -> None: ...

    def total_pushes(self) -> int: ...

    def send(self, i: int, d: int, box: np.ndarray) -> int: ...

    def note_exchange(self, i: int, nz: int) -> None: ...

    def inflight_l1(self, i: int) -> float: ...

    def report(self, i: int, verdict: bool, it: int) -> bool: ...

    def idle_wait(self, seconds: float) -> None: ...

    def record_rounds(self, i: int, it: int) -> None: ...

    def record_idle(self, i: int, seconds: float) -> None: ...


# ---------------------------------------------------------------------------
# the shard worker loop — the cycle itself, written once
# ---------------------------------------------------------------------------
def shard_worker_loop(i: int, r: np.ndarray, part: Partition,
                      plan: ExchangePlan, cfg: WorkerConfig,
                      ctx: TransportContext, drain_fn: DrainFn,
                      obs: Optional[ShardObserver] = None) -> None:
    """One round = one intake + (gated) local update + one Fig. 1
    checkConvergence().  The ExchangePlan runs on its own clock of *local
    updates*: drain rounds tick it, idle-converged spin rounds do not (a
    spin-round clock would force-ship every withheld sub-threshold
    payload within `refresh_every * idle_sleep`, defeating the §6 gate),
    and a round parked *above* the convergence target with the plan
    withholding still ticks — that keeps the forced-refresh bound live,
    so significant parked mass always ships within `refresh_every` local
    updates.  Converged shards may withhold sub-threshold mass
    indefinitely: it is counted in their reported value, so the
    certificate stays sound.  (Transplanted verbatim from the PR 4
    executor; tests/test_executor.py golden-gates the thread rendering.)

    `obs` arms the observability layer (runtime/observe.py): structured
    events at every cycle seam (intake / drain / exchange / Fig. 1
    verdict flips / STOP / caps) plus the per-shard counter slots.  The
    default None is the zero-cost path — every hook is one predictable
    branch.

    The round body itself lives in `step.HostShardStep` — the host
    rendering of the per-shard ShardStep (the jax-traceable rendering of
    the same cycle drives `core.spmd` and the device transport, see
    runtime/step.py).  This function is the thin host driver: construct
    the step, spin rounds until an exit path fires, record telemetry.
    """
    from .step import HostShardStep
    step = HostShardStep(i, r, part, plan, cfg, ctx, drain_fn, obs)
    try:
        while step.round():
            pass
    finally:
        ctx.record_rounds(i, step.it)
        ctx.record_idle(i, step.idle_total)


# ---------------------------------------------------------------------------
# thread rendering (PR 4's executor, re-expressed on the seam)
# ---------------------------------------------------------------------------
class ThreadContext:
    """TransportContext over locks, Events and in-process numpy buffers —
    behavior-identical to the PR 4 executor internals."""

    def __init__(self, part: Partition, driver: TerminationDriver,
                 cfg: WorkerConfig,
                 obs: Optional[ShardObserver] = None):
        p = part.p
        self.part = part
        self.driver = driver
        self.cfg = cfg
        self._obs = obs
        self.mail = [[PairMailbox(part.block(d)[1] - part.block(d)[0])
                      if d != i else None for d in range(p)]
                     for i in range(p)]
        self.outboxes = [np.zeros(part.n) for _ in range(p)]
        self.uniform = UniformAccumulator(p)
        self.driver_lock = threading.Lock()
        self.stat_lock = threading.Lock()
        self.stop_evt = threading.Event()
        self.rounds = np.zeros(p, dtype=np.int64)
        self.pushes = np.zeros(p, dtype=np.int64)
        self.idle_s = np.zeros(p)
        self.last_values = np.zeros(p)
        self.shared = dict(exchanges=0, bytes_moved=0, stop_round=-1,
                           capped=False)
        self._inboxes = [[self.mail[j][i] for j in range(p) if j != i]
                         for i in range(p)]
        # per-pair delivery sequence (writer: shard i only) — lets the
        # mailboxes drop wire-level duplicates; survives worker restarts
        # because the context outlives its workers
        self._next_seq = np.ones((p, p), dtype=np.int64)

    # -- stop/caps -------------------------------------------------------
    def stopped(self) -> bool:
        return self.stop_evt.is_set()

    def note_capped(self) -> None:
        self.shared["capped"] = True
        self.stop_evt.set()

    # -- structures ------------------------------------------------------
    def outbox(self, i: int) -> np.ndarray:
        return self.outboxes[i]

    def intake_ready(self, i: int) -> bool:
        return (self.uniform.pending(i) != 0.0
                or any(mb.l1() != 0.0 for mb in self._inboxes[i]))

    def retract(self, i: int) -> None:
        with self.driver_lock:
            if not self.driver.stopped:
                msg = self.driver.ue_step(i, False)
                if msg is not None:
                    self.driver.monitor_recv(i, msg)

    def fold_intake(self, i: int, r: np.ndarray, s: int, e: int) -> bool:
        progressed = False
        obs = self._obs
        mark = obs.foreign if (obs is not None
                               and obs.foreign is not None) else None
        for mb in self._inboxes[i]:
            if mb.drain_into(r, s, e, mark=mark) != 0.0:
                progressed = True
        dc = self.uniform.take(i)
        if dc != 0.0:
            r[s:e] += dc
            if obs is not None:
                obs.ctr[i, C_UNIFORM_FOLDS] += 1
            progressed = True
        return progressed

    def uniform_add(self, i: int, v: float) -> None:
        self.uniform.add(v)

    def uniform_pending(self, i: int) -> float:
        return self.uniform.pending(i)

    def values_total(self) -> float:
        return float(self.last_values.sum())

    def publish_value(self, i: int, v: float) -> None:
        self.last_values[i] = v

    def add_pushes(self, i: int, k: int) -> None:
        self.pushes[i] += k

    def total_pushes(self) -> int:
        return int(self.pushes.sum())

    def send(self, i: int, d: int, box: np.ndarray,
             dup: bool = False) -> int:
        nz = int(np.count_nonzero(box))
        seq = int(self._next_seq[i, d])
        self._next_seq[i, d] = seq + 1
        mb = self.mail[i][d]
        mb.deposit(box, seq=seq)
        if dup:
            mb.deposit(box, seq=seq)    # wire duplicate: deduped intake
        box[:] = 0.0
        return nz

    def note_exchange(self, i: int, nz: int) -> None:
        with self.stat_lock:
            self.shared["exchanges"] += 1
            self.shared["bytes_moved"] += nz * (4 + self.cfg.bytes_per_entry)

    def inflight_l1(self, i: int) -> float:
        return sum(self.mail[i][d].l1() for d in range(self.part.p)
                   if d != i)

    def report(self, i: int, verdict: bool, it: int) -> bool:
        with self.driver_lock:
            if not self.driver.stopped:
                msg = self.driver.ue_step(i, verdict)
                if msg is not None and self.driver.monitor_recv(i, msg):
                    self.shared["stop_round"] = it
                    self.stop_evt.set()
                    return True
        return False

    def idle_wait(self, seconds: float) -> None:
        self.stop_evt.wait(seconds)

    def record_rounds(self, i: int, it: int) -> None:
        self.rounds[i] = it

    def record_idle(self, i: int, seconds: float) -> None:
        self.idle_s[i] = seconds


class ThreadedShardTransport:
    """Run p shard drains concurrently, one worker thread per shard —
    the PR 4 rendering, now a thin shell around `shard_worker_loop` +
    `ThreadContext` (AsyncShardExecutor delegates here)."""

    def __init__(self, part: Partition, plan: ExchangePlan,
                 driver: TerminationDriver, cfg: WorkerConfig,
                 faults: Optional[FaultPlan] = None,
                 fault_state: Optional[FaultState] = None,
                 max_restarts: Optional[int] = None,
                 restart_backoff: BackoffPolicy = BackoffPolicy(),
                 observe: Optional[ShardObserver] = None):
        if driver.p != part.p or plan.p != part.p:
            raise ValueError(f"partition ({part.p}), plan ({plan.p}) and "
                             f"driver ({driver.p}) disagree on p")
        self.part = part
        self.plan = plan
        self.driver = driver
        self.cfg = cfg
        self.faults = faults
        self.fault_state = fault_state
        self.max_restarts = (2 * part.p if max_restarts is None
                             else int(max_restarts))
        self.restart_backoff = restart_backoff
        self.observe = observe

    def run(self, drain_fn: DrainFn, r: np.ndarray) -> AsyncRunResult:
        """Drive the drains until STOP or a cap; on return every mailbox,
        outbox and pending uniform delta has been folded back into `r`, so
        `r` is again the one exactly-maintained residual.

        An `InjectedWorkerKill` (FaultPlan kill schedule) is supervised,
        not propagated: the shard re-enters Fig. 1 conservatively
        (`driver.restart_shard` — DIVERGE until its value recomputes) and
        its loop restarts after capped exponential backoff, drawing from a
        global restart budget.  Real exceptions keep the PR 4 fail-fast
        contract."""
        p, part = self.part.p, self.part
        t0 = time.perf_counter()
        obs = self.observe
        ctx = ThreadContext(part, self.driver, self.cfg, obs=obs)
        ctx.last_values[:] = [float(np.abs(r[s:e]).sum())
                              for s, e in (part.block(i) for i in range(p))]
        wctx: TransportContext = ctx
        if self.faults is not None:
            fstate = self.fault_state or self.faults.state(p)
            wctx = FaultyContext(ctx, self.faults, part,
                                 fired=fstate.fired, kill_mode="thread",
                                 obs=obs)
        errors: List[Optional[BaseException]] = [None] * p
        budget = [self.max_restarts]
        recovery = dict(n=0, s=0.0)

        def worker(i: int) -> None:
            attempt = 0
            while True:
                try:
                    shard_worker_loop(i, r, part, self.plan, self.cfg,
                                      wctx, drain_fn, obs=obs)
                    return
                except InjectedWorkerKill:
                    with ctx.stat_lock:
                        ok = budget[0] > 0
                        if ok:
                            budget[0] -= 1
                            recovery["n"] += 1
                    if not ok:
                        errors[i] = RuntimeError(
                            f"shard worker {i} killed with the restart "
                            f"budget ({self.max_restarts}) exhausted")
                        ctx.stop_evt.set()
                        return
                    if ctx.stopped():
                        return
                    t_rec = time.perf_counter()
                    with ctx.driver_lock:
                        if not self.driver.stopped:
                            self.driver.restart_shard(i)
                    time.sleep(self.restart_backoff.delay(attempt))
                    attempt += 1
                    dt_rec = time.perf_counter() - t_rec
                    with ctx.stat_lock:
                        recovery["s"] += dt_rec
                    if obs is not None:
                        # shard i's own (restarting) worker writes its own
                        # ring — the single-writer invariant holds
                        obs.ctr[i, C_RECOVERIES] += 1
                        obs.emit(EV_RECOVERY, i, t_rec, dur=dt_rec,
                                 a=float(i))
                except BaseException as exc:  # pragma: no cover - reraised
                    errors[i] = exc
                    ctx.stop_evt.set()
                    return

        threads = [threading.Thread(target=worker, args=(i,),
                                    name=f"shard-drain-{i}", daemon=True)
                   for i in range(p)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

        # fold every in-flight structure back into r: the caller's r is
        # again the exactly-maintained residual (mass conservation)
        for i in range(p):
            for d in range(p):
                if d != i:
                    sd, ed = part.block(d)
                    ctx.mail[i][d].drain_into(r, sd, ed)
            box = ctx.outboxes[i]
            nzr = np.flatnonzero(box)
            if nzr.size:
                r[nzr] += box[nzr]
            s, e = part.block(i)
            dc = ctx.uniform.take(i)
            if dc != 0.0:
                r[s:e] += dc

        for exc in errors:
            if exc is not None:
                raise exc

        return AsyncRunResult(
            stopped=self.driver.stopped and not ctx.shared["capped"],
            capped=ctx.shared["capped"], rounds_per_shard=ctx.rounds,
            pushes_per_shard=ctx.pushes, exchanges=ctx.shared["exchanges"],
            bytes_moved=ctx.shared["bytes_moved"],
            stop_round=ctx.shared["stop_round"],
            idle_s_per_shard=ctx.idle_s,
            wall_s=time.perf_counter() - t0,
            recoveries=recovery["n"], recovery_s=recovery["s"],
            observed=obs.observed() if obs is not None else None)


# ---------------------------------------------------------------------------
# procpool rendering — workers as processes over a ShardArena
# ---------------------------------------------------------------------------
# control-block flag indices
_F_STOP, _F_CAPPED, _F_STOP_ROUND = 0, 1, 2

_MSG_RING_DEPTH = 256


def _ctl_spec(p: int, n: int, part: Partition, ring_depth: int,
              payload_cap: int, observe: bool = False,
              obs_event_cap: int = DEFAULT_EVENT_CAP) -> Dict:
    """Layout of the transport control block: flags, per-shard telemetry,
    the uniform scalar ledger, the in-flight L1 ledgers, the outboxes and
    both ring families (mail payloads, Fig. 1 messages).

    Mail-ring slots hold at most `payload_cap` (idx, value) pairs — a
    larger boundary payload is split across records by `ProcContext.send`
    — so the reservation scales O(p^2 * depth * payload_cap), not
    O(p * depth * n): a dense-block slot layout would reserve hundreds of
    MB of /dev/shm at p=8, n~1e6 and SIGBUS a worker in containers with
    the Docker-default 64 MB tmpfs.

    `observe=True` appends the observability slots (event rings, counter
    registry, attribution flags — runtime/observe.py): putting them in
    the control segment is what makes worker-side metrics survive the
    process boundary and supervisor respawns without locks (the segment
    outlives every worker incarnation, and every slot is single-writer).
    They are only *allocated* when observing — /dev/shm stays small on
    the default path."""
    cap = min(int(part.sizes().max()), int(payload_cap))
    spec = {
        "flags": ((3,), np.int64),          # stop / capped / stop_round
        "err": ((p,), np.int64),
        "values": ((p,), np.float64),
        "rounds": ((p,), np.int64),
        "pushes": ((p,), np.int64),
        "idle_s": ((p,), np.float64),
        "exchanges": ((p,), np.int64),
        "bytes_moved": ((p,), np.int64),
        "uni_add": ((p,), np.float64),      # cumulative adds, writer = i
        "uni_seen": ((p,), np.float64),     # cumulative takes, writer = i
        "sent_abs": ((p, p), np.float64),   # |payload| shipped, writer = src
        "recv_abs": ((p, p), np.float64),   # |payload| folded, writer = dst
        "send_intent": ((p, p), np.float64),  # in-window |payload|: written
        # before the sent_abs bump, cleared after the push — the supervisor
        # rolls an uncleared intent back so a worker killed inside the
        # window can't strand a phantom in-flight payload (livelock)
        "outbox": ((p, n), np.float64),
        "mail_head": ((p, p), np.int64),    # writer = consumer (dst)
        "mail_tail": ((p, p), np.int64),    # writer = producer (src)
        "mail_cnt": ((p, p, ring_depth), np.int64),
        "mail_idx": ((p, p, ring_depth, cap), np.int32),
        "mail_val": ((p, p, ring_depth, cap), np.float64),
        "mail_seq": ((p, p, ring_depth), np.int64),   # record seqs
        "mail_next_seq": ((p, p), np.int64),  # writer = producer (src)
        "mail_last_seq": ((p, p), np.int64),  # writer = consumer (dst)
        "msg_head": ((p,), np.int64),       # consumer = parent pump
        "msg_tail": ((p,), np.int64),       # producer = shard i
        "msg_buf": ((p, _MSG_RING_DEPTH), np.int64),
        # --- self-healing state (supervisor.py) ---
        "busy": ((p,), np.int64),           # 1 while shard i is mid-sweep
        "fault_fired": ((2, p), np.int64),  # FaultPlan kill/hang gates
        "ckpt_seq": ((p,), np.int64),       # seqlock (odd = mid-write)
        "ckpt_r": ((n,), np.float64),       # per-shard residual checkpoint
        "ckpt_x": ((n,), np.float64),       # per-shard iterate checkpoint
        "restarts": ((p,), np.int64),       # writer = parent supervisor
    }
    if observe:
        spec.update(obs_ctl_entries(p, n, event_cap=obs_event_cap))
    return spec


def _ctl_ring(ctl: ShardArena, i: int, d: int) -> ShmRing:
    """The (src=i, dst=d) mail ring over the control arena, sequence-
    numbered: producer/consumer counters live in the arena too, so
    dedupe state survives a worker restart (both sides single-writer)."""
    return ShmRing(
        ctl["mail_head"][i, d:d + 1], ctl["mail_tail"][i, d:d + 1],
        ctl["mail_cnt"][i, d], ctl["mail_idx"][i, d],
        ctl["mail_val"][i, d], seq=ctl["mail_seq"][i, d],
        next_seq=ctl["mail_next_seq"][i, d:d + 1],
        last_seq=ctl["mail_last_seq"][i, d:d + 1])


class ProcContext:
    """TransportContext over a ShardArena control block: flags and
    telemetry are single-writer shared-memory cells, boundary mass moves
    through per-pair `ShmRing`s, and the Fig. 1 computing-UE machines run
    *inside* the workers with their edge-triggered messages ringed to the
    parent's monitor."""

    def __init__(self, ctl: ShardArena, part: Partition, cfg: WorkerConfig,
                 pc_max_compute: int, r: Optional[np.ndarray] = None,
                 x: Optional[np.ndarray] = None,
                 checkpoint_every: int = 0,
                 obs: Optional[ShardObserver] = None):
        self.ctl = ctl
        self.part = part
        self.cfg = cfg
        self._r = r
        self._x = x
        self._ckpt_every = int(checkpoint_every)
        self._obs = obs
        p = part.p
        self._ues = {i: ComputingUEState(pc_max=pc_max_compute)
                     for i in range(p)}
        self._mail = {}
        for i in range(p):
            for d in range(p):
                if d != i:
                    self._mail[(i, d)] = _ctl_ring(ctl, i, d)

    # -- stop/caps -------------------------------------------------------
    def stopped(self) -> bool:
        return self.ctl["flags"][_F_STOP] != 0

    def note_capped(self) -> None:
        self.ctl["flags"][_F_CAPPED] = 1
        self.ctl["flags"][_F_STOP] = 1

    # -- structures ------------------------------------------------------
    def outbox(self, i: int) -> np.ndarray:
        return self.ctl["outbox"][i]

    def intake_ready(self, i: int) -> bool:
        if self.uniform_pending(i) != 0.0:
            return True
        return any(not self._mail[(j, i)].empty()
                   for j in range(self.part.p) if j != i)

    def retract(self, i: int) -> None:
        self._ues[i], msg = self._ues[i].step(False)
        if msg is not None:
            self._post_msg(i, msg)

    def fold_intake(self, i: int, r: np.ndarray, s: int, e: int) -> bool:
        progressed = False
        own = r[s:e]
        obs = self._obs
        mark = (obs.foreign[s:e] if obs is not None
                and obs.foreign is not None else None)
        for j in range(self.part.p):
            if j == i:
                continue
            moved = self._mail[(j, i)].pop_into(own, mark=mark)
            if moved != 0.0:
                # the fold leaves the sender's books only now: recv_abs
                # is bumped AFTER the rows it covers are counted in our
                # own r (sender-side invariant, see module docstring)
                self.ctl["recv_abs"][j, i] += moved
                progressed = True
        total = float(self.ctl["uni_add"].sum())
        dc = total - float(self.ctl["uni_seen"][i])
        if dc != 0.0:
            r[s:e] += dc
            self.ctl["uni_seen"][i] = total
            if obs is not None:
                obs.ctr[i, C_UNIFORM_FOLDS] += 1
            progressed = True
        return progressed

    def uniform_add(self, i: int, v: float) -> None:
        if v != 0.0:
            self.ctl["uni_add"][i] += v

    def uniform_pending(self, i: int) -> float:
        return float(self.ctl["uni_add"].sum()
                     - self.ctl["uni_seen"][i])

    def values_total(self) -> float:
        return float(self.ctl["values"].sum())

    def publish_value(self, i: int, v: float) -> None:
        self.ctl["values"][i] = v

    def add_pushes(self, i: int, k: int) -> None:
        self.ctl["pushes"][i] += k

    def total_pushes(self) -> int:
        return int(self.ctl["pushes"].sum())

    def send(self, i: int, d: int, box: np.ndarray,
             dup: bool = False) -> int:
        rows = np.flatnonzero(box)
        ring = self._mail[(i, d)]
        cap = ring.cap
        intent = self.ctl["send_intent"]
        shipped = 0
        for lo in range(0, int(rows.size), cap):
            chunk = rows[lo:lo + cap]
            vals = box[chunk]
            mass = float(np.abs(vals).sum())
            # record intent, then bump sent_abs BEFORE the push: the mass
            # must be on the sender's books at every instant it could be
            # folded by the receiver.  If this worker is killed anywhere
            # inside the window, the supervisor rolls the uncleared
            # intent back out of sent_abs — over-counting is sound only
            # transiently; a *permanent* phantom in-flight payload would
            # hold this shard's value above target forever.
            intent[i, d] = mass
            self.ctl["sent_abs"][i, d] += mass
            if not ring.push(chunk.astype(np.int32), vals):
                # ring full: roll this record's ledger back (the receiver
                # never saw it).  Already-pushed chunks stay shipped; the
                # remainder stays in the outbox — the caller sees
                # backpressure, leaves its cached outbox L1 stale-high
                # (a sound transient over-count) and retries on a later
                # update.
                self.ctl["sent_abs"][i, d] -= mass
                intent[i, d] = 0.0
                return -1
            if dup:
                # wire-level duplicate: same payload, same seq, no ledger
                # bump — the receiver's seq-deduped fold drops it (best
                # effort; a full ring just loses the duplicate)
                ring.push(chunk.astype(np.int32), vals, dup=True)
            box[chunk] = 0.0
            intent[i, d] = 0.0
            shipped += int(chunk.size)
        return shipped

    def note_exchange(self, i: int, nz: int) -> None:
        self.ctl["exchanges"][i] += 1
        self.ctl["bytes_moved"][i] += nz * (4 + self.cfg.bytes_per_entry)

    def inflight_l1(self, i: int) -> float:
        d = (self.ctl["sent_abs"][i] - self.ctl["recv_abs"][i])
        return float(np.maximum(d, 0.0).sum())

    def report(self, i: int, verdict: bool, it: int) -> bool:
        self.ctl["rounds"][i] = it      # live, so the pump can stamp STOP
        if self._ckpt_every and self._r is not None \
                and it % self._ckpt_every == 0:
            self._checkpoint(i)
        self._ues[i], msg = self._ues[i].step(verdict)
        if msg is not None:
            self._post_msg(i, msg)
        return self.stopped()

    def _checkpoint(self, i: int) -> None:
        """Seqlock'd per-shard (r, x) checkpoint, written at report time —
        never mid-sweep, so `busy[i] == 1` implies the checkpoint is
        committed.  The supervisor restores from it when this worker dies
        inside a drain."""
        s, e = self.part.block(i)
        cs = self.ctl["ckpt_seq"]
        cs[i] += 1                      # odd: write in progress
        self.ctl["ckpt_r"][s:e] = self._r[s:e]
        if self._x is not None:
            self.ctl["ckpt_x"][s:e] = self._x[s:e]
        cs[i] += 1                      # even: committed

    def idle_wait(self, seconds: float) -> None:
        time.sleep(seconds)

    def record_rounds(self, i: int, it: int) -> None:
        self.ctl["rounds"][i] = it

    def record_idle(self, i: int, seconds: float) -> None:
        self.ctl["idle_s"][i] = seconds

    # -- Fig. 1 message ring --------------------------------------------
    def _post_msg(self, i: int, msg: Msg) -> None:
        head, tail = self.ctl["msg_head"], self.ctl["msg_tail"]
        buf = self.ctl["msg_buf"]
        while int(tail[i]) - int(head[i]) >= _MSG_RING_DEPTH:
            if self.stopped():          # pragma: no cover - pump died
                return
            time.sleep(1e-4)
        t = int(tail[i])
        buf[i, t % _MSG_RING_DEPTH] = msg.value
        tail[i] = t + 1


def _procpool_worker_main(shard_ids, data_handle: ArenaHandle,
                          ctl_handle: ArenaHandle, part: Partition,
                          plan: ExchangePlan, cfg: WorkerConfig,
                          drain_factory: DrainFactory,
                          pc_max_compute: int, r_key: str,
                          x_key: Optional[str] = None,
                          faults: Optional[FaultPlan] = None,
                          checkpoint_every: int = 0,
                          observe: bool = False) -> None:
    """Worker-process entry: attach both arenas, rebuild the drain from
    the factory, and run one `shard_worker_loop` per owned shard (several
    shards share a process when p exceeds the pool — they interleave on
    threads, which only serializes shards that were going to share a core
    anyway).

    Crash semantics changed with the supervisor: an exception bumps the
    shard's `err` counter and hard-exits the *process* (exit code 70) —
    it does NOT stamp STOP.  The parent decides whether to restart (the
    default) or give up; sibling shard threads die with the process and
    are restored from their checkpoints exactly like a SIGKILL, so one
    policy covers both."""
    import traceback
    data = ShardArena.attach(data_handle)
    ctl = ShardArena.attach(ctl_handle)
    try:
        views = {k: data[k] for k in data.keys()}
        r = views[r_key]
        x = views.get(x_key) if x_key else None
        drain_fn = drain_factory(views)
        # the worker-side observer wraps the control arena's obs_* views:
        # counters and events land in shared memory, so they survive this
        # process being SIGKILL'd and respawned
        obs = ShardObserver.from_views(ctl) if observe else None
        if obs is not None and hasattr(drain_fn, "set_observer"):
            drain_fn.set_observer(obs)   # arm push-inflation attribution
        ctx: TransportContext = ProcContext(
            ctl, part, cfg, pc_max_compute, r=r, x=x,
            checkpoint_every=checkpoint_every, obs=obs)
        if faults is not None:
            ctx = FaultyContext(ctx, faults, part,
                                fired=ctl["fault_fired"],
                                kill_mode="process", obs=obs)
        busy = ctl["busy"]

        def guarded(i, s, e, t, outbox):
            # busy flag brackets the sweep: the supervisor restores this
            # shard from its checkpoint only when the worker died with
            # the flag up (mid-sweep (x, r) may be torn); a clean-point
            # death keeps the live rows
            busy[i] = 1
            try:
                return drain_fn(i, s, e, t, outbox)
            finally:
                busy[i] = 0

        def run_one(i: int) -> None:
            try:
                shard_worker_loop(i, r, part, plan, cfg, ctx, guarded,
                                  obs=obs)
            except BaseException:
                traceback.print_exc()
                ctl["err"][i] += 1
                # hard exit: siblings checkpoint-restore like a SIGKILL
                os._exit(70)

        if len(shard_ids) == 1:
            run_one(shard_ids[0])
        else:
            ts = [threading.Thread(target=run_one, args=(i,), daemon=True)
                  for i in shard_ids]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
    except BaseException:               # pragma: no cover - defensive
        import traceback
        traceback.print_exc()
        for i in shard_ids:
            ctl["err"][i] += 1
        os._exit(70)
    finally:
        # drop views before detaching the mappings (no unlink: the parent
        # owns both segments)
        views = None
        ctx = None
        data.close(unlink=False)
        ctl.close(unlink=False)


def default_pool_size(p: int) -> int:
    """Worker-pool sizing: min(p, cores).  More processes than cores buys
    nothing (the drains are CPU-bound) and oversubscribes small
    containers — the ROADMAP's p >= 8 pathology."""
    return max(1, min(p, os.cpu_count() or 1))


class ProcPoolShardExecutor:
    """The procpool rendering: shard workers as OS processes over a
    `ShardArena`, mailboxes and Fig. 1 messages over lock-free shared
    rings — the first transport whose raw wall-clock escapes the GIL.

    The caller supplies the shard fragments (r, x, CSR, ...) in a data
    arena plus a picklable `DrainFactory`; the executor owns the control
    arena (flags, ledgers, outboxes, rings), the worker pool
    (`n_workers` defaults to min(p, cores) and is capped at p; asking
    for more than the machine's cores warns — the oversubscription
    guard — but the explicit request is honored, since one process per
    parked-heavy shard can kernel-schedule better than co-residence),
    and the parent-side supervisor.  On return every ring, outbox and
    pending uniform delta has been folded back into the arena's residual.

    Since PR 6 a worker crash or kill no longer aborts the solve: a
    `ShardSupervisor` restarts the dead worker (checkpoint-restored
    rows, reconciled ledgers, conservative Fig. 1 re-entry — see
    supervisor.py) and only an exhausted restart budget raises, with the
    control arena released either way (nothing leaks in /dev/shm; the
    data arena belongs to the caller).  Pass `faults=FaultPlan(...)` to
    inject deterministic kill/hang/drop/dup/delay/slow schedules at the
    transport seam.
    """

    # Coarser drain scheduling than the thread rendering: cross-process
    # exchange has real latency, and deeper per-round drains mean fewer
    # boundary-payload generations — measured ~15-25% fewer total pushes
    # on the 50k drain-dominated bench than the thread defaults
    # (hysteresis * drain_frac stays well under the livelock bound 1.0).
    DRAIN_FRAC = 0.25
    HYSTERESIS = 2.5
    # A parked shard's wake-up checks (ring scans, the uniform ledger)
    # briefly take its process's GIL away from a busy process-mate when
    # shards share a worker; 1 ms wake-ups cut that tax ~5x vs the thread
    # rendering's 0.2 ms with no measurable staleness cost.
    IDLE_SLEEP = 1e-3

    def __init__(self, part: Partition, plan: ExchangePlan,
                 driver: TerminationDriver, *, l1_target: float,
                 bytes_per_entry: int = 8, max_rounds: int = 1_000_000,
                 max_total_pushes: Optional[int] = None,
                 idle_sleep: float = IDLE_SLEEP,
                 drain_frac: float = DRAIN_FRAC,
                 hysteresis: float = HYSTERESIS,
                 n_workers: Optional[int] = None,
                 ring_depth: int = 8,
                 ring_payload_cap: int = 4096,
                 start_method: Optional[str] = None,
                 faults: Optional[FaultPlan] = None,
                 fault_state: Optional[FaultState] = None,
                 max_restarts: Optional[int] = None,
                 restart_backoff: BackoffPolicy = BackoffPolicy(),
                 checkpoint_every: int = 32,
                 observe: bool = False,
                 observe_event_cap: int = DEFAULT_EVENT_CAP,
                 schedule: ScheduleSpec = DEFAULT_SCHEDULE):
        if driver.p != part.p or plan.p != part.p:
            raise ValueError(f"partition ({part.p}), plan ({plan.p}) and "
                             f"driver ({driver.p}) disagree on p")
        self.part = part
        self.p = part.p
        self.plan = plan
        self.driver = driver
        self.cfg = WorkerConfig(
            l1_target=float(l1_target), bytes_per_entry=int(bytes_per_entry),
            max_rounds=int(max_rounds), max_total_pushes=max_total_pushes,
            idle_sleep=float(idle_sleep), drain_frac=float(drain_frac),
            hysteresis=float(hysteresis), schedule=schedule)
        cores = os.cpu_count() or 1
        if n_workers is None:
            n_workers = default_pool_size(self.p)
        elif n_workers > cores:
            # oversubscription guard: honor the explicit request (the
            # kernel can still schedule busy workers onto idle cores —
            # sometimes a win when shards idle unevenly) but say so
            warnings.warn(
                f"procpool n_workers={n_workers} oversubscribes "
                f"{cores} cores; the default is min(p, cores) = "
                f"{default_pool_size(self.p)}", RuntimeWarning,
                stacklevel=2)
        self.n_workers = max(1, min(int(n_workers), self.p))
        self.ring_depth = int(ring_depth)
        self.ring_payload_cap = int(ring_payload_cap)
        self.start_method = start_method
        self.faults = faults if (faults is not None and faults.active) \
            else None
        self.fault_state = fault_state
        self.max_restarts = (2 * self.p if max_restarts is None
                             else int(max_restarts))
        self.restart_backoff = restart_backoff
        self.checkpoint_every = int(checkpoint_every)
        self.observe = bool(observe)
        self.observe_event_cap = int(observe_event_cap)

    # ------------------------------------------------------------------
    def run(self, drain_factory: DrainFactory, data: ShardArena,
            r_key: str = "r", x_key: Optional[str] = None
            ) -> AsyncRunResult:
        """Drive the drains until STOP or a cap.  `data` must hold the
        residual under `r_key` (and the iterate under `x_key` when the
        drain maintains one — required for mid-sweep checkpoint restore
        of x); the factory rebuilds the DrainFn from the attached views
        inside each worker."""
        import multiprocessing as mp

        p, part = self.p, self.part
        r = data[r_key]
        if r.shape != (part.n,):
            raise ValueError(f"data arena {r_key!r} has shape {r.shape}, "
                             f"expected ({part.n},)")
        x = data[x_key] if x_key else None
        t0 = time.perf_counter()
        method = self.start_method or (
            "fork" if "fork" in mp.get_all_start_methods() else "spawn")
        mpctx = mp.get_context(method)
        ctl = ShardArena.create(_ctl_spec(p, part.n, part, self.ring_depth,
                                          self.ring_payload_cap,
                                          observe=self.observe,
                                          obs_event_cap=(
                                              self.observe_event_cap)),
                                prefix="repro_arena_ctl")
        sup: Optional[ShardSupervisor] = None
        procs: List = []
        died = False
        try:
            # seq 0 is the "nothing folded yet" sentinel on the consumer
            # side, so producers must start stamping at 1
            ctl["mail_next_seq"][:] = 1
            for i in range(p):
                s, e = part.block(i)
                ctl["values"][i] = float(np.abs(r[s:e]).sum())
            if self.faults is not None and self.fault_state is not None:
                # kill/hang schedules fire once per *update*: carry the
                # fired flags across executor runs through the caller's
                # FaultState
                ctl["fault_fired"][:] = self.fault_state.fired
            # checkpoint zero: a worker killed before its first report
            # restores to the initial rows, not to garbage
            ctl["ckpt_r"][:] = r
            if x is not None:
                ctl["ckpt_x"][:] = x
            assign = [ids for ids in
                      ([i for i in range(p) if i % self.n_workers == w]
                       for w in range(self.n_workers)) if ids]

            def spawn(w: int):
                pr = mpctx.Process(
                    target=_procpool_worker_main,
                    args=(assign[w], data.handle(), ctl.handle(), part,
                          self.plan, self.cfg, drain_factory,
                          self.driver.pc_max_compute, r_key, x_key,
                          self.faults, self.checkpoint_every,
                          self.observe),
                    name=f"shard-worker-{w}", daemon=True)
                with warnings.catch_warnings():
                    # jax's at-fork hook warns that the parent is
                    # multithreaded; the workers are numpy-only (they
                    # never enter jax/XLA), so the fork is safe — callers
                    # who want belt-and-braces can pass
                    # start_method="spawn" (slower: workers re-import
                    # the stack)
                    warnings.filterwarnings(
                        "ignore", message=r".*os\.fork\(\) was called.*",
                        category=RuntimeWarning)
                    pr.start()
                return pr

            # the parent-side observer reads/writes the same arena slots:
            # supervisor recoveries land in the dead shard's ring while no
            # worker incarnation is alive (single-writer preserved), and
            # the final observed payload is read out before the arena is
            # unlinked
            pobs = (ShardObserver.from_views(ctl) if self.observe
                    else None)
            sup = ShardSupervisor(
                part, self.driver, ctl, r, x, assign, spawn,
                max_restarts=self.max_restarts,
                backoff=self.restart_backoff, obs=pobs)
            procs = [spawn(w) for w in range(len(assign))]
            died = sup.supervise(procs)
            for pr in sup.all_procs:
                pr.join()

            # fold every in-flight structure back into r (mass
            # conservation — even after a crash, whatever mass survives
            # is back in one place)
            flags = ctl["flags"]
            for i in range(p):
                for d in range(p):
                    if d != i:
                        sd, ed = part.block(d)
                        _ctl_ring(ctl, i, d).pop_into(r[sd:ed])
                box = ctl["outbox"][i]
                nzr = np.flatnonzero(box)
                if nzr.size:
                    r[nzr] += box[nzr]
            total = float(ctl["uni_add"].sum())
            for i in range(p):
                s, e = part.block(i)
                dc = total - float(ctl["uni_seen"][i])
                if dc != 0.0:
                    r[s:e] += dc
                    ctl["uni_seen"][i] = total

            if self.faults is not None and self.fault_state is not None:
                self.fault_state.fired[:] = ctl["fault_fired"]

            if died:
                # restart budget exhausted — the PR 5 contract: raise
                # with surviving mass folded back and /dev/shm released.
                # (`err` counts are telemetry now: a *recovered* crash
                # must not raise.)
                errs = np.flatnonzero(ctl["err"])
                detail = (f"; shard worker(s) {errs.tolist()} raised — "
                          "see worker stderr" if errs.size else "")
                raise RuntimeError(
                    "procpool shard worker died mid-drain and the "
                    f"restart budget ({self.max_restarts}) is exhausted"
                    f"{detail}; surviving mass has been folded back "
                    "into r")

            return AsyncRunResult(
                stopped=self.driver.stopped and not bool(flags[_F_CAPPED]),
                capped=bool(flags[_F_CAPPED]),
                rounds_per_shard=ctl["rounds"].copy(),
                pushes_per_shard=ctl["pushes"].copy(),
                exchanges=int(ctl["exchanges"].sum()),
                bytes_moved=int(ctl["bytes_moved"].sum()),
                stop_round=int(flags[_F_STOP_ROUND]),
                idle_s_per_shard=ctl["idle_s"].copy(),
                wall_s=time.perf_counter() - t0,
                recoveries=sup.recoveries,
                recovery_s=sup.recovery_s,
                observed=pobs.observed() if pobs is not None else None)
        finally:
            for pr in (sup.all_procs if sup is not None and sup.all_procs
                       else procs):
                if pr.is_alive():
                    pr.terminate()
                pr.join(timeout=5.0)
            ctl.close(unlink=True)


# ---------------------------------------------------------------------------
# reduction channel — the bulk-synchronous seam (SPMD reuses it)
# ---------------------------------------------------------------------------
class ReductionChannel(Protocol):
    """How per-shard scalars become the global verdict: a host sum for the
    superstep/streaming renderings, a mesh psum for SPMD."""

    def all_reduce(self, values): ...


class HostAllReduce:
    """Plain numpy sum — the host rendering (TerminationDriver's
    allreduce_step and the superstep streaming loop)."""

    def all_reduce(self, values):
        return float(np.asarray(values, dtype=np.float64).sum())


def mesh_psum(axis: str):
    """The SPMD rendering: a jax psum bound to a shard_map mesh axis,
    shaped for `TerminationDriver.bits_step(psum=...)`.  Importing jax is
    deferred so host-only paths never pay for it."""
    import jax

    def _psum(a):
        return jax.lax.psum(a, axis)
    return _psum
