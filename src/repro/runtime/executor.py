"""AsyncShardExecutor — the eq. (5) cycle over real worker threads.

Everything under `repro.runtime` so far *models* asynchrony: the DES engine
simulates it event-by-event, the SPMD loop batches it into supersteps, and
the sharded streaming updater ran its p shard drains in a sequential
superstep loop on one host thread.  This executor makes the asynchrony
real: each shard's local drain runs on its own worker thread and the three
synchronizing phases of the paper's cycle are gone —

  * no exchange barrier: residual mass a shard diffuses into rows another
    shard owns moves through a per-(src, dst) **mailbox** (a lock-protected
    accumulator).  The sender deposits whenever its `ExchangePlan` says so
    (`wants`/`gate_mass` consulted after every local update, `note_sent`
    advancing the §6 refresh clock — including on empty-outbox epochs, so
    quiet pairs never accumulate forced-refresh debt); the receiver folds
    its incoming mailboxes into its own rows whenever it next looks.
  * no reduction barrier: termination runs the Fig. 1 protocol in its
    **message rendering** (`TerminationDriver.ue_step`/`monitor_recv`).
    After each local update a shard evaluates its own residual value
    against its share of the target and the edge-triggered CONVERGE /
    DIVERGE messages are delivered to the monitor under the driver lock —
    the all-reduce of the superstep rendering is never formed.
  * no termination barrier: STOP is a shared event workers observe at the
    top of their loop; nobody waits for anybody's round to finish.

Soundness rests on the same mass-conservation invariant as the superstep
loop, now stated per *data structure* instead of per superstep: every unit
of residual mass lives in exactly one place at any instant — some shard's
own rows (`r[s:e]`), the sender's undelivered outbox, a mailbox in flight,
or the pending uniform scalar — and each structure is counted in exactly
one shard's reported value: own rows + own outbox + *mailbox mass this
shard put in flight* + the uniform share of its rows.  In-flight mass is
deliberately counted by the sender: it leaves the sender's books only
after the receiver has folded it into rows the receiver itself counts, so
a deposit can never be unreported at the instant the monitor evaluates
STOP — the handoff can transiently *double*-count (sender's value is
stale while the receiver drains), which is sound: over-counts delay
convergence, they never fake it.  The one remaining under-count window
(a shard whose value predates a peer's uniform-scalar add) is covered by
the Fig. 1 persistence counters and by the caller: `run()` folds every
structure back into `r` before returning, so the caller can recompute the
exact residual and re-enter the drain if a race let STOP fire early (see
`streaming/sharded.py`, which publishes only exactly-recomputed
certificates in async mode).

Determinism caveat: thread scheduling makes the async schedule — rounds,
exchange epochs, push counts — run-to-run nondeterministic.  The superstep
loop is preserved as the deterministic golden reference; the *results* of
both agree to within the certified tolerance (docs/runtime.md).

Since PR 5 the cycle itself — intake, hysteresis-gated drain, §6-gated
exchange, Fig. 1 report — lives in `runtime/transport.py`
(`shard_worker_loop`), written once against the `TransportContext` seam.
This class is the thread rendering (`ThreadedShardTransport` under the
hood, behavior-preserving and golden-gated by tests/test_executor.py);
`transport.ProcPoolShardExecutor` is the shared-memory process-pool
rendering whose raw wall-clock escapes the GIL.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from ..core.partition import Partition
from .driver import TerminationDriver
from .exchange import ExchangePlan
from .faults import FaultPlan, FaultState
from .observe import ShardObserver
from .schedule import DEFAULT_SCHEDULE, ScheduleSpec
from .transport import (AsyncRunResult, DrainFn, PairMailbox,  # noqa: F401
                        ThreadedShardTransport, UniformAccumulator,
                        WorkerConfig)


class AsyncShardExecutor:
    """Run p shard drains concurrently, one worker thread per shard, with
    mailbox exchange and message-rendered Fig. 1 termination (see module
    docstring for the protocol and its soundness argument).

    The executor owns the concurrency plumbing — mailboxes, the uniform
    scalar, the driver lock, stop propagation, telemetry — and is handed
    the actual local update as a `DrainFn`, so it stays independent of the
    problem being iterated (the streaming updater passes its
    Gauss-Southwell sweep; tests pass synthetic kernels).
    """

    def __init__(self, part: Partition, plan: ExchangePlan,
                 driver: TerminationDriver, *, l1_target: float,
                 bytes_per_entry: int = 8, max_rounds: int = 1_000_000,
                 max_total_pushes: Optional[int] = None,
                 idle_sleep: float = 2e-4, drain_frac: float = 0.05,
                 hysteresis: float = 2.0,
                 faults: Optional[FaultPlan] = None,
                 fault_state: Optional[FaultState] = None,
                 max_restarts: Optional[int] = None,
                 observe: Optional[ShardObserver] = None,
                 schedule: ScheduleSpec = DEFAULT_SCHEDULE):
        if driver.p != part.p or plan.p != part.p:
            raise ValueError(f"partition ({part.p}), plan ({plan.p}) and "
                             f"driver ({driver.p}) disagree on p")
        self.part = part
        self.p = part.p
        self.plan = plan
        self.driver = driver
        self.l1_target = float(l1_target)
        self.bytes_per_entry = int(bytes_per_entry)
        self.max_rounds = int(max_rounds)
        self.max_total_pushes = max_total_pushes
        self.idle_sleep = float(idle_sleep)
        self.drain_frac = float(drain_frac)
        self.hysteresis = float(hysteresis)
        self.faults = faults if (faults is not None and faults.active) \
            else None
        self.fault_state = fault_state
        self.max_restarts = max_restarts
        # an armed ShardObserver (runtime/observe.py) traces the run;
        # None keeps the zero-cost default
        self.observe = observe
        # DrainSchedule spec: the worker loop builds its exchange gate
        # from this (the drain-order half lives in the caller's DrainFn)
        self.schedule = schedule

    def run(self, drain_fn: DrainFn, r: np.ndarray) -> AsyncRunResult:
        """Drive the drains until STOP or a cap; on return every mailbox,
        outbox and pending uniform delta has been folded back into `r`, so
        `r` is again the one exactly-maintained residual.

        The transport is built here, not in __init__, so the knob
        attributes stay live until run() — callers (and tests) that tune
        `ex.max_rounds` etc. after construction keep the PR 4 semantics.
        """
        transport = ThreadedShardTransport(
            self.part, self.plan, self.driver, WorkerConfig(
                l1_target=float(self.l1_target),
                bytes_per_entry=int(self.bytes_per_entry),
                max_rounds=int(self.max_rounds),
                max_total_pushes=self.max_total_pushes,
                idle_sleep=float(self.idle_sleep),
                drain_frac=float(self.drain_frac),
                hysteresis=float(self.hysteresis),
                schedule=self.schedule),
            faults=self.faults, fault_state=self.fault_state,
            max_restarts=self.max_restarts, observe=self.observe)
        return transport.run(drain_fn, r)
