"""AsyncShardExecutor — the eq. (5) cycle over real worker threads.

Everything under `repro.runtime` so far *models* asynchrony: the DES engine
simulates it event-by-event, the SPMD loop batches it into supersteps, and
the sharded streaming updater ran its p shard drains in a sequential
superstep loop on one host thread.  This module makes the asynchrony real:
each shard's local drain runs on its own worker thread and the three
synchronizing phases of the paper's cycle are gone —

  * no exchange barrier: residual mass a shard diffuses into rows another
    shard owns moves through a per-(src, dst) **mailbox** (a lock-protected
    accumulator).  The sender deposits whenever its `ExchangePlan` says so
    (`wants`/`gate_mass` consulted after every local update, `note_sent`
    advancing the §6 refresh clock — including on empty-outbox epochs, so
    quiet pairs never accumulate forced-refresh debt); the receiver folds
    its incoming mailboxes into its own rows whenever it next looks.
  * no reduction barrier: termination runs the Fig. 1 protocol in its
    **message rendering** (`TerminationDriver.ue_step`/`monitor_recv`).
    After each local update a shard evaluates its own residual value
    against its share of the target and the edge-triggered CONVERGE /
    DIVERGE messages are delivered to the monitor under the driver lock —
    the all-reduce of the superstep rendering is never formed.
  * no termination barrier: STOP is a shared event workers observe at the
    top of their loop; nobody waits for anybody's round to finish.

Soundness rests on the same mass-conservation invariant as the superstep
loop, now stated per *data structure* instead of per superstep: every unit
of residual mass lives in exactly one place at any instant — some shard's
own rows (`r[s:e]`), the sender's undelivered outbox, a mailbox in flight,
or the pending uniform scalar — and each structure is counted in exactly
one shard's reported value: own rows + own outbox + *mailbox mass this
shard put in flight* + the uniform share of its rows.  In-flight mass is
deliberately counted by the sender: it leaves the sender's books only
after the receiver has folded it into rows the receiver itself counts, so
a deposit can never be unreported at the instant the monitor evaluates
STOP — the handoff can transiently *double*-count (sender's value is
stale while the receiver drains), which is sound: over-counts delay
convergence, they never fake it.  The one remaining under-count window
(a shard whose value predates a peer's uniform-scalar add) is covered by
the Fig. 1 persistence counters and by the caller: `run()` folds every
structure back into `r` before returning, so the caller can recompute the
exact residual and re-enter the drain if a race let STOP fire early (see
`streaming/sharded.py`, which publishes only exactly-recomputed
certificates in async mode).

Determinism caveat: thread scheduling makes the async schedule — rounds,
exchange epochs, push counts — run-to-run nondeterministic.  The superstep
loop is preserved as the deterministic golden reference; the *results* of
both agree to within the certified tolerance (docs/runtime.md).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, List, Optional, Tuple

import numpy as np

from ..core.partition import Partition
from .driver import TerminationDriver
from .exchange import ExchangePlan

# drain_fn(i, s, e, step_target, outbox) -> (pushes, dangling_mass):
# drain shard i's own rows [s, e) until their L1 is <= step_target,
# accumulating foreign-row contributions into `outbox` (addressed by
# global row id) and returning any mass destined for the dense uniform
# column as `dangling_mass` (the executor owns the shared scalar).
DrainFn = Callable[[int, int, int, float, np.ndarray], Tuple[int, float]]


class PairMailbox:
    """Lock-protected boundary-residual accumulator for one (src, dst)
    pair.  Deposits add the sender's outbox block; the owner folds the
    buffer into its own rows.  `l1()` is a lock-free read of the last
    computed mass (stale reads only ever *over*-count mass that was just
    drained, never under-count mass that was deposited before the last
    `deposit` returned — deposits publish the new l1 under the lock)."""

    __slots__ = ("lock", "buf", "_l1")

    def __init__(self, block_size: int):
        self.lock = threading.Lock()
        self.buf = np.zeros(block_size)
        self._l1 = 0.0

    def deposit(self, block: np.ndarray) -> None:
        with self.lock:
            self.buf += block
            self._l1 = float(np.abs(self.buf).sum())

    def drain_into(self, r: np.ndarray, s: int, e: int) -> float:
        """Fold the buffer into r[s:e] (the owner's rows); returns the L1
        mass moved (0.0 on the lock-free empty fast path)."""
        if self._l1 == 0.0:
            return 0.0
        with self.lock:
            moved = self._l1
            if moved != 0.0:
                r[s:e] += self.buf
                self.buf[:] = 0.0
                self._l1 = 0.0
        return moved

    def l1(self) -> float:
        return self._l1


class UniformAccumulator:
    """The shared uniform-column scalar (dangling pushes smear column e/n).

    Senders `add` mass as they drain; each shard `take`s the delta since it
    last looked and applies it densely to its own rows only — the dense
    fold is sharded too, so no thread ever touches foreign rows.  Pending
    (added but not yet taken) mass is part of the sender-side residual
    accounting: `pending(i) * block_size` joins shard i's reported value.
    """

    def __init__(self, p: int):
        self._lock = threading.Lock()
        self._total = 0.0
        self._seen = np.zeros(p)

    def add(self, v: float) -> None:
        if v != 0.0:
            with self._lock:
                self._total += v

    def take(self, i: int) -> float:
        with self._lock:
            d = self._total - float(self._seen[i])
            self._seen[i] = self._total
        return d

    def pending(self, i: int) -> float:
        return self._total - float(self._seen[i])


@dataclasses.dataclass
class AsyncRunResult:
    """Transcript of one `AsyncShardExecutor.run` (telemetry only — the
    residual itself is folded back into `r` before run() returns)."""

    stopped: bool                   # the monitor issued STOP
    capped: bool                    # a round/push cap fired first
    rounds_per_shard: np.ndarray    # local updates each worker executed
    pushes_per_shard: np.ndarray
    exchanges: int                  # mailbox deposits that actually shipped
    bytes_moved: int                # modeled payload bytes ((idx, value))
    stop_round: int                 # issuing shard's round at STOP (-1)
    idle_s_per_shard: np.ndarray    # time spent parked waiting for mail
    wall_s: float


class AsyncShardExecutor:
    """Run p shard drains concurrently, one worker thread per shard, with
    mailbox exchange and message-rendered Fig. 1 termination (see module
    docstring for the protocol and its soundness argument).

    The executor owns the concurrency plumbing — mailboxes, the uniform
    scalar, the driver lock, stop propagation, telemetry — and is handed
    the actual local update as a `DrainFn`, so it stays independent of the
    problem being iterated (the streaming updater passes its
    Gauss-Southwell sweep; tests pass synthetic kernels).

    One *round* = one intake + (gated) local update + one Fig. 1
    checkConvergence().  The ExchangePlan runs on its own clock of *local
    updates*: drain rounds tick it, idle-converged spin rounds do not (a
    spin-round clock would force-ship every withheld sub-threshold
    payload within `refresh_every * idle_sleep`, defeating the §6 gate),
    and a round parked *above* the convergence target with the plan
    withholding still ticks — that keeps the forced-refresh bound live,
    so significant parked mass always ships within `refresh_every` local
    updates.  Converged shards may withhold sub-threshold mass
    indefinitely: it is counted in their reported value, so the
    certificate stays sound.
    """

    def __init__(self, part: Partition, plan: ExchangePlan,
                 driver: TerminationDriver, *, l1_target: float,
                 bytes_per_entry: int = 8, max_rounds: int = 1_000_000,
                 max_total_pushes: Optional[int] = None,
                 idle_sleep: float = 2e-4, drain_frac: float = 0.05,
                 hysteresis: float = 2.0):
        if driver.p != part.p or plan.p != part.p:
            raise ValueError(f"partition ({part.p}), plan ({plan.p}) and "
                             f"driver ({driver.p}) disagree on p")
        self.part = part
        self.p = part.p
        self.plan = plan
        self.driver = driver
        self.l1_target = float(l1_target)
        self.bytes_per_entry = int(bytes_per_entry)
        self.max_rounds = int(max_rounds)
        self.max_total_pushes = max_total_pushes
        self.idle_sleep = float(idle_sleep)
        self.drain_frac = float(drain_frac)
        self.hysteresis = float(hysteresis)

    # ------------------------------------------------------------------
    def run(self, drain_fn: DrainFn, r: np.ndarray) -> AsyncRunResult:
        """Drive the drains until STOP or a cap; on return every mailbox,
        outbox and pending uniform delta has been folded back into `r`, so
        `r` is again the one exactly-maintained residual."""
        p, part = self.p, self.part
        n = part.n
        t0 = time.perf_counter()

        mail = [[PairMailbox(part.block(d)[1] - part.block(d)[0])
                 if d != i else None for d in range(p)] for i in range(p)]
        outboxes = [np.zeros(n) for _ in range(p)]
        uniform = UniformAccumulator(p)
        driver_lock = threading.Lock()
        stat_lock = threading.Lock()
        stop_evt = threading.Event()

        rounds = np.zeros(p, dtype=np.int64)
        pushes = np.zeros(p, dtype=np.int64)
        idle_s = np.zeros(p)
        # stale-readable last reported values: the sliding drain target is
        # a fraction of their sum (no point draining own rows orders of
        # magnitude below the mass peers still hold)
        last_values = np.array([float(np.abs(r[s:e]).sum())
                                for s, e in (part.block(i)
                                             for i in range(p))])
        shared = dict(exchanges=0, bytes_moved=0, stop_round=-1,
                      capped=False)
        errors: List[Optional[BaseException]] = [None] * p

        def worker(i: int) -> None:
            s, e = part.block(i)
            bs = e - s
            conv_target = self.l1_target * (bs / n) if n else self.l1_target
            drain_floor = 0.5 * conv_target
            outbox = outboxes[i]
            peers = [d for d in range(p) if d != i]
            inboxes = [mail[j][i] for j in range(p) if j != i]
            # cached L1s of the two O(n) structures this worker owns —
            # only intake/drain/exchange can change them, so idle rounds
            # cost O(p) instead of O(n)
            own_l1 = float(np.abs(r[s:e]).sum())
            outbox_l1 = 0.0
            own_dirty = outbox_dirty = False
            it = 0            # raw rounds (spin included): caps, telemetry
            updates = 0       # *local updates*: the ExchangePlan's clock
            tick_pending = False
            try:
                while not stop_evt.is_set():
                    if it >= self.max_rounds:
                        shared["capped"] = True
                        stop_evt.set()
                        break
                    it += 1
                    progressed = False

                    # -- receive: fold incoming mail + my uniform share.
                    #    A nonzero intake RETRACTS convergence before the
                    #    mass leaves the sender's books: once drained, the
                    #    sender's next value read no longer sees it, and
                    #    this shard's own report only happens at round end
                    #    — without the retraction, STOP could ride this
                    #    shard's stale CONVERGE flag while a whole exchange
                    #    generation sits uncounted in its rows. ------------
                    if (uniform.pending(i) != 0.0
                            or any(mb.l1() != 0.0 for mb in inboxes)):
                        with driver_lock:
                            if not self.driver.stopped:
                                msg = self.driver.ue_step(i, False)
                                if msg is not None:
                                    self.driver.monitor_recv(i, msg)
                        for mb in inboxes:
                            if mb.drain_into(r, s, e) != 0.0:
                                progressed = True
                                own_dirty = True
                        dc = uniform.take(i)
                        if dc != 0.0:
                            r[s:e] += dc
                            progressed = True
                            own_dirty = True

                    # -- local update: drain own rows to a sliding target.
                    #    The drain is gated by a hysteresis band: entering
                    #    the coarse-to-fine ladder for every trickling
                    #    arrival pushes near-floor rows over and over (the
                    #    superstep loop batches a whole exchange generation
                    #    per ladder), so arrivals accumulate until own mass
                    #    meaningfully exceeds the sliding target.  At the
                    #    floor the band collapses — parked mass stays at
                    #    <= drain_floor = conv_target/2, which keeps the
                    #    convergence check reachable (no livelock). --------
                    approx_total = float(last_values.sum())
                    step_target = max(drain_floor,
                                      self.drain_frac * approx_total / p)
                    if own_dirty:
                        own_l1 = float(np.abs(r[s:e]).sum())
                        own_dirty = False
                    did_drain = False
                    if own_l1 > (self.hysteresis * step_target
                                 if step_target > drain_floor
                                 else drain_floor):
                        got, c_add = drain_fn(i, s, e, step_target, outbox)
                        uniform.add(c_add)
                        own_dirty = outbox_dirty = True
                        did_drain = True
                        if got:
                            pushes[i] += got
                            progressed = True
                    if (self.max_total_pushes is not None
                            and int(pushes.sum()) > self.max_total_pushes):
                        shared["capped"] = True
                        stop_evt.set()
                        break

                    # -- exchange: plan consulted per *local update*, not
                    #    per spin round — idle-converged rounds must not
                    #    tick the §6 refresh clock (they would force-ship
                    #    every withheld sub-threshold payload within
                    #    refresh_every * idle_sleep).  A blocked-but-
                    #    unconverged round (tick_pending, set below) still
                    #    ticks: mass parked above the convergence target
                    #    keeps the bounded-delay escape hatch live. --------
                    if did_drain or tick_pending:
                        updates += 1
                        tick_pending = False
                        if outbox_dirty:
                            outbox_l1 = float(np.abs(outbox).sum())
                            outbox_dirty = False
                        for d in peers:
                            if not self.plan.wants(i, d, updates):
                                continue
                            if outbox_l1 == 0.0:
                                # nothing pending anywhere: the receiver's
                                # copy already reflects everything this
                                # shard produced, so the epoch counts as a
                                # (zero-byte) refresh — quiet pairs must
                                # not bank forced-refresh debt
                                self.plan.note_sent(i, d, updates)
                                continue
                            sd, ed = part.block(d)
                            box = outbox[sd:ed]
                            mass = float(np.abs(box).sum())
                            if mass == 0.0:
                                self.plan.note_sent(i, d, updates)
                                continue
                            if not self.plan.gate_mass(i, d, updates, mass):
                                continue
                            nz = int(np.count_nonzero(box))
                            mail[i][d].deposit(box)
                            box[:] = 0.0
                            outbox_dirty = True
                            self.plan.note_sent(i, d, updates)
                            self.plan.on_result(i, d, True)
                            with stat_lock:
                                shared["exchanges"] += 1
                                shared["bytes_moved"] += \
                                    nz * (4 + self.bytes_per_entry)
                            progressed = True

                    # -- my residual value: everything I am accountable
                    #    for right now (the conservation invariant): own
                    #    rows, undelivered outbox, mailbox mass *I* put in
                    #    flight, and my rows' share of the pending uniform.
                    #    In-flight mass is counted by the SENDER — it only
                    #    leaves my books when the receiver has folded it
                    #    into rows the receiver itself counts, so a deposit
                    #    can never go unreported at the instant the monitor
                    #    evaluates STOP (the transient double-count while
                    #    the receiver drains is sound: it can only delay
                    #    convergence, never fake it) -----------------------
                    if own_dirty:
                        own_l1 = float(np.abs(r[s:e]).sum())
                        own_dirty = False
                    if outbox_dirty:
                        outbox_l1 = float(np.abs(outbox).sum())
                        outbox_dirty = False
                    value = own_l1 + outbox_l1 + abs(uniform.pending(i)) * bs
                    for d in peers:
                        value += mail[i][d].l1()
                    last_values[i] = value

                    # -- Fig. 1, message rendering ----------------------
                    verdict = value <= conv_target
                    with driver_lock:
                        if not self.driver.stopped:
                            msg = self.driver.ue_step(i, verdict)
                            if msg is not None and \
                                    self.driver.monitor_recv(i, msg):
                                shared["stop_round"] = it
                                stop_evt.set()
                                break
                    if not verdict and not progressed:
                        # parked above target with the plan withholding:
                        # count the next round as a local update so the
                        # forced refresh can fire (no livelock)
                        tick_pending = True

                    # -- idle backoff: park until mail can have arrived --
                    if not progressed:
                        t_idle = time.perf_counter()
                        stop_evt.wait(self.idle_sleep)
                        idle_s[i] += time.perf_counter() - t_idle
            except BaseException as exc:        # pragma: no cover - reraised
                errors[i] = exc
                stop_evt.set()
            finally:
                rounds[i] = it

        threads = [threading.Thread(target=worker, args=(i,),
                                    name=f"shard-drain-{i}", daemon=True)
                   for i in range(p)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

        # fold every in-flight structure back into r: the caller's r is
        # again the exactly-maintained residual (mass conservation)
        for i in range(p):
            for d in range(p):
                if d != i:
                    sd, ed = part.block(d)
                    mail[i][d].drain_into(r, sd, ed)
            box = outboxes[i]
            nzr = np.flatnonzero(box)
            if nzr.size:
                r[nzr] += box[nzr]
            s, e = part.block(i)
            dc = uniform.take(i)
            if dc != 0.0:
                r[s:e] += dc

        for exc in errors:
            if exc is not None:
                raise exc

        return AsyncRunResult(
            stopped=self.driver.stopped and not shared["capped"],
            capped=shared["capped"], rounds_per_shard=rounds,
            pushes_per_shard=pushes, exchanges=shared["exchanges"],
            bytes_moved=shared["bytes_moved"],
            stop_round=shared["stop_round"], idle_s_per_shard=idle_s,
            wall_s=time.perf_counter() - t0)
