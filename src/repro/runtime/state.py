"""ShardState — one shard's owned fragment plus versioned stale views.

This is the per-UE state of eq. (5): shard i owns fragment x_i and holds a
full-length *stale* copy of every other fragment, tagged with the version it
last imported (the tau_j^i(t) table of the paper).  The DES engine keeps one
ShardState per simulated UE; the sharded streaming updater keeps one per
worker; the SPMD loop carries the same fields inside its jax carry (view /
frag / step) — the correspondence is documented in docs/runtime.md.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Tuple

import numpy as np

if TYPE_CHECKING:                    # annotation-only: a module-level
    from ..core.partition import Partition   # import would recreate the
    # state -> core -> des -> state cycle that used to make
    # `import repro.runtime` fail unless repro.core was imported first


@dataclasses.dataclass
class ShardState:
    """Owned fragment + versioned stale views for shard `i` of `part`."""

    i: int
    part: Partition
    view: np.ndarray               # (n,) full-length stale view
    frag_version: np.ndarray       # (p,) version of each fragment held
    produced: int = 0              # own fragment version counter
    iters: int = 0                 # local updates executed
    stopped: bool = False

    @staticmethod
    def create(i: int, part: Partition, x0: np.ndarray) -> "ShardState":
        return ShardState(i=i, part=part, view=np.asarray(x0).copy(),
                          frag_version=np.zeros(part.p, dtype=np.int64))

    @property
    def rows(self) -> Tuple[int, int]:
        return self.part.block(self.i)

    def fragment(self) -> np.ndarray:
        s, e = self.rows
        return self.view[s:e]

    def publish(self, new_frag: np.ndarray) -> int:
        """Install this shard's freshly computed fragment into its own view
        and bump the produced-version counter."""
        s, e = self.rows
        self.view[s:e] = new_frag
        self.iters += 1
        self.produced += 1
        self.frag_version[self.i] = self.produced
        return self.produced

    def import_fragment(self, owner: int, frag: np.ndarray, version: int,
                        s: int, e: int) -> bool:
        """Accept a (possibly relayed) fragment owned by `owner` iff it is
        fresher than the copy currently held.  Returns True on accept."""
        if version <= self.frag_version[owner]:
            return False
        self.view[s:e] = frag
        self.frag_version[owner] = version
        return True

    def import_rows(self, owner: int, rows: np.ndarray, vals: np.ndarray,
                    version: int) -> bool:
        """Sparsified payload: refresh only `rows` (global ids) of `owner`'s
        fragment.  The version table still advances — a row subset is a
        legitimate (partial) refresh under bounded-delay semantics; the
        plan's forced full refresh bounds how long the untouched rows can
        stay stale."""
        if version <= self.frag_version[owner]:
            return False
        self.view[rows] = vals
        self.frag_version[owner] = version
        return True

    def staleness_of(self, owner: int, produced_by_owner: int) -> int:
        return int(produced_by_owner - self.frag_version[owner])
