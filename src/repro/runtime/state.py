"""ShardState — one shard's owned fragment plus versioned stale views —
and ShardArena — the shared-memory allocator those fragments live in when
shard workers are separate processes.

ShardState is the per-UE state of eq. (5): shard i owns fragment x_i and
holds a full-length *stale* copy of every other fragment, tagged with the
version it last imported (the tau_j^i(t) table of the paper).  The DES
engine keeps one ShardState per simulated UE; the sharded streaming updater
keeps one per worker; the SPMD loop carries the same fields inside its jax
carry (view / frag / step) — the correspondence is documented in
docs/runtime.md.

ShardArena packs a set of named numpy arrays into ONE
`multiprocessing.shared_memory` segment so the procpool transport
(runtime/transport.py) can hand every worker process zero-copy views of the
residual, the iterate, the packed CSR and the transport control block.  One
segment = one create/attach/unlink lifecycle, so a crashed run can never
strand a partial set of segments.
"""
from __future__ import annotations

import dataclasses
import os
import secrets
from typing import TYPE_CHECKING, Dict, Tuple

import numpy as np

if TYPE_CHECKING:                    # annotation-only: a module-level
    from ..core.partition import Partition   # import would recreate the
    # state -> core -> des -> state cycle that used to make
    # `import repro.runtime` fail unless repro.core was imported first


@dataclasses.dataclass
class ShardState:
    """Owned fragment + versioned stale views for shard `i` of `part`."""

    i: int
    part: Partition
    view: np.ndarray               # (n,) full-length stale view
    frag_version: np.ndarray       # (p,) version of each fragment held
    produced: int = 0              # own fragment version counter
    iters: int = 0                 # local updates executed
    stopped: bool = False

    @staticmethod
    def create(i: int, part: Partition, x0: np.ndarray) -> "ShardState":
        return ShardState(i=i, part=part, view=np.asarray(x0).copy(),
                          frag_version=np.zeros(part.p, dtype=np.int64))

    @property
    def rows(self) -> Tuple[int, int]:
        return self.part.block(self.i)

    def fragment(self) -> np.ndarray:
        s, e = self.rows
        return self.view[s:e]

    def publish(self, new_frag: np.ndarray) -> int:
        """Install this shard's freshly computed fragment into its own view
        and bump the produced-version counter."""
        s, e = self.rows
        self.view[s:e] = new_frag
        self.iters += 1
        self.produced += 1
        self.frag_version[self.i] = self.produced
        return self.produced

    def import_fragment(self, owner: int, frag: np.ndarray, version: int,
                        s: int, e: int) -> bool:
        """Accept a (possibly relayed) fragment owned by `owner` iff it is
        fresher than the copy currently held.  Returns True on accept."""
        if version <= self.frag_version[owner]:
            return False
        self.view[s:e] = frag
        self.frag_version[owner] = version
        return True

    def import_rows(self, owner: int, rows: np.ndarray, vals: np.ndarray,
                    version: int) -> bool:
        """Sparsified payload: refresh only `rows` (global ids) of `owner`'s
        fragment.  The version table still advances — a row subset is a
        legitimate (partial) refresh under bounded-delay semantics; the
        plan's forced full refresh bounds how long the untouched rows can
        stay stale."""
        if version <= self.frag_version[owner]:
            return False
        self.view[rows] = vals
        self.frag_version[owner] = version
        return True

    def staleness_of(self, owner: int, produced_by_owner: int) -> int:
        return int(produced_by_owner - self.frag_version[owner])


# ---------------------------------------------------------------------------
# ShardArena — one shared-memory segment holding named arrays
# ---------------------------------------------------------------------------
_ALIGN = 64          # cache-line align every array inside the segment
_SHM_DIR = "/dev/shm"


def sweep_stale_segments(prefix: str = "repro_arena") -> int:
    """Unlink orphaned `/dev/shm/<prefix>_<pid>_<hex>` segments whose
    creating process is gone; returns how many were reclaimed.

    Segment names are pid-stamped at create time precisely so this sweep
    can tell "crashed parent's leftover" from "concurrent run's live
    arena": `os.kill(pid, 0)` distinguishes a dead pid
    (ProcessLookupError -> reclaim) from one we merely can't signal
    (PermissionError -> alive, leave it).  Our own segments are skipped —
    they are live by definition.  Called from `ShardArena.create`, so a
    box that accumulates kill-9'd runs can't exhaust /dev/shm; best-
    effort on every syscall because another sweep (or the owner's exit
    handler) may race us to the unlink."""
    try:
        names = os.listdir(_SHM_DIR)
    except OSError:                  # non-Linux / no tmpfs: nothing to do
        return 0
    own = os.getpid()
    reclaimed = 0
    for nm in names:
        if not nm.startswith(prefix + "_"):
            continue
        tokens = nm.split("_")
        if len(tokens) < 3:
            continue
        try:
            pid = int(tokens[-2])    # "<prefix>_<pid>_<hex>" — prefix may
        except ValueError:           # itself contain underscores
            continue
        if pid == own:
            continue
        try:
            os.kill(pid, 0)
            continue                 # delivered: creator is alive
        except ProcessLookupError:
            pass                     # creator is gone: stale segment
        except OSError:
            continue                 # EPERM etc.: alive under another uid
        try:
            os.unlink(os.path.join(_SHM_DIR, nm))
            reclaimed += 1
        except OSError:
            pass
    return reclaimed


def _attach_untracked(name: str):
    """`SharedMemory(name=...)` without resource-tracker registration.

    The arena owner is the single point of unlink.  A worker that merely
    *attaches* must not register the segment with a resource tracker: a
    spawn-started worker's own tracker would unlink it at worker exit,
    and a fork-started worker shares the parent's tracker, so an
    unregister-after-the-fact would erase the parent's registration
    (KeyError noise at the real unlink).  Python < 3.13 has no
    `track=False`, so suppress the register call for the duration of the
    attach (worker startup is single-threaded)."""
    from multiprocessing import resource_tracker, shared_memory
    orig = resource_tracker.register
    resource_tracker.register = lambda *a, **k: None
    try:
        return shared_memory.SharedMemory(name=name)
    finally:
        resource_tracker.register = orig


@dataclasses.dataclass(frozen=True)
class ArenaHandle:
    """Picklable description of an arena: segment name + array layout.
    `ShardArena.attach(handle)` maps the same arrays in another process."""

    name: str
    layout: Tuple[Tuple[str, Tuple[int, ...], str, int], ...]
    # (key, shape, dtype-str, byte offset) per array
    size: int


class ShardArena:
    """Named numpy arrays packed into one shared-memory segment.

    Lifecycle contract (docs/runtime.md):

      * the creator (`ShardArena.create`) OWNS the segment: it must call
        `close(unlink=True)` (or use the arena as a context manager) —
        everything else, including worker crashes, leaks nothing because
        there is nothing else to leak;
      * workers `attach(handle)` and `close()` (no unlink); attaching
        unregisters the segment from the worker's resource tracker so a
        worker exit neither unlinks nor warns;
      * views returned by `arena[key]` alias the segment directly — any
        process's write is every process's read.
    """

    def __init__(self, shm, layout, *, owner: bool):
        self._shm = shm
        self._layout = layout
        self._owner = owner
        self._views: Dict[str, np.ndarray] = {}
        for key, shape, dt, off in layout:
            arr = np.ndarray(shape, dtype=np.dtype(dt),
                             buffer=shm.buf, offset=off)
            self._views[key] = arr

    # -- construction ----------------------------------------------------
    @classmethod
    def create(cls, spec: Dict[str, Tuple[Tuple[int, ...], np.dtype]],
               prefix: str = "repro_arena") -> "ShardArena":
        """Allocate one segment holding an array per `spec` entry
        (key -> (shape, dtype)), zero-initialized.  Creating an arena
        also sweeps orphaned segments left by crashed/killed parents
        (`sweep_stale_segments`) so repeated kill-9'd runs on one box
        can't exhaust /dev/shm."""
        from multiprocessing import shared_memory
        sweep_stale_segments("repro_arena")
        layout = []
        off = 0
        for key, (shape, dtype) in spec.items():
            dt = np.dtype(dtype)
            nbytes = int(np.prod(shape, dtype=np.int64)) * dt.itemsize
            layout.append((key, tuple(int(s) for s in shape), dt.str, off))
            off += -(-max(nbytes, 1) // _ALIGN) * _ALIGN
        name = f"{prefix}_{os.getpid()}_{secrets.token_hex(4)}"
        # POSIX shm_open + ftruncate pages are zero-filled by the kernel;
        # an explicit memset would double transient memory and fault
        # every page eagerly
        shm = shared_memory.SharedMemory(name=name, create=True,
                                         size=max(off, _ALIGN))
        return cls(shm, tuple(layout), owner=True)

    @classmethod
    def from_arrays(cls, arrays: Dict[str, np.ndarray],
                    prefix: str = "repro_arena") -> "ShardArena":
        """Create an arena sized to `arrays` and copy each one in."""
        spec = {k: (a.shape, a.dtype) for k, a in arrays.items()}
        arena = cls.create(spec, prefix=prefix)
        for k, a in arrays.items():
            arena[k][...] = a
        return arena

    @classmethod
    def attach(cls, handle: ArenaHandle) -> "ShardArena":
        shm = _attach_untracked(handle.name)
        return cls(shm, handle.layout, owner=False)

    # -- access ----------------------------------------------------------
    def __getitem__(self, key: str) -> np.ndarray:
        return self._views[key]

    def keys(self):
        return self._views.keys()

    def handle(self) -> ArenaHandle:
        return ArenaHandle(name=self._shm.name, layout=self._layout,
                           size=self._shm.size)

    @property
    def name(self) -> str:
        return self._shm.name

    # -- lifecycle -------------------------------------------------------
    def close(self, unlink: bool = None) -> None:
        """Release this process's mapping; the owner also unlinks the
        segment (idempotent)."""
        if self._shm is None:
            return
        self._views = {}
        unlink = self._owner if unlink is None else unlink
        try:
            self._shm.close()
        except BufferError:
            # a caller still holds a view; the mapping lives until that
            # view is collected, but the segment must not outlive us —
            # fall through to unlink so /dev/shm stays clean
            pass
        finally:
            if unlink:
                try:
                    self._shm.unlink()
                except FileNotFoundError:
                    pass
            self._shm = None

    def __enter__(self) -> "ShardArena":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):            # last-resort leak guard (owner only)
        try:
            self.close()
        except Exception:         # pragma: no cover - interpreter teardown
            pass
