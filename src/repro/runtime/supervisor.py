"""ShardSupervisor — self-healing for the procpool shard runtime.

PR 5's procpool *detected* failure: a dead or crashing worker stamped
STOP and the whole solve raised.  This module turns detection into
recovery.  The parent already owns everything a restart needs — the data
arena (r, x, CSR fragments) and the control arena (outboxes, rings,
ledgers, telemetry) both outlive any worker, because workers only
*attach* — so a worker death costs one respawn, not a solve:

  * the parent pump (subsumed here) watches liveness while delivering
    Fig. 1 messages; an unexpected exit (SIGKILL, a crash that
    `os._exit`s after flagging `err`) triggers recovery instead of STOP;
  * for every shard the dead worker hosted:
      - stale Fig. 1 claims from the dead incarnation are discarded and
        `TerminationDriver.restart_shard` re-enters the protocol
        conservatively (fresh computing machine + a DIVERGE to the
        monitor): the restarted shard reports DIVERGE until its value
        recomputes, so a stale CONVERGE flag can never ride into STOP;
      - if the worker died *mid-sweep* (`busy` flag set), the shard's
        (r, x) rows are re-materialized from the last seqlock'd per-shard
        checkpoint (workers refresh it at report time every
        `checkpoint_every` rounds; the parent writes checkpoint zero
        before spawning); otherwise the live rows are consistent and are
        re-checkpointed as the new baseline;
      - the in-flight ledgers are reconciled on both sides: a
        `send_intent` cell written before the `sent_abs` bump is rolled
        back if the worker died inside the bump-push window, and
        `recv_abs` is re-derived from the rings' actual pending mass
        (a kill can land between a fold and its `recv_abs` bump on any
        co-hosted shard), so a phantom in-flight payload can never hold
        `inflight_l1` above zero forever (the livelock that would
        otherwise block termination);
  * restarts take capped exponential backoff (per worker) and draw from
    a global restart budget; an exhausted budget stamps STOP and the
    executor raises exactly as PR 5 did.

What recovery *cannot* restore exactly — mail folded between the
checkpoint and the kill, outbox rows scattered mid-sweep, held duplicate
payloads — leaves the maintained residual approximate in a bounded way.
That is why the streaming caller re-derives the residual with an exact
O(nnz) recompute whenever `AsyncRunResult.recoveries > 0` and re-enters
the drain: certificates stay sound across any number of restarts (the
argument is spelled out in docs/runtime.md, "Fault model").
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Tuple

import numpy as np

from ..core.termination import Msg
from .observe import C_RECOVERIES, EV_RECOVERY, ShardObserver


@dataclasses.dataclass(frozen=True)
class BackoffPolicy:
    """Capped exponential restart backoff: delay(k) for a worker's k-th
    restart."""

    base_s: float = 0.02
    factor: float = 2.0
    cap_s: float = 0.5

    def delay(self, k: int) -> float:
        return float(min(self.base_s * (self.factor ** k), self.cap_s))


@dataclasses.dataclass(frozen=True)
class RestartEvent:
    """One recovery, for telemetry/benchmarks."""

    worker: int                 # pool slot that died
    shards: Tuple[int, ...]     # shards it hosted
    exitcode: Optional[int]     # SIGKILL => -9, flagged crash => 70
    restart_index: int          # global restart counter value
    mid_sweep: Tuple[int, ...]  # shards restored from checkpoint
    recovery_s: float           # detection -> respawned


class ShardSupervisor:
    """Parent-side monitor pump + worker liveness + restart policy for
    `ProcPoolShardExecutor` (see module docstring).

    `spawn(w)` must return a *started* replacement Process for pool slot
    `w`; `assign[w]` lists the shards that slot hosts.  `r`/`x` are the
    parent's views of the data arena (x may be None for synthetic
    drains without an iterate)."""

    def __init__(self, part, driver, ctl, r: np.ndarray,
                 x: Optional[np.ndarray], assign: List[List[int]],
                 spawn: Callable, *, max_restarts: int,
                 backoff: BackoffPolicy = BackoffPolicy(),
                 obs: Optional[ShardObserver] = None):
        self.part = part
        self.driver = driver
        self.ctl = ctl
        self.r = r
        self.x = x
        self.assign = assign
        self.spawn = spawn
        self.max_restarts = int(max_restarts)
        self.backoff = backoff
        self.obs = obs          # RECOVERY events + counters when tracing
        self.recoveries = 0
        self.recovery_s = 0.0
        self.events: List[RestartEvent] = []
        self.all_procs: List = []       # every incarnation, for cleanup
        self._per_worker_restarts = np.zeros(len(assign), dtype=np.int64)

    # ------------------------------------------------------------------
    def _drain_msgs(self) -> bool:
        """Deliver pending ringed Fig. 1 messages to the monitor machine
        (drained but not delivered once STOP is stamped); True when
        anything moved."""
        from .transport import _F_STOP, _F_STOP_ROUND, _MSG_RING_DEPTH
        ctl = self.ctl
        flags = ctl["flags"]
        head, tail, buf = ctl["msg_head"], ctl["msg_tail"], ctl["msg_buf"]
        moved = False
        for i in range(self.part.p):
            h, t = int(head[i]), int(tail[i])
            while h < t:
                code = int(buf[i, h % _MSG_RING_DEPTH])
                h += 1
                head[i] = h
                moved = True
                if flags[_F_STOP]:
                    continue
                if self.driver.monitor_recv(i, Msg(code)):
                    flags[_F_STOP_ROUND] = int(ctl["rounds"][i])
                    flags[_F_STOP] = 1
        return moved

    # ------------------------------------------------------------------
    def _recover_shard(self, i: int) -> bool:
        """Re-enter shard i after its worker died; True when its rows
        were restored from the mid-sweep checkpoint."""
        from .transport import _MSG_RING_DEPTH  # noqa: F401  (layout dep)
        ctl = self.ctl
        part = self.part
        s, e = part.block(i)

        # 1. discard the dead incarnation's undelivered Fig. 1 claims and
        #    re-enter the protocol conservatively (DIVERGE until the
        #    restarted shard republishes a value)
        ctl["msg_head"][i] = ctl["msg_tail"][i]
        if not self.driver.stopped:
            self.driver.restart_shard(i)

        # 2. sender-side ledger reconciliation: an intent written but not
        #    cleared means the worker died inside the sent_abs-bump /
        #    ring-push window — roll the bump back.  If the push did land,
        #    the receiver's fold makes recv_abs overtake sent_abs and the
        #    clamped inflight reads zero: a bounded under-count the
        #    caller's exact recompute covers, instead of a phantom
        #    in-flight payload blocking termination forever.
        for d in range(part.p):
            if d != i and ctl["send_intent"][i, d] != 0.0:
                ctl["sent_abs"][i, d] -= ctl["send_intent"][i, d]
                ctl["send_intent"][i, d] = 0.0

        # 2b. receiver-side ledger reconciliation: the worker may have
        #     been killed between a ring fold and its recv_abs bump (on
        #     a shared-core pool the SIGKILL lands at arbitrary points
        #     in the *co-hosted* shards, not just at the killed shard's
        #     report), leaving recv_abs permanently behind what actually
        #     left the wire — a phantom in-flight mass that would hold
        #     this pair's inflight_l1 above zero forever and block
        #     termination.  Re-derive from ground truth: whatever the
        #     sender shipped that is not still pending in the ring has
        #     left the channel (folded into r, or lost with the
        #     incarnation — either way the caller's exact recompute
        #     covers the rows; the *books* must not block STOP).  The
        #     ring is scanned BEFORE sent_abs is read so a concurrent
        #     push by a live sender biases recv_abs high — a clamped
        #     under-count (sound), never a phantom.
        from .transport import _ctl_ring
        for j in range(part.p):
            if j == i:
                continue
            pending = _ctl_ring(ctl, j, i).pending_l1()
            ctl["recv_abs"][j, i] = ctl["sent_abs"][j, i] - pending

        # 3. rows: mid-sweep death restores the checkpoint; a clean-point
        #    death keeps the live rows and re-baselines the checkpoint.
        #    `busy` implies the checkpoint is committed (workers only
        #    checkpoint at report time, outside the drain), so a torn
        #    (odd-seq) checkpoint can only belong to a non-busy shard —
        #    normalize it from the live rows.
        mid_sweep = bool(ctl["busy"][i])
        if mid_sweep:
            self.r[s:e] = ctl["ckpt_r"][s:e]
            if self.x is not None:
                self.x[s:e] = ctl["ckpt_x"][s:e]
        else:
            ctl["ckpt_r"][s:e] = self.r[s:e]
            if self.x is not None:
                ctl["ckpt_x"][s:e] = self.x[s:e]
        if ctl["ckpt_seq"][i] % 2:
            ctl["ckpt_seq"][i] += 1
        ctl["busy"][i] = 0
        ctl["restarts"][i] += 1

        # 4. republish a fresh (stale-high is fine) value so peers' sliding
        #    drain targets don't ride a dead shard's last word
        ctl["values"][i] = (float(np.abs(self.r[s:e]).sum())
                            + float(np.abs(ctl["outbox"][i]).sum()))
        return mid_sweep

    # ------------------------------------------------------------------
    def supervise(self, procs: List) -> bool:
        """Pump messages and supervise liveness until every pool slot has
        exited; returns True when a worker stayed dead (restart budget
        exhausted) — the executor then raises after the fold-back, as
        PR 5 did."""
        from .transport import _F_STOP, _F_STOP_ROUND
        flags = self.ctl["flags"]
        flags[_F_STOP_ROUND] = -1
        self.all_procs = list(procs)
        slots: List = list(procs)       # None = slot finished for good
        died = False
        while True:
            moved = self._drain_msgs()
            for w, pr in enumerate(slots):
                if pr is None or pr.is_alive():
                    continue
                ec = pr.exitcode
                if ec == 0 or flags[_F_STOP]:
                    # clean exit, or any exit during normal teardown
                    slots[w] = None
                    continue
                # unexpected death while the run is live
                if self.recoveries >= self.max_restarts:
                    died = True
                    flags[_F_STOP] = 1
                    slots[w] = None
                    continue
                t0 = time.perf_counter()
                self.recoveries += 1
                k = int(self._per_worker_restarts[w])
                self._per_worker_restarts[w] += 1
                restored = tuple(i for i in self.assign[w]
                                 if self._recover_shard(i))
                if self.obs is not None:
                    # written between death detection and respawn: no
                    # worker incarnation is alive, so the parent is the
                    # shard ring's only writer right now
                    for i in self.assign[w]:
                        self.obs.ctr[i, C_RECOVERIES] += 1
                        self.obs.emit(
                            EV_RECOVERY, i, t0,
                            dur=time.perf_counter() - t0, a=float(w),
                            b=float(ec if ec is not None else 0),
                            c=float(i in restored))
                time.sleep(self.backoff.delay(k))
                repl = self.spawn(w)
                self.all_procs.append(repl)
                slots[w] = repl
                dt = time.perf_counter() - t0
                self.recovery_s += dt
                self.events.append(RestartEvent(
                    worker=w, shards=tuple(self.assign[w]), exitcode=ec,
                    restart_index=self.recoveries, mid_sweep=restored,
                    recovery_s=dt))
            if all(pr is None for pr in slots):
                self._drain_msgs()      # late messages are not stranded
                return died
            if not moved:
                time.sleep(5e-4)
