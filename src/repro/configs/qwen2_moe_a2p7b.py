"""Qwen2-MoE-A2.7B — 60 routed experts top-4 + 4 shared
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]. Experts padded 60 -> 64 so EP divides the
16-way model axis (padding experts get zero routing mass — DESIGN.md §5)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=0, vocab_size=151_936, act="silu_glu",
    n_experts=64, top_k=4, n_shared_experts=4, expert_d_ff=1408,
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="qwen2-moe-a2.7b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=0, vocab_size=512, act="silu_glu",
    n_experts=8, top_k=2, n_shared_experts=1, expert_d_ff=32,
    moe_group_size=32, tie_embeddings=False, attn_chunk_q=16,
    param_dtype="float32", compute_dtype="float32",
)
