"""SmolLM-360M — llama-arch small [hf:HuggingFaceTB/SmolLM-135M; hf]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, head_dim=64,
    d_ff=2560, vocab_size=49_152, act="silu_glu", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="smollm-360m-smoke", family="dense",
    n_layers=2, d_model=60, n_heads=3, n_kv_heads=1, head_dim=20,
    d_ff=128, vocab_size=512, act="silu_glu", attn_chunk_q=16,
    param_dtype="float32", compute_dtype="float32",
)
