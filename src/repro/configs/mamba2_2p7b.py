"""Mamba2-2.7B — SSD (state-space duality), attention-free
[arXiv:2405.21060; unverified]. 64 SSD layers, no MLP (d_ff=0);
O(1)-state decode => long_500k runs."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0, head_dim=64,
    d_ff=0, vocab_size=50_280, act="silu_glu",
    block_pattern=("ssd",), ssm_state=128, ssm_headdim=64, ssm_expand=2,
    ssm_chunk=256, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-2.7b-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, head_dim=16,
    d_ff=0, vocab_size=512, act="silu_glu",
    block_pattern=("ssd",), ssm_state=16, ssm_headdim=16, ssm_expand=2,
    ssm_chunk=8, param_dtype="float32", compute_dtype="float32",
)
