"""PaliGemma-3B — SigLIP + Gemma VLM [arXiv:2407.07726; hf].

The transformer BACKBONE only (Gemma-2B-style decoder): the SigLIP vision
frontend is a STUB — input_specs() provides 256 precomputed patch embeddings
that enter as a bidirectional prefix (prefix-LM mask)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=257_216, act="gelu_glu",
    block_pattern=("attn",), prefix_len=256, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="paligemma-3b-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab_size=512, act="gelu_glu",
    block_pattern=("attn",), prefix_len=8, attn_chunk_q=16,
    param_dtype="float32", compute_dtype="float32",
)
