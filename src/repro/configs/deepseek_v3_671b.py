"""DeepSeek-V3-671B — MLA + 1 shared + 256 routed top-8 MoE, first 3 layers
dense [arXiv:2412.19437; hf]. MTP head is optional and off in the dry-run
baseline. Router is softmax top-k (paper uses sigmoid+bias — DESIGN.md §5)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=18432, vocab_size=129_280, act="silu_glu",
    n_experts=256, top_k=8, n_shared_experts=1, expert_d_ff=2048,
    first_dense_layers=3,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    tie_embeddings=False, fsdp=True,
)

SMOKE = ModelConfig(
    name="deepseek-v3-671b-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512, act="silu_glu",
    n_experts=8, top_k=2, n_shared_experts=1, expert_d_ff=32,
    first_dense_layers=1, moe_group_size=32,
    use_mla=True, q_lora_rank=32, kv_lora_rank=16,
    qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
    tie_embeddings=False, attn_chunk_q=16,
    param_dtype="float32", compute_dtype="float32",
)
