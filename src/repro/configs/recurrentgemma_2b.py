"""RecurrentGemma-2B — Griffin: RG-LRU + local attention, 2:1 pattern
[arXiv:2402.19427; hf]. 26 layers cycle (rglru, rglru, local_attn);
sub-quadratic => long_500k runs."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256_000, act="gelu_glu",
    block_pattern=("rglru", "rglru", "local_attn"), local_window=2048,
    lru_width=2560, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="recurrentgemma-2b-smoke", family="hybrid",
    n_layers=3, d_model=64, n_heads=2, n_kv_heads=1, head_dim=32,
    d_ff=128, vocab_size=512, act="gelu_glu",
    block_pattern=("rglru", "rglru", "local_attn"), local_window=16,
    lru_width=64, attn_chunk_q=16,
    param_dtype="float32", compute_dtype="float32",
)
