"""Yi-6B — llama-arch GQA [arXiv:2403.04652; hf]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=11008, vocab_size=64_000, act="silu_glu", tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="yi-6b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, act="silu_glu", tie_embeddings=False,
    attn_chunk_q=16, param_dtype="float32", compute_dtype="float32",
)
