"""Minitron-4B — pruned Nemotron [arXiv:2407.14679; hf]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
    d_ff=9216, vocab_size=256_000, act="silu_glu", tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="minitron-4b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, act="silu_glu", tie_embeddings=False,
    attn_chunk_q=16, param_dtype="float32", compute_dtype="float32",
)
