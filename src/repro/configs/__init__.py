"""Architecture registry: one module per assigned architecture."""
from __future__ import annotations

from typing import Dict

from ..models.config import ModelConfig

from . import (paligemma_3b, recurrentgemma_2b, mamba2_2p7b, smollm_360m,
               qwen1p5_4b, minitron_4b, yi_6b, qwen2_moe_a2p7b,
               deepseek_v3_671b, whisper_base)

_MODULES = [paligemma_3b, recurrentgemma_2b, mamba2_2p7b, smollm_360m,
            qwen1p5_4b, minitron_4b, yi_6b, qwen2_moe_a2p7b,
            deepseek_v3_671b, whisper_base]

REGISTRY: Dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}
SMOKE_REGISTRY: Dict[str, ModelConfig] = {
    m.CONFIG.name: m.SMOKE for m in _MODULES}

ARCH_NAMES = list(REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {ARCH_NAMES}")
    return REGISTRY[name]


def get_smoke_config(name: str) -> ModelConfig:
    return SMOKE_REGISTRY[name]
