"""Qwen1.5-4B — dense MHA with QKV bias [hf:Qwen/Qwen1.5-0.5B; hf]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b", family="dense",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20, head_dim=128,
    d_ff=6912, vocab_size=151_936, act="silu_glu", qkv_bias=True,
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="qwen1.5-4b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512, act="silu_glu", qkv_bias=True,
    tie_embeddings=False, attn_chunk_q=16,
    param_dtype="float32", compute_dtype="float32",
)
