"""Whisper-base — encoder-decoder [arXiv:2212.04356; unverified].

Backbone only: the conv/mel frontend is a STUB — input_specs() provides
precomputed frame embeddings (B, S_enc, d_model). Vocab padded
51,865 -> 51,968; RoPE replaces sinusoidal positions (DESIGN.md §5/§7)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    n_layers=6, n_enc_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    head_dim=64, d_ff=2048, vocab_size=51_865, act="gelu",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="whisper-base-smoke", family="audio",
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=128, vocab_size=512, act="gelu",
    tie_embeddings=True, attn_chunk_q=16,
    param_dtype="float32", compute_dtype="float32",
)
