"""PageRank workload configs — the paper's own experiment presets."""
from __future__ import annotations

import dataclasses
from typing import Optional

from ..core.des import DESConfig


@dataclasses.dataclass(frozen=True)
class PageRankConfig:
    name: str
    n: int
    nnz: int
    n_dangling: int
    alpha: float = 0.85
    locality: float = 0.8
    site_size: int = 512
    seed: int = 0

    def build(self):
        from ..graph.generate import powerlaw_webgraph
        from ..graph.csr import TransitionT
        from ..graph.google import GoogleOperator
        g = powerlaw_webgraph(n=self.n, target_nnz=self.nnz,
                              n_dangling=self.n_dangling,
                              locality=self.locality,
                              site_size=self.site_size, seed=self.seed)
        return GoogleOperator(pt=TransitionT.from_graph(g),
                              alpha=self.alpha)


# the paper's experiment (§5.2): Stanford-Web, alpha = 0.85, local tol 1e-6
STANFORD = PageRankConfig(
    name="stanford-web", n=281_903, nnz=2_312_497, n_dangling=172,
    locality=0.93, site_size=256)

SMALL = PageRankConfig(name="small", n=20_000, nnz=160_000, n_dangling=50)


def paper_des_config(seed: int = 7) -> DESConfig:
    """Testbed calibrated to the paper's cluster (EXPERIMENTS §Paper-repro)."""
    return DESConfig(tol=1e-6, norm="l2", barrier_overhead=0.5, seed=seed)
