"""Roofline-term extraction from compiled dry-run artifacts.

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis(). Collective bytes
are NOT in cost_analysis: we parse the post-SPMD HLO text and sum the
*operand* sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (a symbol table of result shapes resolves
operand names). A refined per-chip model (ring-algorithm factors, group
sizes from replica_groups) is reported alongside.

Hardware constants: TPU v5e.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

# --- v5e hardware constants (per chip) ---
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_LINK_BW = 50e9              # B/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# `%name = bf16[1,2,3]{...} op-name(...)` | tuple results `(f32[..], ..)`
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[a-z0-9_]+\[[^=]*?)\s+"
    r"([\w\-]+)\((.*)$", re.M)
_SHAPE_RE = re.compile(r"([a-z0-9_]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")
_OPERAND_RE = re.compile(r"%?([\w\.\-]+)")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    operand_bytes: Dict[str, int]
    per_chip_bytes: Dict[str, int]   # refined ring-model estimate
    counts: Dict[str, int]

    @property
    def total_operand_bytes(self) -> int:
        return sum(self.operand_bytes.values())

    @property
    def total_per_chip_bytes(self) -> int:
        return sum(self.per_chip_bytes.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    sizes: Dict[str, int] = {}
    operand_bytes = {c: 0 for c in _COLLECTIVES}
    per_chip = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}

    for m in _DEF_RE.finditer(hlo_text):
        name, type_str, op, args = m.groups()
        nbytes = _shape_bytes(type_str)
        sizes[name] = nbytes
        base = op.split(".")[0]
        if base.endswith("-start"):
            base = base[:-6]
        if base.endswith("-done"):
            continue  # counted at -start
        if base not in _COLLECTIVES:
            continue
        counts[base] += 1

        # group size from replica_groups (first group)
        g = _GROUPS_RE.search(args)
        n = len(g.group(1).split(",")) if g else 1

        # operand sizes (resolve via symbol table; fall back to result size).
        # operands live before the closing paren of the op call; config
        # attributes (replica_groups=..., channel_id=...) come after.
        operand_str = args.split(")")[0]
        op_bytes = 0
        for om in _OPERAND_RE.finditer(operand_str):
            nm = om.group(1)
            if nm in sizes:
                op_bytes += sizes[nm]
        if op_bytes == 0:
            op_bytes = nbytes

        operand_bytes[base] += op_bytes
        if base == "all-reduce":
            per_chip[base] += int(2 * op_bytes * (n - 1) / max(n, 1))
        elif base == "all-gather":
            per_chip[base] += int(nbytes * (n - 1) / max(n, 1))
        elif base == "reduce-scatter":
            per_chip[base] += int(op_bytes * (n - 1) / max(n, 1))
        elif base == "all-to-all":
            per_chip[base] += int(op_bytes * (n - 1) / max(n, 1))
        else:  # collective-permute
            per_chip[base] += op_bytes

    return CollectiveStats(operand_bytes=operand_bytes,
                           per_chip_bytes=per_chip, counts=counts)


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    collective_bytes: float          # operand-sum (assignment definition)
    collective_per_chip: float       # refined estimate
    chips: int

    @property
    def compute_s(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS_BF16)

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / (self.chips * ICI_LINK_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict:
        return dict(
            flops=self.flops, hbm_bytes=self.hbm_bytes,
            collective_bytes=self.collective_bytes,
            collective_per_chip=self.collective_per_chip,
            chips=self.chips, compute_s=self.compute_s,
            memory_s=self.memory_s, collective_s=self.collective_s,
            dominant=self.dominant)


def model_flops(n_params_active: int, n_tokens: int,
                train: bool = True) -> float:
    """6*N*D (train fwd+bwd) or 2*N*D (inference forward)."""
    return (6.0 if train else 2.0) * n_params_active * n_tokens


def from_compiled(compiled, chips: int, hlo_text: Optional[str] = None
                  ) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    tx = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = parse_collectives(text)
    return Roofline(flops=flops, hbm_bytes=tx,
                    collective_bytes=float(coll.total_operand_bytes),
                    collective_per_chip=float(coll.total_per_chip_bytes),
                    chips=chips), coll
