"""Analytic parameter / FLOP accounting for the roofline's MODEL_FLOPS."""
from __future__ import annotations

import numpy as np
import jax

from ..models.config import ModelConfig
from ..models.transformer import model_defs
from ..models.param import tree_map_defs


def _leaf_counts(cfg: ModelConfig):
    defs = model_defs(cfg)
    flat = jax.tree_util.tree_flatten_with_path(
        tree_map_defs(lambda d: int(np.prod(d.shape)), defs))[0]
    out = []
    for path, n in flat:
        key = "/".join(str(getattr(p, "key", p)) for p in path)
        out.append((key, n))
    return out


def total_params(cfg: ModelConfig, include_embed: bool = True) -> int:
    return sum(n for k, n in _leaf_counts(cfg)
               if include_embed or not k.startswith("embed"))


def active_params(cfg: ModelConfig, include_embed: bool = False) -> int:
    """MoE: routed-expert weights count at top_k/n_experts utilization."""
    total = 0
    for k, n in _leaf_counts(cfg):
        if not include_embed and k.startswith("embed"):
            continue
        if "/moe/w_" in k or k.endswith("moe/w_gate") or "/moe/" in k and (
                k.endswith("w_gate") or k.endswith("w_up")
                or k.endswith("w_down")):
            n = int(n * cfg.top_k / max(cfg.n_experts, 1))
        total += n
    return total


def model_flops_cell(cfg: ModelConfig, shape: dict) -> float:
    """6*N_active*tokens for training, 2*N_active*new_tokens for decode,
    2*N_active*tokens for prefill."""
    n = active_params(cfg)
    if shape["kind"] == "train":
        tokens = shape["batch"] * shape["seq"]
        return 6.0 * n * tokens
    if shape["kind"] == "prefill":
        tokens = shape["batch"] * shape["seq"]
        return 2.0 * n * tokens
    return 2.0 * n * shape["batch"]  # decode: one token per sequence
