"""Streaming PageRank: incremental push-based updates on evolving graphs
and an update-while-serve rank server (see docs/streaming.md).

Layers:
  delta        — EdgeDelta / DeltaGraph: COO delta log over a CSR base with
                 periodic compaction and per-version operator views.
  incremental  — update_ranks: Gauss-Southwell residual pushes seeded at
                 touched rows, warm-started backend-solver fallback, L1
                 certification bound.
  sharded      — update_ranks_sharded: the Partition-sharded rendering on
                 the runtime layer (per-shard Gauss-Southwell drains,
                 boundary-residual outboxes through an ExchangePlan, the
                 global certificate from the Fig. 1 TerminationDriver).
                 mode="superstep" is the deterministic sequential loop;
                 mode="async" runs the drains on AsyncShardExecutor
                 worker threads with zero barriers (docs/runtime.md).
  server       — RankServer: double-buffered snapshots, atomic publish,
                 top_k/scores/personalized queries with staleness metadata;
                 updater="sharded" (+ shard_mode="async") drains deltas
                 through streaming.sharded.
  scenario     — edge-stream replay (freshness vs throughput, the Table-2
                 mirror) and the BlockOperator bridge into core.des.
"""
from .delta import (CSRGraph, DeltaGraph, DeltaReceipt, EdgeDelta,
                    FrozenGraphView, merge_deltas)
from .incremental import (BatchedPPRStats, RankState, UpdateStats,
                          cold_state, ppr_push, ppr_push_batched,
                          refresh_residual, update_ranks, validate_seeds)
from .sharded import ShardedUpdateStats, update_ranks_sharded
from .server import RankServer, RankSnapshot
from .scenario import (BatchRecord, ReplayConfig, ReplayResult,
                       StreamingBlockOperator, replay_trace,
                       synth_edge_trace)

__all__ = [
    "DeltaGraph", "DeltaReceipt", "EdgeDelta", "FrozenGraphView",
    "merge_deltas",
    "BatchedPPRStats", "RankState", "UpdateStats", "cold_state",
    "ppr_push", "ppr_push_batched", "refresh_residual", "update_ranks",
    "validate_seeds",
    "ShardedUpdateStats", "update_ranks_sharded",
    "RankServer", "RankSnapshot",
    "BatchRecord", "ReplayConfig", "ReplayResult",
    "StreamingBlockOperator", "replay_trace", "synth_edge_trace",
]
