"""Edge-stream replay: freshness vs throughput for the streaming stack.

Discrete-event scenario in the style of `core.des`: one updater UE with a
calibrated work-rate model processes crawl delta batches while a Poisson
query stream is answered from whatever snapshot is currently published.
The per-batch accounting mirrors the paper's Table 2 — where the paper
reports *completed imports* per UE (how much of the data a UE should have
seen actually arrived), the replay reports *fresh serves* per interval
(how many queries were answered from a snapshot that matched the live
graph) next to queue delay, service time and the push/fallback split.

`StreamingBlockOperator` adapts the evolving graph to the `core.des`
`BlockOperator` protocol (block updates always read the freshest
snapshot), so the same DES engine that reproduces the paper's async tables
can iterate against a mutating graph.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..core.partition import Partition
from .delta import DeltaGraph, EdgeDelta
from .incremental import RankState, UpdateStats, update_ranks


# ---------------------------------------------------------------------------
# synthetic crawl traces
# ---------------------------------------------------------------------------
def synth_edge_trace(dg: DeltaGraph, n_batches: int, batch_edges: int,
                     p_delete: float = 0.15, p_new_node: float = 0.02,
                     seed: int = 0) -> List[EdgeDelta]:
    """A crawl-like delta stream against the *current* state of `dg`.

    Insertions pick sources uniformly and targets by sampling an existing
    edge's destination (popularity-proportional, preferential-attachment
    flavored) with a uniform escape; deletions sample existing edges.  The
    stream is generated against a scratch replica so every deletion refers
    to an edge that actually exists when its batch is applied; `dg` itself
    is left untouched.
    """
    rng = np.random.default_rng(seed)
    scratch = DeltaGraph(dg.graph(), compact_frac=dg.compact_frac)
    trace: List[EdgeDelta] = []
    for _ in range(n_batches):
        n = scratch.n
        g = scratch.graph()
        n_del = int(round(batch_edges * p_delete))
        n_add = batch_edges - n_del
        new_nodes = int(rng.random() < p_new_node)

        # deletions: sample existing edge slots
        ds, dd = [], []
        if n_del and g.nnz:
            slots = rng.choice(g.nnz, size=min(n_del, g.nnz), replace=False)
            src_of_edge = np.repeat(np.arange(g.n, dtype=np.int64),
                                    np.diff(g.indptr))
            ds = src_of_edge[slots]
            dd = g.indices[slots].astype(np.int64)

        # insertions: uniform source, popularity-biased target
        n_tot = n + new_nodes
        a_src = rng.integers(0, n_tot, size=n_add)
        if g.nnz:
            pick = rng.integers(0, g.nnz, size=n_add)
            a_dst = g.indices[pick].astype(np.int64)
        else:
            a_dst = rng.integers(0, n, size=n_add)
        uni = rng.random(n_add) < 0.2
        a_dst[uni] = rng.integers(0, n_tot, size=int(uni.sum()))
        if new_nodes:
            # wire each arrival in (one in-link) so it is reachable
            a_src = np.concatenate([a_src, rng.integers(0, n, size=1)])
            a_dst = np.concatenate([a_dst,
                                    np.arange(n, n_tot, dtype=np.int64)])

        d = EdgeDelta(add_src=np.asarray(a_src, np.int64),
                      add_dst=np.asarray(a_dst, np.int64),
                      del_src=np.asarray(ds, np.int64),
                      del_dst=np.asarray(dd, np.int64),
                      new_nodes=new_nodes)
        scratch.apply(d)
        trace.append(d)
    return trace


# ---------------------------------------------------------------------------
# BlockOperator adapter (core.des protocol) over an evolving graph
# ---------------------------------------------------------------------------
class StreamingBlockOperator:
    """Eq. (6)/(7) restricted to partition blocks, against the *current*
    version of a `DeltaGraph` — per-version cached scipy row slices, so a
    DES run whose graph mutates between events always iterates on the
    freshest snapshot (node arrivals are not supported: the partition is
    fixed at construction)."""

    def __init__(self, dg: DeltaGraph, part: Partition,
                 alpha: float = 0.85, kind: str = "power"):
        assert kind in ("power", "linear")
        self.dg = dg
        self.part = part
        self.alpha = alpha
        self.kind = kind
        self.n = dg.n
        self._rows_cache: Tuple[int, list] = (-1, [])

    def _blocks(self) -> list:
        ver, blocks = self._rows_cache
        if ver == self.dg.version:
            return blocks
        if self.dg.n != self.part.n:
            raise ValueError("node arrivals changed n; rebuild the "
                             "partition and operator")
        pt_sp = self.dg.scipy_pt()
        blocks = []
        for i in range(self.part.p):
            s, e = self.part.block(i)
            blocks.append(dict(
                pt_rows=pt_sp[s:e],
                nnz=int(pt_sp.indptr[e] - pt_sp.indptr[s])))
        self._rows_cache = (self.dg.version, blocks)
        return blocks

    def update_block(self, i: int, x_full: np.ndarray) -> np.ndarray:
        blk = self._blocks()[i]
        dangling = self.dg.dangling_mask
        dangling_mass = float(x_full[dangling].sum())
        y = self.alpha * (blk["pt_rows"] @ x_full)
        y += self.alpha * dangling_mass / self.n
        if self.kind == "power":
            y += (1.0 - self.alpha) * float(x_full.sum()) / self.n
        else:
            y += (1.0 - self.alpha) / self.n
        return y

    def block_work(self, i: int) -> float:
        return float(max(self._blocks()[i]["nnz"], 1))


# ---------------------------------------------------------------------------
# the replay
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ReplayConfig:
    """Clock model for the single-updater replay (rates in the spirit of
    DESConfig's calibrated edge-ops/s accounting, but calibrated to this
    repo's measured CPU-container throughput: ~1.2e6 pushes/s on the
    batched-frontier host push path (was ~1e5 for the PR 2 per-node
    drain), ~2e7 edge-ops/s through the jitted backend solver)."""

    query_rate: float = 200.0        # Poisson queries per sim second
    delta_interval: float = 0.25     # mean seconds between batch arrivals
    push_rate: float = 1.2e6         # pushes the updater sustains per second
    solve_edge_rate: float = 2e7     # edge-ops/s for fallback sweeps
    update_overhead: float = 2e-3    # per-batch fixed cost (s)
    tol: float = 1e-5                # serving-grade certificate
    backend: str = "segment_sum"
    push_frontier_frac: float = 0.25  # crossover for the batched sweep
    seed: int = 0


@dataclasses.dataclass
class BatchRecord:
    """One row of the freshness table (the Table-2 mirror)."""

    batch: int
    arrival: float
    start: float
    done: float
    queue_delay: float
    service: float
    path: str
    pushes: int
    visited_frac: float
    version_lag_at_done: int       # batches that arrived while serving this
    fresh_queries: int             # queries served fresh since last publish
    stale_queries: int


@dataclasses.dataclass
class ReplayResult:
    rows: List[BatchRecord]
    queries: int
    fresh_pct: float               # % of queries served with zero lag
    mean_age_s: float              # mean snapshot age at query time
    p95_age_s: float
    mean_lag_batches: float        # mean published-version lag at query time
    busy_frac: float               # updater utilization
    us_per_delta_edge: float       # sim service time per delta edge
    deltas_per_s: float            # sustained capacity 1/mean service

    def table(self) -> str:
        hdr = (f"{'batch':>5} {'arr':>8} {'q-delay':>8} {'service':>8} "
               f"{'path':>12} {'pushes':>7} {'visit%':>7} {'lag':>4} "
               f"{'fresh/stale':>12}")
        lines = [hdr]
        for r in self.rows:
            lines.append(
                f"{r.batch:>5} {r.arrival:>8.3f} {r.queue_delay:>8.4f} "
                f"{r.service:>8.4f} {r.path:>12} {r.pushes:>7} "
                f"{100 * r.visited_frac:>6.2f}% {r.version_lag_at_done:>4} "
                f"{r.fresh_queries:>5}/{r.stale_queries:<6}")
        return "\n".join(lines)


def replay_trace(dg: DeltaGraph, state: RankState,
                 trace: Sequence[EdgeDelta],
                 cfg: Optional[ReplayConfig] = None) -> ReplayResult:
    """Replay an edge-stream trace through the incremental updater under a
    DES clock: batches queue while the updater is busy, queries are served
    from the last published snapshot, and every batch contributes one
    accounting row.  Mutates `dg`/`state` (they end at the trace's final
    version)."""
    cfg = cfg or ReplayConfig()
    rng = np.random.default_rng(cfg.seed)
    n_batches = len(trace)

    arrivals = np.cumsum(rng.exponential(cfg.delta_interval,
                                         size=n_batches))
    events: list = []   # (time, seq, kind, payload)
    seq = 0

    def push_evt(t, kind, payload):
        nonlocal seq
        heapq.heappush(events, (t, seq, kind, payload))
        seq += 1

    for b, t in enumerate(arrivals):
        push_evt(float(t), "delta", b)
    horizon = float(arrivals[-1]) + 1.0
    t_q = float(rng.exponential(1.0 / cfg.query_rate))
    while t_q < horizon:
        push_evt(t_q, "query", None)
        t_q += float(rng.exponential(1.0 / cfg.query_rate))

    pending: List[int] = []      # queued batch ids
    busy_until = 0.0
    busy_time = 0.0
    applied_version = 0          # batches applied (live graph)
    published_version = 0        # batches reflected in the served snapshot
    publish_time = 0.0
    fresh = stale = 0
    interval_fresh = interval_stale = 0
    ages: List[float] = []
    lags: List[int] = []
    rows: List[BatchRecord] = []
    edges_total = 0

    def service_time(stats: UpdateStats, delta: EdgeDelta) -> float:
        if stats.path == "push":
            work = stats.pushes / cfg.push_rate
        else:
            work = stats.solver_iters * dg.nnz / cfg.solve_edge_rate
        return cfg.update_overhead + work

    def start_next(t: float) -> None:
        nonlocal busy_until, busy_time, applied_version, edges_total, \
            interval_fresh, interval_stale, state
        b = pending.pop(0)
        delta = trace[b]
        edges_total += delta.size
        state, stats = update_ranks(
            dg, delta, state, tol=cfg.tol, backend=cfg.backend,
            push_frontier_frac=cfg.push_frontier_frac)
        svc = service_time(stats, delta)
        busy_until = t + svc
        busy_time += svc
        applied_version += 1
        rows.append(BatchRecord(
            batch=b, arrival=float(arrivals[b]), start=t,
            done=busy_until, queue_delay=t - float(arrivals[b]),
            service=svc, path=stats.path, pushes=stats.pushes,
            visited_frac=stats.nodes_visited / max(dg.n, 1),
            version_lag_at_done=len(pending),
            fresh_queries=interval_fresh,
            stale_queries=interval_stale))
        interval_fresh = interval_stale = 0
        push_evt(busy_until, "done", None)

    while events:
        t, _, kind, payload = heapq.heappop(events)
        if kind == "query":
            if published_version == applied_version and not pending:
                fresh += 1
                interval_fresh += 1
            else:
                stale += 1
                interval_stale += 1
            ages.append(t - publish_time)
            lags.append(applied_version + len(pending) - published_version)
        elif kind == "delta":
            pending.append(payload)
            if t >= busy_until:
                start_next(t)
        elif kind == "done":
            published_version = applied_version
            publish_time = t
            if pending:
                start_next(t)

    total_q = max(fresh + stale, 1)
    services = [r.service for r in rows]
    mean_svc = float(np.mean(services)) if services else 0.0
    return ReplayResult(
        rows=rows, queries=fresh + stale,
        fresh_pct=100.0 * fresh / total_q,
        mean_age_s=float(np.mean(ages)) if ages else 0.0,
        p95_age_s=float(np.percentile(ages, 95)) if ages else 0.0,
        mean_lag_batches=float(np.mean(lags)) if lags else 0.0,
        busy_frac=busy_time / max(rows[-1].done if rows else 1.0, 1e-9),
        us_per_delta_edge=1e6 * mean_svc * len(rows) / max(edges_total, 1),
        deltas_per_s=1.0 / mean_svc if mean_svc > 0 else float("inf"),
    )
