"""Update-while-serve rank server.

The ROADMAP's north star is a system that "serves heavy traffic from
millions of users" while the graph keeps changing underneath it.  The
`RankServer` realizes that over the streaming stack:

  * two rank buffers: queries are answered from the **stable** snapshot
    while the updater drains crawl deltas into the **working** state;
  * publishing is an atomic reference swap (CPython reference assignment):
    the working state is frozen into an immutable `RankSnapshot` (rank
    vector copy marked read-only + a frozen graph view + staleness
    metadata) and becomes the new stable buffer — readers never lock, never
    block, and never observe a torn vector;
  * every snapshot carries its certification bound (`cert`, the L1 distance
    to the exact ranks of its own graph version) and staleness metadata
    (graph version, publish time, deltas that were pending when it was
    cut), so a caller can always tell *how* stale an answer is.

Queries:
    top_k(k)            — highest-rank pages from the stable buffer.
    scores(ids)         — rank values for explicit pages.
    personalized(seeds) — approximate personalized PageRank, computed by
                          residual pushes against the snapshot's frozen
                          graph view (localized, serve-side work only).

The updater can run inline (`apply_pending()`, deterministic — what the
tests drive) or as a daemon thread (`start()`/`stop()`) that drains the
ingest queue in merged batches, the update-while-serve mode.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..runtime.observe import render_prometheus
from ..runtime.schedule import make_schedule
from .delta import DeltaGraph, EdgeDelta, FrozenGraphView, merge_deltas
from .incremental import (RankState, UpdateStats, _exact_residual,
                          cold_state, ppr_push, refresh_residual,
                          update_ranks)
from .sharded import ShardedUpdateStats, update_ranks_sharded


@dataclasses.dataclass(frozen=True)
class RankSnapshot:
    """Immutable published view: the stable buffer queries read from."""

    x: np.ndarray               # (n,) read-only rank vector
    view: FrozenGraphView       # the graph this vector certifies against
    version: int                # graph version of the vector
    cert: float                 # certified ||x - x*||_1 for that version
    published_at: float         # wall-clock publish time
    pending_at_publish: int     # deltas still queued when this was cut
    seq: int                    # publish sequence number
    op: Optional[object] = None     # GoogleOperator of `version` (only when
                                    # the server runs with snapshot_ops on:
                                    # the batched-PPR lane solve needs it)
    pt_sp: Optional[object] = None  # host scipy P^T of `version` (exact
                                    # certification spmm for batched PPR)

    @property
    def n(self) -> int:
        return int(self.x.shape[0])

    def _order_cache(self) -> dict:
        # the snapshot is frozen but not slotted: hang the memo off
        # __dict__ (same pattern as GoogleOperator._cache); races between
        # query threads are benign (both compute the same array)
        cache = self.__dict__.get("_topk_memo")
        if cache is None:
            cache = {}
            object.__setattr__(self, "_topk_memo", cache)
        return cache

    def top_k(self, k: int = 10) -> Tuple[np.ndarray, np.ndarray]:
        k = min(k, self.n)
        if k <= 0:
            # np.argpartition(-x, k - 1) would partition on the *last*
            # element for k == 0 (kth=-1 wraps around) — return explicit
            # empties instead
            return (np.empty(0, dtype=np.int64),
                    np.empty(0, dtype=self.x.dtype))
        # memoize the expensive argpartition per power-of-two ceiling K:
        # hot top-k traffic under load re-slices one cached order instead
        # of re-partitioning the full rank vector per call.  Ties break
        # deterministically (descending score, then ascending id) so a
        # k-prefix of the K-order equals a direct top-k.
        K = self.n if k >= self.n else min(1 << (k - 1).bit_length(),
                                           self.n)
        cache = self._order_cache()
        order = cache.get(K)
        if order is None:
            # any cached superset order is already sorted: its k-prefix
            # IS the answer — re-slice it instead of re-partitioning
            bigger = [Kc for Kc in cache if Kc >= k]
            if bigger:
                order = cache[min(bigger)]
            else:
                if K >= self.n:
                    order = np.lexsort((np.arange(self.n), -self.x))
                else:
                    part = np.argpartition(-self.x, K - 1)[:K]
                    order = part[np.lexsort((part, -self.x[part]))]
                order = order.astype(np.int64, copy=False)
                cache[K] = order
        top = order[:k]
        return top, self.x[top]

    def scores(self, ids) -> np.ndarray:
        return self.x[np.asarray(ids, dtype=np.int64)]


class RankServer:
    """Double-buffered PageRank serving over an evolving `DeltaGraph`."""

    def __init__(self, dg: DeltaGraph, alpha: float = 0.85,
                 tol: float = 1e-8, backend: str = "segment_sum",
                 method: str = "linear",
                 push_frontier_frac: float = 0.25,
                 refresh_every: int = 64,
                 cold_tol: Optional[float] = None,
                 updater: str = "incremental",
                 shards: int = 4,
                 exchange: str = "allgather",
                 shard_mode: str = "superstep",
                 shard_transport: str = "threads",
                 shard_workers: Optional[int] = None,
                 drain_schedule=None,
                 snapshot_ops: bool = False):
        if updater not in ("incremental", "sharded"):
            raise ValueError(f"unknown updater {updater!r}; expected "
                             "'incremental' or 'sharded'")
        if shard_mode not in ("superstep", "async"):
            raise ValueError(f"unknown shard_mode {shard_mode!r}; expected "
                             "'superstep' or 'async'")
        if shard_transport not in ("threads", "procpool", "device"):
            raise ValueError(f"unknown shard_transport {shard_transport!r};"
                             " expected 'threads', 'procpool' or 'device'")
        if shard_transport in ("procpool", "device") \
                and shard_mode != "async":
            raise ValueError(f"shard_transport={shard_transport!r} "
                             "requires shard_mode='async'")
        self.dg = dg
        self.alpha = alpha
        self.tol = tol
        self.backend = backend
        self.method = method
        self.push_frontier_frac = push_frontier_frac
        self.refresh_every = refresh_every
        # updater="sharded": drain deltas with the Partition-sharded
        # runtime-layer updater (streaming.sharded) — p shards exchanging
        # boundary residual under `exchange` ("allgather" | "sparsified"),
        # certificate via the Fig. 1 TerminationDriver.  shard_mode="async"
        # runs the drains with no superstep barrier on `shard_transport`:
        # "threads" (AsyncShardExecutor worker threads), "procpool"
        # (worker processes over a shared-memory ShardArena,
        # `shard_workers` sizing the pool), or "device" (p jax shard
        # programs over a device mesh; see docs/runtime.md).
        self.updater = updater
        self.shards = shards
        self.exchange = exchange
        self.shard_mode = shard_mode
        self.shard_transport = shard_transport
        self.shard_workers = shard_workers
        # DrainSchedule (runtime/schedule.py): None, a SCHEDULES name, or
        # a full ScheduleSpec — normalized once and threaded into every
        # batch the updater applies (both updaters accept it; the
        # certificate every snapshot publishes is schedule-independent)
        self.drain_schedule = make_schedule(drain_schedule)

        # query-tier hooks (src/repro/serving): a QueryBatcher fuses
        # concurrent personalized() calls into one (n, nv) lane solve, a
        # PPRCache short-circuits repeats under a certified drift bound,
        # and subscribe() fans each publish out to router read-replicas.
        # snapshot_ops=True captures the per-version GoogleOperator +
        # host P^T on every snapshot (what the batched solve consumes);
        # off by default — it fronts the O(nnz) per-version transition
        # build that pure push/serve paths never need.
        self.snapshot_ops = bool(snapshot_ops)
        self._ppr_batcher = None
        self._ppr_cache = None
        self._subscribers: List = []

        # working buffer (updater-owned) + cold certification
        self._state: RankState = cold_state(
            dg, alpha=alpha, tol=cold_tol if cold_tol is not None else tol,
            backend=backend, method=method)
        self._queue: "queue.Queue[EdgeDelta]" = queue.Queue()
        self._seq = 0
        self._batches_since_refresh = 0
        self._snapshot: RankSnapshot = self._cut_snapshot()

        self._thread: Optional[threading.Thread] = None
        self._stop_evt = threading.Event()
        self._lock = threading.Lock()   # serializes updater entry points
        self._stat_lock = threading.Lock()  # telemetry counters (any thread)

        # counters (telemetry; read-only for callers)
        self.deltas_ingested = 0
        self.batches_applied = 0
        self.fallbacks = 0
        self.queries_served = 0
        self.state_recoveries = 0   # _recover_state entries (any path)
        self.cold_rebuilds = 0      # ...that took the cold_state resort
        self.last_stats = None   # UpdateStats | ShardedUpdateStats

        # degrade-gracefully state (PR 6): a daemon-updater failure no
        # longer dies silently — it is captured here, the working state is
        # re-materialized, and the loop retries with backoff while queries
        # keep answering from the last certified snapshot
        self.last_error: Optional[Dict[str, object]] = None
        self.consecutive_failures = 0
        self.updater_restarts = 0
        self._REQUEUE_CAP = 3
        self._requeue_budget = self._REQUEUE_CAP

    # ------------------------------------------------------------------
    # the swap protocol
    # ------------------------------------------------------------------
    def _cut_snapshot(self) -> RankSnapshot:
        x = self._state.x.copy()
        x.setflags(write=False)
        self._seq += 1
        op = pt_sp = None
        if self.snapshot_ops:
            # memoized per version on the DeltaGraph: the first cut of a
            # version pays the transition build, later cuts are pointer
            # copies — batched PPR and exact certification read these
            op = self.dg.operator(self.alpha)
            pt_sp = self.dg.scipy_pt()
        snap = RankSnapshot(
            x=x, view=self.dg.freeze(), version=self._state.version,
            cert=self._state.cert, published_at=time.time(),
            pending_at_publish=self._queue.qsize(), seq=self._seq,
            op=op, pt_sp=pt_sp)
        self._snapshot = snap   # atomic reference swap — the publish
        for cb in list(self._subscribers):
            # publish fan-out (router read-replicas): subscriber errors
            # must never kill the updater — drop them on the floor, the
            # replica just stays a publish behind
            try:
                cb(snap)
            except Exception:
                pass
        return snap

    def snapshot(self) -> RankSnapshot:
        """The stable buffer (immutable; hold it as long as you like)."""
        return self._snapshot

    def subscribe(self, callback) -> None:
        """Register a publish listener: `callback(snap)` runs on every
        `_cut_snapshot` (updater thread) with the freshly published
        `RankSnapshot`.  This is the router's atomic fan-out channel —
        replicas install the reference, they never copy the vector."""
        self._subscribers.append(callback)
        callback(self._snapshot)   # catch the replica up immediately

    def enable_snapshot_ops(self) -> None:
        """Switch on per-snapshot operator capture and re-publish so the
        current snapshot carries `op`/`pt_sp` too (the query batcher
        calls this when it attaches)."""
        if self.snapshot_ops and self._snapshot.op is not None:
            return
        self.snapshot_ops = True
        with self._lock:
            self._cut_snapshot()

    # ------------------------------------------------------------------
    # ingest + update
    # ------------------------------------------------------------------
    def ingest(self, delta: EdgeDelta) -> None:
        """Enqueue a crawl delta (any thread)."""
        with self._stat_lock:
            self.deltas_ingested += 1
        self._queue.put(delta)

    def _drain(self) -> List[EdgeDelta]:
        out = []
        while True:
            try:
                out.append(self._queue.get_nowait())
            except queue.Empty:
                return out

    def apply_pending(self) -> Optional[UpdateStats]:
        """Drain the queue, apply one merged batch, publish. Inline and
        deterministic (the non-threaded mode); returns the update stats or
        None when the queue was empty."""
        with self._lock:
            batch = self._drain()
            if not batch:
                return None
            merged = merge_deltas(batch)
            ver0 = self.dg.version
            try:
                if self.updater == "sharded":
                    self._state, stats = update_ranks_sharded(
                        self.dg, merged, self._state, tol=self.tol,
                        p=self.shards, exchange=self.exchange,
                        mode=self.shard_mode,
                        transport=self.shard_transport,
                        n_workers=self.shard_workers,
                        backend=self.backend, method=self.method,
                        schedule=self.drain_schedule)
                else:
                    self._state, stats = update_ranks(
                        self.dg, merged, self._state, tol=self.tol,
                        backend=self.backend, method=self.method,
                        push_frontier_frac=self.push_frontier_frac,
                        schedule=self.drain_schedule)
            except BaseException:
                # the batch is only safe to retry when the graph did NOT
                # advance (a failure after dg.apply means the delta is
                # already in the graph — re-enqueueing would double-apply
                # it); a bounded retry budget keeps a poisoned batch from
                # cycling forever
                if self.dg.version == ver0 and self._requeue_budget > 0:
                    self._requeue_budget -= 1
                    self._queue.put(merged)
                raise
            self._requeue_budget = self._REQUEUE_CAP
            fell_back = stats.path not in ("push", "sharded_push")
            self._batches_since_refresh += 1
            if fell_back:
                self._batches_since_refresh = 0
            elif self._batches_since_refresh >= self.refresh_every:
                # long pure-push chains re-derive the residual exactly so
                # float drift never silently erodes the certificate
                refresh_residual(self.dg, self._state)
                self._batches_since_refresh = 0
            # all telemetry lives under _stat_lock (concurrent query
            # threads read these counters; _lock only serializes updaters)
            with self._stat_lock:
                self.batches_applied += 1
                if fell_back:
                    self.fallbacks += 1
                self.last_stats = stats
            cache = self._ppr_cache
            if cache is not None:
                # advance the cache's certified drift accounting BEFORE
                # publishing, so a query against the new snapshot can
                # already hit entries whose bound survived this delta
                cache.note_update(self.dg._last_receipt)
            self._cut_snapshot()
            return stats

    # ------------------------------------------------------------------
    # async updater (update-while-serve)
    # ------------------------------------------------------------------
    def start(self, poll_s: float = 0.01, backoff_base_s: float = 0.05,
              backoff_cap_s: float = 2.0) -> None:
        """Run the updater as a daemon thread.  An unhandled updater
        exception no longer kills the thread silently (the pre-PR 6
        failure mode: the server served forever-stale data with no
        signal): it is captured into `last_error`, the working state is
        re-materialized (`_recover_state`), and the loop retries with
        capped exponential backoff — queries keep answering from the
        last certified snapshot throughout.  `health()` surfaces all of
        it."""
        if self._thread is not None:
            raise RuntimeError("updater already running")
        self._stop_evt.clear()

        def run():
            import traceback
            while not self._stop_evt.is_set():
                if self._queue.empty():
                    self._stop_evt.wait(poll_s)
                    continue
                try:
                    self.apply_pending()
                except Exception as exc:
                    with self._stat_lock:
                        self.consecutive_failures += 1
                        self.updater_restarts += 1
                        self.last_error = dict(
                            time=time.time(), error=repr(exc),
                            traceback=traceback.format_exc())
                        fails = self.consecutive_failures
                    try:
                        self._recover_state()
                    except Exception:   # pragma: no cover - last resort
                        pass            # keep serving; next pass retries
                    self._stop_evt.wait(min(
                        backoff_base_s * (2.0 ** (fails - 1)),
                        backoff_cap_s))
                else:
                    with self._stat_lock:
                        self.consecutive_failures = 0

        self._thread = threading.Thread(
            target=run, name="rank-updater", daemon=True)
        self._thread.start()

    def _recover_state(self) -> None:
        """Re-materialize a consistent working state after an updater
        failure.  A failure *before* `dg.apply` leaves the state valid
        (just re-derive the residual exactly); a failure *after* leaves
        the state a version behind the graph — pad the iterate to the new
        node count and rebuild the exact residual against the current
        graph, falling back to a cold solve if even that fails.  The
        stable snapshot is untouched: it stays the last *certified*
        publish, and the recovered state only reaches readers after the
        next successful (certified) update."""
        with self._lock:
            st = self._state
            n = self.dg.n
            cold = False
            try:
                if st.v is not None and (st.x.shape[0] != n
                                         or st.version != self.dg.version):
                    # a custom teleport vector cannot be padded to new
                    # nodes meaningfully — rebuild from scratch
                    raise ValueError("custom-v state behind the graph")
                if st.x.shape[0] != n or st.version != self.dg.version:
                    x = np.zeros(n)
                    m = min(int(st.x.shape[0]), n)
                    x[:m] = st.x[:m]
                    self._state = RankState(
                        x=x, r=_exact_residual(self.dg, x, self.alpha,
                                               st.v),
                        version=self.dg.version, alpha=st.alpha, v=st.v)
                else:
                    # same version/shape: the iterate is fine, only the
                    # maintained residual is suspect — re-derive it
                    refresh_residual(self.dg, st)
            except Exception:
                cold = True
                self._state = cold_state(
                    self.dg, alpha=self.alpha, tol=self.tol,
                    backend=self.backend, method=self.method)
            self._batches_since_refresh = 0
            self._note_state_recovery(cold)

    def _note_state_recovery(self, cold: bool) -> None:
        """The one place recovery telemetry reconciles, under
        `_stat_lock`.  The cold-fallback path used to move *no* counters:
        a cold rebuild re-certifies through a full solver pass — a
        fallback in every sense `fallbacks` counts — yet the counter (and
        any recovery signal) stayed stale across it, so `metrics()`
        readers saw an "all pushes" server that had in fact been rebuilt
        from scratch."""
        with self._stat_lock:
            self.state_recoveries += 1
            if cold:
                self.cold_rebuilds += 1
                self.fallbacks += 1

    def health(self) -> Dict[str, object]:
        """Liveness + degradation signal for operators/load-balancers.

        status: "ok" (serving, updater healthy), "degraded" (serving
        from the last certified snapshot while the updater recovers from
        failures), "dead" (updater thread exited unexpectedly — should
        be unreachable, the run loop traps exceptions)."""
        snap = self._snapshot
        started = self._thread is not None
        alive = bool(started and self._thread.is_alive())
        with self._stat_lock:
            last_error = self.last_error
            fails = self.consecutive_failures
            restarts = self.updater_restarts
        if started and not alive and not self._stop_evt.is_set():
            status = "dead"
        elif fails > 0:
            status = "degraded"
        else:
            status = "ok"
        return dict(
            status=status, updater_started=started, updater_alive=alive,
            last_error=last_error, consecutive_failures=fails,
            updater_restarts=restarts, snapshot_seq=int(snap.seq),
            snapshot_cert=float(snap.cert),
            version_lag=int(max(self.dg.version - snap.version, 0)),
            pending_deltas=int(self._queue.qsize()))

    def metrics(self) -> Dict[str, object]:
        """One reconciled snapshot of every counter the server keeps,
        plus the serving-freshness gauges (staleness, certificate bound,
        snapshot seq, updater restarts) — the machine-readable companion
        of `health()` and the source for `metrics_text()`.  Counters are
        read together under `_stat_lock`, so a concurrent updater can
        never yield a snapshot where e.g. `cold_rebuilds` moved but
        `fallbacks` did not (the satellite-1 staleness)."""
        stale = self.staleness()
        snap = self._snapshot
        started = self._thread is not None
        alive = bool(started and self._thread.is_alive())
        with self._stat_lock:
            m: Dict[str, object] = dict(
                deltas_ingested=int(self.deltas_ingested),
                batches_applied=int(self.batches_applied),
                fallbacks=int(self.fallbacks),
                queries_served=int(self.queries_served),
                state_recoveries=int(self.state_recoveries),
                cold_rebuilds=int(self.cold_rebuilds),
                consecutive_failures=int(self.consecutive_failures),
                updater_restarts=int(self.updater_restarts),
            )
        m.update(
            updater_started=started, updater_alive=alive,
            snapshot_seq=int(snap.seq), snapshot_cert=float(snap.cert),
            version_lag=int(stale["version_lag"]),
            pending_deltas=int(stale["pending_deltas"]),
            snapshot_age_s=float(stale["age_s"]))
        return m

    def metrics_text(self) -> str:
        """Prometheus text exposition of `metrics()` (rendered by
        `runtime.observe.render_prometheus`; scrape-ready)."""
        m = self.metrics()
        fams = [(k, "counter", m[k]) for k in (
            "deltas_ingested", "batches_applied", "fallbacks",
            "queries_served", "state_recoveries", "cold_rebuilds",
            "updater_restarts")]
        fams += [(k, "gauge", float(m[k])) for k in (  # type: ignore
            "consecutive_failures", "snapshot_seq", "snapshot_cert",
            "version_lag", "pending_deltas", "snapshot_age_s",
            "updater_alive")]
        return render_prometheus(fams, prefix="repro_rank_server")

    def stop(self, drain: bool = True, timeout: float = 30.0) -> None:
        if self._thread is None:
            return
        if drain:
            deadline = time.time() + timeout
            while not self._queue.empty() and time.time() < deadline:
                time.sleep(0.005)
        self._stop_evt.set()
        self._thread.join(timeout=timeout)
        self._thread = None
        if drain and not self._queue.empty():
            self.apply_pending()

    # ------------------------------------------------------------------
    # queries (stable buffer only)
    # ------------------------------------------------------------------
    def top_k(self, k: int = 10) -> Tuple[np.ndarray, np.ndarray]:
        with self._stat_lock:
            self.queries_served += 1
        return self._snapshot.top_k(k)

    def scores(self, ids) -> np.ndarray:
        with self._stat_lock:
            self.queries_served += 1
        return self._snapshot.scores(ids)

    def personalized(self, seeds, weights=None, tol: float = 1e-4):
        """Approximate personalized PageRank served against the stable
        snapshot's frozen graph.  Returns (x, cert, stats); cert bounds
        ||x - x*||_1 against the snapshot's own graph version.

        Plain servers answer with a per-query Gauss-Southwell push solve
        (push-local; never blocks the updater).  With a `QueryBatcher`
        attached (serving.attach_query_tier) concurrent calls fuse into
        one (n, nv) lane solve; with a `PPRCache` attached, repeats whose
        certified drift bound still clears `tol` return without solving.
        """
        with self._stat_lock:
            self.queries_served += 1
        snap = self._snapshot
        cache = self._ppr_cache
        if cache is not None:
            hit = cache.get(snap, seeds, weights, tol)
            if hit is not None:
                return hit
        # with a cache attached, solve misses to half the query tol: a
        # push stops just under its target, so a tol-solved entry would
        # enter the cache with no headroom and die on the first delta
        # that moves any of its mass — half-tol entries survive real
        # version drift (see serving/ppr_cache.py)
        solve_tol = 0.5 * tol if cache is not None else tol
        batcher = self._ppr_batcher
        if batcher is not None:
            x, cert, stats, snap = batcher.submit(seeds, weights,
                                                  solve_tol)
        else:
            x, cert, stats = ppr_push(snap.view, seeds, weights=weights,
                                      alpha=self.alpha, tol=solve_tol)
        if cache is not None and np.isfinite(cert):
            cache.put(snap, seeds, weights, tol, x, cert)
        return x, cert, stats

    def staleness(self) -> Dict[str, float]:
        """How far behind the stable buffer is, right now.

        Seqlock-style read: the graph version is captured *with* the
        snapshot (re-read until the snapshot reference is stable around
        the version read), so a daemon updater mid-`dg.apply`/publish
        cannot produce a lag computed against a snapshot from a different
        instant.  Lag is clamped at 0: `dg.version` is bumped before the
        matching snapshot publishes, never after."""
        for _ in range(8):
            snap = self._snapshot
            version = self.dg.version
            if self._snapshot is snap:
                break
        return dict(
            version_lag=float(max(version - snap.version, 0)),
            pending_deltas=float(self._queue.qsize()),
            age_s=float(time.time() - snap.published_at),
            cert=float(snap.cert),
            seq=float(snap.seq),
        )
