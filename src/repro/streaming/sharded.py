"""Partition-sharded certified streaming updates (runtime-layer rendering).

The single-updater `update_ranks` drains the whole residual from one
thread.  This module shards the drain over a row Partition — the streaming
rendering of the paper's eq. (5) cycle, built directly on `repro.runtime`:

  * each shard runs Gauss-Southwell pushes on its *own* rows (the batched
    frontier sweep of `incremental._push`, restricted to the shard's row
    range — the LocalSolver role);
  * residual mass a push diffuses into rows another shard owns is
    *boundary residual*: it accumulates in a per-shard outbox and moves to
    its owner through a `runtime.ExchangePlan` — every epoch under
    "allgather", or §6-targeted under "sparsified" (an outbox ships only
    when its L1 mass exceeds a threshold, with a forced delivery every
    `refresh_every` sender epochs so delays stay bounded; epochs with an
    *empty* outbox still advance the refresh clock — nothing was withheld,
    so quiet pairs bank no forced-refresh debt);
  * the global certificate comes from the Fig. 1 protocol, not from a
    centralized residual sum.  Because every unit of residual mass is
    counted by exactly one shard at any instant (own rows, mailbox in
    flight, or the sender's undelivered outbox), the reduced sum
    upper-bounds the true ||r||_1 and the certificate
    ||x - x*||_1 <= sum_i ||r_i||_1 / (1 - alpha) is sound at STOP time.

Two execution modes (`mode=`):

  "superstep" (default) — the original sequential loop: all p drains, then
    the exchange, then one `TerminationDriver.allreduce_step` per
    superstep.  Deterministic; the golden reference.
  "async" — the drains run concurrently on `runtime.AsyncShardExecutor`
    worker threads with per-pair mailboxes and **no barrier of any kind**:
    the plan is consulted after every local update and termination is
    driven through the driver's message rendering (`ue_step` /
    `monitor_recv`).  Nondeterministic schedule; after STOP the exact
    residual is recomputed from the folded-back state, and the drain is
    re-entered if an in-flight race let STOP fire before the target was
    truly met — the published certificate is always exact.

The dense uniform terms a dangling push would smear (column = e/n) fold
into a scalar that all shards share and apply at epoch boundaries, so
pushes stay local.  When a batch is too global to drain (work caps), the
updater falls back to the same warm-started backend solve as
`update_ranks`.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from ..core.pagerank import solve_linear, solve_power
from ..core.partition import Partition, block_rows
from ..runtime.driver import TerminationDriver
from ..runtime.exchange import AllToAllPlan, ExchangePlan, SparsifiedPlan
from ..runtime.executor import AsyncShardExecutor
from ..runtime.faults import FaultPlan
from ..runtime.observe import ShardObserver, attribute_frontier
from ..runtime.schedule import ScheduleSpec, make_schedule
from ..runtime.state import ShardArena
from ..runtime.transport import ProcPoolShardExecutor
from .delta import DeltaGraph, EdgeDelta
from .incremental import (RankState, _check_cert, _exact_residual,
                          _frontier_contrib, _group_sums, _seed_delta,
                          _view_arrays)


@dataclasses.dataclass
class ShardedUpdateStats:
    """What one sharded update did (the Fig. 1 transcript included)."""

    path: str                  # "sharded_push" | "solve_linear" | "solve_power"
    p: int
    supersteps: int            # supersteps, or busiest worker's rounds (async)
    pushes: int                # frontier pops over all shards
    pushes_per_shard: np.ndarray
    exchanges: int             # outbox deliveries that actually shipped
    bytes_moved: int           # modeled payload bytes ((idx, value) pairs)
    seed_l1: float
    resid_l1: float            # driver's reduced sum (superstep) or the
                               # exact post-fold ||r||_1 (async)
    cert: float                # resid_l1 / (1 - alpha) — the Fig. 1 bound
    stop_superstep: int = -1   # superstep/round at which STOP was issued
    solver_iters: int = 0
    mode: str = "superstep"    # "superstep" | "async"
    idle_s: float = 0.0        # total worker idle time (async mode only)
    attempts: int = 1          # async drain entries (>1 = STOP raced mass
                               # in flight and the drain was re-entered)
    transport: str = "threads"  # "threads" | "procpool" (async mode only)
    recoveries: int = 0        # supervised worker restarts (faults/crashes)
    recovery_s: float = 0.0    # total detection -> respawned time
    schedule: str = "default"  # DrainSchedule rendering the drain ran under
    # push-inflation attribution (observe=True, async mode): every
    # frontier pop is exactly one of these, so first+local+boundary ==
    # pushes on a fault-free run (a kill can lose counted-but-uncredited
    # pops, leaving the sum a bounded over-count of `pushes`)
    pushes_first: int = 0      # rows pushed for the first time this update
    pushes_local: int = 0      # re-pushes from the shard's own sweep order
    pushes_boundary: int = 0   # re-pushes re-activated by foreign mass
    observed: Optional[dict] = None  # ShardObserver.observed() payload
    # device transport only: the §6 sparsified collective counters
    rows_sent: int = 0         # sparse payload rows shipped in-loop
    fulls: int = 0             # forced full refreshes (bounded-delay)
    device_resid: float = 0.0  # final device-visible delta L1 (telemetry;
    #                          # the published cert is the exact recompute)


def _scatter_add(out: np.ndarray, idx: np.ndarray,
                 val: np.ndarray) -> None:
    """``out[idx] += val`` with duplicate indices — the grouped-scatter
    path PR 1 standardized everywhere else (`np.add.at` is the slow
    buffered ufunc path), via the `_group_sums` heuristic shared with
    `incremental._push`.  Exactly equivalent to `np.add.at(out, idx,
    val)` up to float summation order (tested in
    tests/test_executor.py)."""
    if idx.size == 0:
        return
    uq, sums = _group_sums(idx, val, out.size)
    out[uq] += sums


def _drain_shard(arrays, x: np.ndarray, r: np.ndarray,
                 outbox: np.ndarray, s: int, e: int, alpha: float,
                 local_target: float, eps_floor: float,
                 c_holder: list, attr=None, order=None) -> int:
    """Drain shard rows [s, e) to ||r[s:e]||_1 <= local_target with batched
    frontier sweeps.  Contributions to own rows feed back into r (and keep
    draining); contributions to foreign rows accumulate into `outbox`
    (addressed by global row id); dangling mass accumulates into the shared
    uniform scalar `c_holder[0]`.  Returns the number of pushes.

    `attr=(pushed, foreign, cnt)` arms push-inflation attribution: each
    frontier is classified first/local/boundary into `cnt` (the shard's
    (3,) row) before its flags advance (runtime/observe.py).

    `order` (a `runtime.schedule.DrainOrder`, local coords [0, e-s)) lets
    a DrainSchedule refine each sweep's frontier — priority retention may
    empty a ladder level (the ladder then descends: the retained rows wait
    for the level where their fluid matters) but never the floor, so an
    empty frontier at eps_floor still certifies the remaining mass is
    below bs * eps_floor, schedule or not."""
    n = r.shape[0]
    pushes = 0
    bs = e - s
    if bs <= 0:
        return 0
    if order is not None:
        order.begin_round()
    while True:
        r_own = r[s:e]
        l1_own = float(np.abs(r_own).sum())
        if l1_own <= local_target:
            return pushes
        eps = max(l1_own / bs, eps_floor)
        while True:
            frontier = np.flatnonzero(np.abs(r_own) >= eps)
            if order is not None and frontier.size:
                frontier = order.refine(np.abs(r_own[frontier]), frontier,
                                        eps, eps <= eps_floor)
            if frontier.size:
                break
            if eps <= eps_floor:
                return pushes
            eps = max(eps / 8.0, eps_floor)
        if order is not None:
            order.note_drained(frontier)
        frontier = frontier + s
        if attr is not None:
            attribute_frontier(attr[0], attr[1], attr[2], frontier)
        pushes += int(frontier.size)
        moved = r[frontier].copy()
        x[frontier] += moved
        r[frontier] = 0.0
        dst, val, dmass = _frontier_contrib(arrays, frontier, moved, alpha)
        if dmass != 0.0:
            c_holder[0] += alpha * dmass / n
        if dst.size:
            own = (dst >= s) & (dst < e)
            if own.any():
                r[s:e] += np.bincount(dst[own] - s, weights=val[own],
                                      minlength=bs)
            foreign = ~own
            if foreign.any():
                _scatter_add(outbox, dst[foreign], val[foreign])


def _exchange_epoch(plan: ExchangePlan, part: Partition, r: np.ndarray,
                    outboxes: List[np.ndarray], step: int,
                    bytes_per_entry: int, gates=None,
                    step_target: float = 0.0) -> Tuple[int, int]:
    """One boundary-residual exchange epoch over every (src, dst) pair:
    consult the plan, deliver gated outboxes into the owners' rows of `r`,
    and return ``(exchanges, bytes_moved)`` for the payloads that actually
    shipped.

    An epoch whose outbox is *empty* still advances the plan's refresh
    clock (`note_sent`): nothing was withheld from the receiver, so the
    pair is as refreshed as a full delivery would make it.  Without this,
    `SparsifiedPlan.last_full` never advances for quiet pairs,
    `refresh_due` goes permanently true, and the §6 mass-threshold gate is
    defeated — every later sub-threshold payload ships as a "forced
    refresh" (the PR 4 foregrounded bugfix; regression-tested in
    tests/test_executor.py).  Empty epochs ship nothing and count nothing:
    `exchanges`/`bytes_moved` attribute only real payloads.

    `gates` (per-shard `runtime.schedule.ExchangeGate`, boundary-batched
    schedule) coalesces a pair's mass across epochs in front of the plan:
    withheld mass stays in the outbox (still counted in the sender's
    value) and the gate force-opens within `batch_updates` epochs, so the
    bounded-delay argument composes additively with the plan's."""
    exchanges = 0
    bytes_moved = 0
    for i in range(part.p):
        gate = gates[i] if gates is not None else None
        for d in range(part.p):
            if d == i or not plan.wants(i, d, step):
                continue
            s, e = part.block(d)
            box = outboxes[i][s:e]
            mass = float(np.abs(box).sum())
            if mass == 0.0:
                plan.note_sent(i, d, step)
                if gate is not None:
                    gate.note_quiet(d, step)
                continue
            if gate is not None and not gate.ready(d, step, mass,
                                                   step_target):
                continue
            if not plan.gate_mass(i, d, step, mass):
                continue
            nz = int(np.count_nonzero(box))
            r[s:e] += box
            box[:] = 0.0
            plan.note_sent(i, d, step)
            plan.on_result(i, d, True)
            if gate is not None:
                gate.note_sent(d, step)
            exchanges += 1
            bytes_moved += nz * (4 + bytes_per_entry)
    return exchanges, bytes_moved


def _make_plan(exchange: str, p: int, l1_target: float,
               sparsify_thresh: Optional[float],
               sparsify_refresh_every: int) -> ExchangePlan:
    if exchange == "sparsified":
        thresh = (sparsify_thresh if sparsify_thresh is not None
                  else 0.1 * l1_target / p)
        return SparsifiedPlan(p, thresh=thresh,
                              refresh_every=sparsify_refresh_every)
    return AllToAllPlan(p)


class _ShardDrain:
    """The drain `_ShardDrainFactory` builds inside each worker: PR 5's
    closure as an object, so the observing worker can wire attribution
    through `set_observer` (`_procpool_worker_main` duck-types for it).
    `_drain_shard` is resolved through the module at call time, so a
    scoped override (the benchmark's modeled drain clock) reaches forked
    workers too."""

    def __init__(self, arrays, x: np.ndarray, r: np.ndarray,
                 alpha: float, eps_floor: float,
                 spec: Optional[ScheduleSpec] = None):
        self.arrays = arrays
        self.x = x
        self.r = r
        self.alpha = alpha
        self.eps_floor = eps_floor
        self.spec = spec
        self._orders: dict = {}   # shard id -> DrainOrder (lazy: a worker
        #                         # only ever drains the shards it owns)
        self.obs: Optional[ShardObserver] = None

    def set_observer(self, obs: Optional[ShardObserver]) -> None:
        # attribution needs the per-row flags; a counters-only observer
        # (synthetic drains) leaves the drain untouched
        self.obs = obs if (obs is not None and obs.pushed is not None) \
            else None

    def _order(self, i, s, e):
        if self.spec is None:
            return None
        if i not in self._orders:
            self._orders[i] = self.spec.order(e - s, shard=i)
        return self._orders[i]

    def __call__(self, i, s, e, step_target, outbox):
        holder = [0.0]
        obs = self.obs
        attr = ((obs.pushed, obs.foreign, obs.attr[i])
                if obs is not None else None)
        got = _drain_shard(self.arrays, self.x, self.r, outbox, s, e,
                           self.alpha, step_target, self.eps_floor,
                           holder, attr, self._order(i, s, e))
        return got, holder[0]


class _ShardDrainFactory:
    """Picklable procpool DrainFactory: rebuilds the batched
    Gauss-Southwell sweep inside each worker process from the arena views
    (`runtime.transport.DrainFactory` contract).  The ScheduleSpec rides
    along (frozen dataclass, picklable); each worker incarnation builds
    fresh per-shard DrainOrder state from it — retention and RNG state are
    schedule heuristics, so losing them to a worker restart is sound."""

    def __init__(self, alpha: float, eps_floor: float, base_n: int,
                 spec: Optional[ScheduleSpec] = None):
        self.alpha = alpha
        self.eps_floor = eps_floor
        self.base_n = base_n
        self.spec = spec

    def __call__(self, views):
        arrays = (views["base_indptr"], views["base_indices"], self.base_n,
                  views["dirty_rows"], views["out_deg"],
                  views["dirty_indptr"], views["dirty_indices"])
        return _ShardDrain(arrays, views["x"], views["r"],
                           self.alpha, self.eps_floor, self.spec)


def _device_update(dg: DeltaGraph, state: RankState, *, p: int,
                   exchange: str, tol: float, l1_target: float,
                   seed_l1: float, sparsify_thresh: Optional[float],
                   sparsify_refresh_every: int, pc_max_compute: int,
                   pc_max_monitor: int, max_supersteps: int, backend: str,
                   method: str, solver_max_iters: int, schedule_name: str
                   ) -> Tuple[RankState, ShardedUpdateStats]:
    """The device-transport drain: warm-start the linear form (eq. 7) from
    the current iterate as p shard programs (runtime/device.py), then
    certify with the host-side exact recompute.

    The device loop's own termination sees only the all-reduced fragment
    delta (||r||_1 up to view staleness), so the drain target starts at
    half the l1 target and tightens 4x on every re-entry — the published
    certificate is always `_exact_residual`, never the device criterion,
    matching the other async transports' contract."""
    from ..runtime.device import DeviceShardTransport

    alpha = state.alpha
    x, r = state.x, state.r
    dev = DeviceShardTransport(
        p, exchange=exchange,
        sparsify_thresh=(float(sparsify_thresh)
                         if sparsify_thresh is not None else 0.0),
        sparsify_refresh_every=sparsify_refresh_every,
        pc_max_compute=pc_max_compute, pc_max_monitor=pc_max_monitor)
    op = dg.operator(alpha, v=state.v)
    target = 0.5 * l1_target
    supersteps = rows = fulls = 0
    bytes_total = 0
    attempts = 0
    device_resid = 0.0
    resid = float(np.abs(r).sum())
    while (attempts == 0 or resid > l1_target) and attempts < 4:
        attempts += 1
        res = dev.run(op, x, target=target, max_supersteps=max_supersteps)
        x[:] = res.x
        supersteps += res.supersteps
        rows += res.rows_sent
        fulls += res.fulls
        bytes_total += res.comm_bytes_total
        device_resid = res.device_resid
        # re-derive the maintained residual exactly from the new iterate
        # (one O(nnz) host apply) — both the re-entry decision and the
        # published certificate stand on it
        r[:] = _exact_residual(dg, x, alpha, state.v)
        resid = float(np.abs(r).sum())
        target *= 0.25
    pps = np.zeros(p, dtype=np.int64)
    if resid <= l1_target:
        return state, ShardedUpdateStats(
            path="sharded_push", p=p, supersteps=supersteps, pushes=0,
            pushes_per_shard=pps, exchanges=rows + fulls,
            bytes_moved=bytes_total, seed_l1=seed_l1, resid_l1=resid,
            cert=resid / (1.0 - alpha), stop_superstep=supersteps,
            mode="async", attempts=attempts, transport="device",
            rows_sent=rows, fulls=fulls, device_resid=device_resid,
            schedule=schedule_name)
    return _solver_fallback(
        dg, state, alpha=alpha, tol=tol, method=method, backend=backend,
        solver_max_iters=solver_max_iters,
        stats_kw=dict(p=p, supersteps=supersteps, pushes=0,
                      pushes_per_shard=pps, exchanges=rows + fulls,
                      bytes_moved=bytes_total, seed_l1=seed_l1,
                      mode="async", attempts=max(attempts, 1),
                      transport="device", rows_sent=rows, fulls=fulls,
                      device_resid=device_resid, schedule=schedule_name))


def update_ranks_sharded(
        dg: DeltaGraph, delta: EdgeDelta, state: RankState, *,
        p: int = 4, tol: float = 1e-8, exchange: str = "allgather",
        mode: str = "superstep", transport: str = "threads",
        n_workers: Optional[int] = None,
        sparsify_thresh: Optional[float] = None,
        sparsify_refresh_every: int = 4,
        pc_max_compute: int = 1, pc_max_monitor: int = 1,
        max_supersteps: int = 10_000, max_push_factor: float = 40.0,
        backend: str = "segment_sum", method: str = "linear",
        solver_max_iters: int = 1000,
        bytes_per_entry: int = 8,
        faults: Optional[FaultPlan] = None,
        observe: bool = False,
        schedule=None
        ) -> Tuple[RankState, ShardedUpdateStats]:
    """Apply `delta` and certify the updated ranks with p shards.

    Mirrors `update_ranks` (same RankState in/out, same exact residual
    bookkeeping, same warm-started fallback) but runs the drain as the
    runtime-layer cycle described in the module docstring, either as the
    deterministic superstep loop (``mode="superstep"``) or with zero
    inter-drain barriers (``mode="async"``) on the selected transport:
    ``transport="threads"`` (worker threads, PR 4 behavior),
    ``transport="procpool"`` (worker *processes* over a shared-memory
    ShardArena — the rendering whose raw wall-clock escapes the GIL;
    ``n_workers`` sizes the pool, default min(p, cores)), or
    ``transport="device"`` (p jax shard programs over a ``ue`` device
    mesh running the same traced ShardStep as core.spmd — needs p
    devices; on CPU launch under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=p``.  Faults,
    observe and custom drain schedules are host-seam features and
    raise; the device counters land on ``stats.rows_sent`` /
    ``stats.fulls`` / ``stats.bytes_moved``).  On success
    ``stats.cert`` is sound and ``state.cert <= stats.cert`` (state.r is
    the exactly-maintained residual; the superstep bound is the driver's
    all-reduced sum, the async bound is the exact post-fold recompute —
    under either transport).

    `faults=FaultPlan(...)` (async mode only) injects a deterministic
    seeded fault schedule — worker kill/hang, exchange drop/dup/delay,
    slow shards — at the transport seam (runtime/faults.py).  Killed
    procpool workers are restarted by the `ShardSupervisor` (threads
    restart the worker loop in place); whenever faults were injected or
    recoveries happened, the residual is re-derived with the exact O(nnz)
    recompute and the drain re-entered until the *exact* residual meets
    the target, so the published certificate stays sound across any
    recovered schedule.  Only an exhausted restart budget still raises
    RuntimeError — with the shared segments released and the surviving
    mass folded back; after such an abort re-certify via
    `refresh_residual` (or rebuild via `cold_state`) before trusting the
    state.

    `schedule=` selects the DrainSchedule rendering (a name or a
    `runtime.schedule.ScheduleSpec`): "default", "priority" (D-Iteration
    fluid retention — targets the threads transport's local cadence tax),
    "boundary" / "boundary-batched" (exchange coalescing — targets the
    procpool transport's boundary re-activation tax), "randomized"
    (seeded Ishii-Tempo control arm), or "priority+boundary".  Schedules
    reorder and delay pushes/shipments only — retained fluid stays in r,
    batched mass stays in the counted outbox — so certificates are
    schedule-independent (gated by tests/test_schedule.py; tuning
    guidance in docs/runtime.md "Drain scheduling").

    `observe=True` (async mode only) arms the runtime observer
    (`runtime/observe.py`): per-shard metrics, a ring-buffered event
    trace at the cycle seams, and push-inflation attribution — the
    `pushes_first` / `pushes_local` / `pushes_boundary` decomposition on
    the stats, with the full payload in `stats.observed` and a
    Perfetto-loadable export via
    `runtime.observe.write_chrome_trace(path, stats.observed["events"])`.
    Off (the default) every hook is a skipped None-check: zero cost.
    """
    if state.version != dg.version:
        raise ValueError(
            f"state at version {state.version} but graph at {dg.version}; "
            "states must track every delta (or be rebuilt via cold_state)")
    if method not in ("linear", "power"):
        raise ValueError(f"unknown method {method!r}")
    if exchange not in ("allgather", "sparsified"):
        raise ValueError(f"unknown exchange {exchange!r}")
    if mode not in ("superstep", "async"):
        raise ValueError(f"unknown mode {mode!r}; expected 'superstep' "
                         "or 'async'")
    if transport not in ("threads", "procpool", "device"):
        raise ValueError(f"unknown transport {transport!r}; expected "
                         "'threads', 'procpool' or 'device'")
    if transport in ("procpool", "device") and mode != "async":
        raise ValueError(f"transport={transport!r} requires mode='async' "
                         "(the superstep loop is a host loop)")
    faulty = faults is not None and faults.active
    if faulty and mode != "async":
        raise ValueError("faults= requires mode='async' (the superstep "
                         "loop has no transport seam to inject at)")
    if observe and mode != "async":
        raise ValueError("observe=True requires mode='async' (the "
                         "superstep loop has no worker cycle to trace)")
    if transport == "device":
        # the device rendering is a pure jax program: no worker seam to
        # inject faults at or trace, and drain scheduling is the traced
        # step itself (observe counters roll in host-side, from the
        # program's own (rows, fulls) outputs)
        if faulty:
            raise ValueError("faults= is not supported on "
                             "transport='device' (no host worker seam)")
        if observe:
            raise ValueError("observe=True is not supported on "
                             "transport='device'; the device counters "
                             "(rows_sent/fulls/bytes) land on the stats")
    spec = make_schedule(schedule)
    if transport == "device" and spec.name != "default":
        raise ValueError("schedule= renderings are host-drain heuristics; "
                         "transport='device' supports only the default")
    # the zero-cost contract: a spec whose drain rendering is the default
    # ladder passes order=None straight through (every hook skipped)
    drain_spec = spec if spec.drain_kind != "default" else None
    if delta.new_nodes and state.v is not None:
        raise NotImplementedError(
            "node arrivals with a custom teleport vector are not "
            "supported incrementally; rebuild via cold_state")
    alpha = state.alpha
    rcpt = dg.apply(delta)
    c = _seed_delta(dg, rcpt, state)
    x, r = state.x, state.r
    n = rcpt.n_new
    seed_l1 = float(np.abs(r).sum()) + abs(c) * n

    # the sharded drain keeps no per-shard rescale state, so the uniform
    # component folds densely up front (exact; O(n) once per batch)
    if c != 0.0:
        r += c

    part = block_rows(n, p)
    l1_target = (1.0 - alpha) * tol
    eps_floor = l1_target / max(n, 1)
    max_pushes = int(max_push_factor * n)

    if transport == "device":
        # --- device-program drain: p shard programs under shard_map run
        # the same traced ShardStep as core.spmd (runtime/device.py); the
        # published certificate is the host-side exact recompute, exactly
        # like the other async transports
        return _device_update(
            dg, state, p=p, exchange=exchange, tol=tol,
            l1_target=l1_target, seed_l1=seed_l1,
            sparsify_thresh=sparsify_thresh,
            sparsify_refresh_every=sparsify_refresh_every,
            pc_max_compute=pc_max_compute, pc_max_monitor=pc_max_monitor,
            max_supersteps=max_supersteps, backend=backend, method=method,
            solver_max_iters=solver_max_iters, schedule_name=spec.name)

    arrays = _view_arrays(dg)

    if mode == "async":
        # --- truly asynchronous drain: shard workers on the selected
        # transport (threads: per-pair mailboxes in-process; procpool:
        # worker processes over a shared-memory ShardArena with lock-free
        # rings), plan consulted per local update, Fig. 1 by routed
        # messages.  STOP can race mass in flight, so the exact residual
        # is recomputed after every fold-back and the drain is re-entered
        # (with fresh protocol state) until it truly holds — the
        # published certificate is always the exact recompute.
        arena = None
        # observe=True arms the runtime observer: threads share one
        # in-process ShardObserver across every attempt; procpool grows
        # each run's control arena with the obs_* slots (observe=True on
        # the executor) and hands the payload back via res.observed
        obs = (ShardObserver.alloc(p, n)
               if observe and transport == "threads" else None)
        if transport == "procpool":
            # shard fragments move to shared memory once per update
            # batch; workers rebuild the drain from the arena views
            arena = ShardArena.from_arrays({
                "r": r, "x": x,
                "base_indptr": arrays[0], "base_indices": arrays[1],
                "dirty_rows": arrays[3], "out_deg": arrays[4],
                "dirty_indptr": arrays[5], "dirty_indices": arrays[6],
            })
            factory = _ShardDrainFactory(alpha=alpha, eps_floor=eps_floor,
                                         base_n=int(arrays[2]),
                                         spec=drain_spec)
            r_run = arena["r"]
        else:
            # the same drain object the procpool factory builds, bound to
            # the in-process arrays: per-shard DrainOrder state persists
            # across drain attempts (retention/RNG are heuristics; the
            # certificate never depends on them)
            drain_fn = _ShardDrain(arrays, x, r, alpha, eps_floor,
                                   drain_spec)
            drain_fn.set_observer(obs)
            r_run = r

        pushes_per_shard = np.zeros(p, dtype=np.int64)
        exchanges = bytes_moved = 0
        step = 0
        stop_round = -1
        idle_s = 0.0
        capped = False
        attempts = 0
        recoveries = 0
        recovery_s = 0.0
        observed = None
        attr_tot = np.zeros(3, dtype=np.int64)
        # kill/hang schedules fire once per *update*, so the fired flags
        # live here and cross every drain attempt (and, in procpool,
        # every worker restart via the control arena)
        fstate = faults.state(p) if faulty else None
        try:
            resid = float(np.abs(r_run).sum())
            # always enter at least once (even an already-converged
            # residual gets its STOP from a routed Fig. 1 transcript, not
            # a shortcut)
            while (attempts == 0 or resid > l1_target) \
                    and not capped and attempts < 4:
                attempts += 1
                plan = _make_plan(exchange, p, l1_target, sparsify_thresh,
                                  sparsify_refresh_every)
                driver = TerminationDriver(p, pc_max_compute=pc_max_compute,
                                           pc_max_monitor=pc_max_monitor)
                # 2x push headroom vs the superstep budget: the
                # fine-grained schedule pushes a row per *arrival* where
                # the superstep loop batches a whole exchange generation
                # into one push — same mass drained, more (cheaper) pops
                push_budget = (2 * max_pushes
                               - int(pushes_per_shard.sum()))

                # spec.drain_frac overrides the transport's drain-call
                # granularity, clamped to keep hysteresis * drain_frac
                # under the livelock bound 1.0 (WorkerConfig rejects it)
                def _df_kw(hysteresis: float) -> dict:
                    if spec.drain_frac is None:
                        return {}
                    return dict(drain_frac=min(float(spec.drain_frac),
                                               0.95 / hysteresis))

                if transport == "procpool":
                    ex = ProcPoolShardExecutor(
                        part, plan, driver, l1_target=l1_target,
                        bytes_per_entry=bytes_per_entry,
                        max_rounds=100 * max_supersteps,
                        max_total_pushes=push_budget, n_workers=n_workers,
                        faults=faults, fault_state=fstate,
                        observe=observe, schedule=spec,
                        **_df_kw(ProcPoolShardExecutor.HYSTERESIS))
                    res = ex.run(factory, arena, x_key="x")
                else:
                    ex = AsyncShardExecutor(
                        part, plan, driver, l1_target=l1_target,
                        bytes_per_entry=bytes_per_entry,
                        max_rounds=100 * max_supersteps,
                        max_total_pushes=push_budget,
                        faults=faults, fault_state=fstate, observe=obs,
                        schedule=spec,
                        **_df_kw(2.0))
                    res = ex.run(drain_fn, r_run)
                if res.observed is not None:
                    # threads reuse one observer, so the last payload is
                    # already cumulative; procpool arenas are per-attempt,
                    # so attribution totals accumulate here (the trace in
                    # `observed` covers the final attempt)
                    observed = res.observed
                    if transport == "procpool":
                        a = res.observed.get("attribution")
                        if a is not None:
                            attr_tot += np.array(
                                [a["first"], a["local"], a["boundary"]],
                                dtype=np.int64)
                pushes_per_shard += res.pushes_per_shard
                exchanges += res.exchanges
                bytes_moved += res.bytes_moved
                step = max(step, int(res.rounds_per_shard.max()))
                stop_round = res.stop_round
                idle_s += float(res.idle_s_per_shard.sum())
                capped = res.capped
                recoveries += res.recoveries
                recovery_s += res.recovery_s
                if faulty or res.recoveries:
                    # faults (and checkpoint-restored restarts) leave the
                    # maintained residual only *boundedly* approximate:
                    # re-derive it exactly from the iterate, so both the
                    # re-entry decision and the published certificate
                    # stand on the exact O(nnz) recompute
                    x_cur = arena["x"] if arena is not None else x
                    r_run[:] = _exact_residual(dg, x_cur, alpha, state.v)
                resid = float(np.abs(r_run).sum())
        finally:
            if arena is not None:
                # bring the fragments home, then release the segment
                # (nothing survives in /dev/shm even on a worker crash)
                r[:] = arena["r"]
                x[:] = arena["x"]
                r_run = None
                arena.close()

        if obs is not None:
            # threads: one observer covered every attempt
            observed = obs.observed()
            if obs.attr is not None:
                attr_tot = obs.attr.sum(axis=0)

        pushes = int(pushes_per_shard.sum())
        if resid <= l1_target and not capped:
            return state, ShardedUpdateStats(
                path="sharded_push", p=p, supersteps=step, pushes=pushes,
                pushes_per_shard=pushes_per_shard, exchanges=exchanges,
                bytes_moved=bytes_moved, seed_l1=seed_l1, resid_l1=resid,
                cert=resid / (1.0 - alpha), stop_superstep=stop_round,
                mode=mode, idle_s=idle_s, attempts=attempts,
                transport=transport, recoveries=recoveries,
                recovery_s=recovery_s, pushes_first=int(attr_tot[0]),
                pushes_local=int(attr_tot[1]),
                pushes_boundary=int(attr_tot[2]), observed=observed,
                schedule=spec.name)
        return _solver_fallback(
            dg, state, alpha=alpha, tol=tol, method=method,
            backend=backend, solver_max_iters=solver_max_iters,
            stats_kw=dict(p=p, supersteps=step, pushes=pushes,
                          pushes_per_shard=pushes_per_shard,
                          exchanges=exchanges, bytes_moved=bytes_moved,
                          seed_l1=seed_l1, mode=mode, idle_s=idle_s,
                          attempts=max(attempts, 1), transport=transport,
                          recoveries=recoveries, recovery_s=recovery_s,
                          pushes_first=int(attr_tot[0]),
                          pushes_local=int(attr_tot[1]),
                          pushes_boundary=int(attr_tot[2]),
                          observed=observed, schedule=spec.name))

    local_target = l1_target / (2.0 * p)
    plan = _make_plan(exchange, p, l1_target, sparsify_thresh,
                      sparsify_refresh_every)
    driver = TerminationDriver(p, pc_max_compute=pc_max_compute,
                               pc_max_monitor=pc_max_monitor)

    # DrainSchedule state for the superstep rendering: per-shard frontier
    # orders, per-shard exchange gates, and (randomized) a seeded
    # per-superstep shard permutation — all deterministic given the spec,
    # so this mode stays the replayable golden reference
    orders = ([drain_spec.order(part.block(i)[1] - part.block(i)[0],
                                shard=i) for i in range(p)]
              if drain_spec is not None else [None] * p)
    gates = ([spec.gate(p) for _ in range(p)]
             if spec.batch_exchange else None)
    shard_rng = (np.random.default_rng(
        np.random.SeedSequence(entropy=int(spec.seed), spawn_key=(p,)))
        if spec.drain_kind == "randomized" else None)

    outboxes = [np.zeros(n) for _ in range(p)]
    c_pending = [0.0]
    pushes_per_shard = np.zeros(p, dtype=np.int64)
    exchanges = 0
    bytes_moved = 0
    total = float("inf")
    stop_superstep = -1
    step = 0
    capped = False

    prev_total = max(seed_l1, l1_target)
    while stop_superstep < 0 and step < max_supersteps:
        # ---- local drains (each shard's own rows) ----------------------
        # Each superstep drains to a *sliding* target: a fraction of the
        # previous all-reduced total (no point draining own rows orders of
        # magnitude below the mass peers are about to export here), floored
        # at the final per-shard share of the certificate target.  Mass
        # decays geometrically across supersteps and the total push count
        # stays proportional to log(seed/target).
        step_target = max(local_target, 0.05 * prev_total / p)
        shard_order = (shard_rng.permutation(p) if shard_rng is not None
                       else range(p))
        for i in shard_order:
            s, e = part.block(i)
            pushes_per_shard[i] += _drain_shard(
                arrays, x, r, outboxes[i], s, e, alpha,
                step_target, eps_floor, c_pending, order=orders[i])
        if int(pushes_per_shard.sum()) > max_pushes:
            capped = True
            break

        # ---- boundary-residual exchange (ExchangePlan) -----------------
        sent, moved = _exchange_epoch(plan, part, r, outboxes, step,
                                      bytes_per_entry, gates=gates,
                                      step_target=step_target)
        exchanges += sent
        bytes_moved += moved
        # the uniform scalar is shared state: fold it densely once all
        # shards have accumulated into it (an all-reduced scalar, 0 bytes
        # of payload in the model)
        if c_pending[0] != 0.0:
            r += c_pending[0]
            c_pending[0] = 0.0

        # ---- Fig. 1 over all-reduced per-shard ||r_i||_1 ---------------
        values = np.empty(p)
        for i in range(p):
            s, e = part.block(i)
            values[i] = (float(np.abs(r[s:e]).sum())
                         + float(np.abs(outboxes[i]).sum()))
        total, issued = driver.allreduce_step(values, l1_target)
        prev_total = max(total, l1_target)
        step += 1
        if issued:
            stop_superstep = step

    # fold whatever is still undelivered back into r: state.r stays the
    # exact residual, and the certified total already counted this mass
    for box in outboxes:
        nz = np.flatnonzero(box)
        if nz.size:
            r[nz] += box[nz]
    if c_pending[0] != 0.0:
        r += c_pending[0]

    pushes = int(pushes_per_shard.sum())
    if stop_superstep > 0 and not capped:
        return state, ShardedUpdateStats(
            path="sharded_push", p=p, supersteps=step, pushes=pushes,
            pushes_per_shard=pushes_per_shard, exchanges=exchanges,
            bytes_moved=bytes_moved, seed_l1=seed_l1, resid_l1=total,
            cert=total / (1.0 - alpha), stop_superstep=stop_superstep,
            schedule=spec.name)

    return _solver_fallback(
        dg, state, alpha=alpha, tol=tol, method=method, backend=backend,
        solver_max_iters=solver_max_iters,
        stats_kw=dict(p=p, supersteps=step, pushes=pushes,
                      pushes_per_shard=pushes_per_shard,
                      exchanges=exchanges, bytes_moved=bytes_moved,
                      seed_l1=seed_l1, schedule=spec.name))


def _solver_fallback(dg: DeltaGraph, state: RankState, *, alpha: float,
                     tol: float, method: str, backend: str,
                     solver_max_iters: int, stats_kw: dict
                     ) -> Tuple[RankState, ShardedUpdateStats]:
    """Warm-started full solve (same contract as update_ranks): drive the
    backend solver from the current iterate, recover the exact residual
    with one host-side apply, and certify."""
    op = dg.operator(alpha, v=state.v)
    solver = solve_linear if method == "linear" else solve_power
    res = solver(op, x0=state.x, tol=0.5 * (1.0 - alpha) * tol,
                 max_iters=solver_max_iters, backend=backend)
    state.x = np.asarray(res.x, dtype=np.float64)
    state.r = _exact_residual(dg, state.x, alpha, state.v)
    resid = state.resid_l1
    _check_cert(resid, tol, alpha, f"solve_{method}[{backend}]")
    return state, ShardedUpdateStats(
        path=f"solve_{method}", resid_l1=resid,
        cert=resid / (1.0 - alpha), solver_iters=res.iters, **stats_kw)
