"""Incremental PageRank: push-based residual diffusion on evolving graphs.

The linear form of the paper (eq. 2) solves (I - alpha S) x = b with
b = (1 - alpha) v and S = P^T + w d^T column-stochastic.  For any iterate x
define the residual

    r = b + alpha S x - x        (so  x* = x + (I - alpha S)^{-1} r).

Since ||S||_1 = 1, the certification bound

    ||x - x*||_1  <=  ||r||_1 / (1 - alpha)                       (cert)

holds unconditionally — every state this module returns carries it.

A graph delta perturbs only the transition *columns* of sources whose
out-row changed, so the residual of the previous solution against the new
operator is the previous residual plus a sparse seed:

    r_new = r_prev + alpha * sum_{u touched} x[u] (col_new(u) - col_old(u))
            [+ uniform terms when n or the dangling set changes]

`update_ranks` seeds exactly those rows and drains the residual with
Gauss-Southwell/queue pushes (Hong et al., 1501.06350 "D-Iteration"; the
randomized-order convergence is Ishii & Tempo, 1203.6599): popping node u
moves r_u into x_u and diffuses alpha*r_u/deg(u) to its out-neighbors.
Each push shrinks ||r||_1 by at least (1-alpha)|r_u|, so draining every
|r_u| >= eps = (1-alpha)*tol/n certifies ||x - x*||_1 <= tol without ever
touching the untouched part of the graph.  When the frontier exceeds a
fraction of n the batch is no longer local and the updater falls back to a
warm-started `solve_linear`/`solve_power` through `core.backend` (either
backend), then recovers the exact residual with one host-side apply.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional, Tuple

import numpy as np

from ..core.pagerank import solve_linear, solve_power
from .delta import DeltaGraph, EdgeDelta


@dataclasses.dataclass
class RankState:
    """Mutable incremental-solver state: the rank estimate, its exactly
    maintained residual, and the graph version both are consistent with."""

    x: np.ndarray                    # (n,) float64 rank estimate
    r: np.ndarray                    # (n,) float64 residual b + aSx - x
    version: int
    alpha: float
    v: Optional[np.ndarray] = None   # None = uniform teleport

    @property
    def resid_l1(self) -> float:
        return float(np.abs(self.r).sum())

    @property
    def cert(self) -> float:
        """Certified L1 distance to the exact fixed point."""
        return self.resid_l1 / (1.0 - self.alpha)


@dataclasses.dataclass
class UpdateStats:
    path: str                 # "push" | "solve_linear" | "solve_power"
    pushes: int               # frontier pops (work of the push phase)
    nodes_visited: int        # distinct nodes popped
    frontier_peak: int
    seed_l1: float            # ||r||_1 right after seeding
    resid_l1: float           # ||r||_1 on return
    cert: float               # resid_l1 / (1 - alpha)
    solver_iters: int = 0     # fallback iterations (0 on the push path)


def _exact_residual(dg: DeltaGraph, x: np.ndarray, alpha: float,
                    v: Optional[np.ndarray]) -> np.ndarray:
    """r = b + alpha S x - x via one host-side O(nnz) apply (scipy P^T is
    memoized per version on the DeltaGraph)."""
    op = dg.operator(alpha, v=v)
    y = op.apply_linear_numpy(x, pt_sp=dg.scipy_pt())
    return y - x


def _check_cert(resid_l1: float, tol: float, alpha: float,
                where: str) -> None:
    """The certificate is recomputed exactly, so a solver that stalled
    (e.g. bsr_pallas's f32 residual floor ~1e-7 asked for a tighter
    target) cannot silently violate the contract — it warns instead."""
    if resid_l1 > (1.0 - alpha) * tol:
        import warnings
        cert = resid_l1 / (1.0 - alpha)
        warnings.warn(
            f"{where} missed the residual target: certified L1 error "
            f"{cert:.2e} > tol {tol:.2e} (for bsr_pallas ask tol >= ~1e-5, "
            f"or raise solver_max_iters)", RuntimeWarning, stacklevel=3)


def cold_state(dg: DeltaGraph, alpha: float = 0.85,
               v: Optional[np.ndarray] = None, tol: float = 1e-9,
               backend: str = "segment_sum", method: str = "linear",
               max_iters: int = 2000) -> RankState:
    """Full solve on the current graph, returning a certified RankState.

    `tol` is the certified L1 error: the solver is driven to residual
    (1 - alpha) * tol, then the residual is recovered exactly."""
    op = dg.operator(alpha, v=v)
    solver = solve_linear if method == "linear" else solve_power
    # 0.5x headroom: the solver renormalizes on exit, which perturbs the
    # residual by O(resid); the exact recomputation below must still land
    # under (1 - alpha) * tol.
    res = solver(op, tol=0.5 * (1.0 - alpha) * tol, max_iters=max_iters,
                 backend=backend)
    x = np.asarray(res.x, dtype=np.float64)
    r = _exact_residual(dg, x, alpha, v)
    _check_cert(float(np.abs(r).sum()), tol, alpha,
                f"cold_state[{backend}]")
    return RankState(x=x, r=r, version=dg.version, alpha=alpha, v=v)


def refresh_residual(dg: DeltaGraph, state: RankState) -> RankState:
    """Re-derive the residual exactly (drops any accumulated float error
    from long incremental chains)."""
    if state.version != dg.version:
        raise ValueError("state is stale; apply pending deltas through "
                         "update_ranks first")
    state.r = _exact_residual(dg, state.x, state.alpha, state.v)
    return state


# ---------------------------------------------------------------------------
# the push kernel (shared by update_ranks and personalized queries)
# ---------------------------------------------------------------------------
def _push(view, x: np.ndarray, r: np.ndarray, alpha: float,
          l1_target: float, visit_cap: int, max_pushes: int,
          c_holder: Optional[list] = None) -> Tuple[bool, int, int, int]:
    """Gauss-Southwell pushes against `view` (anything with .n and
    .out_neighbors) until ||r||_1 <= l1_target.  Mutates x and r in place.

    ||r||_1 is maintained incrementally (each push adjusts it by the exact
    change on the touched slice) and re-derived at round boundaries, so the
    loop stops the moment the certificate holds instead of draining every
    node to the worst-case per-node threshold.  Rounds sweep a coarse-to-
    fine threshold eps (largest mass first — the Gauss-Southwell order,
    batched); eps bottoms out at l1_target/n, where an empty frontier
    implies ||r||_1 < n * eps = l1_target.

    A push from a dangling node diffuses uniformly (column = e/n).  With
    `c_holder` (a one-element list; uniform-teleport problems only) that
    mass accumulates into the scalar c — the caller resolves c exactly via
    the rescale identity, see update_ranks — keeping the push local.
    Without it the uniform mass is added densely.

    Returns (certified, pushes, distinct_visited, frontier_peak);
    certified=False when a work cap fired first (callers fall back to a
    full solve).
    """
    n = view.n
    l1 = float(np.abs(r).sum())
    eps_floor = l1_target / max(n, 1)
    eps = max(l1 / max(n, 1), eps_floor)
    in_q = np.zeros(n, dtype=bool)
    visited = np.zeros(n, dtype=bool)
    n_visited = 0
    pushes = 0
    peak = 0
    row_cache = {}
    while l1 > l1_target:
        cand = np.flatnonzero(np.abs(r) >= eps)
        if cand.size == 0:
            if eps <= eps_floor:
                break   # all |r_u| < eps_floor  =>  l1 < n*eps_floor
            eps = max(eps / 8.0, eps_floor)
            continue
        q = deque(int(u) for u in cand)
        in_q[:] = False
        in_q[cand] = True
        peak = max(peak, len(q))
        # drain this threshold; the 0.95 margin absorbs incremental-l1
        # float drift (the exact recompute below has the final word)
        while q and l1 > 0.95 * l1_target:
            u = q.popleft()
            in_q[u] = False
            ru = r[u]
            if abs(ru) < eps:
                continue
            pushes += 1
            if not visited[u]:
                visited[u] = True
                n_visited += 1
                if n_visited > visit_cap:
                    return False, pushes, n_visited, peak
            if pushes > max_pushes:
                return False, pushes, n_visited, peak
            x[u] += ru
            r[u] = 0.0
            nbrs = row_cache.get(u)
            if nbrs is None:
                nbrs = view.out_neighbors(u)
                row_cache[u] = nbrs
            d = nbrs.size
            if d == 0:
                if c_holder is not None:
                    # uniform mass goes to the scalar; resolved by rescale
                    c_holder[0] += alpha * ru / n
                    l1 -= abs(ru)
                else:
                    # dangling column = e/n: a dense uniform push, then a
                    # rescan (a uniform shift can lift anything over eps)
                    r += alpha * ru / n
                    l1 = float(np.abs(r).sum())
                    newly = np.flatnonzero((np.abs(r) >= eps) & ~in_q)
                    in_q[newly] = True
                    q.extend(int(w) for w in newly)
            else:
                add = alpha * ru / d
                old = r[nbrs]
                new = old + add
                l1 += float(np.abs(new).sum() - np.abs(old).sum()) - abs(ru)
                r[nbrs] = new
                hot = nbrs[(np.abs(new) >= eps) & ~in_q[nbrs]]
                in_q[hot] = True
                q.extend(int(w) for w in hot)
            if len(q) > peak:
                peak = len(q)
        l1 = float(np.abs(r).sum())   # exact at every round boundary
        if l1 <= l1_target:
            break
        eps = max(eps / 8.0, eps_floor)
    return True, pushes, n_visited, peak


# ---------------------------------------------------------------------------
# the updater
# ---------------------------------------------------------------------------
def update_ranks(dg: DeltaGraph, delta: EdgeDelta, state: RankState, *,
                 tol: float = 1e-8, backend: str = "segment_sum",
                 method: str = "linear", push_frontier_frac: float = 0.10,
                 max_push_factor: float = 20.0,
                 solver_max_iters: int = 1000
                 ) -> Tuple[RankState, UpdateStats]:
    """Apply `delta` to `dg` and bring `state` to a certified solution of
    the mutated graph.

    Small, local deltas take the scalar frontier-push path (sub-linear:
    only rows the residual actually reaches are visited).  When the seeded
    frontier or the visited set exceeds ``push_frontier_frac * n``, the
    batch is global and the updater falls back to a warm-started
    `solve_linear` (or `solve_power`, per ``method``) on the requested
    backend; the exact residual is then recovered with one O(nnz) apply.

    On return ``state.cert <= tol`` (certified ||x - x*||_1) whenever the
    drain or fallback reached its target; a fallback solver that stalls —
    e.g. bsr_pallas's f32 residual floor (~1e-7) asked for a tighter
    target — emits a RuntimeWarning and the true (larger) certificate is
    reported in ``state.cert``/``stats.cert``.  `state` is mutated in
    place and also returned.
    """
    if state.version != dg.version:
        raise ValueError(
            f"state at version {state.version} but graph at {dg.version}; "
            "states must track every delta (or be rebuilt via cold_state)")
    if method not in ("linear", "power"):
        raise ValueError(f"unknown method {method!r}")
    if delta.new_nodes and state.v is not None:
        # checked BEFORE mutating the graph: raising after dg.apply would
        # leave dg permanently ahead of every state tracking it
        raise NotImplementedError(
            "node arrivals with a custom teleport vector are not "
            "supported incrementally; rebuild via cold_state")
    alpha = state.alpha
    rcpt = dg.apply(delta)
    n0, n1 = rcpt.n_old, rcpt.n_new

    # ---- seed ---------------------------------------------------------
    if n1 != n0:
        state.x = np.concatenate([state.x, np.zeros(n1 - n0)])
        state.r = np.concatenate([state.r, np.zeros(n1 - n0)])
    x, r = state.x, state.r

    # Uniform residual components (a shrinking 1/n, uniform dangling
    # columns) would be dense.  For the uniform-teleport problem they fold
    # into a scalar c instead, resolved exactly at the end by the rescale
    # identity: for any x with residual r = r_sparse + c e,
    #     r(x / gamma) = r_sparse / gamma,   gamma = 1 - c n / (1 - alpha)
    # (the teleport term of the residual regenerates exactly -c e under the
    # rescale).  So pushes drain only r_sparse and stay local even for node
    # arrivals and dangling sources.  Custom-teleport states take the dense
    # route (c stays 0).
    uniform = state.v is None
    c = 0.0

    if n1 != n0:
        # teleport b = (1-alpha) e/n changed for every old node and exists
        # for the arrivals; the dangling jump w = e/n of every *untouched*
        # dangling source shrank too.  Touched sources are excluded here —
        # the per-column seeds below use their exact old/new columns.
        # Untouched nodes kept their degree, so the current (post-apply)
        # dangling mask restricted to untouched old nodes is the old one.
        untouched_dangling = dg.dangling_mask[:n0].copy()
        old_touched = rcpt.touched[rcpt.touched < n0]
        untouched_dangling[old_touched] = False
        dm = float(x[:n0][untouched_dangling].sum())
        amp = (1.0 - alpha) + alpha * dm
        shift = (1.0 / n1 - 1.0 / n0)
        # amp*shift on old nodes + amp/n1 on arrivals, decomposed as
        # amp*shift uniformly everywhere + amp*(1/n1 - shift) on arrivals
        c += amp * shift
        r[n0:] += amp * (1.0 / n1 - shift)

    for u, d0, d1, row0, row1 in zip(rcpt.touched, rcpt.old_deg,
                                     rcpt.new_deg, rcpt.old_rows,
                                     rcpt.new_rows):
        xu = x[int(u)]
        if xu == 0.0:
            continue
        if d0 > 0:
            r[row0] -= alpha * xu / d0
        else:
            # old uniform column spans the old nodes only: uniformly
            # -alpha*xu/n0 everywhere, corrected back on the arrivals
            c -= alpha * xu / n0
            r[n0:] += alpha * xu / n0
        if d1 > 0:
            r[row1] += alpha * xu / d1
        else:
            c += alpha * xu / n1

    if not uniform and c != 0.0:
        r += c          # dense fold-in; no rescale identity without e/n
        c = 0.0

    state.version = dg.version
    seed_l1 = float(np.abs(r).sum()) + abs(c) * n1

    # ---- push or fall back -------------------------------------------
    n = n1
    l1_target = (1.0 - alpha) * tol
    visit_cap = max(int(push_frontier_frac * n), 1)
    max_pushes = int(max_push_factor * n)
    # worst-case frontier (count at the floor threshold); if even that is
    # only modestly above the cap, attempting the push is cheap — _push
    # aborts at visit_cap and the partial pushes still warm the fallback
    frontier0 = int(np.count_nonzero(np.abs(r) >= l1_target / max(n, 1)))

    if frontier0 <= 4 * visit_cap:
        holder = [c] if uniform else None
        drained, pushes, visited, peak = _push(
            dg, x, r, alpha, 0.9 * l1_target, visit_cap, max_pushes,
            c_holder=holder)
        if holder is not None:
            c = holder[0]
        gamma = 1.0 - c * n / (1.0 - alpha)
        if drained and abs(1.0 - gamma) < 0.5:
            if c != 0.0:
                # resolve the uniform component exactly (see above)
                np.divide(x, gamma, out=x)
                np.divide(r, gamma, out=r)
            resid = float(np.abs(r).sum())
            if resid <= l1_target:
                return state, UpdateStats(
                    path="push", pushes=pushes, nodes_visited=visited,
                    frontier_peak=peak, seed_l1=seed_l1, resid_l1=resid,
                    cert=resid / (1.0 - alpha))
        elif c != 0.0:
            r += c      # partial push aborted: fold c back before fallback
    else:
        pushes, visited, peak = 0, 0, frontier0

    # ---- warm-started full solve -------------------------------------
    op = dg.operator(alpha, v=state.v)
    solver = solve_linear if method == "linear" else solve_power
    res = solver(op, x0=state.x, tol=0.5 * (1.0 - alpha) * tol,
                 max_iters=solver_max_iters, backend=backend)
    state.x = np.asarray(res.x, dtype=np.float64)
    state.r = _exact_residual(dg, state.x, alpha, state.v)
    resid = state.resid_l1
    _check_cert(resid, tol, alpha, f"solve_{method}[{backend}]")
    return state, UpdateStats(
        path=f"solve_{method}", pushes=pushes, nodes_visited=visited,
        frontier_peak=peak, seed_l1=seed_l1, resid_l1=resid,
        cert=resid / (1.0 - alpha), solver_iters=res.iters)


# ---------------------------------------------------------------------------
# personalized queries (serve-side): approximate PPR by the same pushes
# ---------------------------------------------------------------------------
def ppr_push(view, seeds, weights=None, alpha: float = 0.85,
             tol: float = 1e-4, max_push_factor: float = 200.0
             ) -> Tuple[np.ndarray, float, UpdateStats]:
    """Personalized PageRank with teleport concentrated on `seeds`, solved
    from scratch by residual pushes against a (frozen) graph view — the
    serving-path analogue of `update_ranks` (localized seeds stay local).

    Returns (x, cert, stats) with ||x - x*||_1 <= cert <= tol when the
    push budget sufficed (cert is inf otherwise — the scores are still a
    usable localized approximation, just uncertified).  Serving tolerances
    are intentionally loose: draining single-seed mass by a factor f costs
    about log(f)/log(1/alpha) frontier sweeps, so tol=1e-6-grade answers
    are full solves in disguise — ask `solve_linear` for those.
    """
    n = view.n
    seeds = np.asarray(seeds, dtype=np.int64).ravel()
    if weights is None:
        w = np.full(seeds.size, 1.0 / seeds.size)
    else:
        w = np.asarray(weights, dtype=np.float64).ravel()
        w = w / w.sum()
    x = np.zeros(n)
    r = np.zeros(n)
    np.add.at(r, seeds, (1.0 - alpha) * w)
    drained, pushes, visited, peak = _push(
        view, x, r, alpha, l1_target=(1.0 - alpha) * tol, visit_cap=n,
        max_pushes=int(max_push_factor * n))
    resid = float(np.abs(r).sum())
    cert = resid / (1.0 - alpha)
    if not drained:
        cert = float("inf")
    return x, cert, UpdateStats(
        path="push", pushes=pushes, nodes_visited=visited,
        frontier_peak=peak, seed_l1=1.0 - alpha, resid_l1=resid, cert=cert)
