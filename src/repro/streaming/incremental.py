"""Incremental PageRank: push-based residual diffusion on evolving graphs.

The linear form of the paper (eq. 2) solves (I - alpha S) x = b with
b = (1 - alpha) v and S = P^T + w d^T column-stochastic.  For any iterate x
define the residual

    r = b + alpha S x - x        (so  x* = x + (I - alpha S)^{-1} r).

Since ||S||_1 = 1, the certification bound

    ||x - x*||_1  <=  ||r||_1 / (1 - alpha)                       (cert)

holds unconditionally — every state this module returns carries it.

A graph delta perturbs only the transition *columns* of sources whose
out-row changed, so the residual of the previous solution against the new
operator is the previous residual plus a sparse seed:

    r_new = r_prev + alpha * sum_{u touched} x[u] (col_new(u) - col_old(u))
            [+ uniform terms when n or the dangling set changes]

`update_ranks` seeds exactly those rows and drains the residual with
Gauss-Southwell/queue pushes (Hong et al., 1501.06350 "D-Iteration"; the
randomized-order convergence is Ishii & Tempo, 1203.6599): popping node u
moves r_u into x_u and diffuses alpha*r_u/deg(u) to its out-neighbors.
Each push shrinks ||r||_1 by at least (1-alpha)|r_u|, so draining every
|r_u| >= eps = (1-alpha)*tol/n certifies ||x - x*||_1 <= tol without ever
touching the untouched part of the graph.  When the frontier exceeds a
fraction of n the batch is no longer local and the updater falls back to a
warm-started `solve_linear`/`solve_power` through `core.backend` (either
backend), then recovers the exact residual with one host-side apply.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from ..core.backend import as_lane_tol, seed_stack
from ..core.pagerank import solve_linear, solve_power
from ..runtime.schedule import make_schedule
from .delta import DeltaGraph, EdgeDelta


@dataclasses.dataclass
class RankState:
    """Mutable incremental-solver state: the rank estimate, its exactly
    maintained residual, and the graph version both are consistent with."""

    x: np.ndarray                    # (n,) float64 rank estimate
    r: np.ndarray                    # (n,) float64 residual b + aSx - x
    version: int
    alpha: float
    v: Optional[np.ndarray] = None   # None = uniform teleport

    @property
    def resid_l1(self) -> float:
        return float(np.abs(self.r).sum())

    @property
    def cert(self) -> float:
        """Certified L1 distance to the exact fixed point."""
        return self.resid_l1 / (1.0 - self.alpha)


@dataclasses.dataclass
class UpdateStats:
    path: str                 # "push" | "solve_linear" | "solve_power"
    pushes: int               # frontier pops (work of the push phase)
    nodes_visited: int        # distinct nodes popped
    frontier_peak: int
    seed_l1: float            # ||r||_1 right after seeding
    resid_l1: float           # ||r||_1 on return
    cert: float               # resid_l1 / (1 - alpha)
    solver_iters: int = 0     # fallback iterations (0 on the push path)
    # single-updater push decomposition (mirrors the sharded updater's
    # first/local/boundary attribution; with one shard there is no
    # boundary, so pops split into first visits and sweep re-pushes)
    pushes_first: int = 0     # distinct rows popped (== nodes_visited)
    pushes_repeat: int = 0    # re-pushes from the sweep order


def _exact_residual(dg: DeltaGraph, x: np.ndarray, alpha: float,
                    v: Optional[np.ndarray]) -> np.ndarray:
    """r = b + alpha S x - x via one host-side O(nnz) apply (scipy P^T is
    memoized per version on the DeltaGraph)."""
    op = dg.operator(alpha, v=v)
    y = op.apply_linear_numpy(x, pt_sp=dg.scipy_pt())
    return y - x


def _check_cert(resid_l1: float, tol: float, alpha: float,
                where: str) -> None:
    """The certificate is recomputed exactly, so a solver that stalled
    (e.g. bsr_pallas's f32 residual floor ~1e-7 asked for a tighter
    target) cannot silently violate the contract — it warns instead."""
    if resid_l1 > (1.0 - alpha) * tol:
        import warnings
        cert = resid_l1 / (1.0 - alpha)
        warnings.warn(
            f"{where} missed the residual target: certified L1 error "
            f"{cert:.2e} > tol {tol:.2e} (for bsr_pallas ask tol >= ~1e-5, "
            f"or raise solver_max_iters)", RuntimeWarning, stacklevel=3)


def cold_state(dg: DeltaGraph, alpha: float = 0.85,
               v: Optional[np.ndarray] = None, tol: float = 1e-9,
               backend: str = "segment_sum", method: str = "linear",
               max_iters: int = 2000) -> RankState:
    """Full solve on the current graph, returning a certified RankState.

    `tol` is the certified L1 error: the solver is driven to residual
    (1 - alpha) * tol, then the residual is recovered exactly."""
    op = dg.operator(alpha, v=v)
    solver = solve_linear if method == "linear" else solve_power
    # 0.5x headroom: the solver renormalizes on exit, which perturbs the
    # residual by O(resid); the exact recomputation below must still land
    # under (1 - alpha) * tol.
    res = solver(op, tol=0.5 * (1.0 - alpha) * tol, max_iters=max_iters,
                 backend=backend)
    x = np.asarray(res.x, dtype=np.float64)
    r = _exact_residual(dg, x, alpha, v)
    _check_cert(float(np.abs(r).sum()), tol, alpha,
                f"cold_state[{backend}]")
    return RankState(x=x, r=r, version=dg.version, alpha=alpha, v=v)


def refresh_residual(dg: DeltaGraph, state: RankState) -> RankState:
    """Re-derive the residual exactly (drops any accumulated float error
    from long incremental chains)."""
    if state.version != dg.version:
        raise ValueError("state is stale; apply pending deltas through "
                         "update_ranks first")
    state.r = _exact_residual(dg, state.x, state.alpha, state.v)
    return state


# ---------------------------------------------------------------------------
# the push kernel (shared by update_ranks, ppr_push and the sharded updater)
# ---------------------------------------------------------------------------
def _group_sums(dst: np.ndarray, val: np.ndarray, n: int
                ) -> Tuple[np.ndarray, np.ndarray]:
    """Group duplicate indices of a contribution list: returns ``(uq,
    sums)`` — sorted unique indices and their summed values.  Dense
    `bincount` when the list is a sizable fraction of n, stable
    argsort + `reduceat` otherwise (the grouped-scatter heuristic PR 1
    standardized; shared by `_push` and `sharded._scatter_add`)."""
    if dst.size >= n // 4:
        adds = np.bincount(dst, weights=val, minlength=n)
        uq = np.flatnonzero(adds)
        return uq, adds[uq]
    order = np.argsort(dst, kind="stable")
    ds, vs = dst[order], val[order]
    head = np.ones(ds.size, dtype=bool)
    head[1:] = ds[1:] != ds[:-1]
    uq = ds[head]
    return uq, np.add.reduceat(vs, np.flatnonzero(head))


def _view_arrays(view) -> Tuple[np.ndarray, np.ndarray, int, np.ndarray,
                                np.ndarray, np.ndarray, np.ndarray]:
    """Normalize a graph view (DeltaGraph or FrozenGraphView) to the arrays
    the batched sweep gathers from: (base_indptr, base_indices, base_n,
    dirty_rows, out_deg, dirty_indptr, dirty_indices).  `dirty_rows`
    (sorted) are sources with overlay edits; their merged out-rows are
    materialized *once* here as a packed CSR (`dirty_indptr`/
    `dirty_indices`, indexed by position in `dirty_rows`), so every sweep
    gathers dirty contributions with the same bucketed vector path as
    clean rows — no per-node python merges on the hot path (a 1% delta
    dirties thousands of rows, and the sharded drains re-sweep them every
    exchange generation).  Everything else gathers straight from the base
    CSR."""
    live = hasattr(view, "_base")
    base = view._base if live else view.base
    deg = view._out_deg if live else view.out_deg
    # overlay-free rows appended by node arrivals are dangling (deg 0) and
    # never gathered, so the base CSR covers every clean non-dangling row
    #
    # the dirty-row scan and merge are memoized per (view, version):
    # overlays only change when apply() bumps the version, and compact()
    # folds the overlay without changing any row's value — so repeated
    # drains at one version (and every ppr_push served against one frozen
    # snapshot) pay the python set/merge work once, not per call
    version = view.version
    cached = getattr(view, "_dirty_csr", None)
    if cached is not None and cached[0] == version:
        dirty_rows, dirty_indptr, dirty_indices = cached[1:]
    else:
        if live:                        # live DeltaGraph
            dirty = {u for u, s in view._add.items() if s} \
                | {u for u, s in view._del.items() if s}
        else:                           # FrozenGraphView
            dirty = {u for u, a in view.add.items() if a.size} \
                | {u for u, d in view.dels.items() if d.size}
        dirty_rows = np.fromiter(dirty, np.int64, len(dirty))
        dirty_rows.sort()
        if dirty_rows.size:
            merged = [view.out_neighbors(int(u)) for u in dirty_rows]
            dirty_indptr = np.zeros(dirty_rows.size + 1, dtype=np.int64)
            np.cumsum([m.size for m in merged], out=dirty_indptr[1:])
            dirty_indices = (np.concatenate(merged).astype(np.int64)
                             if dirty_indptr[-1] else np.empty(0, np.int64))
        else:
            dirty_indptr = np.zeros(1, dtype=np.int64)
            dirty_indices = np.empty(0, np.int64)
        # works for the live DeltaGraph and the frozen snapshot dataclass
        object.__setattr__(view, "_dirty_csr",
                           (version, dirty_rows, dirty_indptr,
                            dirty_indices))
    return (base.indptr, base.indices, base.n, dirty_rows, deg,
            dirty_indptr, dirty_indices)


def _frontier_contrib(arrays, frontier: np.ndarray, moved: np.ndarray,
                      alpha: float) -> Tuple[np.ndarray, np.ndarray, float]:
    """Out-neighbor contributions of one batched sweep: every frontier node
    u with out-degree d > 0 sends alpha*moved[u]/d to each out-neighbor —
    one bucketed gather straight from the base CSR for clean rows, and the
    same bucketed gather from the pre-merged dirty CSR (`_view_arrays`)
    for overlay-dirty rows.  Dangling mass is returned as a scalar for the
    caller's uniform-column handling.

    Returns (dst, val, dangling_mass): parallel contribution arrays plus
    the total mass moved out of dangling frontier nodes."""
    indptr, indices, base_n, dirty_rows, deg, d_indptr, d_indices = arrays
    fdeg = deg[frontier]
    dang = fdeg == 0
    clean = ~dang
    if dirty_rows.size:
        slot = np.searchsorted(dirty_rows, frontier)
        is_dirty = (slot < dirty_rows.size) \
            & (dirty_rows[np.minimum(slot, dirty_rows.size - 1)] == frontier)
        clean &= ~is_dirty
        dirty_here = np.flatnonzero(is_dirty & ~dang)
    else:
        slot = None
        dirty_here = np.empty(0, np.int64)

    # clean rows: one bucketed gather straight from the base CSR
    cf = frontier[clean]
    cnt = fdeg[clean]
    starts = indptr[cf]
    total = int(cnt.sum())
    pos = np.repeat(starts - np.concatenate([[0], np.cumsum(cnt)[:-1]]),
                    cnt) + np.arange(total)
    dst = indices[pos].astype(np.int64)
    val = np.repeat(alpha * moved[clean] / np.maximum(cnt, 1), cnt)
    # dirty rows: the same bucketed gather, from the pre-merged dirty CSR
    if dirty_here.size:
        rows = slot[dirty_here]
        cnt_d = d_indptr[rows + 1] - d_indptr[rows]
        starts_d = d_indptr[rows]
        total_d = int(cnt_d.sum())
        pos_d = np.repeat(
            starts_d - np.concatenate([[0], np.cumsum(cnt_d)[:-1]]),
            cnt_d) + np.arange(total_d)
        dst = np.concatenate([dst, d_indices[pos_d]])
        val = np.concatenate([
            val, np.repeat(alpha * moved[dirty_here] / np.maximum(cnt_d, 1),
                           cnt_d)])
    return dst, val, float(moved[dang].sum())


def _push(view, x: np.ndarray, r: np.ndarray, alpha: float,
          l1_target: float, visit_cap: int, max_pushes: int,
          c_holder: Optional[list] = None,
          order=None) -> Tuple[bool, int, int, int]:
    """Gauss-Southwell pushes against `view` (a DeltaGraph or
    FrozenGraphView) until ||r||_1 <= l1_target.  Mutates x and r in place.

    The drain is a *batched frontier sweep*: every node with |r_u| >= eps
    is pushed at once — x[frontier] += r, r[frontier] = 0, and the diffused
    mass alpha*r_u/deg(u) lands on out-neighbors through one bucketed CSR
    gather (clean rows straight from the base CSR arrays; the few
    overlay-dirty rows merged per node) followed by a grouped scatter-add.
    Mass a frontier node receives from its peers in the same sweep is
    pushed in the next sweep (Jacobi-style batching — each push is an exact
    linear transformation, so ordering affects only the schedule, never the
    certificate).  Sweeps run a coarse-to-fine threshold ladder (largest
    mass first — the Gauss-Southwell order, batched; no per-node heap);
    eps bottoms out at l1_target/n, where an empty frontier implies
    ||r||_1 < n * eps = l1_target.

    ||r||_1 is maintained incrementally (each sweep adjusts it by the exact
    change on the touched slice) and re-derived exactly before the loop
    ever reports success, so float drift can shift work but never the
    certificate.

    A push from a dangling node diffuses uniformly (column = e/n).  With
    `c_holder` (a one-element list; uniform-teleport problems only) that
    mass accumulates into the scalar c — the caller resolves c exactly via
    the rescale identity, see update_ranks — keeping the push local.
    Without it the uniform mass is added densely.

    `order` (a `runtime.schedule.DrainOrder` over all n rows) refines each
    sweep's frontier — D-Iteration retention may empty a ladder level (the
    ladder descends; retained fluid waits for the level where it matters)
    but is released at eps_floor, so the empty-at-the-floor certificate
    argument above holds under every schedule.

    Returns (certified, pushes, distinct_visited, frontier_peak);
    certified=False when a work cap fired first (callers fall back to a
    full solve; x and r stay a consistent pair — sweeps are atomic).
    """
    n = view.n
    arrays = _view_arrays(view)
    l1 = float(np.abs(r).sum())
    eps_floor = l1_target / max(n, 1)
    eps = max(l1 / max(n, 1), eps_floor)
    visited = np.zeros(n, dtype=bool)
    n_visited = 0
    pushes = 0
    peak = 0
    cand: Optional[np.ndarray] = None   # None => full rescan at current eps
    if order is not None:
        order.begin_round()
    while True:
        if l1 <= l1_target:
            l1 = float(np.abs(r).sum())      # exact before reporting success
            if l1 <= l1_target:
                break
        if cand is None:
            frontier = np.flatnonzero(np.abs(r) >= eps)
        else:
            frontier = cand[np.abs(r[cand]) >= eps]
        if order is not None and frontier.size:
            frontier = order.refine(np.abs(r[frontier]), frontier, eps,
                                    eps <= eps_floor)
        if frontier.size == 0:
            if cand is not None:
                cand = None                  # level drained: full rescan
                continue
            l1 = float(np.abs(r).sum())
            if l1 <= l1_target or eps <= eps_floor:
                break   # empty at the floor => l1 < n*eps_floor = target
            eps = max(eps / 8.0, eps_floor)
            continue
        peak = max(peak, int(frontier.size))
        # caps are checked at sweep boundaries (sweeps are atomic), so the
        # final sweep may overshoot — same semantics as the scalar drain,
        # which aborted on the (cap+1)-th visit
        if n_visited > visit_cap:
            return False, pushes, n_visited, peak
        if pushes > max_pushes:
            return False, pushes, n_visited, peak
        fresh = frontier[~visited[frontier]]
        visited[fresh] = True
        n_visited += int(fresh.size)
        pushes += int(frontier.size)
        if order is not None:
            order.note_drained(frontier)

        moved = r[frontier].copy()
        x[frontier] += moved
        r[frontier] = 0.0
        l1 -= float(np.abs(moved).sum())

        dst, val, dmass = _frontier_contrib(arrays, frontier, moved, alpha)
        if dst.size:
            uq, sums = _group_sums(dst, val, n)
            old = r[uq]
            new = old + sums
            l1 += float(np.abs(new).sum() - np.abs(old).sum())
            r[uq] = new
            cand = uq          # only touched rows can (re)cross eps
        else:
            cand = np.empty(0, np.int64)

        if dmass != 0.0:
            if c_holder is not None:
                # uniform mass goes to the scalar; resolved by rescale
                c_holder[0] += alpha * dmass / n
            else:
                # dangling column = e/n: a dense uniform push, then a
                # rescan (a uniform shift can lift anything over eps)
                r += alpha * dmass / n
                l1 = float(np.abs(r).sum())
                cand = None
    return True, pushes, n_visited, peak


# ---------------------------------------------------------------------------
# residual seeding (shared by update_ranks and streaming.sharded)
# ---------------------------------------------------------------------------
def _seed_delta(dg: DeltaGraph, rcpt, state: RankState) -> float:
    """Seed ``state.r`` with the exact residual perturbation of one applied
    delta (its receipt), growing x/r on node arrivals.  Returns the uniform
    component c: for uniform-teleport states the dense uniform terms (a
    shrinking 1/n, uniform dangling columns) fold into this scalar — the
    caller resolves it via the rescale identity (see update_ranks) or adds
    it densely (the sharded updater).  Custom-teleport states get every
    dense term folded into r here and c comes back 0.
    """
    alpha = state.alpha
    n0, n1 = rcpt.n_old, rcpt.n_new
    if n1 != n0:
        state.x = np.concatenate([state.x, np.zeros(n1 - n0)])
        state.r = np.concatenate([state.r, np.zeros(n1 - n0)])
    x, r = state.x, state.r
    uniform = state.v is None
    c = 0.0

    if n1 != n0:
        # teleport b = (1-alpha) e/n changed for every old node and exists
        # for the arrivals; the dangling jump w = e/n of every *untouched*
        # dangling source shrank too.  Touched sources are excluded here —
        # the per-column seeds below use their exact old/new columns.
        # Untouched nodes kept their degree, so the current (post-apply)
        # dangling mask restricted to untouched old nodes is the old one.
        untouched_dangling = dg.dangling_mask[:n0].copy()
        old_touched = rcpt.touched[rcpt.touched < n0]
        untouched_dangling[old_touched] = False
        dm = float(x[:n0][untouched_dangling].sum())
        amp = (1.0 - alpha) + alpha * dm
        shift = (1.0 / n1 - 1.0 / n0)
        # amp*shift on old nodes + amp/n1 on arrivals, decomposed as
        # amp*shift uniformly everywhere + amp*(1/n1 - shift) on arrivals
        c += amp * shift
        r[n0:] += amp * (1.0 / n1 - shift)

    for u, d0, d1, row0, row1 in zip(rcpt.touched, rcpt.old_deg,
                                     rcpt.new_deg, rcpt.old_rows,
                                     rcpt.new_rows):
        xu = x[int(u)]
        if xu == 0.0:
            continue
        if d0 > 0:
            r[row0] -= alpha * xu / d0
        else:
            # old uniform column spans the old nodes only: uniformly
            # -alpha*xu/n0 everywhere, corrected back on the arrivals
            c -= alpha * xu / n0
            r[n0:] += alpha * xu / n0
        if d1 > 0:
            r[row1] += alpha * xu / d1
        else:
            c += alpha * xu / n1

    if not uniform and c != 0.0:
        r += c          # dense fold-in; no rescale identity without e/n
        c = 0.0
    state.version = dg.version
    return c


# ---------------------------------------------------------------------------
# the updater
# ---------------------------------------------------------------------------
def update_ranks(dg: DeltaGraph, delta: EdgeDelta, state: RankState, *,
                 tol: float = 1e-8, backend: str = "segment_sum",
                 method: str = "linear", push_frontier_frac: float = 0.25,
                 max_push_factor: float = 20.0,
                 solver_max_iters: int = 1000,
                 schedule=None) -> Tuple[RankState, UpdateStats]:
    """Apply `delta` to `dg` and bring `state` to a certified solution of
    the mutated graph.

    Small, local deltas take the batched frontier-push path (sub-linear:
    only rows the residual actually reaches are visited, and whole
    frontiers are pushed per numpy sweep).  When the seeded frontier or the
    visited set exceeds ``push_frontier_frac * n``, the batch is global and
    the updater falls back to a warm-started `solve_linear` (or
    `solve_power`, per ``method``) on the requested backend; the exact
    residual is then recovered with one O(nnz) apply.  (The vectorized
    sweep moved the push/fallback crossover: ~1e6 pushes/s on a 50k-node
    host graph vs ~1e5 for the old per-node drain, so the default locality
    cap is 0.25 where it used to be 0.10.)

    On return ``state.cert <= tol`` (certified ||x - x*||_1) whenever the
    drain or fallback reached its target; a fallback solver that stalls —
    e.g. bsr_pallas's f32 residual floor (~1e-7) asked for a tighter
    target — emits a RuntimeWarning and the true (larger) certificate is
    reported in ``state.cert``/``stats.cert``.  `state` is mutated in
    place and also returned.

    ``schedule`` (None, a name from `runtime.schedule.SCHEDULES`, or a
    `ScheduleSpec`) selects the drain ordering for the push path —
    ``"priority"`` (D-Iteration fluid retention) and ``"randomized"``
    (seeded Ishii-Tempo subsetting) reorder the ladder's sweeps; the
    boundary-batched rendering is exchange-side and a no-op here.  Every
    schedule certifies identically: the exact residual recompute above is
    schedule-independent.
    """
    if state.version != dg.version:
        raise ValueError(
            f"state at version {state.version} but graph at {dg.version}; "
            "states must track every delta (or be rebuilt via cold_state)")
    if method not in ("linear", "power"):
        raise ValueError(f"unknown method {method!r}")
    if delta.new_nodes and state.v is not None:
        # checked BEFORE mutating the graph: raising after dg.apply would
        # leave dg permanently ahead of every state tracking it
        raise NotImplementedError(
            "node arrivals with a custom teleport vector are not "
            "supported incrementally; rebuild via cold_state")
    alpha = state.alpha
    rcpt = dg.apply(delta)
    n1 = rcpt.n_new

    # ---- seed ---------------------------------------------------------
    # Uniform residual components (a shrinking 1/n, uniform dangling
    # columns) would be dense.  For the uniform-teleport problem they fold
    # into a scalar c instead, resolved exactly at the end by the rescale
    # identity: for any x with residual r = r_sparse + c e,
    #     r(x / gamma) = r_sparse / gamma,   gamma = 1 - c n / (1 - alpha)
    # (the teleport term of the residual regenerates exactly -c e under the
    # rescale).  So pushes drain only r_sparse and stay local even for node
    # arrivals and dangling sources.  Custom-teleport states take the dense
    # route (c stays 0).
    uniform = state.v is None
    c = _seed_delta(dg, rcpt, state)
    x, r = state.x, state.r
    seed_l1 = float(np.abs(r).sum()) + abs(c) * n1

    # ---- push or fall back -------------------------------------------
    n = n1
    l1_target = (1.0 - alpha) * tol
    visit_cap = max(int(push_frontier_frac * n), 1)
    max_pushes = int(max_push_factor * n)
    # worst-case frontier (count at the floor threshold); if even that is
    # only modestly above the cap, attempting the push is cheap — _push
    # aborts at visit_cap and the partial pushes still warm the fallback
    frontier0 = int(np.count_nonzero(np.abs(r) >= l1_target / max(n, 1)))

    if frontier0 <= 4 * visit_cap:
        holder = [c] if uniform else None
        spec = make_schedule(schedule)
        order = (spec.order(n) if spec.drain_kind != "default" else None)
        drained, pushes, visited, peak = _push(
            dg, x, r, alpha, 0.9 * l1_target, visit_cap, max_pushes,
            c_holder=holder, order=order)
        if holder is not None:
            c = holder[0]
        gamma = 1.0 - c * n / (1.0 - alpha)
        if drained and abs(1.0 - gamma) < 0.5:
            if c != 0.0:
                # resolve the uniform component exactly (see above)
                np.divide(x, gamma, out=x)
                np.divide(r, gamma, out=r)
            resid = float(np.abs(r).sum())
            if resid <= l1_target:
                return state, UpdateStats(
                    path="push", pushes=pushes, nodes_visited=visited,
                    frontier_peak=peak, seed_l1=seed_l1, resid_l1=resid,
                    cert=resid / (1.0 - alpha), pushes_first=visited,
                    pushes_repeat=pushes - visited)
        elif c != 0.0:
            r += c      # partial push aborted: fold c back before fallback
    else:
        pushes, visited, peak = 0, 0, frontier0

    # ---- warm-started full solve -------------------------------------
    op = dg.operator(alpha, v=state.v)
    solver = solve_linear if method == "linear" else solve_power
    res = solver(op, x0=state.x, tol=0.5 * (1.0 - alpha) * tol,
                 max_iters=solver_max_iters, backend=backend)
    state.x = np.asarray(res.x, dtype=np.float64)
    state.r = _exact_residual(dg, state.x, alpha, state.v)
    resid = state.resid_l1
    _check_cert(resid, tol, alpha, f"solve_{method}[{backend}]")
    return state, UpdateStats(
        path=f"solve_{method}", pushes=pushes, nodes_visited=visited,
        frontier_peak=peak, seed_l1=seed_l1, resid_l1=resid,
        cert=resid / (1.0 - alpha), solver_iters=res.iters)


# ---------------------------------------------------------------------------
# personalized queries (serve-side): approximate PPR by the same pushes
# ---------------------------------------------------------------------------
def validate_seeds(n: int, seeds, weights=None
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Validate one personalized query's (seeds, weights) against an
    n-node graph and return the canonical pair: seed ids sorted ascending
    with the matching L1-normalized weight for each.

    Raises ValueError for every input that would previously produce a
    silent wrong answer: duplicate seed ids (the old `np.add.at` scatter
    summed them, skewing the teleport), out-of-range ids (negative or
    >= n: garbage pushes or an IndexError deep in the sweep), and
    non-normalizable weights (length mismatch, non-finite entries,
    negative entries, or total mass <= 0 — dividing by that sum yields
    NaN/sign-flipped teleports)."""
    seeds = np.asarray(seeds, dtype=np.int64).ravel()
    if seeds.size == 0:
        raise ValueError("personalized query needs at least one seed")
    if seeds.min() < 0 or seeds.max() >= n:
        raise ValueError(
            f"seed ids must be in [0, {n}); got "
            f"[{seeds.min()}, {seeds.max()}]")
    order = np.argsort(seeds, kind="stable")
    seeds = seeds[order]
    if np.any(seeds[1:] == seeds[:-1]):
        raise ValueError("duplicate seed ids in personalized query; "
                         "merge their weights instead")
    if weights is None:
        return seeds, np.full(seeds.size, 1.0 / seeds.size)
    w = np.asarray(weights, dtype=np.float64).ravel()
    if w.shape != order.shape:
        raise ValueError(f"{w.size} weights for {seeds.size} seeds")
    if not np.all(np.isfinite(w)):
        raise ValueError("seed weights must be finite")
    if np.any(w < 0):
        raise ValueError("seed weights must be >= 0")
    s = w.sum()
    if s <= 0:
        raise ValueError("seed weights are not normalizable (sum <= 0)")
    return seeds, w[order] / s


def ppr_push(view, seeds, weights=None, alpha: float = 0.85,
             tol: float = 1e-4, max_push_factor: float = 200.0
             ) -> Tuple[np.ndarray, float, UpdateStats]:
    """Personalized PageRank with teleport concentrated on `seeds`, solved
    from scratch by residual pushes against a (frozen) graph view — the
    serving-path analogue of `update_ranks` (localized seeds stay local).

    Returns (x, cert, stats) with ||x - x*||_1 <= cert <= tol when the
    push budget sufficed (cert is inf otherwise — the scores are still a
    usable localized approximation, just uncertified).  Serving tolerances
    are intentionally loose: draining single-seed mass by a factor f costs
    about log(f)/log(1/alpha) frontier sweeps, so tol=1e-6-grade answers
    are full solves in disguise — ask `solve_linear` (or the batched
    lane solve `ppr_push_batched`) for those.
    """
    n = view.n
    seeds, w = validate_seeds(n, seeds, weights)
    x = np.zeros(n)
    r = np.zeros(n)
    r[seeds] = (1.0 - alpha) * w
    drained, pushes, visited, peak = _push(
        view, x, r, alpha, l1_target=(1.0 - alpha) * tol, visit_cap=n,
        max_pushes=int(max_push_factor * n))
    resid = float(np.abs(r).sum())
    cert = resid / (1.0 - alpha)
    if not drained:
        cert = float("inf")
    return x, cert, UpdateStats(
        path="push", pushes=pushes, nodes_visited=visited,
        frontier_peak=peak, seed_l1=1.0 - alpha, resid_l1=resid, cert=cert,
        pushes_first=visited, pushes_repeat=pushes - visited)


@dataclasses.dataclass
class BatchedPPRStats:
    """Stats of one fused multi-seed personalized solve."""
    path: str                 # "batched_linear" | "batched_power" |
                              # "batched_host"
    nv: int                   # lanes (queries) in the batch
    iters: int                # fused-loop iterations (max over lanes)
    lane_iters: np.ndarray    # (nv,) per-lane iterations under freezing
    certs: np.ndarray         # (nv,) exact per-lane certificates
    tol: np.ndarray           # (nv,) per-lane requested tolerances


def _host_stack_solve(pt_sp, dangling_idx: np.ndarray, alpha: float,
                      v_stack: np.ndarray, tol_res: np.ndarray,
                      max_iters: int
                      ) -> Tuple[np.ndarray, np.ndarray, int]:
    """Richardson iteration x <- alpha S x + b on an (n, nv) host stack
    through one scipy CSR spmm per step, with per-lane stopping and lane
    compaction (a finished lane's column leaves the spmm).

    This is the CPU fast path for batched personalized solves: a scipy
    spmm over a dense lane stack runs the same nnz*nv multiply-adds as
    the jax segment-sum gather but without materializing the (nnz, nv)
    gather buffer — on a small-core host that buffer is the whole cost.
    Accelerator runs keep the jax lane backends (`backend=` below).
    """
    n, nv = v_stack.shape
    b = (1.0 - alpha) * v_stack
    x = np.full((n, nv), 1.0 / n)
    out = np.empty((n, nv))
    lane_iters = np.zeros(nv, dtype=np.int64)
    active = np.arange(nv)
    it = 0
    while active.size and it < max_iters:
        y = alpha * (pt_sp @ x)
        y += (alpha / n) * x[dangling_idx].sum(axis=0)[None, :]
        y += b[:, active]
        resid = np.abs(y - x).sum(axis=0)
        x = y
        it += 1
        lane_iters[active] += 1
        done = resid <= tol_res[active]
        if done.any():
            out[:, active[done]] = x[:, done]
            x = x[:, ~done]
            active = active[~done]
    if active.size:                      # max_iters hit: flush as-is
        out[:, active] = x
    return out, lane_iters, it


def ppr_push_batched(view, seed_sets, weight_sets=None, *,
                     alpha: float = 0.85, tol=1e-4, op=None, pt_sp=None,
                     backend: str = "auto", method: str = "linear",
                     max_iters: int = 2000,
                     freeze_lanes="auto", freeze_chunk="auto"
                     ) -> Tuple[np.ndarray, np.ndarray, BatchedPPRStats]:
    """Batched personalized PageRank: nv concurrent queries fused into
    multi-vector (n, nv) lanes — one solve over a seed-stacked teleport,
    so every sparse-structure load is amortized across all queries
    instead of each seed paying its own push cascade.

    `tol` may be a scalar or per-query sequence: mixed-tolerance batches
    run as one solve with per-lane thresholds, and finished lanes drop
    out of the iteration (host compaction, or `freeze_lanes`/
    `freeze_chunk` on the jax backends).

    `backend` picks the lane engine: "scipy" iterates the (n, nv) stack
    through host CSR spmms (`_host_stack_solve` — the fast path on
    CPU-only hosts), "segment_sum"/"bsr_pallas" run the fused jit loops
    of `core.backend` (the accelerator paths, where lanes share every
    block load), and "auto" resolves to "scipy" on a CPU jax backend and
    "segment_sum" otherwise.

    `view` is the graph (DeltaGraph, or a FrozenGraphView when `op` — a
    `GoogleOperator` of the *same version* — is supplied, e.g. captured on
    a `RankSnapshot` by the serving tier).  `pt_sp` (host scipy P^T)
    feeds the host path and the exact certification; it is derived from
    `op`/`view` when omitted.

    Returns (X, certs, stats): X is the (n, nv) column-per-query result,
    and each certs[i] = ||x_i - x*_i||_1 bound is recomputed *exactly*
    (one host spmm over all lanes) — never the solver's own residual — so
    the published certificates match `update_ranks`' contract.  A lane
    whose cert misses its tol (e.g. the bsr_pallas f32 floor) warns via
    `_check_cert` and reports the true, larger bound.
    """
    if method not in ("linear", "power"):
        raise ValueError(f"unknown method {method!r}")
    if backend == "auto":
        import jax
        backend = ("scipy" if jax.default_backend() == "cpu"
                   and method == "linear" else "segment_sum")
    if backend == "scipy" and method != "linear":
        raise ValueError("backend='scipy' implements the linear form "
                         "only; use a jax backend for method='power'")
    n = view.n if view is not None else op.n
    seed_sets = list(seed_sets)
    nv = len(seed_sets)
    if weight_sets is not None and len(weight_sets) != nv:
        raise ValueError(f"{len(weight_sets)} weight sets for {nv} "
                         "seed sets")
    pairs = [validate_seeds(n, s, None if weight_sets is None
                            else weight_sets[i])
             for i, s in enumerate(seed_sets)]
    tol_vec = as_lane_tol(tol, nv)

    if op is None:
        if not isinstance(view, DeltaGraph):
            raise ValueError(
                "ppr_push_batched needs op= (a GoogleOperator of the "
                "view's version) when view is not a DeltaGraph — the "
                "serving tier captures it on each RankSnapshot")
        op = view.operator(alpha)
        if pt_sp is None:
            pt_sp = view.scipy_pt()
    if pt_sp is None:
        pt_sp = op.to_scipy_pt()

    from ..graph.google import GoogleOperator
    v_stack = seed_stack(n, [s for s, _ in pairs], [w for _, w in pairs])
    op_b = GoogleOperator(pt=op.pt, alpha=alpha, v=v_stack)
    # same 0.5x headroom convention as cold_state: the exact recompute
    # below must land under (1 - alpha) * tol after solver exit
    tol_res = 0.5 * (1.0 - alpha) * tol_vec
    if backend == "scipy":
        x, lane_iters, iters = _host_stack_solve(
            pt_sp, np.flatnonzero(op.pt.dangling), alpha, v_stack,
            tol_res, max_iters)
        path = "batched_host"
    else:
        solver = solve_linear if method == "linear" else solve_power
        res = solver(op_b, tol=tol_res, max_iters=max_iters,
                     backend=backend, freeze_lanes=freeze_lanes,
                     freeze_chunk=freeze_chunk)
        x = np.asarray(res.x, dtype=np.float64)
        if x.ndim == 1:
            x = x[:, None]
        lane_iters, iters = res.lane_iters, res.iters
        path = f"batched_{method}"
    r = op_b.apply_linear_numpy(x, pt_sp=pt_sp) - x
    resid = np.abs(r).sum(axis=0)
    certs = resid / (1.0 - alpha)
    worst = int(np.argmax(certs / tol_vec))
    _check_cert(float(resid[worst]), float(tol_vec[worst]), alpha,
                f"ppr_push_batched[{backend}] lane {worst}")
    return x, certs, BatchedPPRStats(
        path=path, nv=nv, iters=int(iters),
        lane_iters=np.asarray(lane_iters), certs=certs, tol=tol_vec)
