"""Dynamic web graphs: batched edge/node deltas over a frozen CSR base.

The paper's premise (§1, §6) is that the Web graph is too large and too
alive for synchronized recomputation.  Every solver in this repo consumes an
immutable `CSRGraph`; this module supplies the evolving-graph layer above
it:

  * `EdgeDelta`     — one batch of edge insertions/deletions plus node
                      arrivals (COO arrays, the unit of the crawl stream);
  * `DeltaGraph`    — a `CSRGraph` base plus a COO overlay log of pending
                      deltas.  Out-degrees and the dangling mask are
                      maintained incrementally (O(touched) per batch, never
                      an O(n) recompute), neighbor queries merge the base
                      row with the overlay, and the log is periodically
                      compacted back into a fresh CSR base;
  * `FrozenGraphView` — an immutable point-in-time view (base ref + overlay
                      copy) that query threads can hold while the updater
                      keeps mutating the live graph.

Operator-view consistency and precise cache invalidation
--------------------------------------------------------
`DeltaGraph.operator()` materializes a `GoogleOperator` for the *current*
version and memoizes everything per version:

  * the CSR snapshot, `TransitionT`, and scipy P^T are built at most once
    per version and shared by every view of that version — so repeated
    fallback solves at one version reuse the operator's device/BSR caches
    instead of re-packing (the caches are invalidated when the graph
    actually changes, not wholesale on every call);
  * views that differ only in alpha or teleport share the *same*
    `TransitionT` instance, so its device edge arrays (memoized on the
    transition itself) carry across — a teleport change never invalidates
    edge state;
  * `compact()` folds the overlay into the base without bumping the
    version: the graph value is unchanged, so every memoized snapshot,
    transition and operator cache survives compaction untouched.

Within one `EdgeDelta`, deletions are applied before insertions (an edge
both deleted and inserted in the same batch ends up present).
`merge_deltas` preserves those semantics across a queue of batches by
keeping only the last operation per (src, dst) pair.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional, Sequence, Tuple

import numpy as np

from ..graph.csr import CSRGraph, TransitionT
from ..graph.google import GoogleOperator


def _splice_transition(prev: TransitionT, rcpt: "DeltaReceipt",
                       out_deg: np.ndarray,
                       dangling: np.ndarray) -> TransitionT:
    """Patch P^T from version v-1 to v by row-splicing only the entries of
    touched sources, instead of the O(nnz log nnz) full rebuild.

    P^T is CSR over destinations with sources ascending within each row
    (the canonical order `TransitionT.from_graph` produces).  A source u
    whose out-row changed contributes three edit sets: entries to delete
    ((j, u) for j removed from u's row), entries to insert (j added, weight
    1/new_deg), and surviving entries whose weight must refresh to
    1/new_deg.  All three are O(touched) against the previous arrays —
    membership tests via (row, src) keys, insertion points via one merge
    `searchsorted` on the kept keys — so the whole patch is O(nnz) copies
    with no sort over the full edge list.
    """
    n_new = rcpt.n_new
    indptr = prev.indptr
    if n_new > prev.n:
        indptr = np.concatenate(
            [indptr, np.full(n_new - prev.n, indptr[-1], dtype=np.int64)])

    add_r, add_s, del_r, del_s = [], [], [], []
    for u, row0, row1 in zip(rcpt.touched, rcpt.old_rows, rcpt.new_rows):
        adds = np.setdiff1d(row1, row0, assume_unique=True)
        dels = np.setdiff1d(row0, row1, assume_unique=True)
        add_r.append(adds)
        add_s.append(np.full(adds.size, u, dtype=np.int64))
        del_r.append(dels)
        del_s.append(np.full(dels.size, u, dtype=np.int64))
    add_r = np.concatenate(add_r) if add_r else np.empty(0, np.int64)
    add_s = np.concatenate(add_s) if add_s else np.empty(0, np.int64)
    del_r = np.concatenate(del_r) if del_r else np.empty(0, np.int64)
    del_s = np.concatenate(del_s) if del_s else np.empty(0, np.int64)

    keys = prev.row_ids.astype(np.int64) * n_new + prev.src.astype(np.int64)
    keep = np.ones(prev.nnz, dtype=bool)
    if del_r.size:
        keep &= ~np.isin(keys, del_r * n_new + del_s)
    src_k = prev.src[keep]
    row_k = prev.row_ids[keep]
    w_k = np.asarray(prev.weight[keep], dtype=np.float64).copy()
    # surviving entries of touched sources: refresh to 1/new_deg
    upd = np.isin(src_k, rcpt.touched)
    if upd.any():
        w_k[upd] = 1.0 / out_deg[src_k[upd].astype(np.int64)]

    if add_r.size:
        ins_keys = add_r * n_new + add_s
        order = np.argsort(ins_keys, kind="stable")   # O(touched) only
        ins_keys = ins_keys[order]
        add_r, add_s = add_r[order], add_s[order]
        pos = np.searchsorted(keys[keep], ins_keys)
        src_f = np.insert(src_k, pos, add_s.astype(np.int32))
        row_f = np.insert(row_k, pos, add_r.astype(np.int32))
        w_f = np.insert(w_k, pos, 1.0 / out_deg[add_s])
    else:
        src_f, row_f, w_f = src_k, row_k, w_k

    delta_cnt = (np.bincount(add_r, minlength=n_new)
                 - np.bincount(del_r, minlength=n_new))
    indptr_f = indptr + np.concatenate(
        [[0], np.cumsum(delta_cnt, dtype=np.int64)])
    return TransitionT(n=n_new, indptr=indptr_f, src=src_f, weight=w_f,
                       row_ids=row_f, dangling=dangling)


def _as_ids(a) -> np.ndarray:
    arr = np.asarray(a, dtype=np.int64).ravel()
    if arr.size and arr.min() < 0:
        raise ValueError("negative node id in delta")
    return arr


@dataclasses.dataclass(frozen=True)
class EdgeDelta:
    """One batch of graph mutations in COO form.

    `new_nodes` appends that many fresh ids to the id space *before* the
    edge arrays are applied, so edges may reference the arriving nodes.
    """

    add_src: np.ndarray
    add_dst: np.ndarray
    del_src: np.ndarray
    del_dst: np.ndarray
    new_nodes: int = 0

    @staticmethod
    def empty(new_nodes: int = 0) -> "EdgeDelta":
        z = np.empty(0, dtype=np.int64)
        return EdgeDelta(z, z, z, z, new_nodes=new_nodes)

    @staticmethod
    def inserts(src, dst, new_nodes: int = 0) -> "EdgeDelta":
        z = np.empty(0, dtype=np.int64)
        return EdgeDelta(_as_ids(src), _as_ids(dst), z, z,
                         new_nodes=new_nodes)

    @staticmethod
    def deletes(src, dst) -> "EdgeDelta":
        z = np.empty(0, dtype=np.int64)
        return EdgeDelta(z, z, _as_ids(src), _as_ids(dst))

    @property
    def size(self) -> int:
        return int(self.add_src.size + self.del_src.size)

    def __post_init__(self):
        if self.add_src.size != self.add_dst.size:
            raise ValueError("add_src/add_dst length mismatch")
        if self.del_src.size != self.del_dst.size:
            raise ValueError("del_src/del_dst length mismatch")
        if self.new_nodes < 0:
            raise ValueError("new_nodes must be >= 0")


def merge_deltas(deltas: Sequence[EdgeDelta]) -> EdgeDelta:
    """Collapse a queue of batches into one equivalent batch.

    Sequential semantics are preserved by keeping, per (src, dst) pair, only
    the *last* operation in the flattened [del_0, add_0, del_1, add_1, ...]
    sequence (within each batch deletions precede insertions).
    """
    deltas = list(deltas)
    if not deltas:
        return EdgeDelta.empty()
    if len(deltas) == 1:
        return deltas[0]
    srcs, dsts, ops = [], [], []  # op 0 = delete, 1 = insert
    for d in deltas:
        srcs += [d.del_src, d.add_src]
        dsts += [d.del_dst, d.add_dst]
        ops += [np.zeros(d.del_src.size, np.int8),
                np.ones(d.add_src.size, np.int8)]
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    op = np.concatenate(ops)
    n_hint = int(max(src.max(initial=-1), dst.max(initial=-1))) + 1
    key = src * max(n_hint, 1) + dst
    # stable sort by key; the last occurrence within each key group wins
    order = np.argsort(key, kind="stable")
    key_s = key[order]
    last = np.ones(key_s.size, dtype=bool)
    last[:-1] = key_s[:-1] != key_s[1:]
    pick = order[last]
    keep_op = op[pick]
    return EdgeDelta(
        add_src=src[pick][keep_op == 1], add_dst=dst[pick][keep_op == 1],
        del_src=src[pick][keep_op == 0], del_dst=dst[pick][keep_op == 0],
        new_nodes=int(sum(d.new_nodes for d in deltas)),
    )


@dataclasses.dataclass(frozen=True)
class DeltaReceipt:
    """What one `DeltaGraph.apply()` actually changed — the exact inputs the
    incremental solver needs to seed residuals (old vs new out-rows of every
    source whose transition column changed)."""

    version: int                 # graph version AFTER the apply
    n_old: int
    n_new: int
    touched: np.ndarray          # (t,) sources whose out-row changed
    old_deg: np.ndarray          # (t,) out-degree before
    new_deg: np.ndarray          # (t,) out-degree after
    old_rows: Tuple[np.ndarray, ...]   # out-neighbors before, per touched
    new_rows: Tuple[np.ndarray, ...]   # out-neighbors after, per touched
    n_added: int                 # effective insertions (no-ops excluded)
    n_deleted: int               # effective deletions (no-ops excluded)

    @property
    def dangling_changed(self) -> bool:
        return bool(np.any((self.old_deg == 0) != (self.new_deg == 0))) \
            or self.n_new != self.n_old


class DeltaGraph:
    """A `CSRGraph` plus a COO overlay of pending edge mutations.

    The overlay is a per-source pair of sets (`_add`, `_del`) kept disjoint
    from each other and consistent with the base row:

        row(u) = (base_row(u) \\ _del[u]) ∪ _add[u]

    `apply()` routes each mutation to the right set (re-inserting an
    overlay-deleted edge just clears the tombstone, deleting an
    overlay-added edge just drops it), so no-op mutations never inflate the
    log.  Once the log exceeds ``compact_frac`` of the base nnz the overlay
    is folded into a fresh CSR base (`compact()`), which preserves the
    version and therefore every per-version memoized operator view.
    """

    def __init__(self, base: CSRGraph, compact_frac: float = 0.25):
        self._base = base
        self.n = base.n
        self.compact_frac = float(compact_frac)
        self._add: Dict[int, set] = {}
        self._del: Dict[int, set] = {}
        self._out_deg = base.out_degree.copy()
        self._log_edges = 0
        self.version = 0
        self._last_receipt: Optional[DeltaReceipt] = None
        # per-version memoized views: version -> object
        self._snap: Dict[int, CSRGraph] = {0: base}
        self._pt: Dict[int, TransitionT] = {}
        self._pt_sp: Dict[int, object] = {}
        self._ops: Dict[Tuple[int, float], GoogleOperator] = {}

    # ------------------------------------------------------------------
    # graph-shaped read API
    # ------------------------------------------------------------------
    @property
    def nnz(self) -> int:
        return int(self._out_deg.sum())

    @property
    def out_degree(self) -> np.ndarray:
        """Incrementally-maintained out-degrees (view; do not mutate)."""
        return self._out_deg

    @property
    def dangling_mask(self) -> np.ndarray:
        return self._out_deg == 0

    def _base_row(self, u: int) -> np.ndarray:
        if u >= self._base.n:
            return np.empty(0, dtype=np.int64)
        s, e = self._base.indptr[u], self._base.indptr[u + 1]
        return self._base.indices[s:e].astype(np.int64)

    def out_neighbors(self, u: int) -> np.ndarray:
        """Current out-row of `u`: base row minus tombstones plus overlay
        additions, sorted. O(base_deg(u) + overlay(u))."""
        row = self._base_row(u)
        dels = self._del.get(u)
        if dels:
            row = row[~np.isin(row, np.fromiter(dels, np.int64, len(dels)))]
        adds = self._add.get(u)
        if adds:
            row = np.concatenate(
                [row, np.fromiter(adds, np.int64, len(adds))])
            row.sort()
        return row

    def _in_base_row(self, u: int, j: int) -> bool:
        if u >= self._base.n:
            return False
        s, e = self._base.indptr[u], self._base.indptr[u + 1]
        k = np.searchsorted(self._base.indices[s:e], j)
        return bool(k < e - s and self._base.indices[s + k] == j)

    def has_edge(self, u: int, j: int) -> bool:
        adds = self._add.get(u)
        if adds and j in adds:
            return True
        dels = self._del.get(u)
        if dels and j in dels:
            return False
        return self._in_base_row(u, j)

    # ------------------------------------------------------------------
    # mutation
    # ------------------------------------------------------------------
    def apply(self, delta: EdgeDelta) -> DeltaReceipt:
        """Apply one batch (deletions first, then insertions). Returns the
        receipt the incremental solver seeds residuals from."""
        n_old = self.n
        n_new = n_old + delta.new_nodes
        hi = int(max(delta.add_src.max(initial=-1),
                     delta.add_dst.max(initial=-1),
                     delta.del_src.max(initial=-1),
                     delta.del_dst.max(initial=-1)))
        if hi >= n_new:
            raise ValueError(f"delta references node {hi} but the graph has "
                             f"only {n_new} nodes after arrivals")
        if delta.new_nodes:
            self.n = n_new
            self._out_deg = np.concatenate(
                [self._out_deg, np.zeros(delta.new_nodes, np.int64)])

        cand = np.unique(np.concatenate([delta.del_src, delta.add_src])) \
            if delta.size else np.empty(0, np.int64)
        old_rows = {int(u): self.out_neighbors(int(u)) for u in cand}

        n_deleted = 0
        for u, j in zip(delta.del_src, delta.del_dst):
            u, j = int(u), int(j)
            adds = self._add.get(u)
            if adds is not None and j in adds:
                adds.discard(j)
                self._log_edges -= 1
                n_deleted += 1
            elif self._in_base_row(u, j) and j not in self._del.get(u, ()):
                self._del.setdefault(u, set()).add(j)
                self._log_edges += 1
                n_deleted += 1

        n_added = 0
        for u, j in zip(delta.add_src, delta.add_dst):
            u, j = int(u), int(j)
            dels = self._del.get(u)
            if dels is not None and j in dels:
                dels.discard(j)
                self._log_edges -= 1
                n_added += 1
            elif not self._in_base_row(u, j) and \
                    j not in self._add.get(u, ()):
                self._add.setdefault(u, set()).add(j)
                self._log_edges += 1
                n_added += 1

        touched, o_deg, n_deg, o_rows, n_rows = [], [], [], [], []
        for u in cand:
            u = int(u)
            new_row = self.out_neighbors(u)
            old_row = old_rows[u]
            if new_row.size == old_row.size and \
                    np.array_equal(new_row, old_row):
                continue
            touched.append(u)
            o_deg.append(old_row.size)
            n_deg.append(new_row.size)
            o_rows.append(old_row)
            n_rows.append(new_row)
            self._out_deg[u] = new_row.size

        self.version += 1
        rcpt = DeltaReceipt(
            version=self.version, n_old=n_old, n_new=n_new,
            touched=np.asarray(touched, dtype=np.int64),
            old_deg=np.asarray(o_deg, dtype=np.int64),
            new_deg=np.asarray(n_deg, dtype=np.int64),
            old_rows=tuple(o_rows), new_rows=tuple(n_rows),
            n_added=n_added, n_deleted=n_deleted,
        )
        self._last_receipt = rcpt   # feeds the P^T row-splice (transition)
        if self._log_edges > self.compact_frac * max(self._base.nnz, 1):
            self.compact()
        self._gc_views()
        return rcpt

    def compact(self) -> None:
        """Fold the overlay into a fresh CSR base. The graph value is
        unchanged, so the version — and every per-version memoized
        operator view — is preserved."""
        if not self._add and not self._del and self.n == self._base.n:
            return
        self._base = self.graph()
        self._add.clear()
        self._del.clear()
        self._log_edges = 0

    def _gc_views(self, keep: int = 2) -> None:
        """Drop memoized views older than the last `keep` versions (their
        device/BSR caches go with them)."""
        floor = self.version - keep
        for d in (self._snap, self._pt, self._pt_sp):
            for k in [k for k in d if k < floor]:
                del d[k]
        for k in [k for k in self._ops if k[0] < floor]:
            del self._ops[k]

    # ------------------------------------------------------------------
    # materialized views (memoized per version)
    # ------------------------------------------------------------------
    def graph(self) -> CSRGraph:
        """Immutable CSR snapshot of the current version."""
        g = self._snap.get(self.version)
        if g is not None:
            return g
        keep = np.ones(self._base.nnz, dtype=bool)
        for u, dels in self._del.items():
            if not dels:
                continue
            s, e = self._base.indptr[u], self._base.indptr[u + 1]
            keep[s:e] &= ~np.isin(
                self._base.indices[s:e],
                np.fromiter(dels, np.int64, len(dels)))
        src_b = np.repeat(np.arange(self._base.n, dtype=np.int64),
                          np.diff(self._base.indptr))[keep]
        dst_b = self._base.indices[keep].astype(np.int64)
        add_s, add_d = [], []
        for u, adds in self._add.items():
            if adds:
                add_s.append(np.full(len(adds), u, np.int64))
                add_d.append(np.fromiter(adds, np.int64, len(adds)))
        src = np.concatenate([src_b] + add_s) if add_s else src_b
        dst = np.concatenate([dst_b] + add_d) if add_d else dst_b
        g = CSRGraph.from_edges(self.n, src, dst)
        self._snap[self.version] = g
        return g

    def transition(self) -> TransitionT:
        """P^T of the current version (shared by every operator view of
        this version, so device edge arrays upload once).

        When the previous version's P^T is memoized and the last receipt is
        one step behind, the new transition is *row-spliced* from it
        (O(touched) edits + O(nnz) copies) instead of rebuilt with the full
        O(nnz log nnz) destination sort.  Keys stay per-version, and
        `compact()` never bumps the version, so the splice inputs — the
        previous P^T and the receipt, neither of which references the base
        CSR — survive compaction unchanged."""
        pt = self._pt.get(self.version)
        if pt is None:
            pt = self._patched_transition()
            if pt is None:
                pt = TransitionT.from_graph(self.graph())
            self._pt[self.version] = pt
        return pt

    def _patched_transition(self) -> Optional[TransitionT]:
        """Row-splice P^T from version-1 when cheap; None => full rebuild."""
        rcpt = self._last_receipt
        prev = self._pt.get(self.version - 1)
        if rcpt is None or prev is None or rcpt.version != self.version:
            return None
        if rcpt.touched.size == 0 and rcpt.n_new == prev.n:
            return prev          # value-identical: share the instance (and
            #                      its memoized device edge arrays)
        edits = int(sum(r.size for r in rcpt.old_rows)
                    + sum(r.size for r in rcpt.new_rows))
        if edits > 0.25 * max(prev.nnz, 1):
            return None          # batch too global: the rebuild is cheaper
        return _splice_transition(prev, rcpt, self._out_deg,
                                  self.dangling_mask)

    def scipy_pt(self):
        """scipy CSR of P^T for host-side exact residuals, per version."""
        m = self._pt_sp.get(self.version)
        if m is None:
            m = self.transition().to_scipy()
            self._pt_sp[self.version] = m
        return m

    def operator(self, alpha: float = 0.85,
                 v: Optional[np.ndarray] = None) -> GoogleOperator:
        """GoogleOperator view of the current version.

        The uniform-teleport view is memoized per (version, alpha) — its
        device/BSR caches persist across every fallback solve at this
        version.  Personalized views are built fresh but share this
        version's `TransitionT`, so the edge device arrays still carry.
        """
        if v is not None:
            return GoogleOperator(pt=self.transition(), alpha=alpha, v=v)
        key = (self.version, float(alpha))
        op = self._ops.get(key)
        if op is None:
            op = GoogleOperator(pt=self.transition(), alpha=alpha)
            self._ops[key] = op
        return op

    def freeze(self) -> "FrozenGraphView":
        """Immutable point-in-time view for concurrent readers (copies only
        the overlay and the degree array, never the base CSR)."""
        return FrozenGraphView(
            base=self._base, n=self.n,
            add={u: np.fromiter(s, np.int64, len(s))
                 for u, s in self._add.items() if s},
            dels={u: np.fromiter(s, np.int64, len(s))
                  for u, s in self._del.items() if s},
            out_deg=self._out_deg.copy(),
            version=self.version,
        )


@dataclasses.dataclass(frozen=True)
class FrozenGraphView:
    """Read-only (base + overlay copy) view; safe to query from any thread
    while the live `DeltaGraph` keeps mutating."""

    base: CSRGraph
    n: int
    add: Dict[int, np.ndarray]
    dels: Dict[int, np.ndarray]
    out_deg: np.ndarray
    version: int

    @property
    def dangling_mask(self) -> np.ndarray:
        return self.out_deg == 0

    def out_neighbors(self, u: int) -> np.ndarray:
        if u < self.base.n:
            s, e = self.base.indptr[u], self.base.indptr[u + 1]
            row = self.base.indices[s:e].astype(np.int64)
        else:
            row = np.empty(0, dtype=np.int64)
        d = self.dels.get(u)
        if d is not None:
            row = row[~np.isin(row, d)]
        a = self.add.get(u)
        if a is not None:
            row = np.concatenate([row, a])
            row.sort()
        return row
