"""CI gate: the device shard transport's acceptance contract (PR 9).

    python benchmarks/check_device_transport.py [BENCH_PR9.json] [--live]

Default mode reads the ``async_shard.device`` rows of the given
perf-trajectory file (default BENCH_PR9.json at the repo root) — the 50k
power-law 1%-delta workload drained by ``transport="device"`` — and
gates:

  * both throughput rows are present (p=1 and p=4);
  * every row drained in-loop (``path == "sharded_push"``, no solver
    fallback) and its published host-side certificate holds
    (``cert <= tol``);
  * the recorded exchange bytes reproduce *exactly* from the row's own
    (supersteps, rows_sent, fulls) counters through
    ``runtime.step.comm_bytes_model`` — the one accounting model the
    SPMD solver and the device transport share.  A mismatch means the
    traced counters and the host-side model drifted apart.

``--live`` additionally runs a fresh in-process p=4 device drain on a
seeded 5k workload and applies the same gates to it.  The live pass
needs 4 jax devices, so run it under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the dedicated
CI step does); without enough devices it fails loudly rather than
skipping.

Exit codes: 0 pass, 1 fail, 2 usage/missing section.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).parent.parent
sys.path.insert(0, str(REPO_ROOT / "src"))


def _check_rows(rows, tol, *, n, label):
    from repro.runtime import comm_bytes_model

    ok = True
    for row in rows:
        p = row["p"]
        tag = f"{label} p={p}"
        row_ok = True
        if row["path"] != "sharded_push":
            row_ok = False
            print(f"FAIL path: {tag} fell back to {row['path']}")
        if row["cert"] > tol:
            row_ok = False
            print(f"FAIL cert: {tag} cert={row['cert']:.2e} > "
                  f"tol={tol:.0e}")
        bsize = -(-n // p)
        model = comm_bytes_model(
            "sparsified", p=p, bsize=bsize, itemsize=8, nv=1,
            steps=row["supersteps"], rows=row["rows_sent"],
            fulls=row["fulls"])
        if row["bytes_moved"] != model:
            row_ok = False
            print(f"FAIL bytes: {tag} recorded {row['bytes_moved']} != "
                  f"model {model} (rows={row['rows_sent']}, "
                  f"fulls={row['fulls']}, steps={row['supersteps']})")
        if row_ok:
            print(f"OK   {tag}: {row['s']}s steps={row['supersteps']} "
                  f"cert={row['cert']:.1e} bytes={row['bytes_moved']}")
        ok = ok and row_ok
    return ok


def _live_gate() -> bool:
    """A fresh p=4 drain under the forced-device CI step: the in-loop
    criterion must certify on this host, not just in the committed
    BENCH rows."""
    import time

    import numpy as np

    import jax
    if len(jax.devices()) < 4:
        print(f"FAIL live: need 4 devices, have {len(jax.devices())}; "
              f"run under XLA_FLAGS="
              f"--xla_force_host_platform_device_count=4")
        return False

    from repro.graph.generate import powerlaw_webgraph
    from repro.streaming import (DeltaGraph, EdgeDelta, cold_state,
                                 update_ranks_sharded)

    tol = 1e-8
    n = 5000
    g = powerlaw_webgraph(n=n, target_nnz=40_000, n_dangling=50, seed=3)
    dg = DeltaGraph(g)
    st = cold_state(dg, tol=tol)
    rng = np.random.default_rng(7)
    delta = EdgeDelta.inserts(rng.integers(0, n, 200),
                              rng.integers(0, n, 200))
    t0 = time.perf_counter()
    st, stats = update_ranks_sharded(dg, delta, st, p=4, tol=tol,
                                     mode="async", transport="device")
    row = dict(mode="async", p=4, transport="device",
               s=round(time.perf_counter() - t0, 3), path=stats.path,
               supersteps=int(stats.supersteps),
               rows_sent=int(stats.rows_sent), fulls=int(stats.fulls),
               bytes_moved=int(stats.bytes_moved), cert=float(stats.cert))
    return _check_rows([row], tol, n=n, label="live(5k)")


def main() -> int:
    argv = [a for a in sys.argv[1:] if a != "--live"]
    live = "--live" in sys.argv[1:]
    target = Path(argv[0]) if argv else REPO_ROOT / "BENCH_PR9.json"
    if not target.is_absolute():
        target = REPO_ROOT / target
    if not target.exists():
        print(f"device transport gate: {target.name} not found")
        return 2
    rec = json.loads(target.read_text())
    arec = rec.get("async_shard", {})
    rows = arec.get("device")
    if not rows:
        print(f"device transport gate: no async_shard.device rows in "
              f"{target.name}")
        return 2

    ok = True
    tol = arec.get("device_tol", 1e-8)
    for p in (1, 4):
        if not any(r["p"] == p for r in rows):
            ok = False
            print(f"FAIL rows: no device row at p={p} in {target.name}")
    ok = _check_rows(rows, tol, n=50_000, label=target.name) and ok
    if live:
        ok = _live_gate() and ok

    if not ok:
        print("device transport failed its acceptance gates — see "
              "docs/runtime.md 'Transports' and runtime/device.py for "
              "the drain/exchange knobs")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
