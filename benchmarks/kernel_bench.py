"""Kernel benches (CPU container: correctness + arithmetic-intensity
derivations; wall-times are for the jnp reference paths — TPU numbers come
from the roofline analysis, not from this box)."""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp

from repro.graph.generate import powerlaw_webgraph
from repro.graph.csr import TransitionT, pt_matvec
from repro.kernels.bsr_spmv import bsr_from_transition, pad_x, spmv, \
    bsr_spmv_ref

RESULTS = Path(__file__).parent / "results"


def _time(f, n=5):
    f()  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        r = f()
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / n


def spmv_bench(n=16384, nnz=131072, nv=8):
    g = powerlaw_webgraph(n=n, target_nnz=nnz, n_dangling=16, seed=4)
    pt = TransitionT.from_graph(g)
    bsr = bsr_from_transition(pt)
    dev = {k: jnp.asarray(v) for k, v in pt.device_arrays().items()}
    x = np.random.default_rng(0).random((n, nv)).astype(np.float32)
    xp = jnp.asarray(pad_x(x, n, bsr.bn))
    xf = jnp.asarray(x[:, 0])

    t_csr = _time(jax.jit(lambda: pt_matvec(dev, xf, n)))
    t_ref = _time(jax.jit(lambda: bsr_spmv_ref(*bsr.device(), xp)))

    # derived: bytes and flops per multi-vector SpMV
    flops = 2.0 * g.nnz * nv
    blk_bytes = bsr.blocks.nbytes + bsr.blk_cols.nbytes
    csr_bytes = g.nnz * (4 + 4 + 4)
    rec = dict(
        n=n, nnz=g.nnz, nv=nv, K=bsr.K, nbr=bsr.nbr,
        fill_ratio=bsr.fill_ratio,
        csr_matvec_us=t_csr * 1e6, bsr_ref_multivec_us=t_ref * 1e6,
        flops_multivec=flops,
        bsr_bytes=blk_bytes, csr_bytes=csr_bytes,
        bsr_arith_intensity=flops / blk_bytes,
        csr_arith_intensity=(2.0 * g.nnz) / csr_bytes,
    )
    print(f"  spmv n={n} nnz={g.nnz}: csr(1v)={t_csr*1e6:.0f}us "
          f"bsr-ref({nv}v)={t_ref*1e6:.0f}us "
          f"AI: bsr={rec['bsr_arith_intensity']:.3f} "
          f"csr={rec['csr_arith_intensity']:.3f} flop/B "
          f"(fill={bsr.fill_ratio:.4f}, K={bsr.K})")
    RESULTS.mkdir(exist_ok=True, parents=True)
    (RESULTS / "kernel_spmv.json").write_text(json.dumps(rec, indent=1))
    return rec


def flash_bench(B=1, H=8, S=1024, D=64):
    from repro.models.attention import flash_attn_jnp
    from repro.kernels.flash_attention import mha_ref
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, H, S, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, H, S, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, H, S, D)), jnp.float32)
    t_flash = _time(jax.jit(lambda: flash_attn_jnp(q, k, v, chunk_q=256,
                                                   chunk_k=256)))
    t_naive = _time(jax.jit(lambda: mha_ref(q, k, v)))
    flops = 4.0 * B * H * S * S * D
    rec = dict(B=B, H=H, S=S, D=D, flash_us=t_flash * 1e6,
               naive_us=t_naive * 1e6, flops=flops,
               naive_score_bytes=B * H * S * S * 4,
               flash_score_bytes=B * H * 256 * 256 * 4)
    print(f"  attn S={S}: flash={t_flash*1e6:.0f}us naive={t_naive*1e6:.0f}us"
          f" score-mem {rec['flash_score_bytes']/rec['naive_score_bytes']:.4f}x")
    (RESULTS / "kernel_attention.json").write_text(json.dumps(rec, indent=1))
    return rec


def main():
    print("[kernel] bsr spmv")
    spmv_bench()
    print("[kernel] flash attention (jnp path)")
    flash_bench()


if __name__ == "__main__":
    main()
