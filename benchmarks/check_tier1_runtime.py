"""CI gate: fail when the tier-1 suite runtime exceeds 1.25x the PR2
baseline.

    python benchmarks/check_tier1_runtime.py <measured_seconds_file_or_value>

The baseline lives in benchmarks/results/tier1_runtime_baseline.json
(seconds measured on the PR2 tree in the reference container).  Because
absolute runtimes differ across machines, the env var TIER1_BASELINE_S
overrides the stored baseline — CI jobs on faster/slower runners should
calibrate once and pin it in the workflow.
"""
from __future__ import annotations

import json
import os
import sys
from pathlib import Path

BASELINE_FILE = Path(__file__).parent / "results" / \
    "tier1_runtime_baseline.json"
MAX_RATIO = 1.25


def main() -> int:
    if len(sys.argv) != 2:
        print(__doc__)
        return 2
    arg = sys.argv[1]
    measured = float(Path(arg).read_text().strip()
                     if os.path.exists(arg) else arg)

    env = os.environ.get("TIER1_BASELINE_S")
    if env:
        baseline = float(env)
        source = "TIER1_BASELINE_S"
    else:
        rec = json.loads(BASELINE_FILE.read_text())
        baseline = float(rec["tier1_seconds"])
        source = f"{BASELINE_FILE.name} ({rec.get('measured_at', '?')})"

    limit = MAX_RATIO * baseline
    ratio = measured / baseline if baseline > 0 else float("inf")
    verdict = "OK" if measured <= limit else "FAIL"
    print(f"tier-1 runtime: {measured:.0f}s vs baseline {baseline:.0f}s "
          f"[{source}] -> {ratio:.2f}x (limit {MAX_RATIO}x) {verdict}")
    if measured > limit:
        print("tier-1 suite slowed beyond the budget — profile the new "
              "tests or raise the baseline deliberately in "
              f"{BASELINE_FILE}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
