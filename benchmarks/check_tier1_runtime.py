"""CI gate: fail when the tier-1 suite runtime exceeds 1.25x the baseline.

    python benchmarks/check_tier1_runtime.py <measured_seconds_file_or_value>

Baseline resolution order (first hit wins):

  1. env var TIER1_BASELINE_S — CI runners differ in speed; jobs calibrate
     once and pin it in the workflow;
  2. the BEST (minimum) `tier1_seconds` recorded in the last two
     BENCH_PR<N>.json perf-trajectory files at the repo root (benchmarks/
     run.py --tier1-seconds embeds it) — so the gate *tightens as the
     repo gets faster* instead of drifting against the frozen PR2
     snapshot forever;
  3. the stored PR2 snapshot
     (benchmarks/results/tier1_runtime_baseline.json).
"""
from __future__ import annotations

import json
import os
import re
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).parent.parent
BASELINE_FILE = Path(__file__).parent / "results" / \
    "tier1_runtime_baseline.json"
MAX_RATIO = 1.25


def _bench_pr_baseline():
    """Best tier1_seconds of the two most recent BENCH_PR<N>.json files
    (files without the field — PRs 1-4 predate it — are skipped)."""
    recs = []
    for f in REPO_ROOT.glob("BENCH_PR*.json"):
        m = re.fullmatch(r"BENCH_PR(\d+)\.json", f.name)
        if not m:
            continue
        try:
            rec = json.loads(f.read_text())
        except (OSError, json.JSONDecodeError):
            continue
        secs = rec.get("tier1_seconds")
        if secs is not None and float(secs) > 0:
            recs.append((int(m.group(1)), float(secs), f.name))
    if not recs:
        return None
    recs.sort()
    last_two = recs[-2:]
    best = min(last_two, key=lambda t: t[1])
    return best[1], "min(tier1_seconds of %s)" % ", ".join(
        name for _, _, name in last_two)


def main() -> int:
    if len(sys.argv) != 2:
        print(__doc__)
        return 2
    arg = sys.argv[1]
    measured = float(Path(arg).read_text().strip()
                     if os.path.exists(arg) else arg)

    env = os.environ.get("TIER1_BASELINE_S")
    if env:
        baseline = float(env)
        source = "TIER1_BASELINE_S"
    else:
        found = _bench_pr_baseline()
        if found is not None:
            baseline, source = found
        else:
            rec = json.loads(BASELINE_FILE.read_text())
            baseline = float(rec["tier1_seconds"])
            source = f"{BASELINE_FILE.name} ({rec.get('measured_at', '?')})"

    limit = MAX_RATIO * baseline
    ratio = measured / baseline if baseline > 0 else float("inf")
    verdict = "OK" if measured <= limit else "FAIL"
    print(f"tier-1 runtime: {measured:.0f}s vs baseline {baseline:.0f}s "
          f"[{source}] -> {ratio:.2f}x (limit {MAX_RATIO}x) {verdict}")
    if measured > limit:
        print("tier-1 suite slowed beyond the budget — profile the new "
              "tests or raise the baseline deliberately (env "
              "TIER1_BASELINE_S, or the tier1_seconds fields the gate "
              "reads)")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
