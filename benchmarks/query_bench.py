"""Query-tier benchmark: batched PPR throughput + closed-loop load gen.

Two sections, both on the 50k acceptance graph:

  batched   — sequential per-seed `ppr_push` loop vs `ppr_push_batched`
              at batch sizes 4/16/32 (same tol, exact certification on
              every lane).  The gated number is the throughput ratio at
              batch >= 16.
  load      — closed-loop mixed traffic (top_k / scores / personalized)
              from concurrent client threads against a live RankServer
              whose daemon updater keeps applying 1%%-delta batches.
              Queries route through the full serving tier: QueryRouter
              read-replicas with staleness-bounded reads (top_k/scores),
              QueryBatcher + PPRCache behind personalized().  Reports
              p50/p99 latency per kind, queries/s-under-update, updater
              progress, and the staleness/cert invariants the gate
              checks (no router reject, every sampled snapshot cert
              certified, every PPR answer within tol).

Run: PYTHONPATH=src python -m benchmarks.query_bench
"""
from __future__ import annotations

import json
import threading
import time
from pathlib import Path

import numpy as np

from repro.graph.generate import powerlaw_webgraph
from repro.serving import attach_query_tier
from repro.serving.router import QueryRouter
from repro.streaming import (DeltaGraph, EdgeDelta, RankServer, ppr_push,
                             ppr_push_batched)

RESULTS = Path(__file__).parent / "results"
N, NNZ = 50_000, 400_000
ALPHA, QTOL = 0.85, 1e-4


def _graph(seed: int = 3):
    return powerlaw_webgraph(n=N, target_nnz=NNZ, n_dangling=50, seed=seed)


def _seed_sets(rng, count: int, n: int = N):
    return [rng.choice(n, size=int(rng.integers(1, 4)), replace=False)
            for _ in range(count)]


def batched_ppr(dg: DeltaGraph, seq_sample: int = 8,
                batches=(4, 16, 32)) -> dict:
    """Sequential per-seed loop vs the fused lane solve, same tol."""
    view = dg.freeze()
    op = dg.operator(ALPHA)
    pt = dg.scipy_pt()
    rng = np.random.default_rng(5)
    sets = _seed_sets(rng, max(batches))

    t0 = time.perf_counter()
    for s in sets[:seq_sample]:
        _, cert, _ = ppr_push(view, s, alpha=ALPHA, tol=QTOL)
        assert np.isfinite(cert)
    seq_per_q = (time.perf_counter() - t0) / seq_sample

    rows = []
    for nv in batches:
        ppr_push_batched(dg, sets[:nv], alpha=ALPHA, tol=QTOL,
                         op=op, pt_sp=pt)          # warm the path
        t0 = time.perf_counter()
        _, certs, stats = ppr_push_batched(dg, sets[:nv], alpha=ALPHA,
                                           tol=QTOL, op=op, pt_sp=pt)
        tb = time.perf_counter() - t0
        rows.append(dict(
            batch=nv, s=tb, ms_per_query=tb / nv * 1e3,
            speedup_vs_sequential=seq_per_q * nv / tb,
            path=stats.path, iters=int(stats.iters),
            certs_ok=bool(np.all(certs <= QTOL)),
            max_cert=float(certs.max())))
        print(f"  [query] batch={nv:3d} {tb:.2f}s "
              f"({tb / nv * 1e3:.0f} ms/q) "
              f"{rows[-1]['speedup_vs_sequential']:.2f}x vs sequential "
              f"[{stats.path}]")
    return dict(tol=QTOL, sequential_ms_per_query=seq_per_q * 1e3,
                sweep=rows,
                speedup_at_16=next(r["speedup_vs_sequential"]
                                   for r in rows if r["batch"] >= 16))


def _pct(a, q):
    return float(np.percentile(np.asarray(a), q)) if len(a) else float("nan")


def load_gen(dg: DeltaGraph, duration_s: float = 8.0, clients: int = 3,
             delta_frac: float = 0.01, server_tol: float = 1e-5) -> dict:
    """Closed-loop clients vs a live updater, through the full tier."""
    srv = RankServer(dg, alpha=ALPHA, tol=server_tol)
    batcher, cache, router = attach_query_tier(
        srv, max_batch=16, max_delay_s=0.005, cache_capacity=64,
        replicas=2, max_version_lag=2, on_stale="redirect")
    rng = np.random.default_rng(11)
    # a finite seed-set pool + skewed popularity so the cache sees repeats
    pool = _seed_sets(rng, 32, dg.n)
    pop = (1.0 / np.arange(1, 33)) ** 1.1
    pop /= pop.sum()

    stop = threading.Event()
    errors: list = []
    lat = {k: [] for k in ("top_k", "scores", "ppr")}
    bad_cert = [0]
    max_snap_cert = [0.0]
    lock = threading.Lock()

    def client(cid: int):
        crng = np.random.default_rng(100 + cid)
        my = {k: [] for k in lat}
        try:
            while not stop.is_set():
                u = crng.random()
                t0 = time.perf_counter()
                if u < 0.55:
                    ids, scores = router.top_k(int(crng.integers(1, 100)))
                    assert np.all(np.diff(scores) <= 0)
                    my["top_k"].append(time.perf_counter() - t0)
                elif u < 0.85:
                    vals = router.scores(crng.integers(0, dg.n, 8))
                    assert np.isfinite(vals).all()
                    my["scores"].append(time.perf_counter() - t0)
                else:
                    s = pool[int(crng.choice(32, p=pop))]
                    x, cert, _ = srv.personalized(s, tol=1e-3)
                    my["ppr"].append(time.perf_counter() - t0)
                    if not (np.isfinite(cert) and cert <= 1e-3):
                        with lock:
                            bad_cert[0] += 1
                snap = srv.snapshot()
                with lock:
                    max_snap_cert[0] = max(max_snap_cert[0],
                                           float(snap.cert))
        except BaseException as exc:
            errors.append(exc)
            stop.set()
        finally:
            with lock:
                for k in lat:
                    lat[k].extend(my[k])

    srv.start(poll_s=0.002)
    threads = [threading.Thread(target=client, args=(i,), daemon=True)
               for i in range(clients)]
    t_start = time.perf_counter()
    for t in threads:
        t.start()
    k_delta = max(1, int(delta_frac * dg.graph().nnz))
    g = dg.graph()
    deltas_sent = 0
    try:
        while time.perf_counter() - t_start < duration_s \
                and not stop.is_set():
            src = rng.integers(0, dg.n, k_delta)
            dst = g.indices[rng.integers(0, g.nnz, k_delta)].astype(
                np.int64)
            srv.ingest(EdgeDelta(np.asarray(src, np.int64), dst,
                                 np.empty(0, np.int64),
                                 np.empty(0, np.int64)))
            deltas_sent += 1
            time.sleep(1.0)
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=30)
        elapsed = time.perf_counter() - t_start
        srv.stop()
        batcher.stop()
    if errors:
        raise errors[0]

    total = sum(len(v) for v in lat.values())
    rec = dict(
        duration_s=elapsed, clients=clients,
        delta_edges_per_batch=k_delta, delta_batches_sent=deltas_sent,
        qps_under_update=total / elapsed,
        queries=dict((k, len(v)) for k, v in lat.items()),
        latency_ms=dict(
            (k, dict(p50=_pct(v, 50) * 1e3, p99=_pct(v, 99) * 1e3))
            for k, v in lat.items()),
        updater=dict(batches_applied=int(srv.batches_applied),
                     fallbacks=int(srv.fallbacks),
                     final_version=int(dg.version)),
        served_cert_ok=bool(max_snap_cert[0] <= server_tol * 1.01),
        max_served_cert=max_snap_cert[0],
        ppr_cert_violations=int(bad_cert[0]),
        router=router.stats(),
        batcher=batcher.stats(),
        cache=cache.stats())
    print(f"  [query] {total} queries in {elapsed:.1f}s "
          f"({rec['qps_under_update']:.0f} qps) while "
          f"{rec['updater']['batches_applied']} delta batches applied; "
          f"top_k p50/p99 = {rec['latency_ms']['top_k']['p50']:.1f}/"
          f"{rec['latency_ms']['top_k']['p99']:.1f} ms, "
          f"ppr p50 = {rec['latency_ms']['ppr']['p50']:.1f} ms, "
          f"cache hits = {rec['cache']['hits']}")
    return rec


def main() -> dict:
    print("  [query] building 50k graph ...")
    dg = DeltaGraph(_graph())
    print("  [query] batched PPR vs sequential ...")
    brec = batched_ppr(dg)
    print("  [query] closed-loop load gen (update-while-serve) ...")
    lrec = load_gen(dg)
    rec = dict(batched=brec, load=lrec)
    RESULTS.mkdir(exist_ok=True)
    (RESULTS / "query_bench.json").write_text(json.dumps(rec, indent=1))
    return rec


if __name__ == "__main__":
    main()
