"""Streaming-update benchmark: us-per-delta-batch and frontier size as a
function of delta size on a 50k-node power-law graph, plus the replay
scenario's freshness-vs-throughput summary.

The interesting curve is the push/fallback crossover: tiny deltas should be
orders of magnitude cheaper than a cold solve (visiting a small fraction of
the graph), while large deltas degrade gracefully into the warm-started
backend solver.
"""
from __future__ import annotations

import time

import numpy as np

from repro.graph.generate import powerlaw_webgraph
from repro.streaming import (DeltaGraph, EdgeDelta, ReplayConfig, cold_state,
                             replay_trace, synth_edge_trace, update_ranks)

N, NNZ = 50_000, 400_000
DELTA_SIZES = (1, 8, 64, 512, 4096)


def _random_delta(dg: DeltaGraph, k: int, rng) -> EdgeDelta:
    """k-edge batch: 85% inserts (uniform src, popularity-biased dst),
    15% deletes of existing edges."""
    g = dg.graph()
    n_del = k * 15 // 100
    n_add = k - n_del
    a_src = rng.integers(0, dg.n, size=n_add)
    a_dst = g.indices[rng.integers(0, g.nnz, size=n_add)].astype(np.int64)
    if n_del:
        slots = rng.choice(g.nnz, size=n_del, replace=False)
        src_of_edge = np.repeat(np.arange(g.n, dtype=np.int64),
                                np.diff(g.indptr))
        d_src, d_dst = src_of_edge[slots], g.indices[slots].astype(np.int64)
    else:
        d_src = d_dst = np.empty(0, np.int64)
    return EdgeDelta(add_src=np.asarray(a_src, np.int64), add_dst=a_dst,
                     del_src=d_src, del_dst=d_dst)


def delta_sweep(tol: float = 1e-5, seed: int = 4, repeats: int = 3):
    """us per delta batch + push-frontier stats vs batch size."""
    g = powerlaw_webgraph(n=N, target_nnz=NNZ, n_dangling=50, seed=seed)
    dg = DeltaGraph(g)
    rng = np.random.default_rng(seed)
    t0 = time.perf_counter()
    state = cold_state(dg, tol=tol)
    cold_s = time.perf_counter() - t0
    rows = []
    for k in DELTA_SIZES:
        times, stats_list = [], []
        for _ in range(repeats):
            d = _random_delta(dg, k, rng)
            t0 = time.perf_counter()
            state, stats = update_ranks(dg, d, state, tol=tol)
            times.append(time.perf_counter() - t0)
            stats_list.append(stats)
        med = float(np.median(times))
        s = stats_list[np.argsort(times)[len(times) // 2]]
        rec = dict(
            delta_edges=k, us_per_batch=med * 1e6,
            us_per_edge=med * 1e6 / k, path=s.path, pushes=s.pushes,
            nodes_visited=s.nodes_visited,
            visited_frac=s.nodes_visited / dg.n,
            frontier_peak=s.frontier_peak, cert=s.cert,
            speedup_vs_cold=cold_s / med,
        )
        rows.append(rec)
        print(f"  delta={k:5d} edges: {med * 1e3:8.1f} ms/batch "
              f"[{s.path:12s}] visited={s.nodes_visited:6d} "
              f"({100 * rec['visited_frac']:5.2f}%) "
              f"frontier_peak={s.frontier_peak:6d} "
              f"{rec['speedup_vs_cold']:6.1f}x vs cold")
    return dict(n=N, nnz=NNZ, tol=tol, cold_solve_s=cold_s, sweep=rows)


def replay_bench(n_batches: int = 24, batch_edges: int = 2,
                 seed: int = 5):
    """Freshness-vs-throughput under the DES replay clock (Table-2 mirror:
    fresh-serve percentages instead of completed-import percentages).
    Small batches keep the updater on the push path — the regime the
    update-while-serve design targets; the delta sweep above maps where
    that regime ends."""
    g = powerlaw_webgraph(n=N, target_nnz=NNZ, n_dangling=50, seed=seed)
    dg = DeltaGraph(g)
    state = cold_state(dg, tol=1e-5)
    trace = synth_edge_trace(dg, n_batches=n_batches,
                             batch_edges=batch_edges, seed=seed)
    cfg = ReplayConfig(query_rate=500.0, delta_interval=0.25, tol=1e-5,
                       seed=seed)
    t0 = time.perf_counter()
    res = replay_trace(dg, state, trace, cfg)
    wall = time.perf_counter() - t0
    push_batches = sum(1 for r in res.rows if r.path == "push")
    rec = dict(
        n=N, batches=n_batches, batch_edges=batch_edges,
        fresh_pct=res.fresh_pct, mean_age_s=res.mean_age_s,
        p95_age_s=res.p95_age_s, mean_lag_batches=res.mean_lag_batches,
        busy_frac=res.busy_frac, us_per_delta_edge=res.us_per_delta_edge,
        deltas_per_s=res.deltas_per_s, push_batches=push_batches,
        wall_s=wall,
    )
    print(f"  replay: fresh={res.fresh_pct:.1f}% "
          f"mean_age={res.mean_age_s * 1e3:.0f}ms "
          f"p95={res.p95_age_s * 1e3:.0f}ms busy={res.busy_frac:.2f} "
          f"{res.deltas_per_s:.1f} deltas/s "
          f"({push_batches}/{n_batches} push-path)")
    return rec


def main():
    print("  [streaming] delta sweep ...")
    sweep = delta_sweep()
    print("  [streaming] replay ...")
    replay = replay_bench()
    return dict(bench="streaming incremental updates (PR 2)",
                delta_sweep=sweep, replay=replay)


if __name__ == "__main__":
    main()
