"""§Roofline report: reads the cached dry-run JSONs and emits the full
(arch x shape x mesh) table with the three terms, dominant bottleneck, and
MODEL_FLOPS/HLO_FLOPs utilization ratio."""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import ARCH_NAMES, get_config
from repro.launch.specs import SHAPES
from repro.analysis.flops import model_flops_cell, active_params

RESULTS = Path(__file__).parent / "results"
DRYRUN = RESULTS / "dryrun"


def load_cells(mesh="16x16"):
    rows = []
    for arch in ARCH_NAMES:
        for shape in SHAPES:
            f = DRYRUN / f"{arch}_{shape}_{mesh}.json"
            if not f.exists():
                continue
            rec = json.loads(f.read_text())
            rows.append(rec)
    return rows


def report(mesh="16x16", out_name="roofline_table.md"):
    rows = load_cells(mesh)
    lines = [
        f"### Roofline — mesh {mesh} (v5e: 197 TF/s bf16, 819 GB/s HBM, "
        "50 GB/s/link ICI)",
        "",
        "| arch | shape | compute s | memory s | collective s | bound | "
        "MODEL_FLOPS/HLO | note |",
        "|---|---|---|---|---|---|---|---|",
    ]
    table = []
    for rec in rows:
        arch, shape = rec["arch"], rec["shape"]
        if rec["status"] == "skipped":
            lines.append(f"| {arch} | {shape} | — | — | — | — | — | "
                         f"skipped: sub-quadratic-only shape |")
            continue
        if rec["status"] != "ok":
            lines.append(f"| {arch} | {shape} | — | — | — | — | — | "
                         f"ERROR {rec.get('error', '')[:60]} |")
            continue
        roof = rec["roofline"]
        mf = model_flops_cell(get_config(arch), SHAPES[shape])
        ratio = mf / max(roof["flops"], 1.0)
        util = roof["flops"] and mf / roof["flops"]
        lines.append(
            f"| {arch} | {shape} | {roof['compute_s']:.4f} | "
            f"{roof['memory_s']:.4f} | {roof['collective_s']:.4f} | "
            f"{roof['dominant']} | {ratio:.2f} | |")
        table.append(dict(arch=arch, shape=shape, **roof,
                          model_flops=mf, useful_ratio=ratio))
    md = "\n".join(lines)
    (RESULTS / out_name).write_text(md)
    (RESULTS / out_name.replace(".md", ".json")).write_text(
        json.dumps(table, indent=1))
    return md, table


def main():
    for mesh in ("16x16", "2x16x16"):
        md, table = report(mesh, f"roofline_{mesh}.md")
        n = len(table)
        dom = {}
        for r in table:
            dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
        print(f"[roofline] mesh {mesh}: {n} cells, dominance {dom}")
        worst = sorted(table, key=lambda r: -max(
            r["memory_s"], r["collective_s"], r["compute_s"]))[:3]
        for w in worst:
            print(f"  slowest: {w['arch']} {w['shape']} "
                  f"bound={w['dominant']}")


if __name__ == "__main__":
    main()
