"""Matvec-backend benchmark: us-per-apply for segment_sum vs bsr_pallas at
several graph sizes, the host-side BSR packing micro-bench (bincount scatter
vs the old np.add.at scatter), and a solver-level rank-agreement record.

Writes the machine-readable perf trajectory file (BENCH_PR<N>.json at the
repo root, consumed by CI / later PRs to track the hot path over time);
benchmarks.run passes the current PR's path via ``--out``.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp

from repro.graph.generate import powerlaw_webgraph
from repro.graph.csr import TransitionT
from repro.graph.google import GoogleOperator
from repro.core import solve_power, kendall_tau_topk
from repro.core.backend import as_spec, prepare, google_apply
from repro.kernels.bsr_spmv import build_hybrid_bsr

REPO_ROOT = Path(__file__).parent.parent
RESULTS = Path(__file__).parent / "results"

SIZES = ((5_000, 40_000), (16_384, 131_072), (50_000, 400_000))


def _time(f, n=10):
    jax.block_until_ready(f())  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        r = f()
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / n


def apply_bench(sizes=SIZES, nv=1, seed=4):
    """Fused Google-apply wall time per backend (jitted, device-resident)."""
    rows = []
    for n, nnz in sizes:
        g = powerlaw_webgraph(n=n, target_nnz=nnz,
                              n_dangling=max(4, n // 1000), seed=seed)
        op = GoogleOperator(pt=TransitionT.from_graph(g), alpha=0.85)
        rec = dict(n=n, nnz=g.nnz, nv=nv)
        for name in ("segment_sum", "bsr_pallas"):
            spec = as_spec(name)
            dev, meta, x0 = prepare(op, spec, dtype=jnp.float32,
                                    v=np.tile(op.teleport()[:, None],
                                              (1, nv)))
            from functools import partial

            @partial(jax.jit, static_argnames=())
            def step(dev, x, _meta=meta):
                return google_apply(_meta, dev, x, False)

            t = _time(lambda: step(dev, x0))
            rec[f"{name}_us_per_apply"] = t * 1e6
            if name == "bsr_pallas":
                hyb = op.hybrid_bsr(bm=spec.bm, bn=spec.bm,
                                    hub_quantile=spec.hub_quantile)
                rec.update(bsr_impl=spec.impl, bsr_bm=spec.bm,
                           bsr_K=hyb.bsr.K,
                           bsr_fill_ratio=hyb.bsr.fill_ratio,
                           hub_nnz_frac=hyb.hub_nnz_frac)
        print(f"  apply n={n:6d} nnz={g.nnz:7d}: "
              f"segment_sum={rec['segment_sum_us_per_apply']:.0f}us "
              f"bsr_pallas[{rec['bsr_impl']}]="
              f"{rec['bsr_pallas_us_per_apply']:.0f}us "
              f"(K={rec['bsr_K']}, fill={rec['bsr_fill_ratio']:.4f}, "
              f"hub={rec['hub_nnz_frac']:.2%})")
        rows.append(rec)
    return rows


_PACK_CHILD = """
import numpy as np, time
from repro.graph.generate import powerlaw_webgraph
from repro.graph.csr import TransitionT
from repro.kernels.bsr_spmv import build_bsr, build_hybrid_bsr
g = powerlaw_webgraph(n={n}, target_nnz={nnz}, n_dangling=16, seed={seed})
pt = TransitionT.from_graph(g)
rows = pt.row_ids.astype(np.int64); cols = pt.src.astype(np.int64)
vals = np.asarray(pt.weight, np.float32)
t0 = time.perf_counter()
if "{mode}" == "seed":
    # the seed path verbatim: fixed-K layout at the kernel default block
    # size, np.add.at scatter, no hub split
    build_bsr(rows, cols, vals, pt.n, pt.n, bm=128, bn=128,
              scatter="add_at")
else:
    build_hybrid_bsr(rows, cols, vals, pt.n, pt.n, bm={bm}, bn={bm},
                     hub_quantile=0.99, unique_pairs=True)
print((time.perf_counter() - t0) * 1e3)
"""


def _pack_cold(mode, n, nnz, bm, seed):
    """One cold packing run in a fresh process (packing happens once per
    operator and is then cached, so cold is the scenario that matters —
    in-process repeats inherit warm pages and measure something else)."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO_ROOT / "src")
    code = _PACK_CHILD.format(mode=mode, n=n, nnz=nnz, bm=bm, seed=seed)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr
    return float(out.stdout.strip().splitlines()[-1])


def packing_bench(n=32_768, nnz=262_144, bm=0, seed=4, repeats=3):
    """Host-side BSR packing: the solve-grade recipe (hub split + raveled
    bincount/assignment scatter + CPU-sized blocks) vs the seed recipe
    (fixed-K 128x128 layout + np.add.at), one cold build per process.

    n defaults to the largest size the seed recipe can pack at all — at the
    acceptance scale (50k) its dense-block array would need ~10 GB and it
    raises MemoryError, which is recorded alongside.
    """
    if bm == 0:
        from repro.core.backend import as_spec
        bm = as_spec("bsr_pallas").bm
    med = lambda xs: float(np.median(xs))
    t_seed = med([_pack_cold("seed", n, nnz, bm, seed)
                  for _ in range(repeats)])
    t_new = med([_pack_cold("new", n, nnz, bm, seed)
                 for _ in range(repeats)])

    # acceptance scale (50k): the seed path's fixed-K layout needs ~10 GB
    # here — its guard fires before allocation — while the solve-grade
    # recipe packs the same graph in a fraction of a second. Any finite
    # time is "at least 5x faster" than a pack that cannot run.
    n50, nnz50 = 50_000, 400_000
    g = powerlaw_webgraph(n=n50, target_nnz=nnz50, n_dangling=50, seed=3)
    pt = TransitionT.from_graph(g)
    try:
        from repro.kernels.bsr_spmv import build_bsr
        build_bsr(pt.row_ids.astype(np.int64), pt.src.astype(np.int64),
                  np.asarray(pt.weight, np.float32), pt.n, pt.n,
                  bm=128, bn=128, scatter="add_at")
        seed_at_50k = "ok"
    except MemoryError as e:
        seed_at_50k = f"MemoryError: {e}"
    t_new_50k = med([_pack_cold("new", n50, nnz50, bm, 3)
                     for _ in range(repeats)])

    rec = dict(
        acceptance_scale=dict(
            n=n50, nnz=nnz50, solve_grade_cold_ms=t_new_50k,
            seed_add_at_path=seed_at_50k,
            speedup="unbounded (seed np.add.at path cannot pack this "
                    "graph; >5x by any reading)"),
        largest_seed_packable=dict(
            n=n, nnz=nnz, bm=bm,
            seed_add_at_cold_ms=t_seed,
            solve_grade_cold_ms=t_new,
            speedup=t_seed / t_new),
        note=("cold one-shot builds, median of fresh processes; packing is "
              "memoized on GoogleOperator so it runs once per operator. "
              "numpy>=1.24 already vectorized ufunc.at, so the same-layout "
              "scatter swap alone is ~2-3x; the big win is the solve-grade "
              "layout (hub split + CPU-sized blocks) that keeps packing "
              "linear where the seed layout grows quadratically and OOMs."))
    print(f"  packing n={n}: seed(add_at,128)={t_seed:.0f}ms "
          f"solve-grade(bincount,{bm})={t_new:.0f}ms "
          f"({t_seed / t_new:.1f}x); n=50k: new={t_new_50k:.0f}ms, "
          f"seed: {seed_at_50k.splitlines()[0]}")
    return rec


def solver_bench(n=50_000, nnz=400_000, seed=3):
    """Solver-level check: both backends end to end, rank agreement."""
    g = powerlaw_webgraph(n=n, target_nnz=nnz, n_dangling=50, seed=seed)
    op = GoogleOperator(pt=TransitionT.from_graph(g), alpha=0.85)
    t0 = time.perf_counter()
    ref = solve_power(op, tol=1e-9, max_iters=500)
    t_ref = time.perf_counter() - t0
    t0 = time.perf_counter()
    bsr = solve_power(op, tol=1e-6, max_iters=300, backend="bsr_pallas")
    t_bsr = time.perf_counter() - t0
    tau = kendall_tau_topk(ref.x, bsr.x, k=100)
    rec = dict(n=n, nnz=g.nnz, segment_sum_iters=ref.iters,
               segment_sum_s=t_ref, bsr_pallas_iters=bsr.iters,
               bsr_pallas_s=t_bsr, kendall_tau_top100=tau)
    print(f"  solver n={n}: segsum {ref.iters}it/{t_ref:.1f}s "
          f"bsr {bsr.iters}it/{t_bsr:.1f}s tau100={tau:.5f}")
    return rec


def main(out_path: Path = REPO_ROOT / "BENCH_PR2.json"):
    rec = dict(
        bench="matvec backends",
        device=jax.default_backend(),
        note=("us_per_apply is the fused Google-apply (SpMV + dangling + "
              "teleport) per backend; on CPU bsr_pallas lowers to the "
              "blocked-einsum contraction, on TPU to the Pallas MXU "
              "kernel"),
        apply=apply_bench(),
        packing=packing_bench(),
        solver=solver_bench(),
    )
    out_path.write_text(json.dumps(rec, indent=1))
    RESULTS.mkdir(exist_ok=True, parents=True)
    (RESULTS / "backend_bench.json").write_text(json.dumps(rec, indent=1))
    print(f"  wrote {out_path}")
    return rec


if __name__ == "__main__":
    main()
