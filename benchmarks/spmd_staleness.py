"""Beyond-paper: TPU-native bounded-staleness schedules (core.spmd) —
supersteps-to-convergence vs per-step collective bytes, run on 8 forced
host devices in a subprocess (the bench process keeps 1 device)."""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

RESULTS = Path(__file__).parent / "results"

_CODE = r"""
import json
import numpy as np
from repro.graph.generate import powerlaw_webgraph
from repro.graph.csr import TransitionT
from repro.graph.google import GoogleOperator, exact_pagerank
from repro.core import SPMDConfig, solve_spmd

g = powerlaw_webgraph(n=16384, target_nnz=131072, n_dangling=32, seed=2)
op = GoogleOperator(pt=TransitionT.from_graph(g), alpha=0.85)
xref = exact_pagerank(op, tol=1e-13)
rows = []
for sched, kw in [("allgather", {}),
                  ("allgather_k", dict(sync_every=2)),
                  ("allgather_k", dict(sync_every=4)),
                  ("allgather_k", dict(sync_every=8)),
                  ("ring", {}),
                  ("ring", dict(delivery_prob=0.7))]:
    cfg = SPMDConfig(p=8, schedule=sched, tol=1e-8, dtype="float32",
                     max_supersteps=5000, **kw)
    r = solve_spmd(op, cfg)
    err = float(np.abs(r.x - xref).max())
    total = r.comm_bytes_per_step * r.supersteps
    rows.append(dict(schedule=sched, **kw, supersteps=r.supersteps,
                     err=err, bytes_per_step=r.comm_bytes_per_step,
                     total_comm_bytes=total))
print(json.dumps(rows))
"""


def main():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(Path(__file__).parents[1] / "src")
    out = subprocess.run([sys.executable, "-c", _CODE], env=env,
                         capture_output=True, text=True, timeout=1800)
    assert out.returncode == 0, out.stderr[-2000:]
    rows = json.loads(out.stdout.strip().splitlines()[-1])
    RESULTS.mkdir(exist_ok=True, parents=True)
    (RESULTS / "spmd_staleness.json").write_text(json.dumps(rows, indent=1))
    base = next(r for r in rows if r["schedule"] == "allgather")
    for r in rows:
        rel = r["total_comm_bytes"] / base["total_comm_bytes"]
        print(f"  {r['schedule']:12s} {str(r.get('sync_every', '')):3s} "
              f"q={r.get('delivery_prob', 1.0):<4} steps={r['supersteps']:4d} "
              f"err={r['err']:.1e} bytes/step={r['bytes_per_step']:>9d} "
              f"total={r['total_comm_bytes']:>12d} ({rel:.2f}x baseline)")
    return rows


if __name__ == "__main__":
    main()
