"""BSR layout study (paper §6 future work: permutations, cf. [11]).

Quantifies block fill / K-budget / arithmetic intensity for the TPU SpMV
under orderings (natural site-local, RCM, degree-sort), block sizes, and
hub-row splitting — the data behind EXPERIMENTS.md §Kernels' design rule:

  * web SpMV is HBM-bound at any layout (AI << v5e ridge);
  * the gather/segment-sum form is the right single-vector path;
  * BSR + hub-split + 32x32 + multi-vector (personalization) is the only
    compute-dense regime.
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from repro.graph.generate import powerlaw_webgraph
from repro.graph.csr import TransitionT
from repro.graph.reorder import (rcm_permutation, degree_sort_permutation,
                                 apply_permutation)

RESULTS = Path(__file__).parent / "results"


def layout_stats(pt: TransitionT, bm: int, hub_quantile: float = 0.99):
    indeg = np.diff(pt.indptr)
    hub_cut = np.quantile(indeg, hub_quantile)
    hubs = indeg > hub_cut
    keep = ~hubs[pt.row_ids]
    nbc = pt.n // bm + 1
    br = pt.row_ids[keep] // bm
    bc = pt.src[keep] // bm
    uniq, _ = np.unique(br.astype(np.int64) * nbc + bc, return_counts=True)
    per_row = np.bincount((uniq // nbc).astype(int))
    nnz_kept = int(keep.sum())
    fill = nnz_kept / (len(uniq) * bm * bm)
    return dict(bm=bm, hub_nnz_frac=float(indeg[hubs].sum() / max(len(pt.src), 1)),
                k_max=int(per_row.max()), k_mean=float(per_row.mean()),
                fill=float(fill),
                # bytes per useful flop: dense blocks f32 vs csr (4+4+4)/nnz
                bsr_bytes_per_nnz=float(bm * bm * 4 / max(fill * bm * bm, 1e-9)),
                csr_bytes_per_nnz=12.0)


def main(n=16384, nnz=131072):
    g = powerlaw_webgraph(n=n, target_nnz=nnz, n_dangling=16, seed=4)
    rows = []
    for tag, perm_fn in [("natural", None), ("rcm", rcm_permutation),
                         ("degree", degree_sort_permutation)]:
        gg = g if perm_fn is None else apply_permutation(g, perm_fn(g))
        pt = TransitionT.from_graph(gg)
        for bm in (32, 128):
            st = dict(order=tag, **layout_stats(pt, bm))
            rows.append(st)
            print(f"  {tag:8s} bm={bm:3d} K_max={st['k_max']:4d} "
                  f"K_mean={st['k_mean']:6.1f} fill={st['fill']:.4f} "
                  f"BSR B/nnz={st['bsr_bytes_per_nnz']:.0f} (csr 12)")
    RESULTS.mkdir(exist_ok=True, parents=True)
    (RESULTS / "bsr_layout.json").write_text(json.dumps(rows, indent=1))
    return rows


if __name__ == "__main__":
    main()
