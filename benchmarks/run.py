"""Benchmark orchestrator — one section per paper table/figure plus the
beyond-paper studies. Prints ``name,us_per_call,derived`` CSV at the end.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--out BENCH_PRN.json]

Every run (including --quick) starts with the matvec-backend bench, the
streaming-update bench, the sharded-runtime bench (sparsified vs
allgather), the async-executor bench (async vs superstep shard
drains, threads vs procpool vs the PR 9 device transport), the
observability bench (push-inflation attribution, chaos trace demo,
zero-cost-when-off gate), the drain-schedule bench (priority /
boundary-batched / randomized inflation arms, PR 8) and the query-tier
bench (batched PPR vs sequential + closed-loop load gen under a live
updater, PR 10) and writes the machine-readable
perf-trajectory file (``--out``, default BENCH_PR10.json) at the repo
root; ``--tier1-seconds`` embeds the measured suite runtime for the
check_tier1_runtime.py gate; --quick then skips the slow DES paper-table
and SPMD staleness studies.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

REPO_ROOT = Path(__file__).parent.parent
RESULTS = Path(__file__).parent / "results"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the slowest studies")
    ap.add_argument("--skip-spmd", action="store_true")
    ap.add_argument("--out", default="BENCH_PR10.json",
                    help="perf-trajectory output (BENCH_PR<N>.json for "
                         "PR N; relative paths land at the repo root)")
    ap.add_argument("--tier1-seconds", default=None,
                    help="measured tier-1 suite runtime (seconds, or a "
                         "file holding it); embedded as `tier1_seconds` "
                         "so benchmarks/check_tier1_runtime.py can gate "
                         "against the best of the last two BENCH files")
    args = ap.parse_args()
    out_path = Path(args.out)
    if not out_path.is_absolute():
        out_path = REPO_ROOT / out_path
    tier1_seconds = None
    if args.tier1_seconds is not None:
        raw = args.tier1_seconds
        tier1_seconds = float(Path(raw).read_text().strip()
                              if Path(raw).exists() else raw)

    csv_rows = [("name", "us_per_call", "derived")]
    t_all = time.time()

    print(f"== Matvec backends (segment_sum vs bsr_pallas) -> "
          f"{out_path.name} ==")
    from benchmarks import backend_bench
    t0 = time.time()
    brec = backend_bench.main(out_path=out_path)
    big = brec["apply"][-1]
    csv_rows.append((
        "backend_apply",
        f"{big['bsr_pallas_us_per_apply']:.0f}",
        f"n={big['n']}:segsum={big['segment_sum_us_per_apply']:.0f}us,"
        f"bsr={big['bsr_pallas_us_per_apply']:.0f}us,"
        f"tau100={brec['solver']['kendall_tau_top100']:.4f}"))
    csv_rows.append((
        "bsr_packing",
        f"{brec['packing']['acceptance_scale']['solve_grade_cold_ms']*1e3:.0f}",
        f"vs_seed_at_32k="
        f"{brec['packing']['largest_seed_packable']['speedup']:.1f}x,"
        f"seed_at_50k=OOM"))

    print("== Streaming incremental updates (push vs fallback) ==")
    from benchmarks import streaming_bench
    srec = streaming_bench.main()
    single = srec["delta_sweep"]["sweep"][0]
    csv_rows.append((
        "streaming_delta",
        f"{single['us_per_batch']:.0f}",
        f"single_edge:{single['path']}:visited"
        f"{100 * single['visited_frac']:.1f}%:"
        f"{single['speedup_vs_cold']:.0f}x_vs_cold,"
        f"fresh={srec['replay']['fresh_pct']:.0f}%"))
    brec["streaming"] = srec

    print("== Sharded runtime (sparsified vs allgather, 50k) ==")
    from benchmarks import shard_bench
    shrec = shard_bench.main()
    sp = next(r for r in shrec["spmd"] if r["schedule"] == "sparsified")
    csv_rows.append((
        "spmd_sparsified",
        f"{sp['total_comm_bytes']}",
        f"vs_allgather={sp['vs_allgather']:.2f}x,"
        f"steps={sp['supersteps']},err={sp['err']:.1e}"))
    sh = next(r for r in shrec["sharded_stream"]
              if r["exchange"] == "sparsified")
    csv_rows.append((
        "sharded_stream",
        f"{sh['s'] * 1e6:.0f}",
        f"path={sh['path']},steps={sh['supersteps']},"
        f"cert={sh['cert']:.1e},bytes={sh['bytes_moved']}"))
    brec["sharded"] = shrec

    print("== Async shard executor (threads vs procpool, 50k, p=1..8) ==")
    from benchmarks import async_shard_bench
    arec = async_shard_bench.main()
    a4 = next(r for r in arec["drain_dominated"]
              if r["mode"] == "async" and r["p"] == 4)
    csv_rows.append((
        "async_shard",
        f"{a4['s'] * 1e6:.0f}",
        f"p4_vs_p1_async={arec['speedup_p4_vs_p1_async']:.2f}x,"
        f"raw={arec['raw_speedup_p4_vs_p1_async']:.2f}x,"
        f"hetero_vs_superstep="
        f"{arec['speedup_async_vs_superstep_hetero_p4']:.2f}x"))
    pp4 = next(r for r in arec["drain_dominated_burn"]
               if r["transport"] == "procpool" and r["p"] == 4)
    csv_rows.append((
        "procpool_shard",
        f"{pp4['s'] * 1e6:.0f}",
        f"burn_p4_vs_p1={arec['procpool_burn_speedup_p4_vs_p1']:.2f}x,"
        f"threads_burn={arec['threads_burn_speedup_p4_vs_p1']:.2f}x,"
        f"raw_p4_vs_p1={arec['procpool_raw_speedup_p4_vs_p1']:.2f}x,"
        f"cores={arec['cores']}"))
    dv4 = next(r for r in arec["device"] if r["p"] == 4)
    csv_rows.append((
        "device_shard",
        f"{dv4['s'] * 1e6:.0f}",
        f"p4_vs_p1={arec['device_speedup_p4_vs_p1']:.2f}x,"
        f"steps={dv4['supersteps']},cert={dv4['cert']:.1e},"
        f"path={dv4['path']},bytes={dv4['bytes_moved']}"))
    ck = next(r for r in arec["chaos"] if r["faults"] == "kill_drop_dup")
    csv_rows.append((
        "chaos_recovery",
        f"{ck['s'] * 1e6:.0f}",
        f"recoveries={ck['recoveries']},"
        f"recovery_s={ck['recovery_s']:.3f},"
        f"overhead_vs_no_faults={ck['overhead_vs_no_faults']:.2f}x,"
        f"cert={ck['cert']:.1e}"))
    brec["async_shard"] = arec

    print("== Runtime observability (attribution, trace, overhead) ==")
    from benchmarks import observe_bench
    orec = observe_bench.main()
    inf = orec["inflation"]["procpool"]
    csv_rows.append((
        "observe_attribution",
        f"{inf['inflation']}",
        f"pp_inflation={inf['inflation_ratio']:.2f}x,"
        f"boundary_share={inf['boundary_share_of_inflation']},"
        f"threads_boundary_share="
        f"{orec['inflation']['threads']['boundary_share_of_inflation']},"
        f"trace_events={orec['trace_demo']['events']}"))
    ov = orec["overhead"]
    csv_rows.append((
        "observe_overhead",
        f"{ov['off_s'] * 1e6:.0f}",
        f"off_vs_baseline={ov['off_vs_baseline']},"
        f"on_vs_off={ov['on_vs_off']:.3f}x,"
        f"within_{ov['limit']}x={ov['within_limit']}"))
    brec["observe"] = orec

    print("== Drain schedules (priority/boundary/randomized inflation) ==")
    from benchmarks import schedule_bench
    screc = schedule_bench.main()
    for transport in ("threads", "procpool"):
        b = screc["best"][transport]
        d0 = screc["summary"][transport]["default"]
        csv_rows.append((
            f"schedule_{transport}",
            f"{b['pushes_p4']}",
            f"best={b['schedule']}:{b['inflation_ratio']:.3f}x,"
            f"default={d0['inflation_ratio']:.3f}x,"
            f"local_excess={b['local_excess']},"
            f"boundary={b['boundary_p4']}"))
    csv_rows.append((
        "schedule_burn",
        f"{screc['burn']['projected_speedup_p4_vs_p1']:.3f}",
        f"projected_p4_vs_p1={screc['burn']['projected_speedup_p4_vs_p1']}"
        f"x_at_{screc['burn']['project_cores']}cores,"
        f"measured={screc['burn']['measured']},"
        f"cores={screc['burn']['cores']}"))
    brec["schedule"] = screc

    print("== Query tier (batched PPR + closed-loop load gen, 50k) ==")
    from benchmarks import query_bench
    qrec = query_bench.main()
    qb = qrec["batched"]
    csv_rows.append((
        "query_batched_ppr",
        f"{qb['sweep'][-1]['ms_per_query'] * 1e3:.0f}",
        f"speedup16={qb['speedup_at_16']:.2f}x,"
        f"seq={qb['sequential_ms_per_query']:.0f}ms,"
        f"path={qb['sweep'][-1]['path']},"
        f"certs_ok={all(r['certs_ok'] for r in qb['sweep'])}"))
    ql = qrec["load"]
    csv_rows.append((
        "query_load_gen",
        f"{ql['latency_ms']['top_k']['p99'] * 1e3:.0f}",
        f"qps={ql['qps_under_update']:.0f},"
        f"updates={ql['updater']['batches_applied']},"
        f"topk_p50={ql['latency_ms']['top_k']['p50']:.2f}ms,"
        f"ppr_p99={ql['latency_ms']['ppr']['p99']:.0f}ms,"
        f"cache_hits={ql['cache']['hits']},"
        f"rejects={ql['router']['rejects']}"))
    brec["query"] = qrec

    if tier1_seconds is not None:
        brec["tier1_seconds"] = tier1_seconds
    out_path.write_text(json.dumps(brec, indent=1))
    (RESULTS / "streaming_bench.json").write_text(
        json.dumps(srec, indent=1))

    if not args.quick:
        from benchmarks import paper_tables
        print("== Paper Table 1 (sync vs async, 2/4/6 UEs) ==")
        op = paper_tables._ops()
        t0 = time.time()
        rows1 = paper_tables.table1(op)
        csv_rows.append(("table1_paper_repro", f"{(time.time()-t0)*1e6:.0f}",
                         f"speedups={[r['speedup'] for r in rows1]}"))

        print("== Paper Table 2 (completed imports) ==")
        t0 = time.time()
        rec2 = paper_tables.table2(op)
        csv_rows.append(("table2_imports", f"{(time.time()-t0)*1e6:.0f}",
                         f"completed_pct={rec2['completed_pct']}"))

        print("== Rank quality vs relaxed thresholds (paper §5.2 question) ==")
        t0 = time.time()
        rq = paper_tables.rank_quality(op)
        csv_rows.append(("rank_quality", f"{(time.time()-t0)*1e6:.0f}",
                         f"tau100@1e-6={next(r['kendall_tau_top100'] for r in rq if r['local_tol']==1e-6)}"))

    if not args.skip_spmd and not args.quick:
        print("== SPMD bounded-staleness schedules (8 host devices) ==")
        from benchmarks import spmd_staleness
        t0 = time.time()
        rows = spmd_staleness.main()
        base = next(r for r in rows if r["schedule"] == "allgather")
        best = min(rows, key=lambda r: r["total_comm_bytes"])
        csv_rows.append(("spmd_staleness", f"{(time.time()-t0)*1e6:.0f}",
                         f"best={best['schedule']}:{best['total_comm_bytes']/base['total_comm_bytes']:.2f}x_comm"))

    print("== Kernel benches ==")
    from benchmarks import kernel_bench
    t0 = time.time()
    spmv_rec = kernel_bench.spmv_bench()
    csv_rows.append(("bsr_spmv_ref", f"{spmv_rec['bsr_ref_multivec_us']:.0f}",
                     f"AI={spmv_rec['bsr_arith_intensity']:.3f}flop/B"))
    att_rec = kernel_bench.flash_bench()
    csv_rows.append(("flash_attention_jnp", f"{att_rec['flash_us']:.0f}",
                     f"score_mem_ratio={att_rec['flash_score_bytes']/att_rec['naive_score_bytes']:.4f}"))

    print("== BSR layout study (orderings x block size x hub split) ==")
    from benchmarks import bsr_layout_study
    t0 = time.time()
    rows_b = bsr_layout_study.main()
    best = min(rows_b, key=lambda r: r["bsr_bytes_per_nnz"])
    csv_rows.append(("bsr_layout_study", f"{(time.time()-t0)*1e6:.0f}",
                     f"best={best['order']}/bm{best['bm']}:"
                     f"{best['bsr_bytes_per_nnz']:.0f}B_per_nnz"))

    print("== Roofline report (from cached dry-run) ==")
    try:
        from benchmarks import roofline_report
        t0 = time.time()
        roofline_report.main()
        tbl = json.loads((RESULTS / "roofline_16x16.json").read_text())
        csv_rows.append(("roofline_cells", f"{(time.time()-t0)*1e6:.0f}",
                         f"n={len(tbl)}"))
    except Exception as e:
        print(f"  (roofline report unavailable: {e})")

    print(f"\nTotal bench time: {time.time()-t_all:.0f}s\n")
    print("\n".join(",".join(map(str, r)) for r in csv_rows))


if __name__ == "__main__":
    main()
