"""Paper Tables 1 & 2 reproduction (DES at Stanford-replica scale) plus the
rank-quality experiment the paper poses as an open question (§5.2)."""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.graph.generate import stanford_web_replica
from repro.graph.csr import TransitionT
from repro.graph.google import GoogleOperator, exact_pagerank
from repro.core import (AsyncFixedPoint, DESConfig, rank_of,
                        kendall_tau_topk)

RESULTS = Path(__file__).parent / "results"

PAPER_TABLE1 = {  # published values for side-by-side display
    2: dict(sync_iters=44, sync_t=179.2, async_iters=(68, 69),
            async_t=(86.3, 94.5), speedup=1.98),
    4: dict(sync_iters=44, sync_t=331.4, async_iters=(82, 111),
            async_t=(139.2, 153.1), speedup=2.27),
    6: dict(sync_iters=44, sync_t=402.8, async_iters=(129, 148),
            async_t=(141.7, 160.6), speedup=2.66),
}


def _ops(seed=0):
    g = stanford_web_replica(seed=seed)
    pt = TransitionT.from_graph(g)
    return GoogleOperator(pt=pt, alpha=0.85)


def des_cfg(seed=7):
    return DESConfig(tol=1e-6, norm="l2", barrier_overhead=0.5, seed=seed)


def table1(op=None, procs=(2, 4, 6), seed=7):
    op = op or _ops()
    afp = AsyncFixedPoint(op, kind="power")
    rows = []
    for p in procs:
        cfg = des_cfg(seed)
        t0 = time.time()
        sres = afp.solve_des_sync(p=p, cfg=cfg)
        ares = afp.solve_des(p=p, cfg=cfg)
        su = sres.time / max(ares.local_conv_time.max(), 1e-9)
        rows.append(dict(
            procs=p, sync_iters=sres.iters, sync_t=round(sres.time, 1),
            async_iters=[int(ares.iters.min()), int(ares.iters.max())],
            async_t=[round(float(ares.local_conv_time.min()), 1),
                     round(float(ares.local_conv_time.max()), 1)],
            speedup=round(float(su), 2),
            global_resid_inf=float(ares.global_resid_inf),
            import_pct=[round(float(x)) for x in ares.completed_import_pct],
            paper=PAPER_TABLE1.get(p),
            wall_s=round(time.time() - t0, 1),
        ))
        print(f"  p={p}: sync {rows[-1]['sync_iters']} it / "
              f"{rows[-1]['sync_t']}s | async {rows[-1]['async_iters']} it / "
              f"{rows[-1]['async_t']}s | speedup {rows[-1]['speedup']}")
    RESULTS.mkdir(exist_ok=True, parents=True)
    (RESULTS / "table1.json").write_text(json.dumps(rows, indent=1))
    return rows


def table2(op=None, p=4, seed=7):
    op = op or _ops()
    afp = AsyncFixedPoint(op, kind="power")
    ares = afp.solve_des(p=p, cfg=des_cfg(seed))
    mat = ares.imports.copy()
    np.fill_diagonal(mat, ares.iters)  # diagonal = locally produced (paper)
    rec = dict(imports=mat.tolist(),
               completed_pct=[round(float(x), 1)
                              for x in ares.completed_import_pct],
               paper_pct=[29, 28, 41, 45])
    (RESULTS / "table2.json").write_text(json.dumps(rec, indent=1))
    print("  imports matrix (diag = local iterations):")
    for r in mat:
        print("   ", " ".join(f"{v:5d}" for v in r))
    print("  completed %:", rec["completed_pct"])
    return rec


def rank_quality(op=None, seed=7):
    """Paper §5.2 open question: effect of relaxed thresholds on rankings."""
    op = op or _ops()
    xref = exact_pagerank(op, tol=1e-13)
    afp = AsyncFixedPoint(op, kind="power")
    rows = []
    for tol in (1e-4, 1e-5, 1e-6, 1e-7):
        cfg = des_cfg(seed)
        cfg.tol = tol
        res = afp.solve_des(p=4, cfg=cfg)
        tau100 = kendall_tau_topk(res.x, xref, k=100)
        tau1k = kendall_tau_topk(res.x, xref, k=1000)
        top10_exact = set(rank_of(xref)[:10])
        top10 = set(rank_of(res.x)[:10])
        rows.append(dict(local_tol=tol,
                         global_resid_inf=float(res.global_resid_inf),
                         kendall_tau_top100=round(tau100, 4),
                         kendall_tau_top1000=round(tau1k, 4),
                         top10_overlap=len(top10 & top10_exact)))
        print(f"  tol={tol:.0e}: gresid={res.global_resid_inf:.1e} "
              f"tau@100={tau100:.4f} tau@1k={tau1k:.4f} "
              f"top10 overlap={rows[-1]['top10_overlap']}/10")
    (RESULTS / "rank_quality.json").write_text(json.dumps(rows, indent=1))
    return rows


def main():
    print("[table1] sync vs async (Stanford replica)")
    op = _ops()
    table1(op)
    print("[table2] completed imports, p=4")
    table2(op)
    print("[rank quality] relaxed thresholds")
    rank_quality(op)


if __name__ == "__main__":
    main()
