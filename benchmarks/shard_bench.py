"""Sharded-runtime benchmark (PR 3): §6 sparsified exchange vs allgather.

Two measurements on the 50k-node power-law graph:

  * SPMD schedules (p=4 forced host devices, subprocess): bytes moved per
    superstep and in total for `allgather` vs `sparsified` at tol=1e-8 —
    the acceptance gate is sparsified <= 50% of allgather's total bytes;
  * the sharded streaming updater (p=4): a 1% edge delta drained with
    boundary-residual exchange under both plans, with the Fig. 1
    all-reduced certificate and modeled exchange bytes.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from pathlib import Path

import numpy as np

RESULTS = Path(__file__).parent / "results"

_SPMD_CODE = r"""
import json
import numpy as np
from repro.graph.generate import powerlaw_webgraph
from repro.graph.csr import TransitionT
from repro.graph.google import GoogleOperator, exact_pagerank
from repro.core import SPMDConfig, solve_spmd

g = powerlaw_webgraph(n=50_000, target_nnz=400_000, n_dangling=50, seed=3)
op = GoogleOperator(pt=TransitionT.from_graph(g), alpha=0.85)
xref = exact_pagerank(op, tol=1e-13)
rows = []
for sched, kw in [("allgather", {}),
                  ("sparsified", {}),
                  ("sparsified", dict(sparsify_refresh_every=32))]:
    cfg = SPMDConfig(p=4, schedule=sched, tol=1e-8, dtype="float32",
                     max_supersteps=4000, seed=3, **kw)
    r = solve_spmd(op, cfg)
    rows.append(dict(schedule=sched, **kw, supersteps=r.supersteps,
                     err=float(np.abs(r.x - xref).max()),
                     bytes_per_step=r.comm_bytes_per_step,
                     total_comm_bytes=r.comm_bytes_total,
                     rows_sent=r.rows_sent))
print(json.dumps(rows))
"""


def spmd_sparsified_bench():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = str(Path(__file__).parents[1] / "src")
    out = subprocess.run([sys.executable, "-c", _SPMD_CODE], env=env,
                         capture_output=True, text=True, timeout=1800)
    assert out.returncode == 0, out.stderr[-2000:]
    rows = json.loads(out.stdout.strip().splitlines()[-1])
    base = next(r for r in rows if r["schedule"] == "allgather")
    for r in rows:
        rel = r["total_comm_bytes"] / base["total_comm_bytes"]
        print(f"  {r['schedule']:11s} R={r.get('sparsify_refresh_every', '-'):>3} "
              f"steps={r['supersteps']:4d} err={r['err']:.1e} "
              f"bytes/step={r['bytes_per_step']:>9d} "
              f"total={r['total_comm_bytes']:>11d} ({rel:.2f}x allgather)")
        r["vs_allgather"] = rel
    return rows


def sharded_stream_bench():
    from repro.graph.generate import powerlaw_webgraph
    from repro.streaming import DeltaGraph, EdgeDelta, cold_state, \
        update_ranks_sharded

    g = powerlaw_webgraph(n=50_000, target_nnz=400_000, n_dangling=50,
                          seed=3)
    rng = np.random.default_rng(31)
    k = g.nnz // 100
    n_del = k * 15 // 100
    slots = rng.choice(g.nnz, size=n_del, replace=False)
    soe = np.repeat(np.arange(g.n, dtype=np.int64), np.diff(g.indptr))
    delta = EdgeDelta(
        add_src=rng.integers(0, g.n, k - n_del),
        add_dst=g.indices[rng.integers(0, g.nnz, k - n_del)].astype(np.int64),
        del_src=soe[slots], del_dst=g.indices[slots].astype(np.int64))

    rows = []
    for exchange in ("allgather", "sparsified"):
        dg = DeltaGraph(g)
        st = cold_state(dg, tol=5e-7)
        t0 = time.perf_counter()
        st, stats = update_ranks_sharded(dg, delta, st, p=4, tol=8e-7,
                                         exchange=exchange)
        dt = time.perf_counter() - t0
        rows.append(dict(exchange=exchange, path=stats.path, s=dt,
                         supersteps=stats.supersteps, pushes=stats.pushes,
                         exchanges=stats.exchanges,
                         bytes_moved=stats.bytes_moved,
                         cert=stats.cert,
                         stop_superstep=stats.stop_superstep))
        print(f"  sharded[{exchange:11s}] {dt:6.1f}s "
              f"steps={stats.supersteps:3d} pushes={stats.pushes} "
              f"bytes={stats.bytes_moved} cert={stats.cert:.2e}")
    return rows


def main():
    print("  [shard] SPMD sparsified-vs-allgather (50k, 4 host devices)...")
    spmd_rows = spmd_sparsified_bench()
    print("  [shard] sharded streaming updater (50k, 1% delta, p=4) ...")
    stream_rows = sharded_stream_bench()
    rec = dict(bench="sharded runtime: sparsified vs allgather (PR 3)",
               spmd=spmd_rows, sharded_stream=stream_rows)
    RESULTS.mkdir(exist_ok=True, parents=True)
    (RESULTS / "shard_bench.json").write_text(json.dumps(rec, indent=1))
    return rec


if __name__ == "__main__":
    main()
