"""CI gate: the query tier must actually scale the query path (PR 10).

    python benchmarks/check_query_tier.py [BENCH_PR10.json]

Reads the ``query`` section of the given perf-trajectory file and gates
the acceptance criteria of the batched-fused query tier:

  * batched PPR throughput >= 3x the sequential per-seed loop at
    batch >= 16 on the 50k graph, every lane exactly certified;
  * the closed-loop load gen served queries while the daemon updater
    applied 1%-delta batches (batches_applied >= 1, qps > 0, finite
    p50/p99 for every query kind);
  * every sampled served snapshot carried a valid certificate
    (cert <= server tol), no personalized answer violated its tol;
  * the router honored its staleness bound: zero rejects (redirects are
    fine — that IS the bound working) and every replica ended admissible.

Exit codes: 0 pass, 1 fail, 2 usage/missing section.
"""
from __future__ import annotations

import json
import math
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).parent.parent

SPEEDUP_FLOOR = 3.0


def main() -> int:
    target = Path(sys.argv[1]) if len(sys.argv) > 1 else \
        REPO_ROOT / "BENCH_PR10.json"
    if not target.is_absolute():
        target = REPO_ROOT / target
    if not target.exists():
        print(f"query tier gate: {target.name} not found")
        return 2
    rec = json.loads(target.read_text())
    q = rec.get("query")
    if q is None:
        print(f"query tier gate: no query section in {target.name}")
        return 2

    ok = True

    # ---- batched PPR throughput -------------------------------------
    b = q["batched"]
    best16 = max(r["speedup_vs_sequential"] for r in b["sweep"]
                 if r["batch"] >= 16)
    verdict = "OK" if best16 >= SPEEDUP_FLOOR else "FAIL"
    print(f"batched   speedup_at_16={b['speedup_at_16']:.2f}x "
          f"best(batch>=16)={best16:.2f}x (floor {SPEEDUP_FLOOR}x) "
          f"{verdict}")
    if best16 < SPEEDUP_FLOOR:
        ok = False
    for r in b["sweep"]:
        if not r["certs_ok"]:
            ok = False
            print(f"FAIL cert: batch={r['batch']} "
                  f"max_cert={r['max_cert']:.2e} > tol={b['tol']:.0e}")

    # ---- load gen under update --------------------------------------
    load = q["load"]
    applied = load["updater"]["batches_applied"]
    qps = load["qps_under_update"]
    verdict = "OK" if applied >= 1 and qps > 0 else "FAIL"
    print(f"load      {qps:.0f} qps over {load['duration_s']:.1f}s, "
          f"{applied} x {load['delta_edges_per_batch']}-edge delta "
          f"batches applied {verdict}")
    if applied < 1 or qps <= 0:
        ok = False
    for kind, p in load["latency_ms"].items():
        finite = all(math.isfinite(p[x]) for x in ("p50", "p99"))
        print(f"          {kind:7s} p50={p['p50']:.1f}ms "
              f"p99={p['p99']:.1f}ms n={load['queries'][kind]} "
              f"{'OK' if finite else 'FAIL'}")
        if not finite:
            ok = False

    # ---- certificates + staleness bounds ----------------------------
    verdict = "OK" if load["served_cert_ok"] else "FAIL"
    print(f"certs     max_served_cert={load['max_served_cert']:.2e} "
          f"ppr_violations={load['ppr_cert_violations']} {verdict}")
    if not load["served_cert_ok"] or load["ppr_cert_violations"]:
        ok = False
    rej = load["router"]["rejects"]
    verdict = "OK" if rej == 0 else "FAIL"
    print(f"router    routed={load['router']['routed']} "
          f"redirects={load['router']['redirects']} rejects={rej} "
          f"{verdict}")
    if rej:
        ok = False
    hits = load["cache"]["hits"]
    verdict = "OK" if hits >= 1 else "FAIL"
    print(f"cache     hits={hits} survivals="
          f"{load['cache']['survivals']} "
          f"flushes={load['cache']['flushes']} {verdict}")
    if hits < 1:
        ok = False

    if not ok:
        print("query tier failed its acceptance gates — see "
              "benchmarks/query_bench.py for the workload and "
              "docs/serving.md for the tier's contract")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
