"""CI gate: drain scheduling must kill the async scaling tax (PR 8).

    python benchmarks/check_schedule_inflation.py [BENCH_PR8.json]

Reads the ``schedule`` section of the given perf-trajectory file (default
BENCH_PR8.json at the repo root) and gates the best schedule per
transport on the acceptance workload (50k power-law, 1% delta,
tol=1e-8, p=4 vs the p=1 default-schedule baseline):

  * threads inflation  <= 1.20x   (default measured ~1.3-1.6x)
  * procpool inflation <= 1.10x   (default measured ~1.2-1.3x)
  * procpool burn p4-vs-p1 >= 2.6x — the measured wall-clock when the
    bench host had >= 4 cores, else the machine-independent push-ratio
    projection at 4 dedicated cores (the burn regime's wall-clock is
    pushes * per-push cost, so the ratio converts 1:1)
  * every arm's certificate holds (cert <= tol)

Inflation ratios are push counts, not wall-clock, so the gate is
machine-independent (the same reasoning as check_observe_overhead.py's
burn comparison).

Exit codes: 0 pass, 1 fail, 2 usage/missing section.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).parent.parent

THREADS_LIMIT = 1.20
PROCPOOL_LIMIT = 1.10
BURN_FLOOR = 2.6


def main() -> int:
    target = Path(sys.argv[1]) if len(sys.argv) > 1 else \
        REPO_ROOT / "BENCH_PR8.json"
    if not target.is_absolute():
        target = REPO_ROOT / target
    if not target.exists():
        print(f"schedule inflation gate: {target.name} not found")
        return 2
    rec = json.loads(target.read_text())
    sched = rec.get("schedule")
    if sched is None:
        print(f"schedule inflation gate: no schedule section in "
              f"{target.name}")
        return 2

    ok = True
    tol = sched.get("tol", 1e-8)
    for arm in sched.get("arms", []):
        if arm["cert"] > tol:
            ok = False
            print(f"FAIL cert: {arm['transport']} p={arm['p']} "
                  f"{arm.get('schedule')} cert={arm['cert']:.2e} > "
                  f"tol={tol:.0e}")

    for transport, limit in (("threads", THREADS_LIMIT),
                             ("procpool", PROCPOOL_LIMIT)):
        b = sched["best"][transport]
        ratio = b["inflation_ratio"]
        verdict = "OK" if ratio <= limit else "FAIL"
        base = sched["summary"][transport]["default"]["inflation_ratio"]
        print(f"{transport:9s} best={b['schedule']:18s} "
              f"inflation={ratio:.3f}x (default {base:.3f}x, "
              f"limit {limit}x) {verdict}")
        if ratio > limit:
            ok = False

    burn = sched["burn"]
    measured = burn.get("measured")
    if measured is not None:
        sp = measured["speedup_p4_vs_p1"]
        verdict = "OK" if sp >= BURN_FLOOR else "FAIL"
        print(f"procpool  burn measured {sp:.2f}x "
              f"(floor {BURN_FLOOR}x, {burn['cores']} cores) {verdict}")
        if sp < BURN_FLOOR:
            ok = False
    sp = burn["projected_speedup_p4_vs_p1"]
    verdict = "OK" if sp >= BURN_FLOOR else "FAIL"
    print(f"procpool  burn projected {sp:.2f}x at "
          f"{burn['project_cores']} cores (floor {BURN_FLOOR}x, host has "
          f"{burn['cores']}) {verdict}")
    if sp < BURN_FLOOR:
        ok = False

    if not ok:
        print("drain scheduling failed its acceptance gates — see "
              "benchmarks/schedule_bench.py TUNED for the knobs and "
              "docs/runtime.md 'Drain scheduling' for the levers")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
