"""CI gate: observability must be pay-for-use (PR 7).

    python benchmarks/check_observe_overhead.py [BENCH_PR7.json]

Reads the ``observe.overhead`` section of the given perf-trajectory file
(default BENCH_PR7.json at the repo root): the drain-dominated burn row
(threads p=1) re-measured with observe=False must land within
``limit`` (1.03x) of the same row in the pre-PR BENCH file.  The burn
regime's wall-clock is dominated by the calibrated per-push spin, so
the comparison is machine-independent — a regression here means the
observe plumbing leaks cost into the observe=off hot path.

Exit codes: 0 pass (or explicit skip when the baseline file predates
the gate), 1 fail, 2 usage/missing section.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).parent.parent


def main() -> int:
    target = Path(sys.argv[1]) if len(sys.argv) > 1 else \
        REPO_ROOT / "BENCH_PR7.json"
    if not target.is_absolute():
        target = REPO_ROOT / target
    if not target.exists():
        print(f"observe overhead gate: {target.name} not found")
        return 2
    rec = json.loads(target.read_text())
    ov = rec.get("observe", {}).get("overhead")
    if ov is None:
        print(f"observe overhead gate: no observe.overhead section in "
              f"{target.name}")
        return 2
    if ov.get("baseline_s") is None:
        print(f"observe overhead gate: SKIP — {ov.get('note') or 'no pre-PR baseline available'}")
        return 0
    ratio = ov["off_vs_baseline"]
    limit = ov.get("limit", 1.03)
    verdict = "OK" if ratio <= limit else "FAIL"
    print(f"observe=off burn: {ov['off_s']:.2f}s vs pre-PR "
          f"{ov['baseline_s']:.2f}s [{ov['baseline']}] -> {ratio:.3f}x "
          f"(limit {limit}x) {verdict}; on_vs_off={ov['on_vs_off']:.3f}x")
    if ratio > limit:
        print("observe=off regressed the drain-dominated hot path — the "
              "off path must not pay for tracing (check for per-push "
              "work gated on `obs is not None` that runs anyway)")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
