"""Observability bench (PR 7): push-inflation attribution, the chaos
trace demo, and the zero-cost-when-off gate.

Three studies over the PR 4/5 acceptance workload (50k power-law graph,
1% edge delta, tol=1e-8):

  attribution
      `update_ranks_sharded(observe=True)` at p = 1 and p = 4 on both
      transports, decomposing the push-inflation ratio pushes_p4 /
      pushes_p1 that every prior BENCH file reports as a single opaque
      number.  The async schedule is wall-clock nondeterministic, so
      since PR 8 every row is the median-of-``ATTR_REPEATS`` by total
      pushes (single-shot rows drifted 15%+ between runs, enough to flip
      the decomposition's headline shares).  Each push is classified at drain time (runtime/observe.py)
      as *first* (the row's first push this update), *boundary* (re-push
      whose residual was re-seeded by a cross-shard exchange fold since
      its last push) or *local* (re-push from same-shard mass movement /
      drain cadence).  first + local + boundary == pushes exactly on a
      fault-free run.  At p = 1 boundary is structurally 0 (there is no
      exchange), so `boundary_p4` is the pure cross-shard re-activation
      cost of sharding and `local` growth is the asynchrony/cadence cost.

  trace_demo
      The Fig. 1 / eq. (5) cycle made visible: a p=4 procpool solve
      under a seeded mid-drain worker SIGKILL (the PR 6 chaos "kill"
      plan), exported as Chrome trace_event JSON --
      benchmarks/results/observe_trace_p4_procpool.json -- loadable in
      Perfetto / chrome://tracing (one track per shard: INTAKE / DRAIN /
      EXCHANGE spans, CONVERGE / STOP / KILL / RECOVERY instants).  The
      KILL instant is written by the dying incarnation (the ring lives
      in the parent-owned arena) and the RECOVERY by the supervisor.
      Also runnable alone: ``python -m benchmarks.observe_bench
      --trace-demo``.

  overhead
      The acceptance gate: observability must be pay-for-use.  The
      drain-dominated burn row (threads p=1, the most deterministic
      regime: wall-clock is dominated by the calibrated per-push spin,
      so it is machine-independent) is re-measured with observe=False
      and compared against the same row of the pre-PR BENCH file --
      within 3% or benchmarks/check_observe_overhead.py fails.  The
      observe=True re-measurement is informational (attribution adds a
      per-frontier classification to every drain).

Emits benchmarks/results/observe_bench.json and feeds the ``observe``
section of BENCH_PR7.json via benchmarks/run.py.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from benchmarks.async_shard_bench import (BURN_REPEATS, DRAIN_RATE, _run,
                                          _workload)

REPO_ROOT = Path(__file__).parent.parent
RESULTS = Path(__file__).parent / "results"
TRACE_PATH = RESULTS / "observe_trace_p4_procpool.json"
BASELINE_BENCH = "BENCH_PR6.json"   # pre-PR perf trajectory (overhead ref)
OVERHEAD_LIMIT = 1.03               # observe=off within 3% of pre-PR burn
ATTR_REPEATS = 3                    # median-of-k by pushes per attribution
#                                   # row (PR 8: the async schedule is
#                                   # nondeterministic; k=1 was too noisy)


def _attr_row(row):
    """Serialize an observe=True row: drop the event stream, keep the
    roll-up (counters + attribution) the JSON record needs."""
    obs = row.pop("_observed", None)
    if obs is not None:
        row["events_written"] = [int(v) for v in obs["events_written"]]
        row["events_dropped"] = [int(v) for v in obs["events_dropped"]]
        row["counters"] = {k: [int(v) for v in vals]
                           for k, vals in obs["counters"].items()}
    return row


def attribution_study(g, delta, base):
    rows = []
    for transport in ("threads", "procpool"):
        for p in (1, 4):
            nw = p if transport == "procpool" else None
            reps = sorted((_run(g, delta, base, "async", p,
                                transport=transport, n_workers=nw,
                                observe=True)
                           for _ in range(ATTR_REPEATS)),
                          key=lambda r: r["pushes"])
            row = reps[len(reps) // 2]
            rows.append(_attr_row(row))
            print(f"    attr      {transport:9s} p={p} {row['s']:7.2f}s "
                  f"pushes={row['pushes']} first={row['pushes_first']} "
                  f"local={row['pushes_local']} "
                  f"boundary={row['pushes_boundary']}")

    def pick(transport, p):
        return next(r for r in rows if r["transport"] == transport
                    and r["p"] == p)

    decomp = {}
    for transport in ("threads", "procpool"):
        r1, r4 = pick(transport, 1), pick(transport, 4)
        inflation = r4["pushes"] - r1["pushes"]
        decomp[transport] = dict(
            pushes_p1=r1["pushes"], pushes_p4=r4["pushes"],
            inflation=inflation,
            inflation_ratio=round(r4["pushes"] / r1["pushes"], 4),
            # cross-shard re-activation: pushes whose residual arrived
            # over the wire (structurally impossible at p=1)
            boundary_p4=r4["pushes_boundary"],
            # asynchrony/cadence: extra same-shard re-pushes vs p=1
            local_excess=r4["pushes_local"] - r1["pushes_local"],
            first_p4=r4["pushes_first"], first_p1=r1["pushes_first"],
            boundary_share_of_inflation=(
                round(r4["pushes_boundary"] / inflation, 4)
                if inflation > 0 else None),
        )
        d = decomp[transport]
        print(f"    decomp    {transport:9s} inflation="
              f"{d['inflation_ratio']:.2f}x boundary={d['boundary_p4']} "
              f"({d['boundary_share_of_inflation']}) "
              f"local_excess={d['local_excess']}")
    return rows, decomp


def trace_demo(g=None, delta=None, base=None):
    """p=4 procpool kill/recovery solve -> Perfetto-loadable trace."""
    from repro.runtime import FaultPlan, write_chrome_trace

    if g is None:
        print("  [observe] building 50k 1%-delta workload (cold solve) ...")
        g, delta, base = _workload()
    row = _run(g, delta, base, "async", 4, transport="procpool",
               n_workers=4, faults=FaultPlan(seed=7, kill={1: 40}),
               observe=True)
    obs = row.pop("_observed")
    events = obs["events"]
    RESULTS.mkdir(exist_ok=True, parents=True)
    write_chrome_trace(TRACE_PATH, events, p=4)
    kinds = {}
    for ev in events:
        kinds[ev["kind"]] = kinds.get(ev["kind"], 0) + 1
    kills = int(sum(obs["counters"]["kills"]))
    recs = int(sum(obs["counters"]["recoveries"]))
    print(f"    trace     p=4 procpool kill: {len(events)} events "
          f"({row['s']:.2f}s, kills={kills}, recoveries={recs}) -> "
          f"{TRACE_PATH.relative_to(REPO_ROOT)}")
    return dict(path=str(TRACE_PATH.relative_to(REPO_ROOT)),
                events=len(events),
                events_dropped=[int(v) for v in obs["events_dropped"]],
                kills=kills, recoveries=recs,
                wall_s=row["s"], cert=row["cert"],
                counters={k: [int(v) for v in vals]
                          for k, vals in obs["counters"].items()})


def overhead_study(g, delta, base):
    """observe=off vs the pre-PR burn baseline, observe=on vs off."""
    def burn(observe):
        return min((_run(g, delta, base, "async", 1,
                         rate_per_shard=[DRAIN_RATE], cost="burn",
                         observe=observe)
                    for _ in range(BURN_REPEATS)), key=lambda r: r["s"])

    off = burn(False)
    on = _attr_row(burn(True))
    baseline_s = None
    note = None
    bpath = REPO_ROOT / BASELINE_BENCH
    if bpath.exists():
        try:
            pre = json.loads(bpath.read_text())
            baseline_s = next(
                r["s"] for r in pre["async_shard"]["drain_dominated_burn"]
                if r["transport"] == "threads" and r["p"] == 1)
        except (KeyError, StopIteration, json.JSONDecodeError) as e:
            note = f"baseline row unreadable in {BASELINE_BENCH}: {e}"
    else:
        note = f"{BASELINE_BENCH} not found; overhead gate will skip"
    rec = dict(
        regime="drain_dominated_burn threads p=1 (best of "
               f"{BURN_REPEATS})",
        off_s=off["s"], on_s=on["s"],
        baseline=BASELINE_BENCH, baseline_s=baseline_s,
        limit=OVERHEAD_LIMIT,
        off_vs_baseline=(round(off["s"] / baseline_s, 4)
                         if baseline_s else None),
        on_vs_off=round(on["s"] / off["s"], 4),
        within_limit=(baseline_s is not None
                      and off["s"] / baseline_s <= OVERHEAD_LIMIT),
        note=note,
    )
    print(f"    overhead  off={off['s']:.2f}s on={on['s']:.2f}s "
          f"baseline={baseline_s} off_vs_baseline={rec['off_vs_baseline']} "
          f"on_vs_off={rec['on_vs_off']}x")
    if note:
        print(f"    overhead  NOTE: {note}")
    return rec


def main():
    print("  [observe] building 50k 1%-delta workload (cold solve) ...")
    g, delta, base = _workload()

    print("  [observe] push-inflation attribution "
          "(threads/procpool, p=1 vs p=4, observe=True) ...")
    rows, decomp = attribution_study(g, delta, base)

    print("  [observe] chaos trace demo (p=4 procpool, seeded kill) ...")
    trace = trace_demo(g, delta, base)

    print("  [observe] zero-cost-when-off gate (burn p=1, "
          f"observe off/on vs {BASELINE_BENCH}) ...")
    overhead = overhead_study(g, delta, base)

    rec = dict(
        bench="runtime observability: attribution, trace, overhead (PR 7)",
        workload="50k power-law, 1% delta, tol=1e-8",
        attribution=rows, inflation=decomp,
        trace_demo=trace, overhead=overhead,
    )
    RESULTS.mkdir(exist_ok=True, parents=True)
    (RESULTS / "observe_bench.json").write_text(json.dumps(rec, indent=1))
    return rec


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace-demo", action="store_true",
                    help="only regenerate the Perfetto kill/recovery "
                         "trace (make trace-demo)")
    if ap.parse_args().trace_demo:
        trace_demo()
    else:
        main()
