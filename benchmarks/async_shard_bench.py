"""Async shard executor benchmark (PR 4): async vs superstep drains.

Workload: the 50k-node power-law graph with a 1% edge delta (the
acceptance workload of PRs 2/3), drained to tol=1e-8 by
`update_ranks_sharded` in both execution modes at p = 1, 2, 4, 8.

Two measurement regimes:

  raw
      Plain wall-clock of the numpy drains.  On small-core containers
      this measures numpy's GIL behavior as much as the executor (most of
      the drain kernel — gathers, bincount, repeat — holds the GIL), so
      it is reported for the record, not as the scaling claim.

  drain_dominated
      The paper's regime: local computation dominates communication.
      Each shard's drain is given a *calibrated* per-push compute cost
      (``DRAIN_RATE`` pushes/s, the same modeled-clock methodology as
      `streaming/scenario.py`'s replay), implemented as a sleep after the
      real sweep — sleeps release the GIL completely, so worker threads
      overlap exactly as heavier real drains would on dedicated cores.
      Here the executor's zero-barrier concurrency is visible on any
      machine: p=4 async must be >= 1.5x faster than p=1 async (the PR 4
      acceptance gate, reported as ``speedup_p4_vs_p1_async``), while the
      sequential superstep loop pays the sum of all shards' drains.

  heterogeneous
      The paper's motivating platform: shard i runs at rate/(1+i) — a 4x
      spread at p=4.  The superstep loop serializes every shard's slow
      drain per superstep; the async executor lets fast shards run ahead
      (bounded by the §6 exchange plan), which is the Table-1 story
      replayed at the streaming layer.

Emits benchmarks/results/async_shard_bench.json and feeds the
``async_shard`` section of BENCH_PR4.json via benchmarks/run.py.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

RESULTS = Path(__file__).parent / "results"

PS = (1, 2, 4, 8)
TOL = 1e-8
DRAIN_RATE = 1.5e5          # modeled pushes/s for the drain-dominated case


def _workload():
    from repro.graph.generate import powerlaw_webgraph
    from repro.streaming import DeltaGraph, EdgeDelta, cold_state

    g = powerlaw_webgraph(n=50_000, target_nnz=400_000, n_dangling=50,
                          seed=3)
    rng = np.random.default_rng(31)
    k = g.nnz // 100
    n_del = k * 15 // 100
    slots = rng.choice(g.nnz, size=n_del, replace=False)
    soe = np.repeat(np.arange(g.n, dtype=np.int64), np.diff(g.indptr))
    delta = EdgeDelta(
        add_src=rng.integers(0, g.n, k - n_del),
        add_dst=g.indices[rng.integers(0, g.nnz, k - n_del)].astype(
            np.int64),
        del_src=soe[slots], del_dst=g.indices[slots].astype(np.int64))
    base = cold_state(DeltaGraph(g), tol=5e-9)
    return g, delta, base


def _run(g, delta, base, mode: str, p: int, rate_per_shard=None):
    """One sharded update; rate_per_shard (pushes/s, per shard) switches
    on the modeled drain clock via a scoped _drain_shard wrapper."""
    from repro.streaming import DeltaGraph, update_ranks_sharded
    from repro.streaming.incremental import RankState
    from repro.streaming import sharded as sharded_mod

    dg = DeltaGraph(g)
    st = RankState(x=base.x.copy(), r=base.r.copy(), version=0,
                   alpha=base.alpha)
    real_drain = sharded_mod._drain_shard
    part_size = -(-g.n // p)

    if rate_per_shard is not None:
        def modeled_drain(arrays, x, r, outbox, s, e, *args):
            got = real_drain(arrays, x, r, outbox, s, e, *args)
            if got:
                time.sleep(got / rate_per_shard[min(s // part_size,
                                                    p - 1)])
            return got
        sharded_mod._drain_shard = modeled_drain
    try:
        t0 = time.perf_counter()
        st, stats = update_ranks_sharded(dg, delta, st, p=p, tol=TOL,
                                         mode=mode)
        dt = time.perf_counter() - t0
    finally:
        sharded_mod._drain_shard = real_drain
    return dict(mode=mode, p=p, s=round(dt, 3), path=stats.path,
                pushes=int(stats.pushes), supersteps=int(stats.supersteps),
                exchanges=int(stats.exchanges),
                bytes_moved=int(stats.bytes_moved),
                cert=float(stats.cert), idle_s=round(float(stats.idle_s), 3),
                attempts=int(stats.attempts))


def main():
    print("  [async] building 50k 1%-delta workload (cold solve) ...")
    g, delta, base = _workload()

    raw = []
    print("  [async] raw wall-clock, p=1..8, async vs superstep ...")
    _run(g, delta, base, "async", 1)            # warm caches
    for mode in ("async", "superstep"):
        for p in PS:
            row = _run(g, delta, base, mode, p)
            raw.append(row)
            print(f"    raw       {mode:9s} p={p} {row['s']:7.2f}s "
                  f"pushes={row['pushes']} path={row['path']}")

    print(f"  [async] drain-dominated (modeled {DRAIN_RATE:.0f} pushes/s "
          "per shard) ...")
    dom = []
    for mode in ("async", "superstep"):
        for p in PS:
            row = _run(g, delta, base, mode, p,
                       rate_per_shard=[DRAIN_RATE] * p)
            dom.append(row)
            print(f"    dominated {mode:9s} p={p} {row['s']:7.2f}s "
                  f"pushes={row['pushes']} idle={row['idle_s']}s")

    print("  [async] heterogeneous shards (rate/(1+i), p=4) ...")
    het = []
    rates = [DRAIN_RATE / (1 + i) for i in range(4)]
    for mode in ("async", "superstep"):
        row = _run(g, delta, base, mode, 4, rate_per_shard=rates)
        het.append(row)
        print(f"    hetero    {mode:9s} p=4 {row['s']:7.2f}s")

    def t(rows, mode, p):
        return next(r["s"] for r in rows if r["mode"] == mode
                    and r["p"] == p)

    rec = dict(
        bench="async shard executor vs superstep loop (PR 4)",
        workload="50k power-law, 1% delta, tol=1e-8",
        drain_rate_pushes_per_s=DRAIN_RATE,
        raw=raw, drain_dominated=dom, heterogeneous=het,
        speedup_p4_vs_p1_async=round(t(dom, "async", 1)
                                     / t(dom, "async", 4), 3),
        raw_speedup_p4_vs_p1_async=round(t(raw, "async", 1)
                                         / t(raw, "async", 4), 3),
        speedup_async_vs_superstep_hetero_p4=round(
            t(het, "superstep", 4) / t(het, "async", 4), 3),
    )
    print(f"  [async] drain-dominated p4-vs-p1 async speedup: "
          f"{rec['speedup_p4_vs_p1_async']:.2f}x  (raw: "
          f"{rec['raw_speedup_p4_vs_p1_async']:.2f}x; hetero p=4 "
          f"async-vs-superstep: "
          f"{rec['speedup_async_vs_superstep_hetero_p4']:.2f}x)")
    RESULTS.mkdir(exist_ok=True, parents=True)
    (RESULTS / "async_shard_bench.json").write_text(
        json.dumps(rec, indent=1))
    return rec


if __name__ == "__main__":
    main()
