"""Async shard executor benchmark (PRs 4/5): async vs superstep drains,
threads vs procpool transports.

Workload: the 50k-node power-law graph with a 1% edge delta (the
acceptance workload of PRs 2/3), drained to tol=1e-8 by
`update_ranks_sharded` at p = 1, 2, 4, 8.

Measurement regimes:

  raw
      Plain wall-clock of the numpy drains.  On small-core containers
      this measures numpy's GIL behavior as much as the executor (most of
      the drain kernel — gathers, bincount, repeat — holds the GIL), so
      it is reported for the record, not as the scaling claim.  PR 5 adds
      ``transport="procpool"`` rows (p = 1..cores and p=4): worker
      *processes* over a shared-memory ShardArena, where the same numpy
      drains no longer share a GIL.

  drain_dominated (sleep)
      The paper's regime: local computation dominates communication.
      Each shard's drain is given a *calibrated* per-push compute cost
      (``DRAIN_RATE`` pushes/s, the same modeled-clock methodology as
      `streaming/scenario.py`'s replay), implemented as a sleep after the
      real sweep — sleeps release the GIL completely, so worker threads
      overlap exactly as heavier real drains would on dedicated cores.
      p=4 async >= 1.5x p=1 async is the PR 4 acceptance gate
      (``speedup_p4_vs_p1_async``).

  drain_dominated_burn (PR 5 acceptance regime)
      The same calibrated per-push cost, but as *real CPU work* (a
      GIL-holding spin) instead of a sleep.  This is the drain-dominated
      regime measured as RAW wall-clock: threads serialize on the GIL
      (<= 1.0x at any p — the ROADMAP pathology), while procpool workers
      burn on separate cores.  The PR 5 acceptance gate is procpool
      p=4 >= 1.5x p=1 (``procpool_burn_speedup_p4_vs_p1``); on a c-core
      container the ceiling is (pushes_p1 / pushes_p4) * min(p, c), and
      the rows run one process per shard (see the inline comment).

  heterogeneous
      The paper's motivating platform: shard i runs at rate/(1+i) — a 4x
      spread at p=4.  The superstep loop serializes every shard's slow
      drain per superstep; the async executor lets fast shards run ahead
      (bounded by the §6 exchange plan).

  chaos (PR 6)
      The acceptance workload drained by p=4 procpool under seeded
      faults (mid-drain worker kill, 10% drop + 10% duplicate, both):
      recovery time and total overhead vs the no-fault baseline, with
      the certificate required to hold in every row.

  device (PR 9)
      The acceptance workload drained by ``transport="device"`` — the
      traced ShardStep as p shard programs over forced host devices
      (``XLA_FLAGS=--xla_force_host_platform_device_count=4``) — at
      p=1 and p=4.  The rows run in a subprocess because this process's
      jax is already initialized single-device; each p is run twice and
      the warm (second) wall-clock is the throughput row, so the jit
      compile is not billed to the drain.  The certificate must hold and
      the recorded bytes must reproduce from the (rows, fulls) counters
      through ``step.comm_bytes_model`` —
      benchmarks/check_device_transport.py gates both.

Emits benchmarks/results/async_shard_bench.json and feeds the
``async_shard`` section of BENCH_PR9.json via benchmarks/run.py.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from pathlib import Path

import numpy as np

RESULTS = Path(__file__).parent / "results"
REPO = Path(__file__).parent.parent

PS = (1, 2, 4, 8)
TOL = 1e-8
DRAIN_RATE = 1e5            # modeled pushes/s for the drain-dominated case
BURN_REPEATS = 2            # burn rows keep the best of N runs (the async
#                           # schedule is nondeterministic; min is the
#                           # standard timing estimator)


def _spin(seconds: float) -> float:
    """Burn ~`seconds` of CPU while HOLDING the GIL (python-level loop):
    the honest stand-in for a heavier drain kernel whose numpy ops don't
    release the GIL.  Sleeping would overlap perfectly on threads and
    hide exactly the contention this regime exists to measure."""
    t_end = time.perf_counter() + seconds
    x = 1.0
    while time.perf_counter() < t_end:
        x = x * 1.0000001 + 1e-9
    return x


def _workload():
    from repro.graph.generate import powerlaw_webgraph
    from repro.streaming import DeltaGraph, EdgeDelta, cold_state

    g = powerlaw_webgraph(n=50_000, target_nnz=400_000, n_dangling=50,
                          seed=3)
    rng = np.random.default_rng(31)
    k = g.nnz // 100
    n_del = k * 15 // 100
    slots = rng.choice(g.nnz, size=n_del, replace=False)
    soe = np.repeat(np.arange(g.n, dtype=np.int64), np.diff(g.indptr))
    delta = EdgeDelta(
        add_src=rng.integers(0, g.n, k - n_del),
        add_dst=g.indices[rng.integers(0, g.nnz, k - n_del)].astype(
            np.int64),
        del_src=soe[slots], del_dst=g.indices[slots].astype(np.int64))
    base = cold_state(DeltaGraph(g), tol=5e-9)
    return g, delta, base


def _run(g, delta, base, mode: str, p: int, rate_per_shard=None,
         transport: str = "threads", cost: str = "sleep",
         n_workers=None, faults=None, observe: bool = False,
         schedule=None):
    """One sharded update; rate_per_shard (pushes/s, per shard) switches
    on the modeled drain clock via a scoped _drain_shard wrapper —
    `cost="sleep"` yields the GIL (dedicated-core model), `cost="burn"`
    holds it (real-CPU model).  The wrapper reaches procpool workers too:
    they are forked after the module is patched."""
    import warnings

    from repro.streaming import DeltaGraph, update_ranks_sharded
    from repro.streaming.incremental import RankState
    from repro.streaming import sharded as sharded_mod

    dg = DeltaGraph(g)
    st = RankState(x=base.x.copy(), r=base.r.copy(), version=0,
                   alpha=base.alpha)
    real_drain = sharded_mod._drain_shard
    part_size = -(-g.n // p)

    if rate_per_shard is not None:
        pay = _spin if cost == "burn" else time.sleep

        def modeled_drain(arrays, x, r, outbox, s, e, *args, **kwargs):
            got = real_drain(arrays, x, r, outbox, s, e, *args, **kwargs)
            if got:
                pay(got / rate_per_shard[min(s // part_size, p - 1)])
            return got
        sharded_mod._drain_shard = modeled_drain
    try:
        t0 = time.perf_counter()
        with warnings.catch_warnings():
            # the burn rows intentionally oversubscribe (one process per
            # shard): the guard's warning is the expected behavior
            warnings.filterwarnings("ignore", message=".*oversubscribes.*",
                                    category=RuntimeWarning)
            st, stats = update_ranks_sharded(dg, delta, st, p=p, tol=TOL,
                                             mode=mode, transport=transport,
                                             n_workers=n_workers,
                                             faults=faults, observe=observe,
                                             schedule=schedule)
        dt = time.perf_counter() - t0
    finally:
        sharded_mod._drain_shard = real_drain
    row = dict(mode=mode, p=p, transport=transport,
               s=round(dt, 3), path=stats.path,
               pushes=int(stats.pushes), supersteps=int(stats.supersteps),
               exchanges=int(stats.exchanges),
               bytes_moved=int(stats.bytes_moved),
               cert=float(stats.cert), idle_s=round(float(stats.idle_s), 3),
               attempts=int(stats.attempts),
               recoveries=int(stats.recoveries),
               recovery_s=round(float(stats.recovery_s), 4))
    if observe:
        # PR 7: attribution roll-up plus the full observed payload (the
        # event stream) — the caller (observe_bench) pops `_observed`
        # before serializing the row
        row.update(pushes_first=int(stats.pushes_first),
                   pushes_local=int(stats.pushes_local),
                   pushes_boundary=int(stats.pushes_boundary))
        row["_observed"] = stats.observed
    return row


_DEVICE_CODE = """
import json, time
import numpy as np
from benchmarks.async_shard_bench import TOL, _workload
from repro.streaming import DeltaGraph, update_ranks_sharded
from repro.streaming.incremental import RankState

g, delta, base = _workload()
rows = []
for p in (1, 4):
    best = None
    for run in range(2):          # second run is warm (jit cached per p)
        dg = DeltaGraph(g)
        st = RankState(x=base.x.copy(), r=base.r.copy(), version=0,
                       alpha=base.alpha)
        t0 = time.perf_counter()
        st, stats = update_ranks_sharded(dg, delta, st, p=p, tol=TOL,
                                         mode="async", transport="device")
        dt = time.perf_counter() - t0
        row = dict(mode="async", p=p, transport="device",
                   s=round(dt, 3), path=stats.path,
                   supersteps=int(stats.supersteps),
                   exchanges=int(stats.exchanges),
                   rows_sent=int(stats.rows_sent), fulls=int(stats.fulls),
                   bytes_moved=int(stats.bytes_moved),
                   cert=float(stats.cert), attempts=int(stats.attempts),
                   device_resid=float(stats.device_resid))
        if run == 0:
            cold_s = row["s"]
        best = row
    best["cold_s"] = cold_s
    rows.append(best)
print("DEVICE_ROWS " + json.dumps(rows))
"""


def _device_rows(timeout: int = 1800):
    """PR 9: the device-transport throughput rows, in a forced-host-device
    subprocess (see the `device` regime note in the module docstring)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.pathsep.join([str(REPO / "src"), str(REPO)])
    out = subprocess.run([sys.executable, "-c", _DEVICE_CODE], env=env,
                         capture_output=True, text=True, timeout=timeout)
    if out.returncode != 0:
        raise RuntimeError(f"device bench subprocess failed:\n"
                           f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}")
    line = next(ln for ln in out.stdout.splitlines()
                if ln.startswith("DEVICE_ROWS "))
    return json.loads(line[len("DEVICE_ROWS "):])


def main():
    print("  [async] building 50k 1%-delta workload (cold solve) ...")
    g, delta, base = _workload()
    cores = os.cpu_count() or 1

    raw = []
    print("  [async] raw wall-clock, p=1..8, async vs superstep ...")
    _run(g, delta, base, "async", 1)            # warm caches
    for mode in ("async", "superstep"):
        for p in PS:
            row = _run(g, delta, base, mode, p)
            raw.append(row)
            print(f"    raw       {mode:9s} p={p} {row['s']:7.2f}s "
                  f"pushes={row['pushes']} path={row['path']}")
    # PR 5: procpool raw rows, p = 1..cores plus the p=4 acceptance point
    pp_ps = sorted({pp for pp in PS if pp <= cores} | {4})
    for p in pp_ps:
        row = _run(g, delta, base, "async", p, transport="procpool")
        raw.append(row)
        print(f"    raw       procpool  p={p} {row['s']:7.2f}s "
              f"pushes={row['pushes']} path={row['path']}")

    print(f"  [async] drain-dominated (modeled {DRAIN_RATE:.0f} pushes/s "
          "per shard, sleep = dedicated cores) ...")
    dom = []
    for mode in ("async", "superstep"):
        for p in PS:
            row = _run(g, delta, base, mode, p,
                       rate_per_shard=[DRAIN_RATE] * p)
            dom.append(row)
            print(f"    dominated {mode:9s} p={p} {row['s']:7.2f}s "
                  f"pushes={row['pushes']} idle={row['idle_s']}s")

    print("  [async] drain-dominated BURN (real CPU per push): threads "
          f"vs procpool, raw wall-clock, best of {BURN_REPEATS} ...")
    # procpool burn rows run one process per shard (n_workers=p): parked
    # shards spend the drain-dominated regime sleeping, and a sleeping
    # shard co-resident with a busy one taxes the busy shard's GIL — one
    # process per shard lets the kernel overlap them (measured ~25% faster
    # than the min(p, cores) pool on the 2-core reference container)
    burn = []
    pp_burn = sorted({pp for pp in (1, 2) if pp <= cores} | {1, 4})
    for transport, ps in (("threads", (1, 4)), ("procpool", pp_burn)):
        for p in ps:
            nw = p if transport == "procpool" else None
            row = min((_run(g, delta, base, "async", p,
                            rate_per_shard=[DRAIN_RATE] * p,
                            transport=transport, cost="burn", n_workers=nw)
                       for _ in range(BURN_REPEATS)), key=lambda r: r["s"])
            burn.append(row)
            print(f"    burn      {transport:9s} p={p} {row['s']:7.2f}s "
                  f"pushes={row['pushes']}")

    print("  [async] chaos (PR 6): p=4 procpool under seeded faults ...")
    # Recovery cost of the self-healing runtime: the acceptance workload
    # drained under (a) a mid-drain worker kill, (b) a 10% drop + 10%
    # duplicate lossy wire, (c) both at once — against a no-fault
    # baseline measured the same way.  `recovery_s` is the supervisor's
    # death-detection -> respawned time; `overhead_vs_no_faults` is total
    # wall-clock (re-drain attempts included) over the clean run.
    from repro.runtime import FaultPlan
    chaos = []
    chaos_plans = [
        ("no_faults", None),
        ("kill", FaultPlan(seed=7, kill={1: 40})),
        ("drop_dup", FaultPlan(seed=7, drop_rate=0.10, dup_rate=0.10)),
        ("kill_drop_dup", FaultPlan(seed=7, kill={1: 40},
                                    drop_rate=0.10, dup_rate=0.10)),
    ]
    base_s = None
    for name, fplan in chaos_plans:
        row = _run(g, delta, base, "async", 4, transport="procpool",
                   faults=fplan)
        row["faults"] = name
        if name == "no_faults":
            base_s = row["s"]
        row["overhead_vs_no_faults"] = (round(row["s"] / base_s, 3)
                                        if base_s else None)
        chaos.append(row)
        print(f"    chaos     {name:14s} p=4 {row['s']:7.2f}s "
              f"recoveries={row['recoveries']} "
              f"recovery_s={row['recovery_s']:.3f} "
              f"overhead={row['overhead_vs_no_faults']}x "
              f"cert={row['cert']:.1e}")

    print("  [async] device transport (PR 9): p=1 vs p=4, forced host "
          "devices, warm wall-clock ...")
    dev = _device_rows()
    for row in dev:
        print(f"    device    {'async':9s} p={row['p']} {row['s']:7.2f}s "
              f"(cold {row['cold_s']:.2f}s) steps={row['supersteps']} "
              f"cert={row['cert']:.1e} path={row['path']}")

    print("  [async] heterogeneous shards (rate/(1+i), p=4) ...")
    het = []
    rates = [DRAIN_RATE / (1 + i) for i in range(4)]
    for mode in ("async", "superstep"):
        row = _run(g, delta, base, mode, 4, rate_per_shard=rates)
        het.append(row)
        print(f"    hetero    {mode:9s} p=4 {row['s']:7.2f}s")

    def t(rows, mode, p, transport="threads"):
        return next(r["s"] for r in rows if r["mode"] == mode
                    and r["p"] == p and r["transport"] == transport)

    rec = dict(
        bench="async shard executor: threads vs procpool (PR 5)",
        workload="50k power-law, 1% delta, tol=1e-8",
        drain_rate_pushes_per_s=DRAIN_RATE,
        cores=cores,
        raw=raw, drain_dominated=dom, drain_dominated_burn=burn,
        heterogeneous=het, chaos=chaos, device=dev,
        device_tol=TOL,
        device_speedup_p4_vs_p1=round(
            t(dev, "async", 1, "device") / t(dev, "async", 4, "device"), 3),
        chaos_recovery_s=next(r["recovery_s"] for r in chaos
                              if r["faults"] == "kill_drop_dup"),
        chaos_overhead_vs_no_faults=next(
            r["overhead_vs_no_faults"] for r in chaos
            if r["faults"] == "kill_drop_dup"),
        speedup_p4_vs_p1_async=round(t(dom, "async", 1)
                                     / t(dom, "async", 4), 3),
        raw_speedup_p4_vs_p1_async=round(t(raw, "async", 1)
                                         / t(raw, "async", 4), 3),
        procpool_raw_speedup_p4_vs_p1=round(
            t(raw, "async", 1, "procpool")
            / t(raw, "async", 4, "procpool"), 3),
        threads_burn_speedup_p4_vs_p1=round(
            t(burn, "async", 1) / t(burn, "async", 4), 3),
        procpool_burn_speedup_p4_vs_p1=round(
            t(burn, "async", 1, "procpool")
            / t(burn, "async", 4, "procpool"), 3),
        procpool_burn_speedup_p2_vs_p1=(round(
            t(burn, "async", 1, "procpool")
            / t(burn, "async", 2, "procpool"), 3)
            if any(r["p"] == 2 and r["transport"] == "procpool"
                   for r in burn) else None),
        speedup_async_vs_superstep_hetero_p4=round(
            t(het, "superstep", 4) / t(het, "async", 4), 3),
    )
    print(f"  [async] device p4-vs-p1 (warm wall-clock, forced host "
          f"devices): {rec['device_speedup_p4_vs_p1']:.2f}x")
    print(f"  [async] drain-dominated p4-vs-p1 async: "
          f"{rec['speedup_p4_vs_p1_async']:.2f}x (sleep) | burn raw: "
          f"threads {rec['threads_burn_speedup_p4_vs_p1']:.2f}x vs "
          f"procpool {rec['procpool_burn_speedup_p4_vs_p1']:.2f}x "
          f"({cores} cores) | hetero p=4 async-vs-superstep: "
          f"{rec['speedup_async_vs_superstep_hetero_p4']:.2f}x")
    RESULTS.mkdir(exist_ok=True, parents=True)
    (RESULTS / "async_shard_bench.json").write_text(
        json.dumps(rec, indent=1))
    return rec


if __name__ == "__main__":
    main()
