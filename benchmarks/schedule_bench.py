"""Drain-schedule bench (PR 8): inflation-aware drain scheduling.

PR 7's attribution decomposed the async scaling tax — p=4 inflates pushes
over p=1, threads losing half-or-more to *local* drain cadence, procpool
~90% to *boundary* re-activation.  This bench measures how far each
`runtime.schedule.ScheduleSpec` rendering closes that gap on the PR 4/5
acceptance workload (50k power-law graph, 1% edge delta, tol=1e-8):

  arms
      For each transport (threads, procpool): the p=1 default-schedule
      baseline, then p=4 under default / priority / boundary / randomized
      / priority+boundary, every arm with attribution on.  The async
      schedule is wall-clock nondeterministic, so every arm is the
      median-of-``REPEATS`` by total pushes (the same stabilization the
      PR 8 observe_bench adopts) and the p=1 / p=4 arms share one
      workload build.  The tuned knobs per transport live in ``TUNED`` —
      priority's boost-2 bar plus a coarser drain stride for the threads
      local-cadence regime; boundary batching (batch_updates=8) on top
      for procpool's boundary regime.

  summary
      Per (transport, schedule): ``inflation_ratio`` = pushes_p4 /
      pushes_p1(default) — the honest denominator: the single-shard
      default drain, so a schedule cannot improve its ratio by inflating
      its own p=1 arm — plus the PR 7 attribution split (local excess vs
      p=1, boundary re-activation) that shows *which* half of the tax the
      schedule removed.  ``best`` picks the lowest-inflation non-default
      schedule per transport; `benchmarks/check_schedule_inflation.py`
      gates threads <= 1.2x and procpool <= 1.1x on it.

  burn projection
      The PR 5 burn regime (real CPU per push) needs >= 4 cores to show
      wall-clock scaling; on smaller containers the machine-independent
      projection ``min(p, cores_assumed=4) * pushes_p1 / pushes_p4`` is
      recorded instead (the burn regime's wall-clock is push-count *
      per-push cost, so fewer pushes convert 1:1).  When the host really
      has >= 4 cores the measured burn rows are emitted too and the gate
      checks both.

Emits benchmarks/results/schedule_bench.json and feeds the ``schedule``
section of BENCH_PR8.json via benchmarks/run.py.
"""
from __future__ import annotations

import dataclasses
import json
import os
import warnings
from pathlib import Path

from benchmarks.async_shard_bench import DRAIN_RATE, _run, _workload
from repro.runtime.schedule import ScheduleSpec

RESULTS = Path(__file__).parent / "results"

REPEATS = 3          # median-of-k by pushes per arm (async nondeterminism)
TOL = 1e-8
PROJECT_CORES = 4    # the burn projection's dedicated-core assumption

#: tuned knobs per transport (measured on the acceptance workload; the
#: spec is recorded verbatim in the JSON so any row is reproducible)
TUNED = {
    "threads": {
        "priority": ScheduleSpec(name="priority", retain_boost=2.0,
                                 drain_frac=0.45),
        "boundary": ScheduleSpec(name="boundary"),
        "randomized": ScheduleSpec(name="randomized", select_frac=0.25),
        "priority+boundary": ScheduleSpec(name="priority+boundary",
                                          retain_boost=2.0,
                                          drain_frac=0.45),
    },
    "procpool": {
        "priority": ScheduleSpec(name="priority", retain_boost=2.0,
                                 drain_frac=0.38),
        "boundary": ScheduleSpec(name="boundary", batch_updates=8),
        "randomized": ScheduleSpec(name="randomized", select_frac=0.25),
        "priority+boundary": ScheduleSpec(name="priority+boundary",
                                          retain_boost=2.0,
                                          batch_updates=8,
                                          drain_frac=0.38),
    },
}


def _median_run(g, delta, base, p, transport, schedule=None, **kw):
    """median-of-REPEATS by total pushes (the gated metric)."""
    nw = p if transport == "procpool" else None
    with warnings.catch_warnings():
        warnings.filterwarnings("ignore", message=".*oversubscribes.*",
                                category=RuntimeWarning)
        rows = sorted((_run(g, delta, base, "async", p, transport=transport,
                            n_workers=nw, observe=True, schedule=schedule,
                            **kw)
                       for _ in range(REPEATS)),
                      key=lambda r: r["pushes"])
    row = rows[len(rows) // 2]
    row.pop("_observed", None)
    return row


def main():
    print("  [schedule] building 50k 1%-delta workload (cold solve) ...")
    g, delta, base = _workload()
    cores = os.cpu_count() or 1

    arms = []
    summary = {}
    for transport in ("threads", "procpool"):
        r1 = _median_run(g, delta, base, 1, transport)
        r1["schedule"] = "default"
        arms.append(r1)
        print(f"    baseline  {transport:9s} p=1 default "
              f"pushes={r1['pushes']}")
        summary[transport] = {}
        scheds = [("default", None)] + sorted(TUNED[transport].items())
        for name, spec in scheds:
            r4 = _median_run(g, delta, base, 4, transport, schedule=spec)
            r4["schedule"] = name
            if spec is not None:
                r4["spec"] = dataclasses.asdict(spec)
            arms.append(r4)
            summary[transport][name] = dict(
                pushes_p1=r1["pushes"], pushes_p4=r4["pushes"],
                inflation_ratio=round(r4["pushes"] / r1["pushes"], 4),
                boundary_p4=r4["pushes_boundary"],
                local_excess=r4["pushes_local"] - r1["pushes_local"],
                cert=r4["cert"],
            )
            d = summary[transport][name]
            print(f"    arm       {transport:9s} p=4 {name:18s} "
                  f"pushes={r4['pushes']} "
                  f"inflation={d['inflation_ratio']:.3f}x "
                  f"local_excess={d['local_excess']} "
                  f"boundary={d['boundary_p4']} cert={r4['cert']:.1e}")

    best = {}
    for transport in ("threads", "procpool"):
        cands = {k: v for k, v in summary[transport].items()
                 if k != "default"}
        name = min(cands, key=lambda k: cands[k]["inflation_ratio"])
        best[transport] = dict(
            schedule=name, spec=dataclasses.asdict(TUNED[transport][name]),
            **cands[name])

    # burn projection (and measurement, when the host can show it)
    pp = best["procpool"]
    projected = round(min(4, PROJECT_CORES)
                      * pp["pushes_p1"] / pp["pushes_p4"], 3)
    burn = dict(cores=cores, project_cores=PROJECT_CORES,
                projected_speedup_p4_vs_p1=projected, measured=None)
    if cores >= 4:
        spec = TUNED["procpool"][best["procpool"]["schedule"]]
        b1 = _median_run(g, delta, base, 1, "procpool",
                         rate_per_shard=[DRAIN_RATE], cost="burn")
        b4 = _median_run(g, delta, base, 4, "procpool", schedule=spec,
                         rate_per_shard=[DRAIN_RATE] * 4, cost="burn")
        burn["measured"] = dict(
            p1_s=b1["s"], p4_s=b4["s"],
            speedup_p4_vs_p1=round(b1["s"] / b4["s"], 3))
        print(f"    burn      procpool  measured "
              f"{burn['measured']['speedup_p4_vs_p1']:.2f}x "
              f"(projected {projected:.2f}x)")
    else:
        print(f"    burn      procpool  projected {projected:.2f}x at "
              f"{PROJECT_CORES} cores ({cores}-core host: wall-clock "
              "scaling cannot manifest; gate uses the push-ratio "
              "projection)")

    for transport in ("threads", "procpool"):
        b = best[transport]
        d0 = summary[transport]["default"]
        print(f"  [schedule] best {transport}: {b['schedule']} "
              f"{b['inflation_ratio']:.3f}x (default "
              f"{d0['inflation_ratio']:.3f}x)")

    rec = dict(
        bench="drain-schedule inflation (PR 8)",
        workload="50k power-law, 1% delta, tol=1e-8",
        tol=TOL, repeats=REPEATS, cores=cores,
        arms=arms, summary=summary, best=best, burn=burn,
    )
    RESULTS.mkdir(exist_ok=True, parents=True)
    (RESULTS / "schedule_bench.json").write_text(json.dumps(rec, indent=1))
    return rec


if __name__ == "__main__":
    main()
