"""Fig. 1 termination state machines — edge-case coverage that must run
even where hypothesis is unavailable (test_termination.py skips wholesale
without it): DIVERGE-after-CONVERGE persistence resets, pc_max > 1
behavior on both machines, and STOP racing an in-flight CONVERGE."""
from repro.core.termination import (CentralizedProtocol, ComputingUEState,
                                    MonitorState, Msg)


def test_diverge_after_converge_resets_pc_with_persistence():
    """DIVERGE after an announced CONVERGE zeroes pc; re-convergence must
    then survive a full pc_max streak before re-announcing."""
    s = ComputingUEState(pc_max=3)
    m = None
    for _ in range(3):
        s, m = s.step(True)
    assert m == Msg.CONVERGE and s.pc == 3
    s, m = s.step(False)
    assert m == Msg.DIVERGE and s.pc == 0 and not s.converged
    # one or two good checks are not enough again
    s, m = s.step(True)
    assert m is None and s.pc == 1
    # Fig. 1 quirk, preserved faithfully: `converged` flips on the FIRST
    # good check, so a flicker emits DIVERGE even though CONVERGE was
    # never announced for this streak (the monitor's recv tolerates it —
    # the flag it clears is already False).
    s, m = s.step(False)
    assert m == Msg.DIVERGE and s.pc == 0
    s, m = s.step(True)
    s, m = s.step(True)
    assert m is None
    s, m = s.step(True)
    assert m == Msg.CONVERGE        # full streak restored


def test_pc_beyond_pcmax_persists_without_reannouncing():
    s = ComputingUEState(pc_max=2)
    msgs = [None] * 6
    for i in range(6):
        s, msgs[i] = s.step(True)
    assert msgs == [None, Msg.CONVERGE, None, None, None, None]
    assert s.pc == 6 and s.converged    # counter keeps the persistence record


def test_monitor_pcmax_persistence_and_diverge_reset():
    """Monitor-side pc_max > 1: STOP needs pc_max consecutive all-green
    evaluations; one DIVERGE in between resets the count."""
    mon = MonitorState.create(2, pc_max=3)
    mon = mon.recv(0, Msg.CONVERGE)
    mon = mon.recv(1, Msg.CONVERGE)
    mon, stop = mon.step()
    assert not stop and mon.pc == 1
    mon, stop = mon.step()
    assert not stop and mon.pc == 2
    mon = mon.recv(1, Msg.DIVERGE)
    mon, stop = mon.step()
    assert not stop and mon.pc == 0 and not mon.converged
    mon = mon.recv(1, Msg.CONVERGE)
    for k in range(3):
        mon, stop = mon.step()
        assert stop == (k == 2)
    assert mon.stop_issued


def test_stop_races_in_flight_converge():
    """A CONVERGE that was in flight when STOP was issued must neither
    re-trigger a stop nor corrupt the monitor; a stopped computing UE
    likewise ignores late local checks."""
    mon = MonitorState.create(2, pc_max=1)
    mon = mon.recv(0, Msg.CONVERGE)
    mon = mon.recv(1, Msg.CONVERGE)
    mon, stop = mon.step()
    assert stop and mon.stop_issued
    # UE 1 diverged and re-converged while the STOP was on the wire: the
    # late messages land on a monitor that already issued STOP
    mon2 = mon.recv(1, Msg.DIVERGE)
    mon2, stop = mon2.step()
    assert not stop                     # no second STOP
    mon2 = mon2.recv(1, Msg.CONVERGE)
    mon2, stop = mon2.step()
    assert not stop and mon2.stop_issued
    # stopped computing UE: step() is a no-op and emits nothing
    ue = ComputingUEState(pc_max=1).stop()
    ue2, msg = ue.step(True)
    assert msg is None and ue2 == ue
    ue2, msg = ue.step(False)
    assert msg is None and ue2 == ue


def test_protocol_stop_latches_against_late_divergence():
    """CentralizedProtocol: once STOP is issued, late reports (e.g. an
    iteration that was already executing) cannot un-stop the system."""
    proto = CentralizedProtocol(p=2)
    proto.report(0, True)
    assert proto.report(1, True)        # STOP
    assert proto.stopped
    assert proto.report(0, False)       # late diverge: still stopped
    assert proto.report(1, True)
    assert all(s.stopped for s in proto.ues)
    assert proto.monitor.stop_issued
