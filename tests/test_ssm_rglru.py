"""SSD chunked scan and RG-LRU vs naive recurrences (oracle tests)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models.ssm import _ssd_scan
from repro.models.rglru import _lru_coeffs, rglru_apply, rglru_defs
from repro.models.param import init_params
from repro.configs import SMOKE_REGISTRY


@pytest.mark.parametrize("S,chunk", [(16, 4), (17, 4), (32, 8), (8, 8)])
def test_ssd_chunked_equals_naive(S, chunk):
    rng = np.random.default_rng(S)
    B, H, P, N = 2, 3, 4, 5
    xh = jnp.asarray(rng.standard_normal((B, S, H, P)), jnp.float32)
    bh = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    ch = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    dt = jnp.asarray(rng.random((B, S, H)) * 0.5 + 0.1, jnp.float32)
    a_log = jnp.asarray(rng.random(H) * 0.5, jnp.float32)

    y, h_last = _ssd_scan(xh, bh, ch, dt, a_log, chunk)

    # naive token recurrence: h_t = exp(dt_t * A) h_{t-1} + dt_t x_t B_t^T
    A = -np.exp(np.asarray(a_log))
    h = np.zeros((B, H, P, N))
    y_ref = np.zeros((B, S, H, P))
    for t in range(S):
        decay = np.exp(np.asarray(dt[:, t]) * A)          # (B, H)
        h = h * decay[:, :, None, None] + np.einsum(
            "bh,bhp,bn->bhpn", np.asarray(dt[:, t]),
            np.asarray(xh[:, t]), np.asarray(bh[:, t]))
        y_ref[:, t] = np.einsum("bn,bhpn->bhp", np.asarray(ch[:, t]), h)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_last), h, rtol=2e-4, atol=2e-4)


def test_rglru_scan_equals_loop():
    cfg = SMOKE_REGISTRY["recurrentgemma-2b"]
    defs = rglru_defs(cfg)
    p = init_params(defs, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 10, cfg.d_model)) * 0.3,
                    jnp.float32)
    y = rglru_apply(p, x, cfg)

    # naive loop over the same coefficients
    from repro.models.rglru import _causal_conv
    u = _causal_conv(x @ p["w_in"], p["conv"])
    a, b = _lru_coeffs(p, u)
    h = np.zeros((2, cfg.lru_width_))
    hs = []
    for t in range(10):
        h = np.asarray(a[:, t]) * h + np.asarray(b[:, t])
        hs.append(h.copy())
    hs = np.stack(hs, axis=1)
    gate = jax.nn.gelu((x @ p["w_gate_branch"]).astype(jnp.float32))
    y_ref = (jnp.asarray(hs) * gate).astype(x.dtype) @ p["w_out"]
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


def test_rglru_decay_in_unit_interval():
    cfg = SMOKE_REGISTRY["recurrentgemma-2b"]
    p = init_params(rglru_defs(cfg), jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    u = jnp.asarray(rng.standard_normal((1, 8, cfg.lru_width_)), jnp.float32)
    a, b = _lru_coeffs(p, u)
    assert bool((a > 0).all()) and bool((a < 1).all())
