"""Required per-arch smoke tests: reduced config, one forward + one train
step on CPU, output shapes + no NaNs."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import SMOKE_REGISTRY, REGISTRY, ARCH_NAMES
from repro.models.param import init_params, count_params
from repro.models.transformer import model_defs, forward
from repro.training.optimizer import OptConfig, init_opt_state
from repro.training.train_step import make_train_step

ARCHS = list(SMOKE_REGISTRY)


def make_inputs(cfg, B=2, S=16, seed=0):
    rng = jax.random.PRNGKey(seed)
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    if cfg.is_encdec:
        batch["enc_inputs"] = jax.random.normal(
            jax.random.PRNGKey(seed + 1), (B, S, cfg.d_model),
            cfg.dtype()) * 0.1
    if cfg.prefix_len:
        batch["prefix_embeds"] = jax.random.normal(
            jax.random.PRNGKey(seed + 2), (B, cfg.prefix_len, cfg.d_model),
            cfg.dtype()) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = SMOKE_REGISTRY[arch]
    params = init_params(model_defs(cfg), jax.random.PRNGKey(0))
    batch = make_inputs(cfg)
    kwargs = {k: v for k, v in batch.items() if k != "tokens"}
    logits, aux = forward(params, cfg, batch["tokens"], **kwargs)
    S_total = batch["tokens"].shape[1] + cfg.prefix_len
    assert logits.shape == (2, S_total, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = SMOKE_REGISTRY[arch]
    opt_cfg = OptConfig(peak_lr=1e-3, warmup_steps=2, total_steps=10)
    params = init_params(model_defs(cfg), jax.random.PRNGKey(0))
    state = {"params": params, "opt": init_opt_state(params, opt_cfg)}
    step = jax.jit(make_train_step(cfg, opt_cfg))
    batch = make_inputs(cfg)
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually changed
    changed = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        state["params"], new_state["params"])
    assert max(jax.tree_util.tree_leaves(changed)) > 0
    assert int(new_state["opt"]["step"]) == 1


def test_all_archs_registered():
    assert len(ARCH_NAMES) == 10
    for name in ARCH_NAMES:
        assert name in REGISTRY and name in SMOKE_REGISTRY


def test_full_config_dims():
    """Spot-check the full (assigned) configs against the assignment."""
    c = REGISTRY["deepseek-v3-671b"]
    assert (c.n_layers, c.d_model, c.n_heads) == (61, 7168, 128)
    assert c.n_experts == 256 and c.top_k == 8 and c.use_mla
    c = REGISTRY["yi-6b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (32, 4096, 32, 4)
    assert c.d_ff == 11008 and c.vocab_size == 64_000
    c = REGISTRY["mamba2-2.7b"]
    assert c.n_layers == 64 and c.d_model == 2560 and c.ssm_state == 128
    assert c.layer_kinds() == ("ssd",) * 64
    c = REGISTRY["recurrentgemma-2b"]
    assert c.layer_kinds()[:3] == ("rglru", "rglru", "local_attn")
    c = REGISTRY["paligemma-3b"]
    assert c.vocab_size == 257_216 and c.prefix_len == 256
    c = REGISTRY["whisper-base"]
    assert c.n_enc_layers == 6 and c.padded_vocab % 128 == 0


def test_vocab_padding_divisible_by_tp():
    for name, c in REGISTRY.items():
        assert c.padded_vocab % 16 == 0, name


def test_param_counts_in_range():
    """Full configs should land near their advertised sizes."""
    expected = {"deepseek-v3-671b": (550e9, 750e9),
                "yi-6b": (5e9, 7e9),
                "qwen1.5-4b": (3e9, 5e9),
                "minitron-4b": (3.5e9, 5.3e9),
                "mamba2-2.7b": (2.2e9, 3.2e9),
                "paligemma-3b": (2.2e9, 3.2e9),
                "recurrentgemma-2b": (2.2e9, 3.4e9),
                "smollm-360m": (0.3e9, 0.45e9),
                "whisper-base": (0.05e9, 0.11e9)}
    for name, (lo, hi) in expected.items():
        n = count_params(model_defs(REGISTRY[name]))
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"
