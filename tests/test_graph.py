import numpy as np
import pytest

from repro.graph.generate import (powerlaw_webgraph, cycle_graph,
                                  stanford_web_replica, STANFORD_N,
                                  STANFORD_NNZ, STANFORD_DANGLING)
from repro.graph.csr import CSRGraph, TransitionT
from repro.graph.google import GoogleOperator, exact_pagerank


def test_generator_statistics(small_graph):
    assert small_graph.n == 2000
    assert abs(small_graph.nnz - 16000) <= 16000 * 0.02
    assert small_graph.dangling_mask.sum() == 10


def test_generator_deterministic():
    g1 = powerlaw_webgraph(n=500, target_nnz=3000, n_dangling=4, seed=3)
    g2 = powerlaw_webgraph(n=500, target_nnz=3000, n_dangling=4, seed=3)
    assert np.array_equal(g1.indices, g2.indices)
    assert np.array_equal(g1.indptr, g2.indptr)


def test_transition_is_stochastic(small_graph):
    pt = TransitionT.from_graph(small_graph)
    col_sums = np.zeros(small_graph.n)
    np.add.at(col_sums, pt.src, pt.weight)
    linked = ~small_graph.dangling_mask
    np.testing.assert_allclose(col_sums[linked], 1.0, atol=1e-12)
    np.testing.assert_allclose(col_sums[~linked], 0.0, atol=1e-12)


def test_transition_matches_scipy(small_graph):
    pt = TransitionT.from_graph(small_graph)
    A = small_graph.to_scipy().astype(np.float64)
    deg = np.asarray(A.sum(axis=1)).ravel()
    inv = np.where(deg > 0, 1.0 / np.maximum(deg, 1), 0.0)
    P = (A.multiply(inv[:, None])).tocsr()
    diff = (pt.to_scipy() - P.T).tocoo()
    assert np.abs(diff.data).max() < 1e-12 if diff.nnz else True


def test_pagerank_vs_networkx(small_graph):
    nx = pytest.importorskip("networkx")
    pt = TransitionT.from_graph(small_graph)
    op = GoogleOperator(pt=pt, alpha=0.85)
    x = exact_pagerank(op, tol=1e-13)
    G = nx.DiGraph()
    G.add_nodes_from(range(small_graph.n))
    for i in range(small_graph.n):
        for j in small_graph.indices[
                small_graph.indptr[i]:small_graph.indptr[i + 1]]:
            G.add_edge(i, int(j))
    pr = nx.pagerank(G, alpha=0.85, tol=1e-12, max_iter=1000)
    xr = np.array([pr[i] for i in range(small_graph.n)])
    assert np.abs(x - xr).max() < 1e-9


def test_cycle_uniform():
    c = cycle_graph(64)
    op = GoogleOperator(pt=TransitionT.from_graph(c))
    x = exact_pagerank(op)
    np.testing.assert_allclose(x, 1.0 / 64, atol=1e-12)


def test_mass_conservation(small_op):
    x = np.random.default_rng(0).random(small_op.n)
    x /= x.sum()
    y = small_op.apply_numpy(x)
    assert abs(y.sum() - 1.0) < 1e-12  # G is column-stochastic


@pytest.mark.slow
def test_stanford_replica_statistics():
    g = stanford_web_replica(seed=0)
    assert g.n == STANFORD_N
    assert abs(g.nnz - STANFORD_NNZ) <= STANFORD_NNZ * 0.02
    assert g.dangling_mask.sum() == STANFORD_DANGLING
