"""Decode/caching correctness: step-by-step decode must reproduce the
training forward exactly (per-arch), including ring-buffer local attention
beyond the window and O(1) SSM/LRU states."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import SMOKE_REGISTRY
from repro.models.param import init_params
from repro.models.transformer import model_defs, forward, _run_stack
from repro.models.blocks import rmsnorm
from repro.models.decode import init_cache, decode_step

NON_PREFIX = [a for a, c in SMOKE_REGISTRY.items() if not c.prefix_len]


def setup(arch, B=2, S=12, seed=0):
    import dataclasses
    cfg = SMOKE_REGISTRY[arch]
    if cfg.n_experts:
        # capacity dropping is a train-time approximation; decode routes
        # tiny groups with no capacity pressure. Equivalence holds only
        # drop-free, so the consistency test raises the factor.
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)
    params = init_params(model_defs(cfg), jax.random.PRNGKey(seed))
    tokens = jax.random.randint(jax.random.PRNGKey(seed + 1), (B, S), 0,
                                cfg.vocab_size)
    enc_inputs = None
    enc_out = None
    if cfg.is_encdec:
        enc_inputs = jax.random.normal(
            jax.random.PRNGKey(seed + 2), (B, 16, cfg.d_model),
            cfg.dtype()) * 0.1
        e, _ = _run_stack(params["encoder"], enc_inputs, cfg,
                          cfg.n_enc_layers, 0, positions=jnp.arange(16),
                          causal=False)
        enc_out = rmsnorm(e, params["enc_norm"], cfg.norm_eps)
    return cfg, params, tokens, enc_inputs, enc_out


@pytest.mark.parametrize("arch", NON_PREFIX)
def test_decode_matches_forward(arch):
    cfg, params, tokens, enc_inputs, enc_out = setup(arch)
    B, S = tokens.shape
    kwargs = {"enc_inputs": enc_inputs} if cfg.is_encdec else {}
    ref_logits, _ = forward(params, cfg, tokens, **kwargs)

    cache = init_cache(cfg, B, t_max=S, enc_out=enc_out, params=params)
    step = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c))
    outs = []
    for t in range(S):
        lg, cache = step(params, tokens[:, t], cache)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    scale = float(jnp.abs(ref_logits).max())
    assert float(jnp.abs(dec - ref_logits).max()) / scale < 1e-4


def test_local_attention_ring_buffer_beyond_window():
    """recurrentgemma with S > window: the ring cache must still match the
    windowed training forward."""
    cfg = SMOKE_REGISTRY["recurrentgemma-2b"]  # window = 16
    S = 24  # exceeds window
    params = init_params(model_defs(cfg), jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0,
                                cfg.vocab_size)
    ref_logits, _ = forward(params, cfg, tokens)
    cache = init_cache(cfg, 1, t_max=S)
    step = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c))
    outs = []
    for t in range(S):
        lg, cache = step(params, tokens[:, t], cache)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    scale = float(jnp.abs(ref_logits).max())
    assert float(jnp.abs(dec - ref_logits).max()) / scale < 1e-4


def test_cache_length_tracking():
    cfg = SMOKE_REGISTRY["smollm-360m"]
    params = init_params(model_defs(cfg), jax.random.PRNGKey(0))
    cache = init_cache(cfg, 1, t_max=8)
    assert int(cache["length"]) == 0
    tok = jnp.zeros((1,), jnp.int32)
    _, cache = decode_step(params, cfg, tok, cache)
    assert int(cache["length"]) == 1
    _, cache = decode_step(params, cfg, tok, cache)
    assert int(cache["length"]) == 2


def test_ssd_state_is_constant_size():
    """SSM decode memory must not grow with sequence length (the long_500k
    enabler)."""
    cfg = SMOKE_REGISTRY["mamba2-2.7b"]
    c1 = jax.eval_shape(lambda: init_cache(cfg, 1, t_max=128))
    c2 = jax.eval_shape(lambda: init_cache(cfg, 1, t_max=1 << 20))
    sz = lambda c: sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(c)
                       if hasattr(l, "shape"))
    assert sz(c1) == sz(c2)
