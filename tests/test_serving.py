"""ServeEngine: batched prefill + generation across cache families."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import SMOKE_REGISTRY
from repro.models.param import init_params
from repro.models.transformer import model_defs
from repro.serving.engine import ServeEngine


def make_engine(arch, max_len=32):
    cfg = SMOKE_REGISTRY[arch]
    params = init_params(model_defs(cfg), jax.random.PRNGKey(0))
    return cfg, ServeEngine(cfg, params, max_len=max_len)


@pytest.mark.parametrize("arch", ["smollm-360m", "mamba2-2.7b",
                                  "recurrentgemma-2b"])
def test_generate_shapes_and_range(arch):
    cfg, eng = make_engine(arch)
    prompts = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (3, 8)),
        jnp.int32)
    out = eng.generate(prompts, 6, temperature=1.0, seed=1)
    assert out.shape == (3, 6)
    assert int(out.min()) >= 0 and int(out.max()) < cfg.vocab_size


def test_greedy_deterministic():
    cfg, eng = make_engine("smollm-360m")
    prompts = jnp.asarray([[5, 9, 2, 7]], jnp.int32)
    a = eng.generate(prompts, 8, temperature=0.0)
    b = eng.generate(prompts, 8, temperature=0.0)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_prefill_matches_decode_path():
    """Prefill is decode-by-construction: its logits equal forward()'s."""
    from repro.models.transformer import forward
    cfg, eng = make_engine("qwen1.5-4b")
    prompts = jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab_size, (2, 6)),
        jnp.int32)
    logits, cache = eng.prefill(prompts)
    ref, _ = forward(eng.params, cfg, prompts)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref[:, -1]),
                               rtol=2e-4, atol=2e-4)
    assert int(cache["length"]) == 6


def test_sampled_tokens_respect_vocab_mask():
    """Padded vocab tail must never be sampled."""
    import dataclasses
    cfg = dataclasses.replace(SMOKE_REGISTRY["whisper-base"],
                              vocab_size=500)  # pads to 512
    from repro.models.param import init_params as ip
    params = ip(model_defs(cfg), jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=32)
    assert cfg.padded_vocab > cfg.vocab_size
    prompts = jnp.asarray([[1, 2, 3]], jnp.int32)
    out = eng.generate(prompts, 16, temperature=2.0, seed=3)
    assert int(out.max()) < cfg.vocab_size
