"""BSR-vs-segment-sum backend equivalence: the bsr_pallas path must produce
the same PageRank (values to f32 accuracy, ranking essentially exactly) as
the segment-sum reference on randomized power-law graphs, with multi-vector
lanes, under reorderings, and end to end through solve_power."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.graph.generate import powerlaw_webgraph
from repro.graph.csr import TransitionT
from repro.graph.google import GoogleOperator
from repro.core import (solve_power, solve_linear, kendall_tau_topk,
                        BackendSpec)
from repro.kernels.bsr_spmv import (build_bsr, build_hybrid_bsr,
                                    hybrid_from_transition, hybrid_matvec,
                                    pad_x, unpad_y)


def _op(n, nnz, seed, alpha=0.85):
    g = powerlaw_webgraph(n=n, target_nnz=nnz, n_dangling=max(2, n // 500),
                          seed=seed)
    return GoogleOperator(pt=TransitionT.from_graph(g), alpha=alpha)


# ---------------------------------------------------------------------------
# layer 1: the hybrid (hub-split) matvec against scipy
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed,bm,hub_q", [(0, 32, 0.99), (1, 16, 0.95),
                                           (2, 32, 1.0)])
def test_hybrid_matvec_vs_scipy(seed, bm, hub_q):
    g = powerlaw_webgraph(n=900, target_nnz=7000, n_dangling=5, seed=seed)
    pt = TransitionT.from_graph(g)
    hyb = hybrid_from_transition(pt, bm=bm, bn=bm, hub_quantile=hub_q)
    rng = np.random.default_rng(seed)
    x = rng.random((g.n, 2)).astype(np.float32)
    xp = jnp.asarray(pad_x(x, g.n, bm))
    y = unpad_y(np.asarray(hybrid_matvec(hyb.device(), xp, impl="ref")), g.n)
    y_ref = pt.to_scipy() @ x.astype(np.float64)
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-6)
    if hub_q < 1.0:
        assert hyb.hub_nnz_frac > 0  # the split actually routed something


def test_bincount_scatter_matches_add_at():
    rng = np.random.default_rng(3)
    rows = rng.integers(0, 500, 4000)
    cols = rng.integers(0, 300, 4000)
    vals = rng.standard_normal(4000)
    a = build_bsr(rows, cols, vals, 500, 300, bm=32, bn=16,
                  scatter="bincount")
    b = build_bsr(rows, cols, vals, 500, 300, bm=32, bn=16,
                  scatter="add_at")
    np.testing.assert_array_equal(a.blk_cols, b.blk_cols)
    np.testing.assert_allclose(a.blocks, b.blocks, rtol=1e-6, atol=1e-6)


def test_hybrid_caps_k():
    # a graph with hub rows: without the split K explodes to ~nbc
    g = powerlaw_webgraph(n=4000, target_nnz=40000, n_dangling=4, seed=11)
    pt = TransitionT.from_graph(g)
    full = build_bsr(pt.row_ids.astype(np.int64), pt.src.astype(np.int64),
                     np.asarray(pt.weight, np.float32), pt.n, pt.n,
                     bm=32, bn=32)
    hyb = hybrid_from_transition(pt, bm=32, bn=32, hub_quantile=0.99)
    assert hyb.bsr.K < full.K
    assert hyb.bsr.fill_ratio > full.fill_ratio


# ---------------------------------------------------------------------------
# layer 2: full solves agree across backends
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_solve_power_backends_agree(seed):
    op = _op(1200 + 700 * seed, 9000 + 4000 * seed, seed)
    ref = solve_power(op, tol=1e-12, max_iters=2000)
    bsr = solve_power(op, tol=3e-7, max_iters=500, backend="bsr_pallas")
    assert np.abs(ref.x - bsr.x).max() < 1e-6
    assert kendall_tau_topk(ref.x, bsr.x, k=100) > 0.999


def test_solve_linear_backends_agree():
    op = _op(1500, 11000, 5)
    ref = solve_linear(op, tol=1e-12, max_iters=2000)
    bsr = solve_linear(op, tol=3e-7, max_iters=500, backend="bsr_pallas")
    assert np.abs(ref.x - bsr.x).max() < 1e-6


def test_multivector_lanes_match_individual_solves():
    op = _op(1000, 8000, 7)
    rng = np.random.default_rng(7)
    V = rng.random((op.n, 3))
    V /= V.sum(axis=0)
    multi = solve_power(op, tol=3e-7, v=V, backend="bsr_pallas")
    assert multi.x.shape == (op.n, 3)
    assert multi.resid_per_vec is not None
    assert multi.resid_per_vec.shape == (3,)
    for k in range(3):
        single = solve_power(op, tol=1e-10, v=V[:, k])
        assert np.abs(multi.x[:, k] - single.x).max() < 1e-6
        assert kendall_tau_topk(multi.x[:, k], single.x, k=50) > 0.999


@pytest.mark.parametrize("method", ["rcm", "indeg"])
def test_reordered_solve_matches(method):
    op = _op(1100, 9000, 13)
    plain = solve_power(op, tol=1e-10)
    perm = solve_power(op, tol=3e-7, backend="bsr_pallas", reorder=method)
    assert np.abs(plain.x - perm.x).max() < 1e-6


def test_interpret_mode_pallas_end_to_end():
    """The actual Pallas kernel (interpret mode on CPU) inside the fused
    solver loop — small graph, real grid."""
    op = _op(400, 2500, 17)
    spec = BackendSpec(name="bsr_pallas", impl="interpret", bm=16)
    ref = solve_power(op, tol=1e-10)
    ki = solve_power(op, tol=3e-7, backend=spec)
    assert np.abs(ref.x - ki.x).max() < 1e-6
    assert kendall_tau_topk(ref.x, ki.x, k=50) > 0.999


def test_repeated_solves_reuse_cached_state():
    op = _op(800, 6000, 19)
    solve_power(op, tol=3e-7, backend="bsr_pallas")
    cache = op._cache()
    assert any(k[0] == "hybrid" for k in cache)
    hyb_before = {k: v for k, v in cache.items() if k[0] == "hybrid"}
    solve_power(op, tol=3e-7, backend="bsr_pallas")
    for k, v in hyb_before.items():
        assert cache[k] is v  # same object — no re-pack
    # segment_sum device arrays are memoized per dtype as well
    d1 = op.device_arrays(dtype=jnp.float32)
    d2 = op.device_arrays(dtype=jnp.float32)
    assert d1["weight"] is d2["weight"]


@pytest.mark.slow
def test_rank_agreement_50k():
    """Acceptance gate: ≥50k-node power-law graph, bsr_pallas vs
    segment_sum, Kendall-tau top-100 ≥ 0.999."""
    op = _op(50_000, 400_000, 3)
    ref = solve_power(op, tol=1e-10, max_iters=1000)
    bsr = solve_power(op, tol=1e-6, max_iters=300, backend="bsr_pallas")
    tau = kendall_tau_topk(ref.x, bsr.x, k=100)
    assert tau >= 0.999, tau
