"""Device shard transport (PR 9): validation seams in-process, then the
acceptance runs in forced-host-device subprocesses — golden agreement with
the threads transport at p in {2, 4}, the 50k 1%-delta certification at
tol=1e-8 against a cold solve, and the comm-bytes accounting contract
(device stats == the shared step.comm_bytes_model == the SPMD counters'
model)."""
import numpy as np
import pytest

import repro.core  # noqa: F401  (resolves the runtime<->core import cycle)
from _subproc import run_with_devices
from repro.runtime import DeviceShardTransport, comm_bytes_model
from repro.runtime.faults import FaultPlan
from repro.streaming import DeltaGraph, EdgeDelta, cold_state, \
    update_ranks_sharded
from repro.streaming.server import RankServer
from repro.graph.generate import powerlaw_webgraph


# ---------------------------------------------------------------------------
# validation (in-process, no device mesh needed)
# ---------------------------------------------------------------------------
def _small_update_args():
    g = powerlaw_webgraph(n=300, target_nnz=2000, n_dangling=3, seed=11)
    dg = DeltaGraph(g)
    st = cold_state(dg, tol=1e-9)
    d = EdgeDelta.inserts(np.array([5, 17]), np.array([40, 2]))
    return dg, d, st


def test_device_transport_validation():
    dg, d, st = _small_update_args()
    with pytest.raises(ValueError, match="requires mode='async'"):
        update_ranks_sharded(dg, d, st, p=2, mode="superstep",
                             transport="device")
    with pytest.raises(ValueError, match="faults"):
        update_ranks_sharded(dg, d, st, p=2, mode="async",
                             transport="device",
                             faults=FaultPlan(kill={0: 1}))
    with pytest.raises(ValueError, match="observe"):
        update_ranks_sharded(dg, d, st, p=2, mode="async",
                             transport="device", observe=True)
    with pytest.raises(ValueError, match="schedule"):
        update_ranks_sharded(dg, d, st, p=2, mode="async",
                             transport="device", schedule="priority")
    with pytest.raises(ValueError, match="unknown transport"):
        update_ranks_sharded(dg, d, st, p=2, mode="async",
                             transport="tpu")


def test_device_transport_ctor_validation():
    with pytest.raises(ValueError, match="schedule"):
        DeviceShardTransport(2, exchange="gossip")
    with pytest.raises(ValueError, match="backend"):
        DeviceShardTransport(2, backend="cusparse")
    # this host exposes a single default device: asking for a p=4 mesh
    # must fail with the XLA_FLAGS hint, not a shard_map shape error
    import jax
    if len(jax.devices()) < 4:
        t = DeviceShardTransport(4)
        with pytest.raises(RuntimeError, match="host_platform_device_count"):
            t._mesh()


def test_server_accepts_device_transport():
    dg, _, _ = _small_update_args()
    with pytest.raises(ValueError, match="requires shard_mode='async'"):
        RankServer(dg, updater="sharded", shard_transport="device")
    srv = RankServer(dg, updater="sharded", shard_mode="async",
                     shard_transport="device")
    assert srv.shard_transport == "device"


def test_comm_bytes_model_schedules():
    # the shared model is what both solve_spmd's chunk accounting and the
    # device transport report through; pin its algebra per schedule
    kw = dict(p=4, bsize=100, itemsize=8, nv=2, steps=10, rows=50,
              fulls=3, sync_every=5)
    assert comm_bytes_model("allgather", **kw) == 4 * 3 * 800 * 2 * 10
    assert comm_bytes_model("ring", **kw) == 4 * 800 * 2 * 10
    assert comm_bytes_model("allgather_k", **kw) \
        == (4 * 3 * 800 * 2 // 5) * 10
    assert comm_bytes_model("sparsified", **kw) \
        == 50 * 3 * (4 + 8 * 2) + 3 * 3 * 800 * 2


# ---------------------------------------------------------------------------
# acceptance (forced host devices, subprocess)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_device_golden_agreement_vs_threads_4dev():
    """p in {2, 4} on a seeded 5k graph: the device drain and the threads
    drain both certify the same update at tol=1e-8, so their iterates
    agree within 2*tol in L1; the device byte accounting reproduces from
    the (rows, fulls) counters through the shared model."""
    out = run_with_devices("""
import numpy as np
from repro.runtime import comm_bytes_model
from repro.streaming import DeltaGraph, EdgeDelta, cold_state, \\
    update_ranks_sharded
from repro.graph.generate import powerlaw_webgraph

tol = 1e-8
g = powerlaw_webgraph(n=5000, target_nnz=40000, n_dangling=50, seed=3)
rng = np.random.default_rng(7)
delta = EdgeDelta.inserts(rng.integers(0, 5000, 200),
                          rng.integers(0, 5000, 200))
for p in (2, 4):
    res = {}
    for transport in ("threads", "device"):
        dg = DeltaGraph(powerlaw_webgraph(n=5000, target_nnz=40000,
                                          n_dangling=50, seed=3))
        st = cold_state(dg, tol=tol)
        st, stats = update_ranks_sharded(
            dg, delta, st, p=p, tol=tol, exchange="sparsified",
            mode="async", transport=transport)
        # the device drain itself must certify; threads may legitimately
        # take its warm-started solver fallback at this tolerance — its
        # certified iterate is still the agreement reference either way
        if transport == "device":
            assert stats.path == "sharded_push", stats.path
        assert stats.cert <= tol, (transport, stats.cert)
        res[transport] = (st.x.copy(), stats)
    xd, sd = res["device"]
    xt, _ = res["threads"]
    gap = np.abs(xd - xt).sum()
    assert gap <= 2 * tol, (p, gap)
    # §6 counters are live and the bytes reproduce through the model
    assert sd.rows_sent > 0 and sd.fulls > 0
    bsize = -(-5000 // p)
    model = comm_bytes_model("sparsified", p=p, bsize=bsize, itemsize=8,
                             nv=1, steps=sd.supersteps, rows=sd.rows_sent,
                             fulls=sd.fulls)
    assert sd.bytes_moved == model, (sd.bytes_moved, model)
    print("p", p, "gap", gap, "steps", sd.supersteps, "OK")
print("golden-agreement OK")
""", n_devices=4, timeout=900)
    assert "golden-agreement OK" in out


@pytest.mark.slow
def test_device_50k_delta_certifies_vs_cold_4dev():
    """The acceptance workload: 50k pages, a ~1% edge delta, device drain
    at p=4 certifies ||x - x*||_1 <= tol at tol=1e-8 against a cold
    solve of the post-delta graph."""
    out = run_with_devices("""
import numpy as np
from repro.streaming import DeltaGraph, EdgeDelta, cold_state, \\
    update_ranks_sharded
from repro.graph.generate import powerlaw_webgraph

tol = 1e-8
n = 50_000
g = powerlaw_webgraph(n=n, target_nnz=400_000, n_dangling=500, seed=9)
dg = DeltaGraph(g)
st = cold_state(dg, tol=tol)
rng = np.random.default_rng(13)
m = 4000   # ~1% of edges
src = rng.integers(0, n, m)
dst = rng.integers(0, n, m)
st, stats = update_ranks_sharded(dg, EdgeDelta.inserts(src, dst), st,
                                 p=4, tol=tol, exchange="sparsified",
                                 mode="async", transport="device")
assert stats.path == "sharded_push", stats.path
assert stats.transport == "device" and stats.mode == "async"
assert stats.cert <= tol, stats.cert

# certify against an independent cold solve of the SAME post-delta graph
dg2 = DeltaGraph(powerlaw_webgraph(n=n, target_nnz=400_000,
                                   n_dangling=500, seed=9))
dg2.apply(EdgeDelta.inserts(src, dst))
cold = cold_state(dg2, tol=tol)
gap = np.abs(st.x - cold.x).sum()
assert gap <= 2 * tol, gap
print("50k cert", stats.cert, "gap", gap, "steps", stats.supersteps, "OK")
""", n_devices=4, timeout=900)
    assert "OK" in out


@pytest.mark.slow
def test_device_matches_spmd_sparsified_accounting_4dev():
    """The tentpole's shared-step contract: solve_spmd and the device
    transport run the same traced body, so on the same operator and
    schedule their sparsified byte accounting goes through the identical
    model (bytes == model(rows, fulls) on both sides)."""
    out = run_with_devices("""
import numpy as np
from repro.core import SPMDConfig, solve_spmd
from repro.runtime import DeviceShardTransport, comm_bytes_model
from repro.graph.generate import powerlaw_webgraph
from repro.graph.csr import TransitionT
from repro.graph.google import GoogleOperator, exact_pagerank

g = powerlaw_webgraph(n=800, target_nnz=6000, n_dangling=5, seed=3)
op = GoogleOperator(pt=TransitionT.from_graph(g), alpha=0.85)
xref = exact_pagerank(op, tol=1e-13)

cfg = SPMDConfig(p=4, schedule="sparsified", tol=1e-8, max_supersteps=500,
                 sparsify_refresh_every=8)
r = solve_spmd(op, cfg, observe=True)
bsize = -(-800 // 4)
# the SPMD side: the chunk log carries the honest (rows, fulls) in-loop
# counters, and the recorded bytes must reproduce through the one model
c = r.chunk_log[0]
assert r.comm_bytes_total == comm_bytes_model(
    "sparsified", p=4, bsize=bsize,
    itemsize=np.dtype(cfg.dtype).itemsize, nv=1,
    steps=c["steps"], rows=c["rows"], fulls=c["fulls"])

# the device side: same model, float64 itemsize
dev = DeviceShardTransport(4, exchange="sparsified",
                           sparsify_refresh_every=8)
x0 = np.full(800, 1.0 / 800)
res = dev.run(op, x0, target=0.5 * 0.15 * 1e-8, max_supersteps=2000)
assert res.converged
assert np.abs(res.x - xref).sum() <= 5e-8
assert res.comm_bytes_total == comm_bytes_model(
    "sparsified", p=4, bsize=bsize, itemsize=8, nv=1,
    steps=res.supersteps, rows=res.rows_sent, fulls=res.fulls)
print("accounting OK")
""", n_devices=4, timeout=900)
    assert "accounting OK" in out
