"""BSR SpMV Pallas kernel: interpret-mode sweeps vs the pure-jnp oracle."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels.bsr_spmv import (build_bsr, bsr_from_transition, pad_x,
                                    unpad_y, spmv, bsr_spmv_ref)
from repro.graph.generate import powerlaw_webgraph
from repro.graph.csr import TransitionT


def random_coo(rng, n_rows, n_cols, nnz):
    rows = rng.integers(0, n_rows, nnz)
    cols = rng.integers(0, n_cols, nnz)
    vals = rng.standard_normal(nnz)
    # dedup
    key = rows * n_cols + cols
    _, idx = np.unique(key, return_index=True)
    return rows[idx], cols[idx], vals[idx]


@pytest.mark.parametrize("n_rows,n_cols,nnz,bm,bn,nv", [
    (100, 100, 500, 32, 32, 1),
    (257, 130, 800, 64, 32, 4),
    (512, 512, 4000, 128, 128, 8),
    (64, 300, 600, 16, 64, 2),
])
def test_kernel_matches_ref_shapes(n_rows, n_cols, nnz, bm, bn, nv):
    rng = np.random.default_rng(nnz)
    rows, cols, vals = random_coo(rng, n_rows, n_cols, nnz)
    bsr = build_bsr(rows, cols, vals, n_rows, n_cols, bm=bm, bn=bn)
    x = rng.standard_normal((n_cols, nv)).astype(np.float32)
    xp = jnp.asarray(pad_x(x, n_cols, bn))
    y_k = np.asarray(spmv(bsr, xp, interpret=True))
    y_r = np.asarray(bsr_spmv_ref(*bsr.device(), xp))
    np.testing.assert_allclose(y_k, y_r, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_kernel_dtypes(dtype):
    rng = np.random.default_rng(0)
    rows, cols, vals = random_coo(rng, 128, 128, 700)
    bsr = build_bsr(rows, cols, vals, 128, 128, bm=32, bn=32)
    x = rng.standard_normal((128, 2)).astype(dtype)
    xp = jnp.asarray(pad_x(x, 128, 32))
    y_k = np.asarray(spmv(bsr, xp, interpret=True))
    y_r = np.asarray(bsr_spmv_ref(*bsr.device(), xp))
    np.testing.assert_allclose(y_k, y_r, rtol=2e-2, atol=2e-2)


def test_kernel_vs_scipy_on_webgraph():
    g = powerlaw_webgraph(n=800, target_nnz=6000, n_dangling=4, seed=5)
    pt = TransitionT.from_graph(g)
    bsr = bsr_from_transition(pt, bm=64, bn=64)
    rng = np.random.default_rng(1)
    x = rng.random((g.n, 3)).astype(np.float32)
    xp = jnp.asarray(pad_x(x, g.n, 64))
    y_k = unpad_y(np.asarray(spmv(bsr, xp, interpret=True)), g.n)
    y_s = pt.to_scipy() @ x.astype(np.float64)
    np.testing.assert_allclose(y_k, y_s, rtol=1e-4, atol=1e-5)


def test_empty_rows_and_padding():
    # a matrix with fully-empty block rows must produce zeros there
    rows = np.array([0, 1, 300])
    cols = np.array([5, 200, 10])
    vals = np.array([1.0, 2.0, 3.0])
    bsr = build_bsr(rows, cols, vals, 400, 256, bm=64, bn=64)
    x = np.ones((256, 1), np.float32)
    xp = jnp.asarray(pad_x(x, 256, 64))
    y = unpad_y(np.asarray(spmv(bsr, xp, interpret=True)), 400)
    assert y[0, 0] == pytest.approx(1.0)
    assert y[1, 0] == pytest.approx(2.0)
    assert y[300, 0] == pytest.approx(3.0)
    assert np.abs(y).sum() == pytest.approx(6.0)


def test_fill_ratio_reported():
    g = powerlaw_webgraph(n=500, target_nnz=3000, n_dangling=2, seed=2)
    pt = TransitionT.from_graph(g)
    bsr = bsr_from_transition(pt)
    assert 0 < bsr.fill_ratio <= 1
