"""BSR SpMV Pallas kernel: interpret-mode sweeps vs the pure-jnp oracle."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels.bsr_spmv import (build_bsr, bsr_from_transition, pad_x,
                                    unpad_y, spmv, bsr_spmv_ref)
from repro.graph.generate import powerlaw_webgraph
from repro.graph.csr import TransitionT


def random_coo(rng, n_rows, n_cols, nnz):
    rows = rng.integers(0, n_rows, nnz)
    cols = rng.integers(0, n_cols, nnz)
    vals = rng.standard_normal(nnz)
    # dedup
    key = rows * n_cols + cols
    _, idx = np.unique(key, return_index=True)
    return rows[idx], cols[idx], vals[idx]


@pytest.mark.parametrize("n_rows,n_cols,nnz,bm,bn,nv", [
    (100, 100, 500, 32, 32, 1),
    (257, 130, 800, 64, 32, 4),
    (512, 512, 4000, 128, 128, 8),
    (64, 300, 600, 16, 64, 2),
])
def test_kernel_matches_ref_shapes(n_rows, n_cols, nnz, bm, bn, nv):
    rng = np.random.default_rng(nnz)
    rows, cols, vals = random_coo(rng, n_rows, n_cols, nnz)
    bsr = build_bsr(rows, cols, vals, n_rows, n_cols, bm=bm, bn=bn)
    x = rng.standard_normal((n_cols, nv)).astype(np.float32)
    xp = jnp.asarray(pad_x(x, n_cols, bn))
    y_k = np.asarray(spmv(bsr, xp, interpret=True))
    y_r = np.asarray(bsr_spmv_ref(*bsr.device(), xp))
    np.testing.assert_allclose(y_k, y_r, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_kernel_dtypes(dtype):
    rng = np.random.default_rng(0)
    rows, cols, vals = random_coo(rng, 128, 128, 700)
    bsr = build_bsr(rows, cols, vals, 128, 128, bm=32, bn=32)
    x = rng.standard_normal((128, 2)).astype(dtype)
    xp = jnp.asarray(pad_x(x, 128, 32))
    y_k = np.asarray(spmv(bsr, xp, interpret=True))
    y_r = np.asarray(bsr_spmv_ref(*bsr.device(), xp))
    np.testing.assert_allclose(y_k, y_r, rtol=2e-2, atol=2e-2)


def test_kernel_vs_scipy_on_webgraph():
    g = powerlaw_webgraph(n=800, target_nnz=6000, n_dangling=4, seed=5)
    pt = TransitionT.from_graph(g)
    bsr = bsr_from_transition(pt, bm=64, bn=64)
    rng = np.random.default_rng(1)
    x = rng.random((g.n, 3)).astype(np.float32)
    xp = jnp.asarray(pad_x(x, g.n, 64))
    y_k = unpad_y(np.asarray(spmv(bsr, xp, interpret=True)), g.n)
    y_s = pt.to_scipy() @ x.astype(np.float64)
    np.testing.assert_allclose(y_k, y_s, rtol=1e-4, atol=1e-5)


def test_empty_rows_and_padding():
    # a matrix with fully-empty block rows must produce zeros there
    rows = np.array([0, 1, 300])
    cols = np.array([5, 200, 10])
    vals = np.array([1.0, 2.0, 3.0])
    bsr = build_bsr(rows, cols, vals, 400, 256, bm=64, bn=64)
    x = np.ones((256, 1), np.float32)
    xp = jnp.asarray(pad_x(x, 256, 64))
    y = unpad_y(np.asarray(spmv(bsr, xp, interpret=True)), 400)
    assert y[0, 0] == pytest.approx(1.0)
    assert y[1, 0] == pytest.approx(2.0)
    assert y[300, 0] == pytest.approx(3.0)
    assert np.abs(y).sum() == pytest.approx(6.0)


def test_fill_ratio_reported():
    g = powerlaw_webgraph(n=500, target_nnz=3000, n_dangling=2, seed=2)
    pt = TransitionT.from_graph(g)
    bsr = bsr_from_transition(pt)
    assert 0 < bsr.fill_ratio <= 1


# ---------------------------------------------------------------------------
# accumulation lanes (PR 9): compensated kernel vs the f64 reference
# ---------------------------------------------------------------------------
def _deep_bsr(rng, nbc=64, bm=8):
    """A block row with a long K chain — accumulation error grows with the
    number of partial sums, which is what the compensated lane targets."""
    n_rows, n_cols = bm, nbc * bm
    rows = np.repeat(np.arange(bm), nbc)
    cols = (np.tile(np.arange(nbc), bm) * bm
            + rng.integers(0, bm, nbc * bm))
    vals = rng.standard_normal(nbc * bm) * 10.0 ** rng.integers(
        -3, 3, nbc * bm)
    return build_bsr(rows, cols, vals, n_rows, n_cols, bm=bm, bn=bm)


def test_kahan_lane_matches_f64_reference():
    """The compensated-summation kernel lane lands (much) nearer the f64
    segment-sum-grade reference than the plain f32 lane on a deep-K
    contraction, and stays float32 end to end."""
    from jax.experimental import enable_x64
    from repro.kernels.bsr_spmv import bsr_spmv

    rng = np.random.default_rng(42)
    bsr = _deep_bsr(rng, nbc=128, bm=8)
    x = rng.standard_normal((bsr.n_cols, 2)).astype(np.float32)
    xp = jnp.asarray(pad_x(x, bsr.n_cols, 8))
    blocks, blk_cols = bsr.device()

    with enable_x64():
        ref64 = np.asarray(bsr_spmv_ref(
            np.asarray(blocks, dtype=np.float64), np.asarray(blk_cols),
            np.asarray(xp, dtype=np.float64), accum="f64"))
    y32 = np.asarray(bsr_spmv(blocks, blk_cols, xp, interpret=True))
    yk = np.asarray(bsr_spmv(blocks, blk_cols, xp, interpret=True,
                             accum="kahan"))
    assert yk.dtype == np.float32
    err32 = np.abs(y32 - ref64).max()
    errk = np.abs(yk - ref64).max()
    # compensation may tie on lucky draws but must never be worse, and
    # on a deep chain it should win clearly
    assert errk <= err32
    assert errk < 0.5 * err32, (errk, err32)


def test_ref_accum_lanes():
    rng = np.random.default_rng(5)
    bsr = _deep_bsr(rng, nbc=32, bm=8)
    x = rng.standard_normal((bsr.n_cols, 1)).astype(np.float32)
    xp = jnp.asarray(pad_x(x, bsr.n_cols, 8))
    blocks, blk_cols = bsr.device()
    # without x64, the wide lanes silently degrade to f32 (no crash, no
    # warning spam) and still match the f32 oracle closely
    y_f32 = np.asarray(bsr_spmv_ref(blocks, blk_cols, xp, accum="f32"))
    y_k = np.asarray(bsr_spmv_ref(blocks, blk_cols, xp, accum="kahan"))
    assert y_k.dtype == np.float32
    np.testing.assert_allclose(y_f32, y_k, rtol=1e-5, atol=1e-5)
    with pytest.raises(ValueError, match="accum"):
        bsr_spmv_ref(blocks, blk_cols, xp, accum="f16")


def test_resolve_impl_dispatch():
    import jax
    from repro.kernels.bsr_spmv import resolve_impl

    # explicit names pass through untouched; auto picks by backend
    for impl in ("pallas", "interpret", "ref"):
        assert resolve_impl(impl) == impl
    auto = resolve_impl("auto")
    if jax.default_backend() in ("tpu", "gpu"):
        assert auto == "pallas"
    else:
        assert auto == "interpret"
    with pytest.raises(ValueError):
        resolve_impl("simd")


def test_spmv_impl_auto_matches_explicit():
    """The dispatching entry point (impl=) agrees with the historic
    boolean overrides on the same operand."""
    from repro.kernels.bsr_spmv import bsr_matvec

    rng = np.random.default_rng(9)
    rows, cols, vals = random_coo(rng, 128, 128, 700)
    bsr = build_bsr(rows, cols, vals, 128, 128, bm=32, bn=32)
    x = rng.standard_normal((128, 2)).astype(np.float32)
    xp = jnp.asarray(pad_x(x, 128, 32))
    blocks, blk_cols = bsr.device()
    y_auto = np.asarray(bsr_matvec(blocks, blk_cols, xp))
    y_interp = np.asarray(spmv(bsr, xp, interpret=True))
    y_ref = np.asarray(spmv(bsr, xp, use_ref=True))
    np.testing.assert_allclose(y_auto, y_interp, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(y_auto, y_ref, rtol=1e-5, atol=1e-5)
