"""Streaming subsystem: DeltaGraph semantics, push-based incremental
updates (certified against cold solves), the rank server's swap protocol,
and the replay scenario.

Acceptance gates (ISSUE 2):
  * a random 1% edge delta on a 50k-node power-law graph updates to within
    tol (L1) of a cold solve_power on the mutated graph, on both backends;
  * the push path visits < 20% of the nodes for single-edge deltas.
"""
import numpy as np
import pytest

from repro.graph.generate import powerlaw_webgraph
from repro.graph.google import exact_pagerank
from repro.core import solve_power, solve_linear, block_rows
from repro.streaming import (DeltaGraph, EdgeDelta, RankServer, RankState,
                             ReplayConfig, StreamingBlockOperator, cold_state,
                             merge_deltas, ppr_push, refresh_residual,
                             replay_trace, synth_edge_trace, update_ranks,
                             update_ranks_sharded)


def _warm(base):
    """Fresh mutable copy of the session-scoped certified 50k warm start
    (the fixture state is shared — never hand it to a mutating updater)."""
    return RankState(x=base.x.copy(), r=base.r.copy(), version=0,
                     alpha=base.alpha)


def _edge_set(g):
    src = np.repeat(np.arange(g.n, dtype=np.int64), np.diff(g.indptr))
    return set(zip(src.tolist(), g.indices.tolist()))


@pytest.fixture(scope="module")
def dgraph():
    g = powerlaw_webgraph(n=2000, target_nnz=16000, n_dangling=10, seed=7)
    return DeltaGraph(g)


# ---------------------------------------------------------------------------
# DeltaGraph semantics
# ---------------------------------------------------------------------------
def test_delta_graph_matches_reference_edge_set():
    g = powerlaw_webgraph(n=300, target_nnz=2400, n_dangling=4, seed=1)
    dg = DeltaGraph(g, compact_frac=0.02)   # force frequent compaction
    ref = _edge_set(g)
    rng = np.random.default_rng(2)
    n = g.n
    for step in range(25):
        k = int(rng.integers(1, 12))
        a_s = rng.integers(0, n, k)
        a_d = rng.integers(0, n, k)
        existing = list(ref)
        picks = rng.integers(0, len(existing), max(k // 2, 1))
        d_s = np.array([existing[p][0] for p in picks], np.int64)
        d_d = np.array([existing[p][1] for p in picks], np.int64)
        dg.apply(EdgeDelta(add_src=a_s, add_dst=a_d,
                           del_src=d_s, del_dst=d_d))
        ref -= set(zip(d_s.tolist(), d_d.tolist()))
        ref |= set(zip(a_s.tolist(), a_d.tolist()))
        assert dg.nnz == len(ref)
    got = _edge_set(dg.graph())
    assert got == ref
    # incremental degree/dangling bookkeeping agrees with the snapshot
    np.testing.assert_array_equal(dg.out_degree, dg.graph().out_degree)
    np.testing.assert_array_equal(dg.dangling_mask, dg.graph().dangling_mask)


def test_delta_graph_noop_mutations_and_receipt():
    g = powerlaw_webgraph(n=200, target_nnz=1500, n_dangling=2, seed=3)
    dg = DeltaGraph(g)
    u = int(np.flatnonzero(g.out_degree > 2)[0])
    j = int(dg.out_neighbors(u)[0])
    # inserting an existing edge and deleting a missing one are no-ops
    rcpt = dg.apply(EdgeDelta.inserts([u], [j]))
    assert rcpt.n_added == 0 and rcpt.touched.size == 0
    rcpt = dg.apply(EdgeDelta.deletes([199], [0])
                    if not dg.has_edge(199, 0) else EdgeDelta.empty())
    assert rcpt.n_deleted == 0
    # delete + re-insert round-trips through the overlay
    rcpt = dg.apply(EdgeDelta.deletes([u], [j]))
    assert rcpt.n_deleted == 1 and not dg.has_edge(u, j)
    rcpt = dg.apply(EdgeDelta.inserts([u], [j]))
    assert rcpt.n_added == 1 and dg.has_edge(u, j)
    assert dg._log_edges == 0       # tombstone cleared, nothing pending


def test_delta_graph_node_arrivals():
    g = powerlaw_webgraph(n=150, target_nnz=900, n_dangling=2, seed=4)
    dg = DeltaGraph(g)
    rcpt = dg.apply(EdgeDelta(add_src=np.array([150, 10]),
                              add_dst=np.array([10, 151]),
                              del_src=np.empty(0, np.int64),
                              del_dst=np.empty(0, np.int64), new_nodes=2))
    assert dg.n == 152 and rcpt.n_new == 152
    assert dg.out_degree[150] == 1 and dg.out_degree[151] == 0
    assert bool(dg.dangling_mask[151])
    assert dg.graph().n == 152
    with pytest.raises(ValueError):
        dg.apply(EdgeDelta.inserts([999], [0]))


def test_merge_deltas_keeps_last_op():
    d1 = EdgeDelta.inserts([1], [2])
    d2 = EdgeDelta.deletes([1], [2])
    m = merge_deltas([d1, d2])
    assert m.del_src.size == 1 and m.add_src.size == 0   # ends absent
    m = merge_deltas([d2, d1])
    assert m.add_src.size == 1 and m.del_src.size == 0   # ends present
    m = merge_deltas([EdgeDelta.inserts([3], [4], new_nodes=1),
                      EdgeDelta.inserts([5], [6], new_nodes=2)])
    assert m.new_nodes == 3 and m.add_src.size == 2


def test_transition_splice_matches_rebuild():
    """The per-version P^T row-splice must equal the full rebuild exactly —
    arrays, dtypes, intra-row order — across random deltas, node arrivals
    and forced compactions."""
    from repro.graph.csr import TransitionT
    g = powerlaw_webgraph(n=800, target_nnz=6400, n_dangling=6, seed=17)
    dg = DeltaGraph(g, compact_frac=0.03)
    rng = np.random.default_rng(18)
    for step in range(20):
        dg.transition()             # memoize v-1 so the splice path runs
        k = int(rng.integers(1, 16))
        gg = dg.graph()
        soe = np.repeat(np.arange(gg.n, dtype=np.int64), np.diff(gg.indptr))
        slots = rng.choice(gg.nnz, size=max(k // 2, 1), replace=False)
        nn = int(rng.random() < 0.3)
        a_s = rng.integers(0, dg.n + nn, k)
        a_d = rng.integers(0, dg.n + nn, k)
        dg.apply(EdgeDelta(add_src=a_s, add_dst=a_d, del_src=soe[slots],
                           del_dst=gg.indices[slots].astype(np.int64),
                           new_nodes=nn))
        got = dg.transition()
        ref = TransitionT.from_graph(dg.graph())
        np.testing.assert_array_equal(got.indptr, ref.indptr)
        np.testing.assert_array_equal(got.src, ref.src)
        np.testing.assert_array_equal(got.row_ids, ref.row_ids)
        np.testing.assert_array_equal(got.weight, ref.weight)
        np.testing.assert_array_equal(got.dangling, ref.dangling)


def test_transition_noop_delta_shares_instance():
    g = powerlaw_webgraph(n=300, target_nnz=2400, n_dangling=4, seed=19)
    dg = DeltaGraph(g)
    pt0 = dg.transition()
    u = int(np.flatnonzero(g.out_degree > 0)[0])
    j = int(dg.out_neighbors(u)[0])
    dg.apply(EdgeDelta.inserts([u], [j]))      # already present: no-op
    assert dg.transition() is pt0              # value-identical: shared


def test_operator_views_memoized_per_version(dgraph):
    dg = dgraph
    op_a = dg.operator(0.85)
    assert dg.operator(0.85) is op_a                 # same version: reused
    assert dg.transition() is op_a.pt
    v = np.zeros(dg.n)
    v[5] = 1.0
    assert dg.operator(0.85, v=v).pt is op_a.pt      # shared transition
    dg.apply(EdgeDelta.inserts([11], [13])
             if not dg.has_edge(11, 13) else EdgeDelta.deletes([11], [13]))
    op_b = dg.operator(0.85)
    assert op_b is not op_a                          # new version: rebuilt
    assert dg.operator(0.85) is op_b


# ---------------------------------------------------------------------------
# incremental updates, certified against exact solutions
# ---------------------------------------------------------------------------
def test_incremental_sequence_tracks_exact():
    g = powerlaw_webgraph(n=1200, target_nnz=9000, n_dangling=6, seed=11)
    dg = DeltaGraph(g, compact_frac=0.01)
    st = cold_state(dg, tol=1e-9)
    rng = np.random.default_rng(12)
    for step in range(12):
        k = int(rng.integers(1, 5))
        d = EdgeDelta.inserts(rng.integers(0, dg.n, k),
                              rng.integers(0, dg.n, k))
        st, stats = update_ranks(dg, d, st, tol=1e-7,
                                 push_frontier_frac=0.6)
        assert stats.cert <= 1e-7
    x_ref = exact_pagerank(dg.operator(0.85), tol=1e-13)
    # (push-path coverage lives in the 50k locality test — on graphs this
    # small a certified drain legitimately reaches the whole graph and
    # falls back; chained-receipt correctness is what this test pins)
    assert np.abs(st.x - x_ref).sum() < 1.5e-7
    # the maintained residual matches a from-scratch recomputation
    r_inc = st.r.copy()
    refresh_residual(dg, st)
    assert np.abs(r_inc - st.r).max() < 1e-12


def test_incremental_deletion_and_dangling_transition():
    g = powerlaw_webgraph(n=800, target_nnz=6000, n_dangling=4, seed=13)
    dg = DeltaGraph(g)
    st = cold_state(dg, tol=1e-9)
    u = int(np.argmax(dg.out_degree))        # make the biggest hub dangling
    row = dg.out_neighbors(u)
    st, stats = update_ranks(dg, EdgeDelta.deletes(np.full(row.size, u), row),
                             st, tol=1e-7, push_frontier_frac=1.0)
    assert bool(dg.dangling_mask[u])
    x_ref = exact_pagerank(dg.operator(0.85), tol=1e-13)
    assert np.abs(st.x - x_ref).sum() < 1.5e-7
    # and back: re-wire the hub
    st, stats = update_ranks(dg, EdgeDelta.inserts(np.full(row.size, u), row),
                             st, tol=1e-7, push_frontier_frac=1.0)
    x_ref = exact_pagerank(dg.operator(0.85), tol=1e-13)
    assert np.abs(st.x - x_ref).sum() < 1.5e-7


def test_incremental_node_arrival():
    g = powerlaw_webgraph(n=900, target_nnz=7000, n_dangling=5, seed=14)
    dg = DeltaGraph(g)
    st = cold_state(dg, tol=1e-9)
    d = EdgeDelta(add_src=np.array([900, 900, 3]),
                  add_dst=np.array([17, 42, 900]),
                  del_src=np.empty(0, np.int64),
                  del_dst=np.empty(0, np.int64), new_nodes=1)
    st, stats = update_ranks(dg, d, st, tol=1e-7, push_frontier_frac=1.0)
    assert st.x.shape == (901,)
    x_ref = exact_pagerank(dg.operator(0.85), tol=1e-13)
    assert np.abs(st.x - x_ref).sum() < 1.5e-7
    assert st.x[900] > 0


def test_stale_state_rejected(dgraph):
    st = cold_state(dgraph, tol=1e-8)
    st.version -= 1
    with pytest.raises(ValueError):
        update_ranks(dgraph, EdgeDelta.empty(), st)


# ---------------------------------------------------------------------------
# acceptance gates (50k graph, both backends) — the accept_graph /
# accept_delta / accept_cold fixtures are session-scoped in conftest.py
# (shared with tests/test_transport.py so the 50k builds happen once)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend,tol", [("segment_sum", 1e-6),
                                         ("bsr_pallas", 1e-4)])
def test_accept_one_percent_delta_50k(accept_graph, accept_delta,
                                      accept_cold, accept_base, backend, tol):
    """Incremental update after a 1% delta lands within tol (L1) of a cold
    solve_power on the mutated graph — both backends.  Warm-starts from the
    session-certified accept_base instead of re-running a per-arm 50k cold
    solve (the delta re-perturbs the residual either way)."""
    dg = DeltaGraph(accept_graph)
    st = _warm(accept_base)
    st, stats = update_ranks(dg, accept_delta, st, tol=0.8 * tol,
                             backend=backend)
    assert stats.cert <= 0.8 * tol
    l1 = np.abs(st.x - accept_cold).sum()
    assert l1 < tol, (backend, l1)


def test_accept_single_edge_push_locality(accept_graph, accept_base):
    """Single-edge deltas take the push path and visit < 20% of nodes."""
    dg = DeltaGraph(accept_graph)
    st = _warm(accept_base)
    rng = np.random.default_rng(7)
    g = accept_graph
    for _ in range(3):
        d = EdgeDelta.inserts(
            rng.integers(0, dg.n, 1),
            g.indices[rng.integers(0, g.nnz, 1)].astype(np.int64))
        st, stats = update_ranks(dg, d, st, tol=1e-5,
                                 push_frontier_frac=0.2)
        assert stats.path == "push", stats
        assert stats.nodes_visited < 0.2 * dg.n, stats.nodes_visited
        assert stats.cert <= 1e-5


# ---------------------------------------------------------------------------
# sharded certified updates (runtime layer)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("exchange", ["allgather", "sparsified"])
def test_sharded_update_sequence_tracks_exact(exchange):
    g = powerlaw_webgraph(n=2500, target_nnz=20000, n_dangling=12, seed=61)
    dg = DeltaGraph(g)
    st = cold_state(dg, tol=1e-9)
    rng = np.random.default_rng(62)
    paths = set()
    for step in range(5):
        k = int(rng.integers(1, 6))
        d = EdgeDelta.inserts(rng.integers(0, dg.n, k),
                              rng.integers(0, dg.n, k))
        st, stats = update_ranks_sharded(dg, d, st, p=4, tol=1e-7,
                                         exchange=exchange)
        assert stats.cert <= 1e-7
        paths.add(stats.path)
        if stats.path == "sharded_push":
            # the certificate is the driver's all-reduced bound: it must
            # dominate the exactly maintained residual
            assert st.cert <= stats.cert + 1e-15
            assert stats.stop_superstep > 0
            assert stats.exchanges > 0
    assert "sharded_push" in paths
    x_ref = exact_pagerank(dg.operator(0.85), tol=1e-13)
    assert np.abs(st.x - x_ref).sum() < 1.5e-7
    # the maintained residual is still exact after outbox folds
    r_inc = st.r.copy()
    refresh_residual(dg, st)
    assert np.abs(r_inc - st.r).max() < 1e-12


def test_sharded_update_node_arrivals_and_deletions():
    g = powerlaw_webgraph(n=1500, target_nnz=11000, n_dangling=8, seed=63)
    dg = DeltaGraph(g)
    st = cold_state(dg, tol=1e-9)
    d = EdgeDelta(add_src=np.array([1500, 7]), add_dst=np.array([3, 1500]),
                  del_src=np.empty(0, np.int64),
                  del_dst=np.empty(0, np.int64), new_nodes=1)
    st, stats = update_ranks_sharded(dg, d, st, p=3, tol=1e-7)
    assert st.x.shape == (1501,)
    u = int(np.argmax(dg.out_degree))
    row = dg.out_neighbors(u)
    st, stats = update_ranks_sharded(
        dg, EdgeDelta.deletes(np.full(row.size, u), row), st, p=3, tol=1e-7)
    assert bool(dg.dangling_mask[u])
    x_ref = exact_pagerank(dg.operator(0.85), tol=1e-13)
    assert np.abs(st.x - x_ref).sum() < 1.5e-7


def test_sharded_rejects_stale_state_and_bad_args(dgraph):
    st = cold_state(dgraph, tol=1e-8)
    st.version -= 1
    with pytest.raises(ValueError):
        update_ranks_sharded(dgraph, EdgeDelta.empty(), st)
    st.version += 1
    with pytest.raises(ValueError):
        update_ranks_sharded(dgraph, EdgeDelta.empty(), st,
                             exchange="carrier-pigeon")


def test_accept_sharded_one_percent_delta_50k(accept_graph, accept_delta,
                                              accept_cold, accept_base):
    """ISSUE 3 acceptance: the sharded updater (p=4) applies the 1% delta
    on the 50k graph and certifies ||x - x*||_1 <= tol against the cold
    solve, with the certificate produced by the Fig. 1 TerminationDriver
    all-reducing per-shard ||r_i||_1 — not a centralized residual sum."""
    tol = 1e-6
    for exchange in ("allgather", "sparsified"):
        dg = DeltaGraph(accept_graph)
        st = _warm(accept_base)
        st, stats = update_ranks_sharded(dg, accept_delta, st, p=4,
                                         tol=0.8 * tol, exchange=exchange)
        assert stats.path == "sharded_push", (exchange, stats)
        assert stats.p == 4 and stats.stop_superstep > 0
        assert stats.cert <= 0.8 * tol
        l1 = np.abs(st.x - accept_cold).sum()
        assert l1 <= tol, (exchange, l1)
        # the bound certified by the driver dominates the true error
        assert l1 <= stats.cert + 0.5 * tol


def test_rank_server_sharded_updater():
    g = powerlaw_webgraph(n=1500, target_nnz=12000, n_dangling=8, seed=64)
    dg = DeltaGraph(g)
    srv = RankServer(dg, tol=1e-7, updater="sharded", shards=3,
                     exchange="sparsified")
    rng = np.random.default_rng(65)
    srv.ingest(EdgeDelta.inserts(rng.integers(0, dg.n, 3),
                                 rng.integers(0, dg.n, 3)))
    stats = srv.apply_pending()
    assert stats is not None and stats.p == 3
    snap = srv.snapshot()
    assert snap.version == dg.version
    ref = solve_power(dg.operator(0.85), tol=1e-10)
    assert np.abs(snap.x - ref.x).sum() < 2e-7
    with pytest.raises(ValueError):
        RankServer(dg, updater="telepathic")
    with pytest.raises(ValueError):
        RankServer(dg, updater="sharded", shard_mode="psychic")


def test_rank_server_async_shard_mode():
    """shard_mode="async": the server's sharded updater drains on the
    AsyncShardExecutor worker threads and still publishes certified
    snapshots."""
    g = powerlaw_webgraph(n=1500, target_nnz=12000, n_dangling=8, seed=66)
    dg = DeltaGraph(g)
    srv = RankServer(dg, tol=1e-7, updater="sharded", shards=2,
                     exchange="sparsified", shard_mode="async")
    rng = np.random.default_rng(67)
    srv.ingest(EdgeDelta.inserts(rng.integers(0, dg.n, 3),
                                 rng.integers(0, dg.n, 3)))
    stats = srv.apply_pending()
    assert stats is not None and stats.p == 2 and stats.mode == "async"
    snap = srv.snapshot()
    assert snap.version == dg.version and snap.cert <= 1e-7
    ref = solve_power(dg.operator(0.85), tol=1e-10)
    assert np.abs(snap.x - ref.x).sum() < 2e-7


def test_accept_async_one_percent_delta_50k(accept_graph, accept_delta,
                                            accept_cold, accept_base):
    """ISSUE 4 acceptance: mode="async" certifies the 1% delta on the 50k
    graph at tol=1e-8 for p in {2, 4} with zero inter-drain barriers —
    termination only via the routed Fig. 1 messages of the
    AsyncShardExecutor, the certificate the exact folded-back residual."""
    tol = 1e-8
    for p in (2, 4):
        dg = DeltaGraph(accept_graph)
        st = _warm(accept_base)
        st, stats = update_ranks_sharded(dg, accept_delta, st, p=p,
                                         tol=tol, mode="async")
        assert stats.path == "sharded_push", (p, stats)
        assert stats.mode == "async" and stats.p == p
        assert stats.stop_superstep > 0          # STOP came from the monitor
        assert stats.cert <= tol
        # the maintained residual IS the published certificate in async
        # mode (exact post-fold recompute)
        assert st.cert == pytest.approx(stats.cert, rel=1e-12)
        # accept_cold is a tol=1e-9-grade solve: agreement within
        # cert + reference error
        l1 = np.abs(st.x - accept_cold).sum()
        assert l1 <= stats.cert + 1e-8, (p, l1)


# ---------------------------------------------------------------------------
# rank server
# ---------------------------------------------------------------------------
def test_rank_server_inline_updates_and_metadata():
    g = powerlaw_webgraph(n=1500, target_nnz=12000, n_dangling=8, seed=21)
    dg = DeltaGraph(g)
    srv = RankServer(dg, tol=1e-7, push_frontier_frac=0.6)
    snap0 = srv.snapshot()
    assert snap0.version == 0 and snap0.cert <= 1e-7
    ids, scores = srv.top_k(10)
    assert np.all(np.diff(scores) <= 0) and ids.size == 10
    assert not snap0.x.flags.writeable

    rng = np.random.default_rng(22)
    srv.ingest(EdgeDelta.inserts(rng.integers(0, dg.n, 3),
                                 rng.integers(0, dg.n, 3)))
    srv.ingest(EdgeDelta.inserts(rng.integers(0, dg.n, 2),
                                 rng.integers(0, dg.n, 2)))
    stats = srv.apply_pending()
    assert stats is not None and srv.batches_applied == 1  # merged batch
    snap1 = srv.snapshot()
    assert snap1.seq == snap0.seq + 1
    assert snap1.version == dg.version
    # the old snapshot is untouched (double-buffering: readers keep theirs)
    assert snap0.version == 0 and snap0.x.sum() == pytest.approx(1.0, abs=1e-6)
    ref = solve_power(dg.operator(0.85), tol=1e-10)
    assert np.abs(snap1.x - ref.x).sum() < 2e-7
    stale = srv.staleness()
    assert stale["version_lag"] == 0 and stale["pending_deltas"] == 0
    assert srv.apply_pending() is None


def test_rank_snapshot_top_k_edge_cases():
    g = powerlaw_webgraph(n=400, target_nnz=3000, n_dangling=3, seed=25)
    srv = RankServer(DeltaGraph(g), tol=1e-6)
    snap = srv.snapshot()
    # k <= 0: explicit empties (np.argpartition(-x, -1) would partition on
    # the *last* element instead)
    for k in (0, -3):
        ids, scores = snap.top_k(k)
        assert ids.size == 0 and scores.size == 0
        assert ids.dtype == np.int64
    ids, scores = srv.top_k(0)
    assert ids.size == 0 and scores.size == 0
    # k > n clamps to n, and k == n is a full argsort
    ids, scores = snap.top_k(10 * g.n)
    assert ids.size == g.n
    assert np.all(np.diff(scores) <= 0)
    assert set(ids.tolist()) == set(range(g.n))


def test_rank_server_concurrent_serving_stress():
    """Update-while-serve under fire: a daemon updater and concurrent
    readers (top_k / scores / personalized / staleness).  Every observed
    snapshot must be intact (read-only unit-sum vector, certified cert,
    consistent version) and each reader's seq must be monotone."""
    import threading
    import time
    g = powerlaw_webgraph(n=1200, target_nnz=9000, n_dangling=6, seed=26)
    dg = DeltaGraph(g)
    tol = 1e-6
    srv = RankServer(dg, tol=tol, push_frontier_frac=0.6)
    errors = []
    stop = threading.Event()

    def reader(kind: int):
        rng = np.random.default_rng(kind)
        last_seq = 0
        try:
            while not stop.is_set():
                snap = srv.snapshot()
                # torn-snapshot checks: immutable, normalized, certified
                assert not snap.x.flags.writeable
                assert snap.x.shape == (snap.n,)
                assert abs(float(snap.x.sum()) - 1.0) < 1e-6
                assert snap.cert <= tol * 1.01
                assert snap.seq >= last_seq, "seq went backwards"
                last_seq = snap.seq
                if kind % 4 == 0:
                    ids, scores = srv.top_k(int(rng.integers(0, 8)))
                    assert np.all(np.diff(scores) <= 0)
                elif kind % 4 == 1:
                    ids = rng.integers(0, 1200, 5)
                    vals = srv.scores(ids)
                    assert vals.shape == (5,) and np.isfinite(vals).all()
                elif kind % 4 == 2:
                    stale = srv.staleness()
                    assert stale["version_lag"] >= 0
                    assert stale["pending_deltas"] >= 0
                    assert stale["cert"] <= tol * 1.01
                else:
                    x, cert, _ = srv.personalized(
                        rng.choice(1200, 2, replace=False), tol=1e-2)
                    assert np.isfinite(x).all()
        except BaseException as exc:   # surfaced to the main thread
            errors.append(exc)
            stop.set()

    srv.start(poll_s=0.001)
    readers = [threading.Thread(target=reader, args=(k,)) for k in range(4)]
    for t in readers:
        t.start()
    rng = np.random.default_rng(27)
    try:
        deadline = time.time() + 3.0
        while time.time() < deadline and not stop.is_set():
            srv.ingest(EdgeDelta.inserts(rng.integers(0, 1200, 2),
                                         rng.integers(0, 1200, 2)))
            time.sleep(0.01)
    finally:
        stop.set()
        for t in readers:
            t.join(timeout=10)
        srv.stop()
    assert not errors, errors[0]
    assert srv.batches_applied >= 1
    assert srv.snapshot().seq >= 1
    with srv._stat_lock:
        assert srv.queries_served > 0


def test_rank_server_threaded_update_while_serve():
    import time
    g = powerlaw_webgraph(n=1200, target_nnz=9000, n_dangling=6, seed=23)
    dg = DeltaGraph(g)
    srv = RankServer(dg, tol=1e-6, push_frontier_frac=0.6)
    srv.start(poll_s=0.002)
    rng = np.random.default_rng(24)
    try:
        for _ in range(6):
            srv.ingest(EdgeDelta.inserts(rng.integers(0, 1200, 2),
                                         rng.integers(0, 1200, 2)))
            srv.top_k(5)            # serve while updating
        deadline = time.time() + 20
        while (srv.snapshot().version != dg.version
               or not srv._queue.empty()) and time.time() < deadline:
            time.sleep(0.005)
    finally:
        srv.stop()
    assert srv.snapshot().version == dg.version
    ref = solve_power(dg.operator(0.85), tol=1e-10)
    assert np.abs(srv.snapshot().x - ref.x).sum() < 2e-6
    assert srv.batches_applied >= 1


def test_personalized_query_certified(dgraph):
    dg = dgraph
    srv = RankServer(dg, tol=1e-7, push_frontier_frac=0.6)
    x, cert, stats = srv.personalized([42, 99], tol=1e-3)
    assert np.isfinite(cert) and cert <= 1e-3
    v = np.zeros(dg.n)
    v[[42, 99]] = 0.5
    ref = solve_linear(dg.operator(0.85, v=v), tol=1e-10)
    assert np.abs(x - ref.x).sum() <= cert + 1e-9


# ---------------------------------------------------------------------------
# replay scenario + DES bridge
# ---------------------------------------------------------------------------
def test_replay_trace_accounting():
    g = powerlaw_webgraph(n=1000, target_nnz=8000, n_dangling=5, seed=31)
    dg = DeltaGraph(g)
    st = cold_state(dg, tol=1e-6)
    trace = synth_edge_trace(dg, n_batches=8, batch_edges=3, seed=32)
    assert dg.version == 0                  # trace generation is side-effect-free
    res = replay_trace(dg, st, trace,
                       ReplayConfig(query_rate=60.0, delta_interval=0.3,
                                    tol=1e-5, push_frontier_frac=0.6,
                                    seed=33))
    assert len(res.rows) == 8
    assert dg.version == 8                  # every batch applied
    assert 0.0 <= res.fresh_pct <= 100.0
    assert res.queries > 0 and res.busy_frac >= 0
    assert all(r.queue_delay >= -1e-9 for r in res.rows)
    assert res.table()                      # formats without error
    x_ref = exact_pagerank(dg.operator(0.85), tol=1e-13)
    assert np.abs(st.x - x_ref).sum() < 1.5e-5


def test_streaming_block_operator_matches_dense():
    g = powerlaw_webgraph(n=600, target_nnz=4500, n_dangling=3, seed=41)
    dg = DeltaGraph(g)
    part = block_rows(dg.n, 3)
    sop = StreamingBlockOperator(dg, part)
    rng = np.random.default_rng(42)
    x = rng.random(dg.n)
    y = np.concatenate([sop.update_block(i, x) for i in range(3)])
    np.testing.assert_allclose(y, dg.operator(0.85).apply_numpy(x),
                               rtol=1e-12, atol=1e-14)
    # mutate; the operator must follow the new version
    dg.apply(EdgeDelta.inserts(rng.integers(0, 600, 5),
                               rng.integers(0, 600, 5)))
    y2 = np.concatenate([sop.update_block(i, x) for i in range(3)])
    np.testing.assert_allclose(y2, dg.operator(0.85).apply_numpy(x),
                               rtol=1e-12, atol=1e-14)
    assert np.abs(y - y2).max() > 0


# ---------------------------------------------------------------------------
# per-lane freezing (satellite: multi-vector solves)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend,tol", [("segment_sum", 1e-9),
                                         ("bsr_pallas", 3e-7)])
def test_lane_freezing_matches_unfrozen(backend, tol):
    g = powerlaw_webgraph(n=1100, target_nnz=8500, n_dangling=6, seed=51)
    from repro.graph.csr import TransitionT
    from repro.graph.google import GoogleOperator
    op = GoogleOperator(pt=TransitionT.from_graph(g), alpha=0.85)
    rng = np.random.default_rng(51)
    nv = 8
    V = rng.random((op.n, nv))
    V /= V.sum(axis=0)
    X0 = np.full((op.n, nv), 1.0 / op.n)
    for k in range(nv // 2):        # warm-start half the lanes
        X0[:, k] = solve_power(op, tol=1e-12, v=V[:, k]).x
    frz = solve_power(op, tol=tol, v=V, x0=X0, backend=backend,
                      freeze_lanes=True, freeze_chunk=8)
    ref = solve_power(op, tol=tol, v=V, x0=X0, backend=backend,
                      freeze_lanes=False)
    assert (frz.resid_per_vec <= tol).all()
    # warm lanes froze early; every lane still meets the contract
    assert frz.lane_iters.min() < frz.lane_iters.max()
    assert frz.lane_iters.max() == frz.iters
    assert np.abs(frz.x - ref.x).max() < 2 * tol / 0.15


def test_adaptive_freeze_chunk_certifies_and_freezes():
    """freeze_chunk="auto" (the default): the recheck cadence adapts to
    the observed per-lane spread; every lane still meets the tol contract
    and warm lanes still freeze ahead of cold ones."""
    g = powerlaw_webgraph(n=1100, target_nnz=8500, n_dangling=6, seed=51)
    from repro.graph.csr import TransitionT
    from repro.graph.google import GoogleOperator
    op = GoogleOperator(pt=TransitionT.from_graph(g), alpha=0.85)
    rng = np.random.default_rng(52)
    nv = 8
    V = rng.random((op.n, nv))
    V /= V.sum(axis=0)
    X0 = np.full((op.n, nv), 1.0 / op.n)
    for k in range(nv // 2):
        X0[:, k] = solve_power(op, tol=1e-12, v=V[:, k]).x
    auto = solve_power(op, tol=1e-9, v=V, x0=X0, freeze_lanes=True)
    ref = solve_power(op, tol=1e-9, v=V, x0=X0, freeze_lanes=False)
    assert (auto.resid_per_vec <= 1e-9).all()
    assert auto.lane_iters.min() < auto.lane_iters.max()
    assert auto.lane_iters.max() == auto.iters
    assert np.abs(auto.x - ref.x).max() < 2e-9 / 0.15
    # a cadence that is neither an int nor "auto" is rejected
    with pytest.raises(ValueError):
        solve_power(op, tol=1e-6, v=V, x0=X0, freeze_lanes=True,
                    freeze_chunk="sometimes")


def test_adapt_chunk_predicts_from_lane_rates():
    """Unit contract of the spread extrapolation: the next recheck lands
    just past the fastest survivor's predicted tol crossing, drawn from
    the pow2 menu; stalled estimates fall back to the previous cadence."""
    from repro.core.pagerank import _CHUNK_MENU, _adapt_chunk
    # 100x decay over 32 iters from 1e-8: 16 predicted iters to 1e-9,
    # times the 1.25 drift margin -> menu entry 32
    assert _adapt_chunk(np.array([1e-6]), np.array([1e-8]), 32,
                        1e-9, 8) == 32
    # slow geometric decay extrapolates past the menu -> clamped to max
    assert _adapt_chunk(np.array([1e-2]), np.array([9e-3]), 32,
                        1e-9, 32) == _CHUNK_MENU[-1]
    # non-contracting lanes give no finite estimate -> fallback
    assert _adapt_chunk(np.array([1e-6]), np.array([1e-6]), 32,
                        1e-9, 99) == 99
    # the fastest of a spread-out pack sets the cadence (freeze early):
    # adding a near-stalled lane must not lengthen the recheck
    fast_only = _adapt_chunk(np.array([1e-6]), np.array([1e-8]), 32,
                             1e-9, 8)
    fast_and_slow = _adapt_chunk(np.array([1e-6, 1e-1]),
                                 np.array([1e-8, 9e-2]), 32, 1e-9, 8)
    assert fast_and_slow == fast_only


def test_spmd_compact_exit_validation():
    """compact_exit must be "auto" or a fraction in (0, 1] — checked
    before any device work, so this runs on the single-CPU host."""
    from repro.core import SPMDConfig, solve_spmd
    g = powerlaw_webgraph(n=300, target_nnz=2400, n_dangling=4, seed=1)
    from repro.graph.csr import TransitionT
    from repro.graph.google import GoogleOperator
    op = GoogleOperator(pt=TransitionT.from_graph(g), alpha=0.85)
    for bad in (0.0, 1.5, -0.2, "half", True):
        cfg = SPMDConfig(p=1, freeze_lanes=True, compact_lanes=True,
                         compact_exit=bad)
        with pytest.raises(ValueError):
            solve_spmd(op, cfg)
