"""Unified runtime observability (PR 7): metrics registry, event-trace
soundness (Fig. 1 causal ordering on both transports), push-inflation
attribution, procpool metric survival across a SIGKILL respawn, the
Chrome trace export, the RankServer metrics endpoint, the SPMD chunk
log's cumulative contract, and the zero-cost-when-off guarantees.
"""
import json
import os
import warnings

import numpy as np
import pytest

import repro.core  # noqa: F401  (resolves the runtime<->core import cycle)
from repro.core.partition import block_rows
from repro.graph.generate import powerlaw_webgraph
from repro.runtime import (AllToAllPlan, AsyncShardExecutor, FaultPlan,
                           PairMailbox, ProcPoolShardExecutor, ShardArena,
                           ShardObserver, ShmRing, TerminationDriver,
                           chrome_trace, render_prometheus,
                           write_chrome_trace)
from repro.runtime.observe import (C_KILLS, C_RECOVERIES, EV_NAMES,
                                   OBS_COUNTERS, attribute_frontier)
from repro.streaming import DeltaGraph, EdgeDelta, cold_state, update_ranks
from repro.streaming.server import RankServer
from repro.streaming.sharded import update_ranks_sharded

from _subproc import run_with_devices


def _shm_leftovers():
    try:
        return [f for f in os.listdir("/dev/shm")
                if f.startswith("repro_arena")]
    except FileNotFoundError:        # pragma: no cover - non-Linux
        return []


def _small_workload(n=2000, seed=7, k=20):
    g = powerlaw_webgraph(n=n, target_nnz=8 * n, n_dangling=max(n // 200, 2),
                          seed=seed)
    dg = DeltaGraph(g)
    st = cold_state(dg, tol=1e-9)
    rng = np.random.default_rng(seed + 1)
    delta = EdgeDelta.inserts(rng.integers(0, n, k), rng.integers(0, n, k))
    return dg, delta, st


# ---------------------------------------------------------------------------
# registry / attribution primitives
# ---------------------------------------------------------------------------
def test_attribute_frontier_classification():
    pushed = np.zeros(10, dtype=np.uint8)
    foreign = np.zeros(10, dtype=np.uint8)
    cnt = np.zeros(3, dtype=np.int64)
    attribute_frontier(pushed, foreign, cnt, np.array([0, 1, 2]))
    assert list(cnt) == [3, 0, 0]                   # all first
    foreign[1] = 1
    attribute_frontier(pushed, foreign, cnt, np.array([0, 1]))
    assert list(cnt) == [3, 1, 1]                   # local + boundary
    assert foreign[1] == 0                          # mark consumed
    attribute_frontier(pushed, foreign, cnt, np.array([], dtype=np.int64))
    assert list(cnt) == [3, 1, 1]


def test_observer_ring_overwrite_and_drop_accounting():
    obs = ShardObserver.alloc(p=1, event_cap=4)
    for k in range(6):
        obs.emit(2, 0, float(k), a=float(k))
    snap = obs.snapshot()
    assert snap["events_written"] == [6]
    assert snap["events_dropped"] == [2]
    evs = obs.events()
    assert len(evs) == 4                            # oldest two overwritten
    assert [ev["a"] for ev in evs] == [2.0, 3.0, 4.0, 5.0]


def test_mailbox_and_ring_mark_foreign_rows():
    # PairMailbox.drain_into(mark=) flags exactly the delivered rows
    mb = PairMailbox(10)
    block = np.zeros(10)
    block[3] = 0.5
    block[7] = -0.25
    mb.deposit(block)
    r = np.zeros(10)
    mark = np.zeros(10, dtype=np.uint8)
    assert mb.drain_into(r, 0, 10, mark=mark) == pytest.approx(0.75)
    assert list(np.flatnonzero(mark)) == [3, 7]
    assert r[3] == 0.5 and r[7] == -0.25
    # ShmRing.pop_into(mark=) flags popped rows in block coordinates
    arena = ShardArena.create(dict(
        head=((1,), np.int64), tail=((1,), np.int64),
        cnt=((4,), np.int64), idx=((4, 8), np.int32),
        val=((4, 8), np.float64)))
    try:
        ring = ShmRing(arena["head"], arena["tail"], arena["cnt"],
                       arena["idx"], arena["val"])
        ring.push(np.array([1, 4], np.int32), np.array([1.0, 2.0]))
        out = np.zeros(6)
        mark2 = np.zeros(6, dtype=np.uint8)
        ring.pop_into(out, mark=mark2)
        assert list(np.flatnonzero(mark2)) == [1, 4]
    finally:
        arena.close()


def test_render_prometheus_format():
    txt = render_prometheus([
        ("queries", "counter", 12),
        ("pushes", "counter", {(("shard", "0"),): 41.0,
                               (("shard", "1"),): 7.5}),
    ])
    assert '# TYPE repro_queries counter' in txt
    assert "repro_queries 12" in txt                # int formatting
    assert 'repro_pushes{shard="0"} 41' in txt
    assert 'repro_pushes{shard="1"} 7.5' in txt


# ---------------------------------------------------------------------------
# zero cost when off
# ---------------------------------------------------------------------------
def test_zero_cost_off_no_arena_slots_no_payload():
    from repro.runtime.transport import _ctl_spec
    part = block_rows(40, 2)
    spec_off = _ctl_spec(2, 40, part, ring_depth=8, payload_cap=64)
    assert not any(k.startswith("obs_") for k in spec_off)
    spec_on = _ctl_spec(2, 40, part, ring_depth=8, payload_cap=64,
                        observe=True)
    assert {"obs_buf", "obs_n", "obs_ctr", "obs_hist", "obs_pushed",
            "obs_foreign", "obs_attr"} <= set(spec_on)

    dg, delta, st = _small_workload(n=1200, seed=31, k=8)
    st, stats = update_ranks_sharded(dg, delta, st, p=2, tol=1e-7,
                                     mode="async")
    assert stats.observed is None
    assert stats.pushes_first == stats.pushes_local \
        == stats.pushes_boundary == 0


def test_observe_requires_async_mode():
    dg, delta, st = _small_workload(n=600, seed=33, k=4)
    with pytest.raises(ValueError, match="observe"):
        update_ranks_sharded(dg, delta, st, p=2, mode="superstep",
                             observe=True)


# ---------------------------------------------------------------------------
# trace soundness (Fig. 1 causal ordering) + attribution, both transports
# ---------------------------------------------------------------------------
def _by_shard(events):
    out = {}
    for ev in events:
        out.setdefault(ev["shard"], []).append(ev)
    return out


def _check_causal(events):
    """Fig. 1 causal ordering inside each shard's (time-ordered = writer
    program-ordered) stream: CONVERGE/DIVERGE never follow STOP within a
    worker epoch (epochs split by RECOVERY), and every RECOVERY is
    preceded by a KILL somewhere in the global stream."""
    kill_ts = sorted(ev["t"] for ev in events if ev["name"] == "KILL")
    for i, evs in _by_shard(events).items():
        stopped = False
        for ev in evs:
            if ev["name"] == "RECOVERY":
                stopped = False          # a fresh worker epoch begins
                assert kill_ts and kill_ts[0] <= ev["t"], \
                    f"RECOVERY on shard {i} with no prior KILL"
            elif ev["name"] == "STOP":
                stopped = True
            elif ev["name"] in ("CONVERGE", "DIVERGE"):
                assert not stopped, \
                    f"{ev['name']} after STOP on shard {i} (same epoch)"


@pytest.mark.parametrize("transport", ["threads", "procpool"])
def test_trace_and_attribution_sound(transport):
    # under suite-level CPU contention the async drain can legitimately
    # exhaust its 2x push budget and take the solver fallback; rebuild
    # the workload (dg.apply mutates the graph) and retry the
    # timing-dependent run rather than assert on one sample
    for _ in range(3):
        dg, delta, st = _small_workload(n=2000, seed=7, k=20)
        st, stats = update_ranks_sharded(dg, delta, st, p=4, tol=1e-8,
                                         mode="async", transport=transport,
                                         observe=True)
        if stats.path == "sharded_push":
            break
    assert stats.path == "sharded_push"
    obs = stats.observed
    assert obs is not None
    evs = obs["events"]
    assert evs and obs["events_dropped"] == [0, 0, 0, 0]
    _check_causal(evs)
    # every shard that stopped cleanly traced its STOP
    names = {ev["name"] for ev in evs}
    assert {"INTAKE", "DRAIN", "EXCHANGE", "STOP"} <= names
    # attribution partitions the pushes exactly on a fault-free run
    assert stats.pushes_first + stats.pushes_local \
        + stats.pushes_boundary == stats.pushes
    assert 0 < stats.pushes_first <= dg.n
    assert stats.pushes_boundary > 0        # foreign mass re-activated rows
    # the DRAIN events' per-drain deltas reconcile with the counters
    c = obs["counters"]
    drains = [ev for ev in evs if ev["name"] == "DRAIN"]
    assert sum(c["drains"]) == len(drains)
    assert sum(c["drain_rows"]) == sum(ev["a"] for ev in drains) \
        == stats.pushes
    assert sum(c["exchanges"]) == stats.exchanges
    assert sum(c["exchange_bytes"]) == stats.bytes_moved
    assert set(OBS_COUNTERS) == set(c)
    if transport == "procpool":
        assert not _shm_leftovers()


# ---------------------------------------------------------------------------
# procpool kill -9: metrics survive the respawn, no double counting
# ---------------------------------------------------------------------------
class _AbsorbDrain:
    """Synthetic absorbing drain (picklable): keep 30% of own mass, ship
    20% to the successor's rows, absorb the rest."""

    def __init__(self, p, n):
        self.p, self.n = p, n

    def __call__(self, views):
        part = block_rows(self.n, self.p)
        r = views["r"]

        def drain_fn(i, s, e, step_target, outbox):
            own = r[s:e]
            l1 = float(np.abs(own).sum())
            if l1 <= step_target:
                return 0, 0.0
            moved = own.copy()
            own[:] = 0.0
            ns, ne = part.block((i + 1) % self.p)
            outbox[ns:ns + moved.size] += 0.2 * moved
            r[s:e] += 0.3 * moved
            return moved.size, 0.0
        return drain_fn


def test_procpool_kill9_metrics_survive_respawn():
    p, n = 2, 40
    part = block_rows(n, p)
    arena = ShardArena.from_arrays(dict(r=np.ones(n)))
    try:
        with warnings.catch_warnings():
            # one worker per shard even on single-core CI hosts: the test
            # needs the kill to take down only shard 0's process
            warnings.simplefilter("ignore", RuntimeWarning)
            ex = ProcPoolShardExecutor(
                part, AllToAllPlan(p), TerminationDriver(p), l1_target=1e-9,
                max_rounds=10 ** 6, n_workers=p,
                faults=FaultPlan(kill={0: 3}), observe=True)
        res = ex.run(_AbsorbDrain(p, n), arena)
        assert res.stopped and res.recoveries >= 1
        obs = res.observed
        assert obs is not None
        c = obs["counters"]
        # the KILL was traced by the dying incarnation (the ring lives in
        # the parent-owned arena, so it survived the SIGKILL), the fired
        # flag kept the respawned worker from re-firing: exactly one
        assert c["kills"][0] == 1 and c["kills"][1] == 0
        assert c["recoveries"][0] >= 1
        # the respawned incarnation kept accumulating into the same slots
        # (counters survive the respawn) and the run still terminated, so
        # shard 0 drained both before and after the kill
        assert c["drains"][0] > 1
        assert c["stops"] == [1.0, 1.0]       # one STOP per shard: no
        #                                     # double counting across
        #                                     # incarnations
        evs = obs["events"]
        _check_causal(evs)
        kills = [ev for ev in evs if ev["name"] == "KILL"]
        recs = [ev for ev in evs if ev["name"] == "RECOVERY"]
        assert len(kills) == 1 and recs
        assert kills[0]["t"] <= min(ev["t"] for ev in recs)
    finally:
        arena.close()
    assert not _shm_leftovers()


def test_threads_kill_trace_and_recovery():
    dg, delta, st = _small_workload(n=1500, seed=11, k=12)
    st, stats = update_ranks_sharded(
        dg, delta, st, p=2, tol=1e-7, mode="async", transport="threads",
        faults=FaultPlan(kill={1: 3}), observe=True)
    assert stats.cert <= 1e-7
    obs = stats.observed
    c = obs["counters"]
    assert c["kills"][1] == 1
    assert c["recoveries"][1] >= 1
    _check_causal(obs["events"])


# ---------------------------------------------------------------------------
# Chrome trace_event export
# ---------------------------------------------------------------------------
def test_chrome_trace_export_loads(tmp_path):
    dg, delta, st = _small_workload(n=1200, seed=17, k=8)
    st, stats = update_ranks_sharded(dg, delta, st, p=2, tol=1e-7,
                                     mode="async", observe=True)
    path = tmp_path / "trace.json"
    write_chrome_trace(path, stats.observed["events"], p=2)
    with open(path) as fh:
        doc = json.load(fh)
    tev = doc["traceEvents"]
    meta = [ev for ev in tev if ev["ph"] == "M"]
    names = {ev["args"]["name"] for ev in meta
             if ev["name"] == "thread_name"}
    assert names == {"shard 0", "shard 1"}       # one track per shard
    spans = [ev for ev in tev if ev["ph"] == "X"]
    instants = [ev for ev in tev if ev["ph"] == "i"]
    assert spans and instants
    assert all(ev["ts"] >= 0 and ev["dur"] >= 0 for ev in spans)
    assert {ev["name"] for ev in instants} >= {"STOP"}
    assert all(ev["s"] == "t" for ev in instants)
    # every non-meta name is a known event kind
    assert {ev["name"] for ev in spans + instants} \
        <= set(EV_NAMES.values())


# ---------------------------------------------------------------------------
# single-updater decomposition + RankServer metrics endpoint
# ---------------------------------------------------------------------------
def test_update_stats_push_decomposition():
    dg, delta, st = _small_workload(n=1500, seed=23, k=10)
    # relax the locality caps so the delta stays on the push path (the
    # default crossover sends this frontier to the warm solver)
    st, stats = update_ranks(dg, delta, st, tol=1e-5,
                             push_frontier_frac=1.0, max_push_factor=100.0)
    assert stats.path == "push"
    assert stats.pushes > stats.nodes_visited > 0
    assert stats.pushes_first == stats.nodes_visited
    assert stats.pushes_first + stats.pushes_repeat == stats.pushes


def test_rank_server_metrics_reconcile_cold_fallback(monkeypatch):
    dg, delta, st = _small_workload(n=1000, seed=29, k=6)
    srv = RankServer(dg, tol=1e-7)
    srv.ingest(delta)
    srv.apply_pending()
    srv.top_k(3)
    m0 = srv.metrics()
    assert m0["batches_applied"] == 1 and m0["queries_served"] == 1
    assert m0["state_recoveries"] == 0 and m0["cold_rebuilds"] == 0
    assert m0["snapshot_cert"] <= 1e-7 and m0["version_lag"] == 0

    # drive _recover_state through the cold last-resort path and assert
    # the counters reconcile in one step (the satellite-1 staleness:
    # fallbacks used to stay behind across a cold rebuild)
    import repro.streaming.server as server_mod

    def boom(dg_, st_):
        raise RuntimeError("injected refresh failure")
    monkeypatch.setattr(server_mod, "refresh_residual", boom)
    srv._recover_state()
    m1 = srv.metrics()
    assert m1["state_recoveries"] == 1
    assert m1["cold_rebuilds"] == 1
    assert m1["fallbacks"] == m0["fallbacks"] + 1

    txt = srv.metrics_text()
    assert "# TYPE repro_rank_server_cold_rebuilds counter" in txt
    assert "repro_rank_server_cold_rebuilds 1" in txt
    assert "repro_rank_server_state_recoveries 1" in txt
    assert "# TYPE repro_rank_server_snapshot_cert gauge" in txt
    # health() stays consistent with metrics()
    h = srv.health()
    assert h["snapshot_seq"] == m1["snapshot_seq"]


# ---------------------------------------------------------------------------
# SPMD: comm totals cumulative across compact_lanes chunk re-keying
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_spmd_chunk_log_cumulative_4dev():
    out = run_with_devices("""
import numpy as np
from repro.graph.generate import powerlaw_webgraph
from repro.graph.csr import TransitionT
from repro.graph.google import GoogleOperator
from repro.core import SPMDConfig, solve_spmd

g = powerlaw_webgraph(n=800, target_nnz=6000, n_dangling=5, seed=3)
op = GoogleOperator(pt=TransitionT.from_graph(g), alpha=0.85)
nv = 8
rng = np.random.default_rng(0)
V = np.abs(rng.random((g.n, nv)))
V = V / V.sum(0)
for sched in ("sparsified", "allgather"):
    cfg = SPMDConfig(p=4, schedule=sched, tol=1e-8, max_supersteps=600,
                     freeze_lanes=True, compact_lanes=True,
                     sparsify_refresh_every=8)
    r = solve_spmd(op, cfg, v=V, observe=True)
    log = r.chunk_log
    assert log is not None and len(log) == r.lane_chunks
    assert r.lane_chunks > 1, r.lane_chunks      # >= 2 chunk boundaries
    # the in-loop counters restart at zero each chunk; the totals must
    # be cumulative across every re-keyed chunk, not the last chunk's
    assert r.comm_bytes_total == sum(c["bytes"] for c in log), (sched, log)
    assert r.rows_sent == sum(c["rows"] for c in log), (sched, log)
    assert sum(c["steps"] for c in log) == r.supersteps
    if sched == "sparsified":
        assert r.rows_sent > 0
        assert any(c["rows"] > 0 for c in log[1:])   # later chunks count
    # off by default: no log allocated
    r0 = solve_spmd(op, cfg, v=V)
    assert r0.chunk_log is None
    print(sched, "chunks=%d" % r.lane_chunks, "OK")
print("CHUNKLOG OK")
""", n_devices=4, timeout=900)
    assert "CHUNKLOG OK" in out
