"""Roofline extraction: HLO collective parsing + term math."""
import numpy as np
import pytest

from repro.analysis.roofline import (parse_collectives, Roofline,
                                     model_flops, _shape_bytes,
                                     PEAK_FLOPS_BF16, HBM_BW, ICI_LINK_BW)

HLO = """
HloModule test
ENTRY main {
  %p0 = bf16[16,4096,64]{2,1,0} parameter(0)
  %ag = bf16[16,4096,1024]{2,1,0} all-gather(%p0), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, dimensions={2}
  %ar = f32[256,128]{1,0} all-reduce(%conv), replica_groups={{0,1,2,3}}, to_apply=%sum
  %rs = bf16[8,64]{1,0} reduce-scatter(%big), replica_groups={{0,1}}, dimensions={0}
  %cp.1 = f32[1024]{0} collective-permute(%x), source_target_pairs={{0,1}}
  %ag-start = bf16[4,4]{1,0} all-gather-start(%p0), replica_groups={{0,1}}
  %ag-done = bf16[4,4]{1,0} all-gather-done(%ag-start)
}
"""


def test_shape_bytes():
    assert _shape_bytes("bf16[2,3]") == 12
    assert _shape_bytes("f32[10]") == 40
    assert _shape_bytes("(f32[2], bf16[4])") == 16
    assert _shape_bytes("pred[8]") == 8


def test_parse_collectives_counts():
    st = parse_collectives(HLO)
    assert st.counts["all-gather"] == 2  # ag + ag-start (done not counted)
    assert st.counts["all-reduce"] == 1
    assert st.counts["reduce-scatter"] == 1
    assert st.counts["collective-permute"] == 1


def test_parse_collectives_bytes():
    st = parse_collectives(HLO)
    # all-gather operand p0: 16*4096*64*2 bytes (+ tiny ag-start operand)
    p0 = 16 * 4096 * 64 * 2
    assert st.operand_bytes["all-gather"] >= p0
    assert st.operand_bytes["collective-permute"] == 4096
    assert st.total_operand_bytes > 0
    # refined all-gather estimate uses the RESULT size scaled by (n-1)/n
    res = 16 * 4096 * 1024 * 2
    assert st.per_chip_bytes["all-gather"] >= int(res * 15 / 16)


def test_roofline_terms_and_dominance():
    r = Roofline(flops=197e12 * 256, hbm_bytes=819e9 * 256 * 2,
                 collective_bytes=50e9 * 256 * 0.5,
                 collective_per_chip=0, chips=256)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(2.0)
    assert r.collective_s == pytest.approx(0.5)
    assert r.dominant == "memory"
    assert r.bound_s == pytest.approx(2.0)


def test_model_flops():
    assert model_flops(1e9, 1e6, train=True) == pytest.approx(6e15)
    assert model_flops(1e9, 1e6, train=False) == pytest.approx(2e15)
