"""Property tests of the paper's core mathematical claim (hypothesis):
bounded-staleness iterations on a contraction converge to the same fixed
point regardless of the (arbitrary, adversarial) delay pattern. This is a
direct numpy model of eq. (5), independent of the DES implementation."""
import numpy as np
import pytest
hypothesis = pytest.importorskip(
    "hypothesis")  # not baked into every container image
from hypothesis import given, settings, strategies as st


def _random_google(rng, n, alpha=0.85):
    """Dense random column-stochastic R = alpha*S plus b = (1-alpha)/n."""
    A = (rng.random((n, n)) < 0.3).astype(float)
    np.fill_diagonal(A, 0)
    deg = A.sum(axis=1)
    P = np.divide(A, np.maximum(deg[:, None], 1), where=deg[:, None] > 0)
    S = P.T.copy()
    dang = deg == 0
    S[:, dang] = 1.0 / n
    return alpha * S, np.full(n, (1 - alpha) / n)


@given(st.integers(0, 10_000), st.integers(2, 5), st.integers(1, 6))
@settings(max_examples=60, deadline=None)
def test_bounded_staleness_converges_to_fixed_point(seed, p, max_delay):
    rng = np.random.default_rng(seed)
    n = 12
    R, b = _random_google(rng, n)
    x_star = np.linalg.solve(np.eye(n) - R, b)

    # partition rows into p blocks, iterate with random bounded delays
    bounds = np.linspace(0, n, p + 1).astype(int)
    history = [np.full(n, 1.0 / n)]
    for t in range(400):
        x_new = history[-1].copy()
        for i in range(p):
            s, e = bounds[i], bounds[i + 1]
            if e <= s:
                continue
            # each peer fragment read at an arbitrary stale time
            view = np.empty(n)
            for j in range(p):
                sj, ej = bounds[j], bounds[j + 1]
                delay = 0 if j == i else int(rng.integers(0, max_delay + 1))
                src = history[max(0, len(history) - 1 - delay)]
                view[sj:ej] = src[sj:ej]
            x_new[s:e] = R[s:e] @ view + b[s:e]
        history.append(x_new)
        if len(history) > max_delay + 2:
            history.pop(0)

    assert np.abs(history[-1] - x_star).max() < 1e-8


@given(st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_power_form_converges_up_to_scale(seed):
    """Lubachevsky–Mitra: the normalization-free power form with stale reads
    converges to the eigenvector up to a positive scalar."""
    rng = np.random.default_rng(seed)
    n = 10
    R, b = _random_google(rng, n, alpha=0.85)
    # G = R + v e^T (1-alpha): column-stochastic
    G = R + np.outer(np.full(n, 0.15 / n), np.ones(n))
    w, v = np.linalg.eig(G)
    k = np.argmax(np.abs(w))
    x_star = np.real(v[:, k])
    x_star = x_star / x_star.sum()

    x = np.full(n, 1.0 / n)
    hist = [x]
    for t in range(600):
        view = hist[max(0, len(hist) - 1 - int(rng.integers(0, 3)))]
        i = int(rng.integers(0, 2))
        half = n // 2
        (s, e) = (0, half) if i == 0 else (half, n)
        x = hist[-1].copy()
        x[s:e] = G[s:e] @ view
        hist.append(x)
        if len(hist) > 5:
            hist.pop(0)
    x = x / x.sum()
    assert np.abs(x - x_star).max() < 1e-6
